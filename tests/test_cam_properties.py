"""Deeper property tests on the CAM functional simulator's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig)


def cfg_best(h_merge, bits=0, rows=8, cols=8, sl=0.0, k=1):
    cell = "acam" if bits == 0 else "mcam"
    return CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=k,
                      data_bits=bits),
        arch=ArchConfig(h_merge=h_merge, v_merge="comparator"),
        circuit=CircuitConfig(rows=rows, cols=cols, cell_type=cell,
                              sensing="best", sensing_limit=sl),
        device=DeviceConfig(device="fefet"))


# ---------------------------------------------------------------------------
# voting is an APPROXIMATION of adder: agreement high, never better recall
# of the true argmin than the lossless merge
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_adder_exact_where_voting_approximate(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (24, 32))
    q = jax.random.uniform(k2, (8, 32))
    d = np.square(np.asarray(stored)[None] - np.asarray(q)[:, None]
                  ).sum(-1)
    true_nn = d.argmin(1)

    sim_a = CAMASim(cfg_best("adder"))
    idx_a, _ = sim_a.query(sim_a.write(stored), q)
    # adder merge is lossless: always the true argmin (mod fp ties)
    for i, g in enumerate(np.asarray(idx_a[:, 0])):
        assert d[i, g] == pytest.approx(d[i, true_nn[i]], rel=1e-5,
                                        abs=1e-6)

    sim_v = CAMASim(cfg_best("voting"))
    idx_v, _ = sim_v.query(sim_v.write(stored), q)
    # voting is approximate but must return valid indices
    got = np.asarray(idx_v[:, 0])
    assert ((got >= 0) & (got < 24)).all()


# ---------------------------------------------------------------------------
# quantization monotonicity: more bits never hurts the retrieved distance
# (on average over queries)
# ---------------------------------------------------------------------------
def test_more_bits_better_retrieval():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (64, 64))
    q = jax.random.uniform(k2, (32, 64))
    d = np.square(np.asarray(stored)[None] - np.asarray(q)[:, None]
                  ).sum(-1)

    def mean_retrieved_distance(bits):
        sim = CAMASim(cfg_best("adder", bits=bits, rows=16, cols=16))
        idx, _ = sim.query(sim.write(stored), q)
        return float(np.mean([d[i, g] for i, g in
                              enumerate(np.asarray(idx[:, 0]))]))

    d2, d3, d5 = (mean_retrieved_distance(b) for b in (2, 3, 5))
    assert d5 <= d3 + 1e-3
    assert d3 <= d2 + 1e-3


# ---------------------------------------------------------------------------
# duplicates: exact match must return ALL duplicates (gather completeness)
# ---------------------------------------------------------------------------
@given(st.integers(1, 6), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_exact_match_finds_all_duplicates(n_dup, seed):
    key = jax.random.PRNGKey(seed)
    base = (jax.random.uniform(key, (20, 16)) > 0.5).astype(jnp.float32)
    row = base[3]
    stored = jnp.concatenate([base, jnp.tile(row[None], (n_dup, 1))])
    cfg = CAMConfig(
        app=AppConfig(distance="hamming", match_type="exact",
                      match_param=8, data_bits=1),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="tcam",
                              sensing="exact"),
        device=DeviceConfig(device="cmos"))
    sim = CAMASim(cfg)
    _, mask = sim.query(sim.write(stored), row[None])
    found = set(np.where(np.asarray(mask[0]) > 0)[0].tolist())
    expected = {i for i in range(stored.shape[0])
                if (np.asarray(stored[i]) == np.asarray(row)).all()}
    assert found == expected


# ---------------------------------------------------------------------------
# C2C noise statistics: fraction of flipped best-matches grows with STD
# ---------------------------------------------------------------------------
def test_c2c_flip_rate_increases_with_std():
    key = jax.random.PRNGKey(1)
    stored = jax.random.uniform(key, (40, 32))
    q = jnp.tile(stored[7][None], (32, 1))

    def flips(std):
        cfg = cfg_best("adder", bits=3, rows=8, cols=8)
        cfg = cfg.replace(device=dict(variation="c2c",
                                      variation_std=std))
        sim = CAMASim(cfg)
        idx, _ = sim.query(sim.write(stored), q,
                           key=jax.random.PRNGKey(2))
        return float(np.mean(np.asarray(idx[:, 0]) != 7))

    f0, f1, f2 = flips(0.0), flips(1.0), flips(4.0)
    assert f0 == 0.0
    assert f2 >= f1 - 0.05
    assert f2 > 0.1


# ---------------------------------------------------------------------------
# kernel-backed functional sim == pure-jnp functional sim
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_kernel_backend_equivalence(seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (30, 40))
    q = jax.random.uniform(k2, (4, 40))
    cfg = cfg_best("adder", bits=3, rows=8, cols=8, k=3)
    a = CAMASim(cfg, use_kernel=False)
    b = CAMASim(cfg, use_kernel=True)
    ia, _ = a.query(a.write(stored), q)
    ib, _ = b.query(b.write(stored), q)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


# ---------------------------------------------------------------------------
# hierarchical CAM merge == global merge (multi-device, subprocess)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_hierarchical_merge_equals_global():
    import os
    import subprocess
    import sys
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"   # host-device trick needs the CPU backend
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import compat_make_mesh
from repro.models.cam_attention import (cam_decode_attention,
                                        cam_decode_attention_hierarchical)
from repro.runtime import sharding_ctx
mesh = compat_make_mesh((2, 4), ("data", "model"))
B, S, H, KVH, D = 4, 64, 6, 2, 16
cfg = get_config("chameleon-34b").reduced().replace(cam_topk=8)
k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(k1, (B, H, D))
kc = jax.random.normal(k2, (B, S, KVH, D))
vc = jax.random.normal(k3, (B, S, KVH, D))
pos = jnp.asarray([63, 40, 17, 5], jnp.int32)
ref = cam_decode_attention(q, kc, vc, pos, cfg)
with sharding_ctx(mesh):
    hier = jax.jit(lambda *a: cam_decode_attention_hierarchical(*a, cfg))(
        q, kc, vc, pos)
err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)
                            - hier.astype(jnp.float32))))
assert err < 2e-2, err
print("HIER_OK")
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "HIER_OK" in proc.stdout, \
        proc.stderr[-2000:]
