"""The config-driven CAMASim facade: one JSON config drives the whole
experiment through either backend.

Guarantees:
  * full five-section config round-trip: CAMConfig -> JSON file ->
    ``CAMASim.from_json`` -> identical compiled search results and
    identical ``eval_perf`` report vs constructing the backend directly
    (both backends; the multi-device matrix reruns through the facade in
    test_sharded_search's subprocess sweep);
  * ``from_dict`` drops unknown keys in EVERY section (forward compat —
    regression for the circuit-only asymmetry);
  * the deprecated constructor kwargs still work for one release and warn;
  * ``plan`` makes ``eval_perf`` usable before ``write`` (estimator-only
    design sweeps) and agrees with the write-derived prediction;
  * ``SearchResult`` / ``PerfReport`` keep the historical tuple/dict
    behavior bit-for-bit while adding the typed surface.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AppConfig, ArchConfig, Backend, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig, FunctionalSimulator,
                        PerfReport, SearchResult, ShardedCAMSimulator,
                        SimConfig, make_backend)
from repro.core.results import SearchResult as ResultsSearchResult

KEY = jax.random.PRNGKey(0)

PERF_KEYS = ("arch", "search", "latency_ns", "energy_pj", "area_um2",
             "edp_pj_ns", "inserts_per_s", "device_inserts_per_s")


def _cfg(**sim):
    return CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet", variation="d2d",
                            variation_std=0.3),
        sim=SimConfig(**sim))


def _data(K=37, N=12, Q=9):
    k1, k2 = jax.random.split(KEY)
    return (jax.random.uniform(k1, (K, N)),
            jax.random.uniform(k2, (Q, N)))


# ---------------------------------------------------------------------------
# config round-trip through a JSON file, both backends
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["functional", "sharded"])
def test_json_roundtrip_drives_identical_experiment(tmp_path, backend):
    cfg = _cfg(backend=backend, c2c_fold="bank", serve_batch=7)
    path = tmp_path / "exp.json"
    path.write_text(cfg.to_json(indent=1))

    sim = CAMASim.from_json(path)
    assert sim.config == cfg                 # five sections survive
    if backend == "functional":
        direct = FunctionalSimulator(cfg)
        assert isinstance(sim.backend, FunctionalSimulator)
    else:
        direct = ShardedCAMSimulator(cfg)    # devices=0: all local
        assert isinstance(sim.backend, ShardedCAMSimulator)

    stored, queries = _data()
    wkey, qkey = jax.random.split(jax.random.PRNGKey(3))
    ia, ma = sim.query(sim.write(stored, wkey), queries, key=qkey)
    ib, mb = direct.query(direct.write(stored, wkey), queries, key=qkey)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))

    # ...and the same perf report, key for key
    pa, pb = sim.eval_perf(n_queries=9), direct.eval_perf(n_queries=9)
    assert set(pa.keys()) == set(pb.keys())
    for k in ("latency_ns", "energy_pj", "area_um2", "edp_pj_ns", "arch"):
        assert pa[k] == pb[k], k


def test_facade_backend_swap_is_bit_identical_single_device():
    """backend='functional' vs 'sharded' on a 1-device mesh: the one-line
    config change must not move a single bit (c2c bank fold on both)."""
    stored, queries = _data()
    qkey = jax.random.PRNGKey(11)
    res = {}
    for backend in ("functional", "sharded"):
        sim = CAMASim(_cfg(backend=backend, c2c_fold="bank"))
        res[backend] = sim.query(sim.write(stored), queries, key=qkey)
    np.testing.assert_array_equal(np.asarray(res["functional"].indices),
                                  np.asarray(res["sharded"].indices))
    np.testing.assert_array_equal(np.asarray(res["functional"].mask),
                                  np.asarray(res["sharded"].mask))


# ---------------------------------------------------------------------------
# forward compat: unknown keys dropped in every section
# ---------------------------------------------------------------------------
def test_from_dict_drops_unknown_keys_in_all_sections():
    d = _cfg().to_dict()
    for section in ("app", "arch", "circuit", "device", "sim"):
        d[section]["from_the_future"] = 123
    cfg = CAMConfig.from_dict(d)
    assert cfg == _cfg()


def test_from_dict_missing_sim_section_defaults():
    """Configs serialized BEFORE the sim section existed still load."""
    d = _cfg().to_dict()
    del d["sim"]
    cfg = CAMConfig.from_dict(d)
    assert cfg.sim == SimConfig()


def test_sim_config_validation():
    with pytest.raises(ValueError):
        SimConfig(backend="quantum")
    with pytest.raises(ValueError):
        SimConfig(c2c_fold="nope")
    with pytest.raises(ValueError):
        SimConfig(c2c_query_tile=0)
    with pytest.raises(ValueError):
        SimConfig(serve_batch=0)
    with pytest.raises(ValueError):
        SimConfig(devices=-1)


# ---------------------------------------------------------------------------
# deprecated constructor kwargs: one release of warning + override
# ---------------------------------------------------------------------------
def test_deprecated_kwargs_warn_and_override():
    cfg = _cfg()
    with pytest.warns(DeprecationWarning):
        sim = CAMASim(cfg, use_kernel=True)
    assert sim.config.sim.use_kernel is True
    assert sim.functional.use_kernel is True

    with pytest.warns(DeprecationWarning):
        f = FunctionalSimulator(cfg, c2c_query_tile=4, c2c_fold="bank")
    assert f.c2c_query_tile == 4 and f.c2c_fold == "bank"

    with pytest.warns(DeprecationWarning):
        s = ShardedCAMSimulator(cfg, use_kernel=True)
    assert s.sim.use_kernel is True

    # invalid override values still fail loudly (via SimConfig validation)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError):
            CAMASim(cfg, c2c_fold="nope")


def test_config_driven_construction_does_not_warn(recwarn):
    cfg = _cfg(use_kernel=False, c2c_query_tile=2, c2c_fold="bank")
    f = FunctionalSimulator(cfg)
    assert f.c2c_query_tile == 2 and f.c2c_fold == "bank"
    CAMASim(cfg)
    ShardedCAMSimulator(cfg)
    assert not [w for w in recwarn.list
                if issubclass(w.category, DeprecationWarning)]


# ---------------------------------------------------------------------------
# estimator-only planning
# ---------------------------------------------------------------------------
def test_plan_matches_write_derived_perf():
    cfg = _cfg()
    stored, _ = _data(K=37, N=12)

    planned = CAMASim(cfg)
    planned.plan(37, 12)                    # shapes only, no data
    written = CAMASim(cfg)
    written.write(stored)

    pa, pb = planned.eval_perf(n_queries=5), written.eval_perf(n_queries=5)
    assert pa == pb                          # identical report dicts
    assert planned.arch_specifics().describe() == \
        written.arch_specifics().describe()


def test_eval_perf_before_plan_or_write_raises():
    sim = CAMASim(_cfg())
    with pytest.raises(RuntimeError):
        sim.eval_perf()
    sharded = CAMASim(_cfg(backend="sharded"))
    with pytest.raises(RuntimeError):
        sharded.eval_perf()
    sharded.plan(37, 12)
    assert sharded.eval_perf()["latency_ns"] > 0


# ---------------------------------------------------------------------------
# typed results / typed report
# ---------------------------------------------------------------------------
def test_search_result_tuple_compat_and_topk():
    cfg = _cfg()
    sim = CAMASim(cfg)
    stored, queries = _data()
    state = sim.write(stored)
    res = sim.query(state, queries)
    assert isinstance(res, SearchResult)
    assert SearchResult is ResultsSearchResult

    idx, mask = res                          # tuple unpacking
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(res.indices))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(res.mask))
    assert res[0] is res.indices and res[1] is res.mask
    assert len(res) == 2 and res.dist is None
    assert res.n_queries == queries.shape[0]

    np.testing.assert_array_equal(np.asarray(res.topk(2)),
                                  np.asarray(res.indices[:, :2]))

    single = sim.query(state, queries[0])    # (N,) query: 1-D results
    assert single.indices.ndim == 1 and single.n_queries == 1

    # a pytree (so jax.block_until_ready / jit boundaries accept it)
    leaves = jax.tree_util.tree_leaves(res)
    assert len(leaves) == 2
    jax.block_until_ready(res)


def test_perf_report_is_dict_with_typed_surface():
    sim = CAMASim(_cfg())
    sim.plan(37, 12)
    rep = sim.eval_perf(include_write=True)
    assert isinstance(rep, PerfReport) and isinstance(rep, dict)
    # the historical dict shape, key for key (BENCH consumers)
    assert set(rep.keys()) == set(PERF_KEYS) | {"write"}
    assert rep.to_dict() == dict(rep)
    assert type(rep.to_dict()) is dict
    assert rep.latency_ns == rep["latency_ns"]
    assert rep.search is rep["search"]
    assert rep.write is rep["write"]
    assert rep.energy_pj == rep["energy_pj"]

    mesh_rep = sim.eval_perf(mesh=4)
    assert set(mesh_rep.keys()) == set(PERF_KEYS) | {"mesh"}


# ---------------------------------------------------------------------------
# backend protocol
# ---------------------------------------------------------------------------
def test_backends_satisfy_protocol_and_dispatch():
    f = make_backend(_cfg(backend="functional"))
    s = make_backend(_cfg(backend="sharded"))
    assert isinstance(f, FunctionalSimulator) and isinstance(f, Backend)
    assert isinstance(s, ShardedCAMSimulator) and isinstance(s, Backend)
    # a config object is not a backend
    assert not isinstance(_cfg(), Backend)
