"""CAM-integrated LM layers: retrieval attention, CAM MoE router, CAM
episodic memory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import compat_make_mesh
from repro.models.attention import decode_attention
from repro.models.cam_attention import (cam_decode_attention,
                                        cam_decode_attention_pallas,
                                        cam_select_scores)

KEY = jax.random.PRNGKey(0)


def _setup(B=2, S=64, H=4, KVH=2, D=16, pos=None):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (B, H, D))
    kc = jax.random.normal(k2, (B, S, KVH, D))
    vc = jax.random.normal(k3, (B, S, KVH, D))
    pos = jnp.full((B,), S - 1, jnp.int32) if pos is None else pos
    return q, kc, vc, pos


def test_cam_attention_full_topk_equals_dense():
    """With k >= S the CAM retrieval set is everything -> exact match with
    dense decode attention."""
    q, kc, vc, pos = _setup()
    cfg = get_config("granite-8b").reduced().replace(cam_topk=64)
    a = cam_decode_attention(q, kc, vc, pos, cfg)
    b = decode_attention(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_cam_attention_respects_pos_mask():
    q, kc, vc, _ = _setup()
    pos = jnp.asarray([3, 10], jnp.int32)
    cfg = get_config("granite-8b").reduced().replace(cam_topk=8)
    # poison the cache beyond pos: results must not change
    kc2 = kc.at[0, 5:].set(1e3)
    vc2 = vc.at[0, 5:].set(1e3)
    a = cam_decode_attention(q, kc, vc, pos, cfg)
    b = cam_decode_attention(q, kc2, vc2, pos, cfg)
    np.testing.assert_allclose(np.asarray(a[0], np.float32),
                               np.asarray(b[0], np.float32), atol=1e-4)


def test_cam_attention_retrieves_strong_match():
    """A planted high-similarity key must dominate the output."""
    B, S, H, KVH, D = 1, 32, 2, 1, 8
    q = jnp.ones((B, H, D)) * 2.0
    kc = jax.random.normal(KEY, (B, S, KVH, D)) * 0.01
    kc = kc.at[0, 17].set(5.0)               # strong match at position 17
    vc = jnp.zeros((B, S, KVH, D)).at[0, 17].set(7.0)
    cfg = get_config("granite-8b").reduced().replace(cam_topk=4)
    out = cam_decode_attention(q, kc, vc,
                               jnp.asarray([S - 1], jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(out, np.float32), 7.0, atol=0.1)


def test_cam_attention_pallas_matches_xla():
    q, kc, vc, pos = _setup(S=128)
    cfg = get_config("granite-8b").reduced().replace(cam_topk=16,
                                                     cam_chunk=32)
    a = cam_decode_attention(q, kc, vc, pos, cfg)
    b = cam_decode_attention_pallas(q, kc, vc, pos, cfg)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_cam_attention_quantized_still_works():
    q, kc, vc, pos = _setup()
    cfg = get_config("granite-8b").reduced().replace(cam_topk=8,
                                                     cam_attn_bits=3)
    out = cam_decode_attention(q, kc, vc, pos, cfg)
    assert bool(jnp.isfinite(out).all())


def test_cam_select_scores_mla():
    s = jax.random.normal(KEY, (2, 4, 32))
    cfg = get_config("minicpm3-4b").reduced().replace(cam_topk=5)
    pos = jnp.asarray([31, 15], jnp.int32)
    out = cam_select_scores(s, pos, cfg)
    kept = np.isfinite(np.asarray(out)) & (np.asarray(out) > -1e29)
    assert (kept.sum(-1) <= 5).all()
    # batch 1: nothing beyond pos 15 survives
    assert not kept[1, :, 16:].any()


# ---------------------------------------------------------------------------
# CAM MoE router
# ---------------------------------------------------------------------------
def test_cam_router_topk_shape_and_validity():
    from repro.models import moe as M
    from repro.models import layers as L
    cfg = get_config("deepseek-moe-16b").reduced().replace(
        cam_router=True, cam_router_bits=3)
    params = L.init_params(KEY, M.moe_spec(cfg))
    x = jax.random.normal(KEY, (10, cfg.d_model)).astype(jnp.bfloat16)
    idx, w = M.route(params, cfg, x)
    assert idx.shape == (10, cfg.moe_top_k)
    assert ((np.asarray(idx) >= 0)
            & (np.asarray(idx) < cfg.n_experts)).all()
    np.testing.assert_allclose(np.asarray(w.sum(-1), np.float32), 1.0,
                               atol=1e-2)
    # top-k distinct per token
    for row in np.asarray(idx):
        assert len(set(row.tolist())) == cfg.moe_top_k


def test_cam_router_quantization_changes_routing_somewhere():
    from repro.models import moe as M
    from repro.models import layers as L
    cfg_fp = get_config("deepseek-moe-16b").reduced().replace(
        cam_router=True, cam_router_bits=0)
    cfg_q = cfg_fp.replace(cam_router_bits=2)
    params = L.init_params(KEY, M.moe_spec(cfg_fp))
    x = jax.random.normal(KEY, (64, cfg_fp.d_model)).astype(jnp.bfloat16)
    i1, _ = M.route(params, cfg_fp, x)
    i2, _ = M.route(params, cfg_q, x)
    assert (np.asarray(i1) != np.asarray(i2)).any()


def test_moe_ep_mode_matches_tp_single_device():
    """EP and TP shard_map modes agree on a 1-device mesh (no drops)."""
    from repro.models import moe as M
    from repro.models import layers as L
    from repro.runtime import sharding_ctx
    cfg = get_config("deepseek-moe-16b").reduced()
    params = L.init_params(KEY, M.moe_spec(cfg))
    x = jax.random.normal(KEY, (8, cfg.d_model)).astype(jnp.bfloat16)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    with sharding_ctx(mesh):
        tp = M.moe_block(params, cfg, x, mode="tp")
        ep = M.moe_block(params, cfg, x, mode="ep")
    np.testing.assert_allclose(np.asarray(tp, np.float32),
                               np.asarray(ep, np.float32), rtol=5e-2,
                               atol=5e-2)


# ---------------------------------------------------------------------------
# CAM episodic memory
# ---------------------------------------------------------------------------
def test_cam_memory_classification():
    from repro.core import (AppConfig, ArchConfig, CAMConfig,
                            CircuitConfig, DeviceConfig)
    from repro.models.cam_memory import CAMMemory, accuracy
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="voting", v_merge="comparator"),
        circuit=CircuitConfig(rows=16, cols=32, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"))
    mem = CAMMemory(cfg)
    protos = jax.random.normal(KEY, (4, 64))
    keys = jnp.repeat(protos, 8, axis=0) + 0.05 * jax.random.normal(
        jax.random.PRNGKey(1), (32, 64))
    labels = jnp.repeat(jnp.arange(4), 8)
    mem.write(keys, labels)
    queries = protos + 0.05 * jax.random.normal(jax.random.PRNGKey(2),
                                                (4, 64))
    acc = accuracy(mem, queries, jnp.arange(4))
    assert acc == 1.0
    perf = mem.perf()
    assert perf["latency_ns"] > 0 and perf["energy_pj"] > 0


def test_moe_a2a_mode_matches_reference():
    """a2a expert parallelism == local reference (ample capacity)."""
    import subprocess, sys, os
    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import compat_make_mesh
from repro.models import moe as M
from repro.models import layers as L
from repro.runtime import sharding_ctx
cfg = get_config("deepseek-moe-16b").reduced().replace(moe_capacity_factor=8.0)
params = L.init_params(jax.random.PRNGKey(0), M.moe_spec(cfg))
x = (0.5*jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))).astype(jnp.bfloat16)
ref = M.moe_block(params, cfg, x)
mesh = compat_make_mesh((2, 4), ("data", "model"))
with sharding_ctx(mesh):
    a = jax.jit(lambda p, x: M.moe_block(p, cfg, x, mode="a2a"))(params, x)
err = float(jnp.max(jnp.abs(ref.astype(jnp.float32)-a.astype(jnp.float32))))
assert err < 0.05, err
print("A2A_TEST_OK")
'''
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "A2A_TEST_OK" in proc.stdout, \
        proc.stderr[-2000:]
