"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# cam_search
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nv,nh,R,C", [
    (1, 1, 8, 16), (3, 2, 32, 64), (2, 4, 16, 128), (4, 1, 64, 64),
    (1, 3, 128, 32)])
@pytest.mark.parametrize("distance", ["hamming", "l1", "l2", "dot"])
def test_cam_search_shapes(nv, nh, R, C, distance):
    key = jax.random.PRNGKey(nv * 100 + nh)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (nv, nh, R, C))
    q = jax.random.uniform(k2, (nh, C))
    got = ops.cam_search(stored, q, distance=distance)
    want = ref.cam_search_ref(stored, q, distance)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cam_search_dtypes(dtype):
    stored = jax.random.uniform(jax.random.PRNGKey(0), (2, 2, 16, 32)
                                ).astype(dtype)
    q = jax.random.uniform(jax.random.PRNGKey(1), (2, 32)).astype(dtype)
    got = ops.cam_search(stored, q, distance="l2")
    want = ref.cam_search_ref(stored.astype(jnp.float32),
                              q.astype(jnp.float32), "l2")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_cam_search_col_valid():
    stored = jax.random.uniform(jax.random.PRNGKey(0), (2, 2, 8, 16))
    q = jax.random.uniform(jax.random.PRNGKey(1), (2, 16))
    cv = jnp.ones((2, 16)).at[1, 10:].set(0.0)
    got = ops.cam_search(stored, q, distance="l1", col_valid=cv)
    want = ref.cam_search_ref(stored, q, "l1", cv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 5),
       st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_cam_search_batched_property(nv, nh, Q, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (nv, nh, 8, 16))
    qb = jax.random.uniform(k2, (Q, nh, 16))
    got = ops.cam_search(stored, qb, distance="l2")
    for i in range(Q):
        np.testing.assert_allclose(
            np.asarray(got[i]),
            np.asarray(ref.cam_search_ref(stored, qb[i], "l2")),
            rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# cam_topk
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,D,k,chunk", [
    (256, 32, 4, 64), (1024, 64, 16, 256), (512, 16, 1, 128),
    (1000, 48, 8, 256), (128, 128, 128, 128)])
@pytest.mark.parametrize("distance", ["dot", "l2"])
def test_cam_topk_shapes(S, D, k, chunk, distance):
    key = jax.random.PRNGKey(S + D)
    k1, k2 = jax.random.split(key)
    keys = jax.random.normal(k1, (S, D))
    q = jax.random.normal(k2, (D,))
    v, i = ops.cam_topk(keys, q, k=k, chunk=chunk, distance=distance)
    rv, ri = ref.cam_topk_ref(keys, q, k, distance)
    np.testing.assert_allclose(np.asarray(v), np.asarray(rv),
                               rtol=1e-4, atol=1e-4)
    # indices must agree where scores are distinct
    assert set(np.asarray(i).tolist()) == set(np.asarray(ri).tolist())


def test_cam_topk_valid_len():
    keys = jnp.concatenate([jnp.zeros((10, 8)),
                            jnp.ones((6, 8)) * 100])  # big scores at end
    q = jnp.ones((8,))
    v, i = ops.cam_topk(keys, q, k=4, chunk=8, distance="dot", valid_len=10)
    assert (np.asarray(i) < 10).all()


def test_cam_topk_batched():
    keys = jax.random.normal(jax.random.PRNGKey(0), (3, 256, 32))
    q = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    v, i = ops.cam_topk(keys, q, k=8, chunk=64)
    for b in range(3):
        rv, ri = ref.cam_topk_ref(keys[b], q[b], 8, "dot")
        np.testing.assert_allclose(np.asarray(v[b]), np.asarray(rv),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# hamming_pack
# ---------------------------------------------------------------------------
@given(st.integers(1, 200), st.integers(1, 130), st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_hamming_packed_property(R, C, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    bits = (jax.random.uniform(k1, (R, C)) > 0.5).astype(jnp.float32)
    qbits = (jax.random.uniform(k2, (C,)) > 0.5).astype(jnp.float32)
    sp, qp = ops.pack_bits(bits), ops.pack_bits(qbits)
    got = ops.hamming_packed(sp, qp, n_valid_bits=C)
    want = np.asarray((bits != qbits[None, :]).sum(-1))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_hamming_packed_ternary_dont_care():
    bits = jnp.asarray([[1., 0., 1., 0.], [1., 1., 1., 1.]])
    qbits = jnp.asarray([1., 1., 0., 0.])
    care = jnp.asarray([1., 0., 1., 1.])    # column 1 is don't-care
    sp = ops.pack_bits(bits, care=jnp.broadcast_to(care, bits.shape))
    qp = ops.pack_bits(qbits, care=care)
    got = np.asarray(ops.hamming_packed(sp, qp, n_valid_bits=4))
    # row0: mismatch at col2 only (col1 ignored) -> 1
    # row1: mismatch at col2? stored=1 q=0 -> 1; col3: 1 vs 0 -> 1 => 2
    np.testing.assert_array_equal(got, [1, 2])


def test_pack_bits_matches_ref():
    bits = (jax.random.uniform(jax.random.PRNGKey(0), (5, 70)) > 0.5
            ).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(ops.pack_bits(bits)),
                                  np.asarray(ref.pack_bits_ref(bits)))


# ---------------------------------------------------------------------------
# fused flash attention kernel
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,KVH,D,qt,kt", [
    (2, 128, 4, 2, 32, 32, 64), (1, 256, 8, 8, 16, 64, 64),
    (2, 64, 6, 2, 64, 64, 32), (1, 128, 2, 1, 128, 128, 128)])
def test_flash_attention_pallas(B, S, H, KVH, D, qt, kt):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import naive_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(S + H), 3)
    q = jax.random.normal(k1, (B, S, H, D))
    k = jax.random.normal(k2, (B, S, KVH, D))
    v = jax.random.normal(k3, (B, S, KVH, D))
    got = flash_attention_pallas(q, k, v, q_tile=qt, kv_tile=kt,
                                 interpret=True)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_pallas_noncausal():
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import naive_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 64, 4, 32))
    k = jax.random.normal(k2, (1, 64, 4, 32))
    v = jax.random.normal(k3, (1, 64, 4, 32))
    got = flash_attention_pallas(q, k, v, q_tile=32, kv_tile=32,
                                 causal=False, interpret=True)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
