"""Per-arch smoke tests (reduced configs): one forward/train step + one
decode step on CPU, asserting output shapes and no NaNs.  Also the
decode==train consistency check and flash==naive attention equivalence.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import ARCH_IDS, get_config
import repro.models.layers as L

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.input_mode == "tokens":
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}
    return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                        jnp.bfloat16),
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(KEY, cfg)
    B, S = 2, 32
    loss, metrics = jax.jit(
        lambda p, b: models.loss_fn(p, cfg, b))(params, _batch(cfg, B, S))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # one optimizer step too: full train_step path
    from repro.optim import AdamW, constant
    from repro.runtime import init_state, make_train_step
    opt = AdamW(lr=constant(1e-3))
    state = init_state(KEY, cfg, opt)
    state2, m = jax.jit(make_train_step(cfg, opt))(state,
                                                   _batch(cfg, B, S))
    assert int(state2.step) == 1
    assert bool(jnp.isfinite(m["loss"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, state2.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = models.init_params(KEY, cfg)
    B, S = 2, 16
    cache = models.init_cache(cfg, B, S)
    if cfg.input_mode == "tokens":
        inputs = {"token": jnp.zeros((B,), jnp.int32)}
    else:
        inputs = {"embed": jnp.zeros((B, cfg.d_model), jnp.bfloat16)}
    logits, cache2 = jax.jit(
        lambda p, i, po, c: models.forward_decode(p, cfg, i, po, c)
    )(params, inputs, jnp.zeros((B,), jnp.int32), cache)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    # cache structure preserved
    jax.tree_util.tree_map(lambda a, b: None, cache, cache2)


@pytest.mark.parametrize("arch", ["granite-8b", "minicpm3-4b",
                                  "mamba2-2.7b", "zamba2-7b"])
def test_decode_matches_train_f32(arch):
    cfg = get_config(arch).reduced().replace(
        cam_attention=False, remat=False, dtype="float32",
        cache_dtype="float32")
    spec = models.model_specs(cfg)
    spec = L.tree_map_specs(
        lambda p: dataclasses.replace(p, dtype=jnp.float32), spec)
    params = L.init_params(KEY, spec)
    B, S = 2, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    lt = models.forward_train(params, cfg, {"tokens": toks,
                                            "labels": toks})
    cache = models.init_cache(cfg, B, S)
    dec = jax.jit(lambda p, i, po, c: models.forward_decode(p, cfg, i,
                                                            po, c))
    for t in range(S):
        lg, cache = dec(params, {"token": toks[:, t]},
                        jnp.full((B,), t, jnp.int32), cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(lt[:, t]),
                                   rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-2.7b",
                                  "zamba2-7b"])
def test_prefill_matches_decode(arch):
    cfg = get_config(arch).reduced().replace(
        cam_attention=False, remat=False, dtype="float32",
        cache_dtype="float32")
    spec = models.model_specs(cfg)
    spec = L.tree_map_specs(
        lambda p: dataclasses.replace(p, dtype=jnp.float32), spec)
    params = L.init_params(KEY, spec)
    B, S = 2, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_pf, cache_pf = models.forward_prefill(params, cfg,
                                                 {"tokens": toks})
    cache = models.init_cache(cfg, B, S)
    for t in range(S):
        lg, cache = models.forward_decode(params, cfg,
                                          {"token": toks[:, t]},
                                          jnp.full((B,), t, jnp.int32),
                                          cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits_pf),
                               rtol=1e-4, atol=1e-5)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-3, atol=1e-4), cache, cache_pf)


def test_flash_equals_naive_attention():
    from repro.models.attention import flash_attention, naive_attention
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (2, 128, 8, 32))
    k = jax.random.normal(k2, (2, 128, 2, 32))
    v = jax.random.normal(k3, (2, 128, 2, 16))   # Dv != Dk
    a = flash_attention(q, k, v, q_chunk=32, kv_chunk=64)
    b = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3,
                               atol=2e-3)


def test_param_counts_close_to_published():
    # full configs should land near the published sizes
    expected = {
        "qwen2-1.5b": 1.5e9, "granite-8b": 8e9, "granite-20b": 20e9,
        "minicpm3-4b": 4e9, "deepseek-moe-16b": 16e9,
        # the ASSIGNED moonshot config (48L x 64 experts x d_ff 1408) sums
        # to ~30B total; the HF model of that name is shallower — we
        # implement the assignment as written (active params ~4B)
        "moonshot-v1-16b-a3b": 29.7e9, "chameleon-34b": 34e9,
        "mamba2-2.7b": 2.7e9, "zamba2-7b": 7e9,
    }
    for arch, want in expected.items():
        cfg = get_config(arch)
        got = cfg.n_params()
        assert 0.6 * want < got < 1.45 * want, (arch, got, want)


def test_moe_active_params_less_than_total():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.active_params() < 0.35 * cfg.n_params()
