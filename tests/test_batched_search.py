"""Query-batched fused CAM search: kernel parity + pipeline bit-identity.

Three layers of guarantees:
  * the batched Pallas kernel matches the pure-jnp oracle AND the old
    per-query vmap kernel path, across distances, unaligned shapes, masks;
  * the fused sense-and-reduce epilogue matches ``subarray.sense`` composed
    with the unfused distance pass (interpret mode);
  * ``FunctionalSimulator.query`` is bit-identical to the pre-batching
    per-query vmap pipeline for every match_type/sensing combination.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                        DeviceConfig)
from repro.core import mapping, merge, quantize, subarray, variation
from repro.core.functional import FunctionalSimulator
from repro.kernels import ops, ref

DISTANCES = ("hamming", "l1", "l2", "dot")


# ---------------------------------------------------------------------------
# batched kernel vs oracle vs per-query vmap
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("nv,nh,R,C,Q", [
    (1, 1, 8, 16, 1),      # single query through the batched entry
    (3, 2, 32, 64, 16),    # aligned tiles
    (2, 3, 17, 21, 5),     # unaligned R, C and Q < q_tile
    (4, 1, 64, 64, 19),    # Q not a multiple of q_tile
    (1, 4, 16, 128, 33),
])
@pytest.mark.parametrize("distance", DISTANCES)
def test_batched_kernel_parity(nv, nh, R, C, Q, distance):
    key = jax.random.PRNGKey(nv * 1000 + nh * 100 + Q)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (nv, nh, R, C))
    qb = jax.random.uniform(k2, (Q, nh, C))
    got = ops.cam_search(stored, qb, distance=distance)
    want = ref.cam_search_batched_ref(stored, qb, distance)
    old = ops.cam_search_vmap(stored, qb, distance=distance)
    assert got.shape == (Q, nv, nh, R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(old), atol=1e-4)


@pytest.mark.parametrize("distance", DISTANCES)
def test_batched_kernel_col_valid(distance):
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    stored = jax.random.uniform(k1, (2, 2, 8, 16))
    qb = jax.random.uniform(k2, (6, 2, 16))
    cv = jnp.ones((2, 16)).at[1, 10:].set(0.0)
    got = ops.cam_search(stored, qb, distance=distance, col_valid=cv)
    want = ref.cam_search_batched_ref(stored, qb, distance, cv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_batched_kernel_q_tile_invariance():
    """Result must not depend on the Q-tiling."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    stored = jax.random.uniform(k1, (2, 2, 16, 32))
    qb = jax.random.uniform(k2, (13, 2, 32))
    outs = [ops.cam_search(stored, qb, distance="l2", q_tile=qt)
            for qt in (1, 4, 8, 13, 64)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# fused sense-and-reduce epilogue vs subarray.sense
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sensing", ["exact", "best", "threshold"])
@pytest.mark.parametrize("distance", DISTANCES)
def test_fused_sense_matches_unfused(sensing, distance):
    k1, k2 = jax.random.split(jax.random.PRNGKey(11))
    stored = jax.random.uniform(k1, (3, 2, 16, 24))
    qb = jax.random.uniform(k2, (5, 2, 24))
    cv = jnp.ones((2, 24)).at[1, 20:].set(0.0)
    rv = jnp.ones((3, 16)).at[2, 10:].set(0.0)
    kw = dict(distance=distance, sensing=sensing, sensing_limit=0.1,
              threshold=2.0, col_valid=cv, row_valid=rv)
    d, m = ops.cam_search_fused(stored, qb, **kw)
    dj, mj = subarray.subarray_query(stored, qb, **kw)
    dj_, d_ = np.asarray(dj), np.asarray(d)
    finite = np.isfinite(dj_)
    # padding rows carry +inf in both pipelines
    assert (finite == np.isfinite(d_)).all()
    np.testing.assert_allclose(d_[finite], dj_[finite], atol=1e-4)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mj))


def test_fused_sense_match_only():
    """want_dist=False returns the match lines alone (no dist write-back)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(13))
    stored = jax.random.uniform(k1, (2, 2, 8, 16))
    qb = jax.random.uniform(k2, (4, 2, 16))
    kw = dict(distance="hamming", sensing="exact", sensing_limit=0.5)
    m = ops.cam_search_fused(stored, qb, want_dist=False, **kw)
    _, mj = ops.cam_search_fused(stored, qb, want_dist=True, **kw)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mj))


def test_subarray_query_batched_kernel_vs_jnp():
    k1, k2 = jax.random.split(jax.random.PRNGKey(17))
    stored = jax.random.uniform(k1, (2, 2, 12, 20))
    qb = jax.random.uniform(k2, (7, 2, 20))
    kw = dict(distance="l1", sensing="best", sensing_limit=0.05,
              col_valid=jnp.ones((2, 20)), row_valid=jnp.ones((2, 12)))
    dk, mk = subarray.subarray_query_batched(stored, qb, use_kernel=True,
                                             **kw)
    dj, mj = subarray.subarray_query_batched(stored, qb, use_kernel=False,
                                             **kw)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dj), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mj))


# ---------------------------------------------------------------------------
# batched bit-packed hamming
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("R,C,Q", [(64, 70, 1), (64, 70, 5), (96, 33, 12),
                                   (256, 2048, 3)])
def test_hamming_packed_batched(R, C, Q):
    k1, k2 = jax.random.split(jax.random.PRNGKey(R + Q))
    bits = (jax.random.uniform(k1, (R, C)) > 0.5).astype(jnp.float32)
    qbits = (jax.random.uniform(k2, (Q, C)) > 0.5).astype(jnp.float32)
    sp, qp = ops.pack_bits(bits), ops.pack_bits(qbits)
    got = ops.hamming_packed(sp, qp, n_valid_bits=C)
    assert got.shape == (Q, R)
    for i in range(Q):
        want = np.asarray((bits != qbits[i][None, :]).sum(-1))
        np.testing.assert_array_equal(np.asarray(got[i]), want)
        np.testing.assert_array_equal(
            np.asarray(ops.hamming_packed(sp, qp[i], n_valid_bits=C)), want)


# ---------------------------------------------------------------------------
# FunctionalSimulator: bit-identity vs the per-query vmap pipeline
# ---------------------------------------------------------------------------
def _old_query(sim: FunctionalSimulator, state, queries, key=None):
    """The pre-batching pipeline: per-query vmap of search + merge."""
    cfg = sim.config
    bits = cfg.app.data_bits
    qcodes, _, _ = quantize.quantize_for_cell(
        queries, cfg.circuit.cell_type, bits, state.lo, state.hi)
    qseg = mapping.partition_query(qcodes, state.spec)

    def search_one(grid, q):
        dist, match = subarray.subarray_query(
            grid, q,
            distance=cfg.app.distance,
            sensing=cfg.circuit.sensing,
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0,
            col_valid=state.col_valid,
            row_valid=state.row_valid,
            use_kernel=False)
        k = cfg.app.match_param if cfg.app.match_type == "best" else max(
            1, min(state.spec.padded_K, 16))
        return merge.merge(
            dist, match,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge,
            v_merge=cfg.arch.v_merge,
            match_param=k,
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0)

    if cfg.device.variation in ("c2c", "both"):
        keys = variation.split_for_queries(key, queries.shape[0])
        return jax.vmap(lambda q, k: search_one(
            variation.apply_c2c(state.grid, cfg.device, bits, k), q)
            )(qseg, keys)
    return jax.vmap(lambda q: search_one(state.grid, q))(qseg)


COMBOS = [
    # (distance, match_type, h_merge, v_merge, cell, bits, sensing, sl)
    ("hamming", "exact", "and", "gather", "tcam", 1, "exact", 0.0),
    ("l2", "exact", "adder", "gather", "mcam", 3, "exact", 0.5),
    ("l2", "best", "adder", "comparator", "mcam", 3, "best", 0.0),
    ("l2", "best", "voting", "comparator", "mcam", 3, "best", 0.5),
    ("l1", "best", "and", "comparator", "acam", 0, "best", 0.0),  # nh == 1
    ("hamming", "threshold", "adder", "gather", "tcam", 1, "threshold", 0.0),
    ("dot", "best", "adder", "comparator", "acam", 0, "best", 0.0),
]


@pytest.mark.parametrize(
    "distance,match,h_merge,v_merge,cell,bits,sensing,sl", COMBOS)
def test_query_bit_identical_to_vmap_pipeline(distance, match, h_merge,
                                              v_merge, cell, bits,
                                              sensing, sl):
    K, N = 21, 12
    cols = N if h_merge == "and" and match == "best" else 6
    cfg = CAMConfig(
        app=AppConfig(distance=distance, match_type=match, match_param=2,
                      data_bits=bits),
        arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
        circuit=CircuitConfig(rows=8, cols=cols, cell_type=cell,
                              sensing=sensing, sensing_limit=sl),
        device=DeviceConfig(device="fefet"))
    sim = FunctionalSimulator(cfg)
    key = jax.random.PRNGKey(42)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (K, N))
    queries = jax.random.uniform(k2, (9, N))
    state = sim.write(stored)
    idx, mask = sim.query(state, queries)
    oidx, omask = _old_query(sim, state, queries)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(oidx))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(omask))


def test_query_bit_identical_with_c2c_noise():
    """Default c2c_query_tile=1 reproduces the per-query noise draw."""
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=1,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet", variation="c2c",
                            variation_std=0.4))
    sim = FunctionalSimulator(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (30, 16))
    queries = jax.random.uniform(jax.random.PRNGKey(1), (8, 16))
    state = sim.write(stored)
    qkey = jax.random.PRNGKey(5)
    idx, mask = sim.query(state, queries, key=qkey)
    oidx, omask = _old_query(sim, state, queries, key=qkey)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(oidx))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(omask))


def test_query_c2c_tiled_noise_runs():
    """c2c_query_tile > 1: one noise draw per Q-tile (cycle group)."""
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=1,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet", variation="c2c",
                            variation_std=0.2))
    sim = FunctionalSimulator(cfg, c2c_query_tile=4)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (20, 16))
    queries = jax.random.uniform(jax.random.PRNGKey(1), (10, 16))  # pad to 12
    state = sim.write(stored)
    idx, mask = sim.query(state, queries, key=jax.random.PRNGKey(2))
    assert idx.shape == (10, 1) and mask.shape[0] == 10
    assert ((np.asarray(idx) >= 0) & (np.asarray(idx) < 24)).all()


def test_query_batch_matches_single_query_calls():
    """Batch processing must be query-independent."""
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=0),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="acam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"))
    sim = FunctionalSimulator(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (25, 14))
    queries = jax.random.uniform(jax.random.PRNGKey(1), (6, 14))
    state = sim.write(stored)
    idx, mask = sim.query(state, queries)
    for i in range(queries.shape[0]):
        ii, mi = sim.query(state, queries[i])
        np.testing.assert_array_equal(np.asarray(idx[i]), np.asarray(ii))
        np.testing.assert_array_equal(np.asarray(mask[i]), np.asarray(mi))


def test_query_kernel_path_matches_jnp_path():
    """use_kernel=True (fused batched Pallas) agrees with the jnp path."""
    for match, h_merge, v_merge, sensing in [
            ("exact", "and", "gather", "exact"),
            ("best", "adder", "comparator", "best"),
            ("threshold", "adder", "gather", "threshold")]:
        cfg = CAMConfig(
            app=AppConfig(distance="l2", match_type=match, match_param=2,
                          data_bits=3),
            arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
            circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                                  sensing=sensing, sensing_limit=0.5),
            device=DeviceConfig(device="fefet"))
        a = FunctionalSimulator(cfg, use_kernel=False)
        b = FunctionalSimulator(cfg, use_kernel=True)
        stored = jax.random.uniform(jax.random.PRNGKey(3), (20, 12))
        queries = jax.random.uniform(jax.random.PRNGKey(4), (5, 12))
        sa, sb = a.write(stored), b.write(stored)
        ia, ma = a.query(sa, queries)
        ib, mb = b.query(sb, queries)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))


# ---------------------------------------------------------------------------
# ACAM range path (5-D [lo, hi] stored grids)
# ---------------------------------------------------------------------------
def test_range_violations_oracle():
    """range_violations == brute-force count of cells whose [lo, hi]
    range excludes the query value, with padded columns masked out."""
    from repro.core.distance import range_violations
    rng = np.random.default_rng(0)
    R, C = 6, 5
    lo = rng.random((R, C)).astype(np.float32) * 0.6
    hi = lo + rng.random((R, C)).astype(np.float32) * 0.4
    stored = jnp.asarray(np.stack([lo, hi], axis=-1))
    q = jnp.asarray(rng.random((C,)).astype(np.float32))
    valid = jnp.ones((C,)).at[C - 1].set(0.0)
    got = np.asarray(range_violations(stored, q, valid))
    qn = np.asarray(q)
    want = (((qn[None, :] < lo) | (qn[None, :] > hi))
            * np.asarray(valid)[None, :]).sum(-1)
    np.testing.assert_array_equal(got, want)
    # boundary values are INSIDE the range (closed interval)
    edge = jnp.asarray(lo[0])
    got_edge = np.asarray(range_violations(stored, edge, None))
    assert got_edge[0] == 0.0


def test_acam_batched_roundtrip_matches_per_query():
    """subarray_distances on a 5-D range grid must round-trip through the
    batched entry point: subarray_query_batched == per-query
    subarray_query == unpartitioned oracle, for every query in the batch."""
    from repro.core.distance import range_violations
    rng = np.random.default_rng(3)
    K, N, Q = 21, 10, 7
    lo = rng.random((K, N)).astype(np.float32) * 0.5
    hi = lo + rng.random((K, N)).astype(np.float32) * 0.5
    stored = jnp.asarray(np.stack([lo, hi], axis=-1))
    spec = mapping.grid_spec(K, N, 8, 4)
    grid = mapping.partition_stored(stored, spec)           # (nv, nh, R, C, 2)
    assert grid.ndim == 5
    queries = jnp.asarray(rng.random((Q, N)).astype(np.float32))
    qseg = mapping.partition_query(queries, spec)
    kw = dict(distance="range", sensing="exact", sensing_limit=0.0,
              col_valid=mapping.col_valid_mask(spec),
              row_valid=mapping.row_valid_mask(spec))
    db, mb = subarray.subarray_query_batched(grid, qseg, **kw)
    assert db.shape == (Q, spec.nv, spec.nh, spec.padded_K // spec.nv)
    # batched == per-query (the ACAM path has no kernel; both broadcast)
    for i in range(Q):
        dq, mq = subarray.subarray_query(grid, qseg[i], **kw)
        np.testing.assert_array_equal(np.asarray(db[i]), np.asarray(dq))
        np.testing.assert_array_equal(np.asarray(mb[i]), np.asarray(mq))
    # horizontal adder merge over the partition == unpartitioned oracle
    total = np.asarray(db).sum(axis=-2).reshape(Q, -1)[:, :K]
    want = np.asarray(range_violations(stored, queries, None))
    np.testing.assert_array_equal(total, want)


def test_acam_functional_exact_match_on_containing_ranges():
    """End-to-end ACAM: a query inside every cell range of entry i is an
    exact match for entry i (X-TIME-style decision rule), on the batched
    pipeline."""
    cfg = CAMConfig(
        app=AppConfig(distance="range", match_type="exact", match_param=4,
                      data_bits=0),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=4, cols=4, cell_type="acam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"))
    rng = np.random.default_rng(5)
    K, N = 11, 6
    centers = rng.random((K, N)).astype(np.float32)
    lo, hi = centers - 0.02, centers + 0.02
    sim = FunctionalSimulator(cfg)
    state = sim.write(jnp.asarray(np.stack([lo, hi], axis=-1)))
    queries = jnp.asarray(centers[[2, 9, 4]])
    idx, mask = sim.query(state, queries)
    for row, entry in enumerate((2, 9, 4)):
        assert np.asarray(mask[row])[entry] == 1.0
        assert np.asarray(idx[row])[0] == entry


# ---------------------------------------------------------------------------
# cam_topk reshape regression
# ---------------------------------------------------------------------------
def test_cam_topk_batched_3d_shapes_and_values():
    """(B, S, D) input must produce (B, k) — not a silently flattened axis —
    even when k is clamped below the requested value."""
    B, S, D, k = 3, 64, 16, 8
    keys = jax.random.normal(jax.random.PRNGKey(0), (B, S, D))
    q = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    v, i = ops.cam_topk(keys, q, k=k, chunk=32)
    assert v.shape == (B, k) and i.shape == (B, k)
    for b in range(B):
        rv, ri = ref.cam_topk_ref(keys[b], q[b], k)
        np.testing.assert_allclose(np.asarray(v[b]), np.asarray(rv),
                                   rtol=1e-4, atol=1e-4)
    # k larger than S: clamped to S, shape must follow the clamp
    v2, i2 = ops.cam_topk(keys, q, k=S + 10, chunk=S)
    assert v2.shape == (B, S) and i2.shape == (B, S)


# ---------------------------------------------------------------------------
# pipelined (bank-blocked) schedule: off-switch bit-identity + autotuned
# q_tile invariance
# ---------------------------------------------------------------------------
from _hypothesis_compat import given, settings, strategies as st  # noqa: E402

from repro.kernels.cam_search import Q_TILES  # noqa: E402


@pytest.mark.parametrize("distance", DISTANCES)
def test_pipeline_off_bit_identical_kernels(distance):
    """sim.pipeline=False (historical per-tile grid, default_q_tile) and
    the bank-blocked pipelined schedule share the same tile functions, so
    they must agree BITWISE — on the dist-only kernel and on the fused
    kernel's dist and match outputs alike."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(23))
    for nv, nh, R, C, Q in [(3, 2, 32, 64, 16), (2, 3, 17, 21, 5),
                            (4, 1, 64, 64, 19)]:
        stored = jax.random.uniform(k1, (nv, nh, R, C))
        qb = jax.random.uniform(k2, (Q, nh, C))
        on = ops.cam_search(stored, qb, distance=distance, pipeline=True)
        off = ops.cam_search(stored, qb, distance=distance, pipeline=False)
        np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
        kw = dict(distance=distance, sensing="best", sensing_limit=0.1)
        don, mon = ops.cam_search_fused(stored, qb, pipeline=True, **kw)
        doff, moff = ops.cam_search_fused(stored, qb, pipeline=False, **kw)
        np.testing.assert_array_equal(np.asarray(don), np.asarray(doff))
        np.testing.assert_array_equal(np.asarray(mon), np.asarray(moff))


@pytest.mark.parametrize(
    "distance,match,h_merge,v_merge,cell,bits,sensing,sl", COMBOS)
def test_query_pipeline_off_bit_identical(distance, match, h_merge,
                                          v_merge, cell, bits, sensing, sl):
    """End-to-end FunctionalSimulator: sim.pipeline=False must reproduce
    the default pipelined query bit-for-bit for every match/merge combo —
    including the quantized-code int fast paths the pipelined schedule
    turns on (data_bits <= 8, exact small-integer sums)."""
    K, N = 21, 12
    cols = N if h_merge == "and" and match == "best" else 6
    def mk(pipeline):
        cfg = CAMConfig(
            app=AppConfig(distance=distance, match_type=match,
                          match_param=2, data_bits=bits),
            arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
            circuit=CircuitConfig(rows=8, cols=cols, cell_type=cell,
                                  sensing=sensing, sensing_limit=sl),
            device=DeviceConfig(device="fefet"))
        return FunctionalSimulator(
            cfg.replace(sim=dict(use_kernel=True, pipeline=pipeline)))
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    stored = jax.random.uniform(k1, (K, N))
    queries = jax.random.uniform(k2, (9, N))
    son, soff = mk(True), mk(False)
    ion, mon = son.query(son.write(stored), queries)
    ioff, moff = soff.query(soff.write(stored), queries)
    np.testing.assert_array_equal(np.asarray(ion), np.asarray(ioff))
    np.testing.assert_array_equal(np.asarray(mon), np.asarray(moff))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, len(Q_TILES) - 1),
       st.sampled_from(DISTANCES),
       st.integers(0, 3))
def test_q_tile_choice_never_changes_results(qt_idx, distance, seed):
    """Property: the Q-tile is a pure schedule knob — ANY ladder rung,
    and the autotuned choice (q_tile=None -> choose_q_tile), produce
    bitwise-identical fused results on both pipeline settings."""
    qt = Q_TILES[qt_idx]
    k1, k2 = jax.random.split(jax.random.PRNGKey(100 + seed))
    stored = jax.random.uniform(k1, (2, 2, 12, 20))
    qb = jax.random.uniform(k2, (11, 2, 20))
    kw = dict(distance=distance, sensing="best", sensing_limit=0.05)
    want_d, want_m = ops.cam_search_fused(stored, qb, q_tile=None,
                                          pipeline=True, **kw)
    for pipeline in (True, False):
        d, m = ops.cam_search_fused(stored, qb, q_tile=qt,
                                    pipeline=pipeline, **kw)
        np.testing.assert_array_equal(np.asarray(d), np.asarray(want_d))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(want_m))
