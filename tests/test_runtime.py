"""Runtime: checkpoint/restart, fault supervision, elastic re-shard,
gradient compression, sharding resolver, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint import committed_steps, restore, save
from repro.launch.mesh import compat_make_mesh, compat_shard_map
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.optim import (AdamW, constant, dequantize_int8, ef_compress,
                         init_error_state, quantize_int8)
from repro.runtime import (ShardingRules, init_state, make_train_step,
                           state_axes)
from repro.runtime.fault import StepFailure, Supervisor

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                                         "d": jnp.int32(7)}}
    save(str(tmp_path), 5, tree)
    step, back = restore(str(tmp_path), tree)
    assert step == 5
    jax.tree_util.tree_map(
        lambda x, y: np.testing.assert_array_equal(
            np.asarray(x, np.float32), np.asarray(y, np.float32)),
        tree, back)


def test_checkpoint_keep_n_and_commit_marker(tmp_path):
    tree = {"x": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        save(str(tmp_path), s, tree, keep=2)
    assert committed_steps(str(tmp_path)) == [3, 4]
    # torn checkpoint (no marker) is ignored
    os.makedirs(tmp_path / "step_00000009")
    assert committed_steps(str(tmp_path)) == [3, 4]
    step, _ = restore(str(tmp_path), tree)
    assert step == 4


def test_checkpoint_structure_mismatch_rejected(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="structure mismatch"):
        restore(str(tmp_path), {"a": jnp.zeros(2), "b": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# fault supervision (injected failures + stragglers)
# ---------------------------------------------------------------------------
def test_supervisor_restart_resumes_from_checkpoint(tmp_path):
    calls = {"n": 0}

    def step_fn(state, batch):
        return {"v": state["v"] + batch}, {"loss": state["v"]}

    def batch_fn(step):
        return jnp.float32(1.0)

    fail_at = {12}

    def fault_hook(step):
        if step in fail_at and calls["n"] < 50:
            fail_at.discard(step)
            raise StepFailure("injected node failure")
        calls["n"] += 1

    sup = Supervisor(step_fn=step_fn, batch_fn=batch_fn,
                     ckpt_dir=str(tmp_path), ckpt_every=5,
                     fault_hook=fault_hook)
    final_step, state = sup.run({"v": jnp.float32(0.0)}, 0, 20)
    assert final_step == 20
    assert sup.restarts == 1
    assert any(e.startswith("restore@") for e in sup.events)
    # deterministic data => same final value as a clean run
    assert float(state["v"]) == 20.0


def test_supervisor_straggler_detection(tmp_path):
    import time as _t
    times = iter([0.01] * 10 + [0.3] + [0.01] * 5)

    def step_fn(state, batch):
        _t.sleep(next(times, 0.01))
        return state, {}

    sup = Supervisor(step_fn=step_fn, batch_fn=lambda s: None,
                     ckpt_dir=str(tmp_path), ckpt_every=100,
                     straggler_factor=3.0)
    sup.run({}, 0, 16)
    assert any("straggler@" in e for e in sup.events)


# ---------------------------------------------------------------------------
# elastic re-shard (checkpoint written on one mesh, restored on another)
# ---------------------------------------------------------------------------
def test_elastic_restore_across_mesh_shapes(tmp_path):
    from repro.runtime import elastic
    cfg = get_config("qwen2-1.5b").reduced()
    opt = AdamW(lr=constant(1e-3))
    state = init_state(KEY, cfg, opt)
    save(str(tmp_path), 3, state)
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    step, restored = elastic.elastic_restore(
        str(tmp_path), state, state_axes(cfg), mesh)
    assert step == 3
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)),
        state.params, restored.params)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_int8_quantization_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-6


def test_error_feedback_converges():
    """EF compression: the running mean of dequantized grads approaches
    the true mean (bias -> 0 over steps)."""
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, s, err = ef_compress(g, err)
        total = total + dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(total / n), np.asarray(g),
                               atol=2e-3)


def test_compressed_psum_shard_map():
    devs = jax.devices()
    mesh = compat_make_mesh((len(devs),), ("data",))
    from jax.sharding import PartitionSpec as P
    from repro.optim import compressed_psum

    grads = {"w": jax.random.normal(KEY, (8, 16))}
    errs = init_error_state(grads)

    def body(g, e):
        return compressed_psum(g, e, "data")

    out, new_err = jax.jit(compat_shard_map(
        body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))(
        grads, errs)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(grads["w"]), atol=0.05)


# ---------------------------------------------------------------------------
# CAM search serving (micro-batching over the store-once simulators)
# ---------------------------------------------------------------------------
def test_cam_search_server_batches_and_matches_direct_query():
    from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                            DeviceConfig, FunctionalSimulator)
    from repro.runtime import CAMSearchServer

    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=2,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"))
    sim = FunctionalSimulator(cfg)
    stored = jax.random.uniform(KEY, (30, 16))
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                            (11, 16)))
    state = sim.write(stored)
    srv = CAMSearchServer(sim, state, batch=4)
    reqs = [srv.submit(q) for q in queries]
    assert srv.step() == 4                 # one full batch
    assert reqs[3].done and not reqs[4].done
    done = srv.run()
    assert len(done) == 11 and all(r.done for r in reqs)
    # answers equal the direct batched query (no variation => key-free)
    idx, mask = sim.query(state, jnp.asarray(queries))
    for i, r in enumerate(done):
        assert r.rid == i
        np.testing.assert_array_equal(r.indices, np.asarray(idx[i]))
        np.testing.assert_array_equal(r.mask, np.asarray(mask[i]))


def _cam_server_cfg(variation: str = "none"):
    from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                            DeviceConfig)
    return CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=2,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet", variation=variation,
                            variation_std=0.8))


def test_cam_search_server_tail_padding_discards_padded_results():
    """A batch+1 submission leaves a 1-request tail step: the padded
    zero-queries ride the search but their results must be discarded, and
    every answer must equal the unpadded single-shot query bit-for-bit."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    batch = 4
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(2),
                                            (batch + 1, 16)))
    srv = CAMSearchServer(sim, state, batch=batch)
    reqs = [srv.submit(q) for q in queries]
    done = srv.run()
    assert len(done) == batch + 1 and all(r.done for r in reqs)
    for q, r in zip(queries, reqs):
        idx, mask = sim.query(state, jnp.asarray(q))     # single, unpadded
        np.testing.assert_array_equal(r.indices, np.asarray(idx))
        np.testing.assert_array_equal(r.mask, np.asarray(mask))


def test_cam_search_server_empty_step_does_not_fold_key():
    """step() on an empty queue returns 0 WITHOUT consuming a per-step C2C
    key: the first real batch must still search with fold_in(key, 0)."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg("c2c"))
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    srv = CAMSearchServer(sim, state, batch=4)
    for _ in range(3):
        assert srv.step() == 0
    assert srv._steps == 0
    qs = np.asarray(jax.random.uniform(jax.random.PRNGKey(3), (4, 16)))
    for q in qs:
        srv.submit(q)
    assert srv.step() == 4
    idx, mask = sim.query(state, jnp.asarray(qs),
                          key=jax.random.fold_in(srv.key, 0))
    for i, r in enumerate(srv.finished):
        np.testing.assert_array_equal(r.indices, np.asarray(idx[i]))
        np.testing.assert_array_equal(r.mask, np.asarray(mask[i]))


def test_cam_search_server_c2c_keys_differ_across_steps():
    """Each served batch draws its cycle noise from fold_in(key, step):
    consecutive steps use different keys, and each step's answers are
    bit-identical to a direct query under that step's key."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg("c2c"))
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    batch = 4
    q = np.asarray(jax.random.uniform(jax.random.PRNGKey(4), (16,)))
    srv = CAMSearchServer(sim, state, batch=batch)
    for _ in range(2 * batch):          # the SAME query in both batches
        srv.submit(q)
    assert srv.step() == batch and srv.step() == batch
    k0 = jax.random.fold_in(srv.key, 0)
    k1 = jax.random.fold_in(srv.key, 1)
    assert not np.array_equal(np.asarray(k0), np.asarray(k1))
    qs = jnp.asarray(np.stack([q] * batch))
    for step, key in ((0, k0), (1, k1)):
        idx, mask = sim.query(state, qs, key=key)
        for i in range(batch):
            r = srv.finished[step * batch + i]
            np.testing.assert_array_equal(r.indices, np.asarray(idx[i]))
            np.testing.assert_array_equal(r.mask, np.asarray(mask[i]))


def test_cam_search_server_reads_serve_batch_from_config_and_facade():
    """batch=None: the server picks up config.sim.serve_batch, and accepts
    the CAMASim facade as its simulator."""
    from repro.core import CAMASim
    from repro.runtime import CAMSearchServer

    cfg = _cam_server_cfg().replace(sim=dict(serve_batch=4))
    sim = CAMASim(cfg)
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    srv = CAMSearchServer(sim, state)
    assert srv.batch == 4
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(5), (9, 16)))
    for q in queries:
        srv.submit(q)
    assert srv.step() == 4                  # one serve_batch-sized step
    done = srv.run()
    assert len(done) == 9
    idx, mask = sim.query(state, jnp.asarray(queries))
    for i, r in enumerate(done):
        np.testing.assert_array_equal(r.indices, np.asarray(idx[i]))
        np.testing.assert_array_equal(r.mask, np.asarray(mask[i]))


def test_cam_search_server_autoscale_ladder_widths():
    """The padded width is the smallest power-of-two rung >= the step's
    requests, capped at batch; fixed-batch always pads to batch."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    auto = CAMSearchServer(sim, state, batch=32, autoscale=True)
    fixed = CAMSearchServer(sim, state, batch=32)
    for n, want in ((1, 1), (2, 2), (3, 4), (5, 8), (9, 16), (17, 32),
                    (32, 32)):
        assert auto._padded_width(n) == want, n
        assert fixed._padded_width(n) == 32, n


def test_cam_search_server_autoscale_parity_with_fixed_batch():
    """Same requests, same fold_in(key, step) schedule: the autoscaled
    server's answers are bit-exact vs fixed-batch serving (the ladder only
    changes the zero-padding width)."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(6),
                                            (11, 16)))
    key = jax.random.PRNGKey(9)
    fixed = CAMSearchServer(sim, state, batch=8, key=key)
    auto = CAMSearchServer(sim, state, batch=8, key=key, autoscale=True)
    for srv in (fixed, auto):
        for q in queries:
            srv.submit(q)
        srv.run()
    assert fixed._steps == auto._steps == 2   # same request grouping
    for rf, ra in zip(fixed.finished, auto.finished):
        assert rf.rid == ra.rid
        np.testing.assert_array_equal(rf.indices, ra.indices)
        np.testing.assert_array_equal(rf.mask, ra.mask)


def test_cam_search_server_autoscale_c2c_matches_direct_padded_query():
    """With C2C noise the per-cycle draw count is the padded width, so
    each autoscaled step must bit-match a direct query of that step's
    ladder width under the same fold_in(key, step) key."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg("c2c"))
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(7),
                                            (3, 16)))
    srv = CAMSearchServer(sim, state, batch=8, autoscale=True)
    for q in queries:
        srv.submit(q)
    assert srv.step() == 3                   # ladder width 4, one step
    padded = np.concatenate([queries, np.zeros((1, 16), np.float32)])
    idx, mask = sim.query(state, jnp.asarray(padded),
                          key=jax.random.fold_in(srv.key, 0))
    for i, r in enumerate(srv.finished):
        np.testing.assert_array_equal(r.indices, np.asarray(idx[i]))
        np.testing.assert_array_equal(r.mask, np.asarray(mask[i]))


def _cascade_cfg():
    from repro.core import CAMConfig
    return CAMConfig.from_dict(dict(
        app=dict(distance="l2", match_type="best", match_param=1,
                 data_bits=3),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=8, cols=8, cell_type="mcam", sensing="best"),
        device=dict(device="fefet"),
        sim=dict(prefilter="signature", top_p_banks=2)))


def test_cascade_pad_routing_regression():
    """THE serve-padding routing bug: `select_banks` min-reduces per-query
    margins over the batch axis, so an all-zero pad query used to vote for
    ITS best banks and evict the real query's — padded answers diverged
    from the unpadded ones.  `valid_count` must make them bit-identical,
    and on these seeds the unmasked padded query must still reproduce the
    divergence (else the regression test guards nothing)."""
    from repro.core import FunctionalSimulator

    sim = FunctionalSimulator(_cascade_cfg())
    state = sim.write(jax.random.uniform(jax.random.PRNGKey(0), (64, 8)))
    diverged = 0
    for qseed in (1000, 1001, 1005):
        q = jax.random.uniform(jax.random.PRNGKey(qseed), (1, 8))
        direct = sim.query(state, q)
        for width in (2, 4, 8):
            padded = jnp.concatenate(
                [q, jnp.zeros((width - 1, 8), q.dtype)])
            fixed = sim.query(state, padded, valid_count=1)
            np.testing.assert_array_equal(np.asarray(direct.indices[0]),
                                          np.asarray(fixed.indices[0]))
            np.testing.assert_array_equal(np.asarray(direct.mask[0]),
                                          np.asarray(fixed.mask[0]))
            buggy = sim.query(state, padded)       # no mask: pads vote
            if not np.array_equal(np.asarray(direct.indices[0]),
                                  np.asarray(buggy.indices[0])):
                diverged += 1
    assert diverged > 0      # the masked path is actually load-bearing


def test_cascade_served_answers_stable_across_pad_widths_and_depths():
    """Through the server: the same requests answer bit-identically no
    matter the serve batch, autoscale rung, or how many other requests
    share the queue — pad queries never steer the cascade's bank vote."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cascade_cfg())
    state = sim.write(jax.random.uniform(jax.random.PRNGKey(0), (64, 8)))
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(1000),
                                            (3, 8)))
    want = sim.query(state, jnp.asarray(queries), valid_count=3)
    for batch, autoscale in ((4, False), (8, False), (8, True), (16, True)):
        srv = CAMSearchServer(sim, state, batch=batch, autoscale=autoscale)
        for q in queries:
            srv.submit(q)
        done = srv.run()
        assert len(done) == 3
        for i, r in enumerate(done):
            np.testing.assert_array_equal(r.indices,
                                          np.asarray(want.indices[i]))
            np.testing.assert_array_equal(r.mask, np.asarray(want.mask[i]))


def test_cam_search_server_valid_count_noop_without_cascade():
    """valid_count is routing-only: with the cascade off it must not
    change full-batch answers (all-valid mask == no mask)."""
    from repro.core import FunctionalSimulator

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    qs = jnp.asarray(np.asarray(
        jax.random.uniform(jax.random.PRNGKey(8), (4, 16))))
    a = sim.query(state, qs)
    b = sim.query(state, qs, valid_count=4)
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.mask), np.asarray(b.mask))


def test_cam_search_server_rejects_malformed_requests_at_submit():
    """Malformed requests fail alone at the door — the queue they would
    have poisoned is untouched and keeps serving."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    srv = CAMSearchServer(sim, state, batch=4)
    good = srv.submit(np.zeros(16, np.float32))
    with pytest.raises(ValueError, match="shape"):
        srv.submit(np.zeros(9, np.float32))          # wrong width
    with pytest.raises(ValueError, match="numeric"):
        srv.submit(np.array(["a"] * 16))             # wrong dtype
    with pytest.raises(ValueError, match="width"):
        srv.submit_insert(np.zeros((2, 9), np.float32))
    with pytest.raises(ValueError, match="numeric"):
        srv.submit_insert(np.array([["a"] * 16]))
    with pytest.raises(ValueError, match="ids but"):
        srv.submit_update([1, 2], np.zeros((1, 16), np.float32))
    assert [r.rid for r in srv.queue] == [good.rid]
    assert srv.step() == 1 and good.done


def test_cam_search_server_step_failure_restores_queue():
    """A failing engine call must not lose requests: step() restores its
    popped batch to the queue front and re-raises; the retry then serves
    the SAME requests under the SAME fold_in(key, step) key."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    srv = CAMSearchServer(sim, state, batch=4)
    queries = np.asarray(jax.random.uniform(jax.random.PRNGKey(11),
                                            (3, 16)))
    reqs = [srv.submit(q) for q in queries]
    real_query = sim.query

    def boom(*a, **kw):
        raise RuntimeError("injected engine fault")

    sim.query = boom
    try:
        with pytest.raises(RuntimeError, match="injected"):
            srv.step()
    finally:
        sim.query = real_query
    assert [r.rid for r in srv.queue] == [r.rid for r in reqs]
    assert srv._steps == 0                   # key schedule untouched
    assert srv.step() == 3
    idx, mask = sim.query(state, jnp.asarray(queries))
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(r.indices, np.asarray(idx[i]))
        np.testing.assert_array_equal(r.mask, np.asarray(mask[i]))
    # mutation-unit failure restores too
    bad = srv.submit_delete([10**6])         # out-of-range id
    with pytest.raises(ValueError, match=r"ids must be in"):
        srv.step()
    assert srv.queue and srv.queue[0].rid == bad.rid


def test_cam_search_server_queue_full_backpressure():
    from repro.core import CAMASim
    from repro.runtime import CAMSearchServer, QueueFull

    cfg = _cam_server_cfg().replace(sim=dict(serve_queue=2))
    sim = CAMASim(cfg)
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    srv = CAMSearchServer(sim, state, batch=4)   # max_queue from config
    assert srv.max_queue == 2
    srv.submit(np.zeros(16, np.float32))
    srv.submit(np.zeros(16, np.float32))
    with pytest.raises(QueueFull):
        srv.submit(np.zeros(16, np.float32))
    srv.step()                                   # drains the queue
    srv.submit(np.zeros(16, np.float32))         # admits again
    # explicit max_queue overrides the config default
    assert CAMSearchServer(sim, state, batch=4, max_queue=7).max_queue == 7


def test_cam_search_server_mutations_interleave_deterministically():
    """insert → search → delete → search through the serve loop: answers
    reflect submission order, the final state is bit-identical to direct
    engine mutations under the server's mutation key lane, and an
    identical server replays the identical trace."""
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    cfg = _cam_server_cfg("both").replace(
        sim=dict(capacity=48, d2d_fold="row"),
        device=dict(variation_std=0.05))
    sim = FunctionalSimulator(cfg)
    stored = jax.random.uniform(KEY, (30, 16))
    stored = stored.at[0].set(0.0).at[1].set(1.0)
    extra = np.asarray(jax.random.uniform(jax.random.PRNGKey(12), (4, 16)))
    state = sim.write(stored, KEY)

    def drive(srv):
        ins = srv.submit_insert(extra)
        hits = [srv.submit(row) for row in extra]    # see the new rows
        dels = srv.submit_delete([3, 4])
        miss = srv.submit(np.asarray(stored[3]))     # deleted row's data
        srv.run()
        return ins, hits, dels, miss

    srv = CAMSearchServer(sim, state, batch=4, key=jax.random.PRNGKey(9))
    ins, hits, dels, miss = drive(srv)
    assert ins.done and dels.done
    np.testing.assert_array_equal(ins.ids, np.arange(30, 34))
    for i, r in enumerate(hits):                 # inserted rows match
        assert r.indices[0] == ins.ids[i]
    assert miss.indices[0] not in (3, 4)         # deleted rows never match
    # server state == direct mutations under the same mutation key lane
    mk = jax.random.fold_in(srv._mut_key, 0)
    direct, _ = sim.insert(state, jnp.asarray(extra), key=mk)
    direct = sim.delete(direct, [3, 4])
    np.testing.assert_array_equal(np.asarray(srv.state.grid),
                                  np.asarray(direct.grid))
    np.testing.assert_array_equal(np.asarray(srv.state.row_valid),
                                  np.asarray(direct.row_valid))
    # identical server → identical trace
    srv2 = CAMSearchServer(sim, sim.write(stored, KEY), batch=4,
                           key=jax.random.PRNGKey(9))
    drive(srv2)
    assert len(srv.finished) == len(srv2.finished)
    for a, b in zip(srv.finished, srv2.finished):
        assert a.rid == b.rid and a.slo == b.slo
        if hasattr(a, "query"):                  # search requests
            np.testing.assert_array_equal(a.indices, b.indices)
            np.testing.assert_array_equal(a.mask, b.mask)


def test_cam_search_server_latency_stats_by_slo():
    from repro.core import FunctionalSimulator
    from repro.runtime import CAMSearchServer

    sim = FunctionalSimulator(_cam_server_cfg())
    state = sim.write(jax.random.uniform(KEY, (30, 16)))
    srv = CAMSearchServer(sim, state, batch=4)
    for i in range(5):
        srv.submit(np.zeros(16, np.float32),
                   slo="interactive" if i % 2 else "batch")
    srv.submit_insert(np.ones((1, 16), np.float32))
    srv.run()
    stats = srv.latency_stats()
    assert set(stats) == {"interactive", "batch", "mutation"}
    assert stats["interactive"]["n"] == 2 and stats["batch"]["n"] == 3
    for s in stats.values():
        assert 0 <= s["p50_us"] <= s["p99_us"]


# ---------------------------------------------------------------------------
# sharding resolver
# ---------------------------------------------------------------------------
def _mesh_16x16_abstract():
    # AbstractMesh-like resolution check without devices: use a tiny mesh
    # and a fake big one via spec_for's pure math (mesh only provides
    # axis names and sizes, so we use jax.sharding.AbstractMesh).
    from repro.launch.mesh import compat_abstract_mesh
    return compat_abstract_mesh((16, 16), ("data", "model"))


def test_resolver_divisibility_fallback():
    rules = ShardingRules()
    mesh = _mesh_16x16_abstract()
    # 12 heads on model=16: must NOT shard
    spec = rules.spec_for((1536, 12, 128),
                          ("embed", "heads", "head_dim"), mesh)
    assert spec == jax.sharding.PartitionSpec()
    # d_ff 8960 shards fine
    spec = rules.spec_for((1536, 8960), ("embed", "mlp"), mesh)
    assert spec == jax.sharding.PartitionSpec(None, "model")


def test_resolver_no_double_axis_use():
    rules = ShardingRules()
    mesh = _mesh_16x16_abstract()
    # both dims want 'model': only one (higher priority) gets it
    spec = rules.spec_for((4096, 4096), ("mlp", "vocab"), mesh)
    got = [s for s in spec if s is not None]
    assert got.count("model") <= 1


def test_resolver_cam_rules():
    """cam_bank/cam_query resolve on a CAM mesh and stay silent on the
    LM meshes (no 'bank'/'query' axes there)."""
    from repro.launch.mesh import compat_abstract_mesh
    rules = ShardingRules()
    cam_mesh = compat_abstract_mesh((4, 2), ("bank", "query"))
    spec = rules.spec_for((8, 2, 16, 16),
                          ("cam_bank", None, "cam_row", "cam_col"),
                          cam_mesh)
    assert spec == jax.sharding.PartitionSpec("bank")
    qspec = rules.spec_for((6, 2, 16), ("cam_query", None, None), cam_mesh)
    assert qspec == jax.sharding.PartitionSpec("query")
    # nv=3 does not divide bank=4: replicated, never a crash
    assert rules.spec_for((3, 2, 16, 16),
                          ("cam_bank", None, None, None),
                          cam_mesh) == jax.sharding.PartitionSpec()
    # LM mesh: cam axes silently replicate
    lm = _mesh_16x16_abstract()
    assert rules.spec_for((8, 2, 16, 16),
                          ("cam_bank", None, None, None),
                          lm) == jax.sharding.PartitionSpec()


def test_resolver_kv_seq_takes_data_when_batch_cannot():
    rules = ShardingRules()
    mesh = _mesh_16x16_abstract()
    # batch=1 long-context: kv_seq gets model AND data
    spec = rules.spec_for((36, 1, 524288, 8, 128),
                          ("layers", "batch", "kv_seq", "kv_heads",
                           "head_dim"), mesh)
    flat = []
    for s in spec:
        if isinstance(s, tuple):
            flat += list(s)
        elif s:
            flat.append(s)
    assert "model" in flat and "data" in flat


def test_resolver_fsdp_on_params():
    rules = ShardingRules()
    mesh = _mesh_16x16_abstract()
    spec = rules.spec_for((4096, 14336), ("embed", "mlp"), mesh,
                          fsdp=True)
    flat = []
    for s in spec:
        if isinstance(s, tuple):
            flat += list(s)
        elif s:
            flat.append(s)
    assert "data" in flat and "model" in flat


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_determinism_and_range():
    d = SyntheticLM(vocab_size=1000, seq_len=16, global_batch=4, seed=3)
    b1, b2 = d.batch(7), d.batch(7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert (np.asarray(b1["tokens"]) < 1000).all()
    b3 = d.batch(8)
    assert np.abs(np.asarray(b3["tokens"]) -
                  np.asarray(b1["tokens"])).max() > 0
    # restart-from-state reproduces the stream
    d2 = SyntheticLM.from_state(d.state(7))
    np.testing.assert_array_equal(np.asarray(d2.batch(7)["tokens"]),
                                  np.asarray(b1["tokens"]))


def test_train_microbatch_equivalence():
    """Grad accumulation over k microbatches == one big batch (same data)."""
    cfg = get_config("qwen2-1.5b").reduced().replace(dtype="float32")
    import dataclasses
    import repro.models.layers as L
    from repro import models
    opt = AdamW(lr=constant(1e-2), clip_norm=None)
    spec = models.model_specs(cfg)
    spec = L.tree_map_specs(
        lambda p: dataclasses.replace(p, dtype=jnp.float32), spec)
    params = L.init_params(KEY, spec)
    from repro.runtime.train_loop import TrainState
    state = TrainState(step=jnp.zeros((), jnp.int32), params=params,
                       opt=opt.init(params))
    data = SyntheticLM(cfg.vocab_size, 16, 8, seed=0)
    batch = data.batch(0)
    s1, m1 = jax.jit(make_train_step(cfg, opt))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, opt, microbatch=4))(state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-4)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=5e-5),
        s1.params, s2.params)
