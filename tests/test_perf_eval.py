"""Performance evaluator: Table IV calibration + structural properties."""
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import CAMASim, estimate_arch, predict_search, predict_write
from repro.core.validation import TARGETS


@pytest.mark.parametrize("target", TARGETS, ids=lambda t: t.name)
def test_table4_within_8pct(target):
    sim = CAMASim(target.config)
    sim.write(jnp.zeros((target.K, target.N)))
    perf = sim.eval_perf(ops_per_query=target.ops_per_query,
                         clock_hz=target.clock_hz)
    assert perf["latency_ns"] == pytest.approx(target.sim_latency_ns,
                                               rel=0.08)
    assert perf["energy_pj"] == pytest.approx(target.sim_energy_pj,
                                              rel=0.08)


# Golden snapshot of the CURRENT calibration (rel=1e-6, far tighter than
# the ±8% paper band): estimator refactors that silently shift the Table IV
# rollup must fail here loudly instead of drifting inside the tolerance.
# A deliberate recalibration regenerates these from
# CAMASim.eval_perf(ops_per_query=t.ops_per_query, clock_hz=t.clock_hz)
# per target (latency_ns, energy_pj, area_um2).
_TABLE4_GOLDEN = {
    "DRL [4]": (946.6666666666667, 44681541.58538784, 698887.2811836092),
    "MANN [8]": (6.255124060521206, 17.672045870958204, 8367.636229702011),
    "HDC [7]": (12.786644524378557, 252.33384877314623, 19673.19192773514),
}


@pytest.mark.parametrize("target", TARGETS, ids=lambda t: t.name)
def test_table4_golden_snapshot(target):
    lat, en, area = _TABLE4_GOLDEN[target.name]
    sim = CAMASim(target.config)
    sim.write(jnp.zeros((target.K, target.N)))
    perf = sim.eval_perf(ops_per_query=target.ops_per_query,
                         clock_hz=target.clock_hz)
    assert perf["latency_ns"] == pytest.approx(lat, rel=1e-6)
    assert perf["energy_pj"] == pytest.approx(en, rel=1e-6)
    assert perf["area_um2"] == pytest.approx(area, rel=1e-6)


def test_edp_aj_s_unit_conversion():
    """pJ*ns = 1e-21 J*s = 1e-3 aJ*s (regression: an extra *1e-9 used to
    contradict the property's own comment)."""
    from repro.core.perf.estimator import PerfResult
    known = PerfResult(latency_ns=2.0, energy_pj=3.0, area_um2=1.0)
    assert known.edp == 6.0
    assert known.edp_aj_s == pytest.approx(6e-3, rel=1e-12)
    for lat, en in ((0.5, 80.0), (946.7, 4.5e7), (12.8, 252.3)):
        r = PerfResult(latency_ns=lat, energy_pj=en, area_um2=0.0)
        assert r.edp_aj_s == r.edp * 1e-3


def test_arch_estimation_counts():
    from repro.core.validation import DRL, HDC, MANN
    for t, n_sub in ((DRL, 64), (MANN, 8), (HDC, 16)):
        arch = estimate_arch(t.config, t.K, t.N)
        assert arch.n_subarrays == n_sub, (t.name, arch.n_subarrays)


@given(st.integers(8, 256), st.integers(8, 256), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_latency_monotone_in_subarray_size(rows, cols, i):
    """Bigger subarrays -> longer search (parasitics; paper §IV-B1)."""
    t = TARGETS[i % len(TARGETS)]
    cfg1 = t.config.replace(circuit=dict(rows=rows, cols=cols))
    cfg2 = t.config.replace(circuit=dict(rows=rows, cols=cols * 2))
    a1 = estimate_arch(cfg1, rows, cols)
    a2 = estimate_arch(cfg2, rows, cols * 2)
    p1 = predict_search(cfg1, a1)
    p2 = predict_search(cfg2, a2)
    assert p2.latency_ns > p1.latency_ns


@given(st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_energy_scales_with_store_size(mult):
    """More stored entries -> proportionally more subarrays -> energy."""
    from repro.core.validation import MANN
    cfg = MANN.config
    K, N = 32, 512
    a1 = estimate_arch(cfg, K, N)
    a2 = estimate_arch(cfg, K * mult, N)
    p1 = predict_search(cfg, a1)
    p2 = predict_search(cfg, a2)
    assert p2.energy_pj >= p1.energy_pj
    assert a2.n_subarrays == a1.n_subarrays * mult


def test_write_perf_positive_and_serial_in_rows():
    from repro.core.validation import MANN
    cfg = MANN.config
    a = estimate_arch(cfg, 32, 512)
    w = predict_write(cfg, a)
    assert w.latency_ns > 0 and w.energy_pj > 0
    cfg2 = cfg.replace(circuit=dict(rows=64))
    a2 = estimate_arch(cfg2, 64, 512)
    w2 = predict_write(cfg2, a2)
    assert w2.latency_ns > w.latency_ns


def test_area_includes_peripherals():
    from repro.core.validation import HDC
    arch = estimate_arch(HDC.config, HDC.K, HDC.N)
    p = predict_search(HDC.config, arch)
    sub_area = p.breakdown["subarray"]["area_um2"]
    assert p.area_um2 > sub_area  # peripherals + interconnect add area


def test_unknown_device_raises():
    from repro.core.perf.devices import get_cell_model
    with pytest.raises(KeyError):
        get_cell_model("unobtainium", "tcam", 1)


def test_register_custom_cell_model():
    from repro.core.perf.devices import (CellModel, get_cell_model,
                                         register_cell_model)
    m = CellModel(t_base=1, t_wl=0, t_ml=0, t_sa=0, e_cell=1, e_pre=0,
                  e_sa=0, t_wr_row=1, e_wr_cell=1, a_cell=1, a_sa=0,
                  a_drv=0)
    register_cell_model("cmos", "mcam", 4, m)
    assert get_cell_model("cmos", "mcam", 4) is m
