"""Performance evaluator: Table IV calibration + structural properties."""
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import CAMASim, estimate_arch, predict_search, predict_write
from repro.core.validation import TARGETS


@pytest.mark.parametrize("target", TARGETS, ids=lambda t: t.name)
def test_table4_within_8pct(target):
    sim = CAMASim(target.config)
    sim.write(jnp.zeros((target.K, target.N)))
    perf = sim.eval_perf(ops_per_query=target.ops_per_query,
                         clock_hz=target.clock_hz)
    assert perf["latency_ns"] == pytest.approx(target.sim_latency_ns,
                                               rel=0.08)
    assert perf["energy_pj"] == pytest.approx(target.sim_energy_pj,
                                              rel=0.08)


def test_arch_estimation_counts():
    from repro.core.validation import DRL, HDC, MANN
    for t, n_sub in ((DRL, 64), (MANN, 8), (HDC, 16)):
        arch = estimate_arch(t.config, t.K, t.N)
        assert arch.n_subarrays == n_sub, (t.name, arch.n_subarrays)


@given(st.integers(8, 256), st.integers(8, 256), st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_latency_monotone_in_subarray_size(rows, cols, i):
    """Bigger subarrays -> longer search (parasitics; paper §IV-B1)."""
    t = TARGETS[i % len(TARGETS)]
    cfg1 = t.config.replace(circuit=dict(rows=rows, cols=cols))
    cfg2 = t.config.replace(circuit=dict(rows=rows, cols=cols * 2))
    a1 = estimate_arch(cfg1, rows, cols)
    a2 = estimate_arch(cfg2, rows, cols * 2)
    p1 = predict_search(cfg1, a1)
    p2 = predict_search(cfg2, a2)
    assert p2.latency_ns > p1.latency_ns


@given(st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_energy_scales_with_store_size(mult):
    """More stored entries -> proportionally more subarrays -> energy."""
    from repro.core.validation import MANN
    cfg = MANN.config
    K, N = 32, 512
    a1 = estimate_arch(cfg, K, N)
    a2 = estimate_arch(cfg, K * mult, N)
    p1 = predict_search(cfg, a1)
    p2 = predict_search(cfg, a2)
    assert p2.energy_pj >= p1.energy_pj
    assert a2.n_subarrays == a1.n_subarrays * mult


def test_write_perf_positive_and_serial_in_rows():
    from repro.core.validation import MANN
    cfg = MANN.config
    a = estimate_arch(cfg, 32, 512)
    w = predict_write(cfg, a)
    assert w.latency_ns > 0 and w.energy_pj > 0
    cfg2 = cfg.replace(circuit=dict(rows=64))
    a2 = estimate_arch(cfg2, 64, 512)
    w2 = predict_write(cfg2, a2)
    assert w2.latency_ns > w.latency_ns


def test_area_includes_peripherals():
    from repro.core.validation import HDC
    arch = estimate_arch(HDC.config, HDC.K, HDC.N)
    p = predict_search(HDC.config, arch)
    sub_area = p.breakdown["subarray"]["area_um2"]
    assert p.area_um2 > sub_area  # peripherals + interconnect add area


def test_unknown_device_raises():
    from repro.core.perf.devices import get_cell_model
    with pytest.raises(KeyError):
        get_cell_model("unobtainium", "tcam", 1)


def test_register_custom_cell_model():
    from repro.core.perf.devices import (CellModel, get_cell_model,
                                         register_cell_model)
    m = CellModel(t_base=1, t_wl=0, t_ml=0, t_sa=0, e_cell=1, e_pre=0,
                  e_sa=0, t_wr_row=1, e_wr_cell=1, a_cell=1, a_sa=0,
                  a_drv=0)
    register_cell_model("cmos", "mcam", 4, m)
    assert get_cell_model("cmos", "mcam", 4) is m
