"""Smoke tests: each refactored example's ``main()`` runs end to end.

Run in subprocesses (the examples are scripts, not importable from the
test env's path) with the repo's src + examples on PYTHONPATH; marked
``slow`` — they pay a full jax import and real model/simulator work.
"""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_example(name: str, *args: str, timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    script = os.path.join(_ROOT, "examples", name)
    return subprocess.run([sys.executable, script, *args], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_quickstart_example_runs():
    proc = _run_example("quickstart.py")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "top-3 matches per query" in proc.stdout
    assert "search latency" in proc.stdout


@pytest.mark.slow
def test_long_context_retrieval_example_runs():
    proc = _run_example("long_context_retrieval.py")
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK: CAM best-match retrieval recovered the needle" \
        in proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("config", ["functional.json", "sharded.json",
                                    "serve.json"])
def test_camasim_run_cli_executes_checked_in_configs(config):
    """The camasim-run entry point drives a checked-in JSON config end to
    end (functional sim + perf report as JSON on stdout); the sharded
    config runs on a forced 2-host-device mesh."""
    import json

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    if config == "sharded.json":
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    else:
        env.pop("XLA_FLAGS", None)
    cfg_path = os.path.join(_ROOT, "examples", "configs", config)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.cli", cfg_path, "--queries", "4"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    report = json.loads(proc.stdout)
    assert report["latency_ns"] > 0 and report["area_um2"] > 0
    assert set(report) >= {"arch", "search", "latency_ns", "energy_pj",
                           "area_um2", "edp_pj_ns"}


@pytest.mark.slow
def test_camasim_run_cli_autotune_mode(tmp_path):
    """--autotune ranks the deployment space on the estimator alone and
    writes the winning config next to the input (copied to a tmp dir so
    the tuned JSON never lands in the repo)."""
    import json
    import shutil

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("XLA_FLAGS", None)
    src = os.path.join(_ROOT, "examples", "configs", "autotune.json")
    cfg_path = str(tmp_path / "autotune.json")
    shutil.copy(src, cfg_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.cli", cfg_path, "--autotune",
         "--entries", "256", "--dims", "32", "--queries", "8"],
        env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    summary = json.loads(proc.stdout)
    assert summary["candidates"] > 1
    assert set(summary["best"]) == {"knobs", "metrics"}
    assert summary["best"]["metrics"]["edp_pj_ns"] > 0
    assert "candidates ranked by edp" in proc.stderr
    tuned = tmp_path / "autotune.tuned.json"
    assert str(tuned) == summary["tuned_config"]
    assert tuned.exists()
    # the tuned config is a complete experiment, loadable as-is
    tuned_cfg = json.loads(tuned.read_text())
    assert set(tuned_cfg) == {"app", "arch", "circuit", "device", "sim"}


@pytest.mark.slow
@pytest.mark.parametrize("args", [(), ("--kernel",)])
def test_acam_decision_tree_example_runs(args):
    """X-TIME-style decision-tree inference, on both the jnp broadcast
    path and the fused batched ACAM range Pallas kernel."""
    proc = _run_example("acam_decision_tree.py", *args)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK: one ACAM search == full decision-tree inference." \
        in proc.stdout
    if args:
        assert "fused range kernel" in proc.stdout
