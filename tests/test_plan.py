"""Query compiler + estimator autotuner (``core.plan``).

Four guarantee layers:

* the compiled decision tree is BIT-IDENTICAL to the historical hand
  lowering of ``examples/acam_decision_tree.py`` — same written grid,
  same indices/mask, same predictions — on the functional backend (jnp
  and fused-kernel paths) and, in a 2-host-device subprocess, on the
  sharded backend;
* every lowering (DNF predicates, point CAM, trees, ensembles, aligned
  and multi-pass placements) agrees with the pure-numpy reference
  semantics ``ir.evaluate``;
* ``autotune`` is exactly the exhaustive estimator sweep: its argmin
  matches a hand-rolled loop over the same pinned space, and the sweep
  never writes (counting stubs on both backends' ``write``);
* ``predict_schedule`` bills a multi-pass schedule as the SUM of the
  per-pass ``perf_report`` predictions (one pass == the plain report,
  key for key), and ``sim.q_tile`` validates on the power-of-two ladder
  without changing search results.
"""
import itertools
import math
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig, FunctionalSimulator,
                        ShardedCAMSimulator, SimConfig, estimate_arch,
                        predict_schedule)
from repro.core.perf import MeshSpec, perf_report, predict_write
from repro.core.plan import (And, Band, Ensemble, Or, Point, autotune,
                             evaluate, lower, tree_from_paths)

N_FEAT = 6


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _acam_cfg(use_kernel=False, rows=8, **sim):
    return CAMConfig(
        app=AppConfig(distance="range", match_type="exact", match_param=1,
                      data_bits=0),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=rows, cols=8, cell_type="acam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig(use_kernel=use_kernel, **sim))


def _tile_paths(n_feat=N_FEAT, depth=3, seed=0, n_labels=2):
    """Random leaves that TILE [0,1]^n (recursive splits), as
    (lo, hi, label) triples — the example's ``tree_paths`` shape."""
    rng = np.random.default_rng(seed)
    paths = []

    def split(lo, hi, d):
        if d == 0:
            paths.append((lo.copy(), hi.copy(),
                          int(rng.integers(0, n_labels))))
            return
        f = int(rng.integers(0, n_feat))
        span = hi[f] - lo[f]
        t = float(rng.uniform(lo[f] + 0.2 * span, hi[f] - 0.2 * span))
        hi2 = hi.copy()
        hi2[f] = t
        split(lo, hi2, d - 1)
        lo2 = lo.copy()
        lo2[f] = t
        split(lo2, hi, d - 1)

    split(np.zeros(n_feat), np.ones(n_feat), depth)
    return paths


# ---------------------------------------------------------------------------
# bit-identity to the historical hand lowering
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "kernel"])
def test_compiled_tree_bit_identical_to_hand_lowering(use_kernel):
    """``CAMASim.compile(tree)`` reproduces what the example used to
    hand-roll, bit for bit: same written grid, same SearchResult, same
    ``labels[max(idx[:, 0], 0)]`` predictions."""
    paths = _tile_paths()
    sim = CAMASim(_acam_cfg(use_kernel=use_kernel))
    rng = np.random.default_rng(1)
    X = jnp.asarray(rng.uniform(0, 1, (40, N_FEAT)).astype(np.float32))

    # the historical hand lowering, verbatim
    lo = jnp.asarray(np.stack([p[0] for p in paths]), jnp.float32)
    hi = jnp.asarray(np.stack([p[1] for p in paths]), jnp.float32)
    labels = np.asarray([p[2] for p in paths])
    state = sim.write(jnp.stack([lo, hi], axis=-1))
    idx, mask = sim.query(state, X)
    hand_pred = labels[np.maximum(np.asarray(idx[:, 0]), 0)]

    compiled = sim.compile(tree_from_paths(paths)).write()
    assert len(compiled.states) == 1          # single tree: dense, 1 pass
    assert compiled.schedule.passes[0].rows == len(paths)   # no filler
    np.testing.assert_array_equal(np.asarray(compiled.states[0].grid),
                                  np.asarray(state.grid))
    res = compiled.query_raw(X)[0]
    np.testing.assert_array_equal(np.asarray(res.indices), np.asarray(idx))
    np.testing.assert_array_equal(np.asarray(res.mask), np.asarray(mask))
    np.testing.assert_array_equal(compiled.run(X), hand_pred)


_SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ["JAX_PLATFORMS"] = "cpu"
import numpy as np, jax.numpy as jnp
from repro.core import CAMASim
from repro.core.plan import evaluate, tree_from_paths
from test_plan import _acam_cfg, _tile_paths

paths = _tile_paths(depth=4, seed=3)        # 16 leaves -> 4 banks of 4 rows
prog = tree_from_paths(paths)
rng = np.random.default_rng(4)
X = jnp.asarray(rng.uniform(0, 1, (30, 6)).astype(np.float32))

fun = CAMASim(_acam_cfg(rows=4)).compile(prog)
sh = CAMASim(_acam_cfg(rows=4, backend="sharded", devices=2)).compile(prog)
rf, rs = fun.query_raw(X)[0], sh.query_raw(X)[0]
np.testing.assert_array_equal(np.asarray(rf.mask), np.asarray(rs.mask))
np.testing.assert_array_equal(np.asarray(rf.indices),
                              np.asarray(rs.indices))
np.testing.assert_array_equal(fun.run(X), sh.run(X))
np.testing.assert_array_equal(sh.run(X), evaluate(prog, np.asarray(X)))
print("SHARDED-BIT-IDENTICAL")
'''


@pytest.mark.slow
def test_compiled_tree_sharded_backend_bit_identical():
    """The same compiled schedule on ``backend='sharded'`` (2 forced host
    devices) returns bit-identical masks/indices/predictions."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         os.path.dirname(__file__)])
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED-BIT-IDENTICAL" in proc.stdout


# ---------------------------------------------------------------------------
# lowerings vs the reference semantics
# ---------------------------------------------------------------------------
def test_dnf_predicate_matches_oracle():
    prog = Or(And(Band(0, 0.2, 0.8), Band(1, hi=0.5)),
              And(Band(2, 0.6), Band(0, hi=0.3)),
              Band(4, 0.9))
    sim = CAMASim(_acam_cfg())
    compiled = sim.compile(prog, n_features=N_FEAT)
    assert compiled.schedule.kind == "match"
    assert compiled.schedule.passes[0].rows == 3   # one row per conjunction
    rng = np.random.default_rng(2)
    X = rng.uniform(0, 1, (64, N_FEAT)).astype(np.float32)
    np.testing.assert_array_equal(compiled.run(jnp.asarray(X)),
                                  evaluate(prog, X))


def test_infeasible_conjunction_never_matches():
    # Band(0, 0.7, inf) AND Band(0, -inf, 0.3) is empty -> lo > hi row
    prog = Or(And(Band(0, lo=0.7), Band(0, hi=0.3)), Band(1, 0.4, 0.6))
    sim = CAMASim(_acam_cfg())
    compiled = sim.compile(prog, n_features=N_FEAT)
    rng = np.random.default_rng(5)
    X = rng.uniform(0, 1, (50, N_FEAT)).astype(np.float32)
    got = compiled.run(jnp.asarray(X))
    np.testing.assert_array_equal(got, evaluate(prog, X))
    # and the empty row really contributed nothing
    np.testing.assert_array_equal(got, evaluate(Band(1, 0.4, 0.6), X))


def test_point_cam_or_of_points_matches_oracle():
    cfg = CAMConfig(
        app=AppConfig(distance="hamming", match_type="exact", match_param=0,
                      data_bits=2),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig())
    pts = [(0.0, 1.0, 2.0, 3.0), (3.0, 2.0, 1.0, 0.0),
           (1.0, 1.0, 2.0, 2.0)]
    prog = Or([Point(p) for p in pts])
    compiled = CAMASim(cfg).compile(prog)
    assert not compiled.schedule.range_mode
    X = np.asarray(pts[:2] + [(0.0, 0.0, 0.0, 0.0), (2.0, 1.0, 2.0, 2.0)],
                   np.float32)
    got = compiled.run(jnp.asarray(X))
    np.testing.assert_array_equal(got, evaluate(prog, X))
    assert got.tolist() == [True, True, False, False]


def test_ensemble_aligned_placement_and_majority_vote():
    trees = [tree_from_paths(_tile_paths(n_feat=4, depth=2, seed=s,
                                         n_labels=3))
             for s in (10, 11, 12)]
    prog = Ensemble(trees)
    sim = CAMASim(_acam_cfg())
    compiled = sim.compile(prog)
    sched = compiled.schedule
    assert sched.kind == "ensemble" and sched.n_groups == 3
    # multi-group range schedule bank-aligns by default: every group
    # starts on a subarray-row boundary, gaps are unmatchable filler
    R = sim.config.circuit.rows
    groups = sched.passes[0].groups
    for g in range(3):
        assert np.where(groups == g)[0][0] % R == 0
    filler = sched.passes[0].stored[groups == -1]
    assert (filler[..., 0] > filler[..., 1]).all()   # lo > hi: never match
    rng = np.random.default_rng(6)
    X = rng.uniform(0, 1, (48, 4)).astype(np.float32)
    np.testing.assert_array_equal(compiled.run(jnp.asarray(X)),
                                  evaluate(prog, X))


def test_multi_pass_packing_matches_single_pass_and_oracle():
    trees = [tree_from_paths(_tile_paths(n_feat=4, depth=2, seed=s,
                                         n_labels=3))
             for s in (20, 21, 22, 23, 24)]
    prog = Ensemble(trees)
    sim = CAMASim(_acam_cfg())
    one = sim.compile(prog)
    packed = sim.compile(prog, max_rows_per_pass=16)
    assert len(one.schedule.passes) == 1
    assert len(packed.schedule.passes) > 1
    assert all(p.rows <= 16 for p in packed.schedule.passes)
    # every group lands whole in exactly one pass
    seen = [set(p.groups[p.groups >= 0].tolist())
            for p in packed.schedule.passes]
    assert sorted(g for s_ in seen for g in s_) == list(range(5))
    rng = np.random.default_rng(7)
    X = rng.uniform(0, 1, (32, 4)).astype(np.float32)
    want = evaluate(prog, X)
    np.testing.assert_array_equal(one.run(jnp.asarray(X)), want)
    np.testing.assert_array_equal(packed.run(jnp.asarray(X)), want)


def test_oversized_group_still_gets_one_pass():
    prog = tree_from_paths(_tile_paths(depth=3, seed=8))   # 8 leaves
    sched = lower(prog, _acam_cfg(), max_rows_per_pass=4)
    assert len(sched.passes) == 1 and sched.passes[0].rows == 8


def test_lowering_rejections():
    acam = _acam_cfg()
    with pytest.raises(ValueError, match="exact match"):
        lower(Band(0, 0.1, 0.2),
              _acam_cfg().replace(app=dict(match_type="threshold")))
    point_cfg = CAMConfig(
        app=AppConfig(distance="hamming", match_type="exact", match_param=0,
                      data_bits=2),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"), sim=SimConfig())
    with pytest.raises(ValueError, match="range CAM"):
        lower(tree_from_paths(_tile_paths(depth=1)), point_cfg)
    with pytest.raises(ValueError, match="OR-of-Point"):
        lower(Band(0, 0.1, 0.2), point_cfg)
    with pytest.raises(ValueError, match="bank alignment"):
        lower(Or(Point((0.0, 1.0)), Point((1.0, 0.0))), point_cfg,
              align_banks=True)
    with pytest.raises(ValueError, match="n_features"):
        lower(Band(3, 0.1, 0.2), acam, n_features=2)


# ---------------------------------------------------------------------------
# schedule billing == sum of per-pass predictions
# ---------------------------------------------------------------------------
def test_predict_schedule_is_sum_of_per_pass_reports():
    cfg = _acam_cfg()
    shapes = [(16, 6), (9, 6), (4, 6)]
    rep = predict_schedule(cfg, shapes, n_queries=5, queries_per_batch=3)
    per = [perf_report(cfg, estimate_arch(cfg, K, N), n_queries=5,
                       queries_per_batch=3) for K, N in shapes]
    for key in ("latency_ns", "energy_pj", "area_um2"):
        assert rep[key] == pytest.approx(sum(p[key] for p in per))
    assert rep["edp_pj_ns"] == pytest.approx(
        rep["latency_ns"] * rep["energy_pj"] / 5)
    assert len(rep["passes"]) == 3


def test_predict_schedule_one_pass_equals_plain_report():
    cfg = _acam_cfg()
    mesh = MeshSpec(2, "pcb")
    rep = predict_schedule(cfg, [(24, 6)], mesh=mesh, n_queries=7,
                           queries_per_batch=4)
    plain = perf_report(cfg, estimate_arch(cfg, 24, 6), mesh=mesh,
                        n_queries=7, queries_per_batch=4)
    for key in ("latency_ns", "energy_pj", "area_um2", "edp_pj_ns"):
        assert rep[key] == pytest.approx(plain[key])


def test_predict_schedule_include_write_bills_partial_rows():
    cfg = _acam_cfg()
    shapes = [(16, 6), (9, 6)]
    rep = predict_schedule(cfg, shapes, include_write=True)
    dry = predict_schedule(cfg, shapes, include_write=False)
    writes = [predict_write(cfg, estimate_arch(cfg, K, N), rows=K)
              for K, N in shapes]
    assert rep["write"].energy_pj == pytest.approx(
        sum(w.energy_pj for w in writes))
    assert rep["energy_pj"] == pytest.approx(
        dry["energy_pj"] + rep["write"].energy_pj)


def test_compiled_estimate_equals_predict_schedule():
    sim = CAMASim(_acam_cfg())
    compiled = sim.compile(
        Ensemble([tree_from_paths(_tile_paths(n_feat=4, depth=2, seed=s))
                  for s in (30, 31)]))
    got = compiled.estimate(queries_per_batch=4, n_queries=9)
    want = predict_schedule(sim.config, compiled.schedule.pass_shapes(),
                            queries_per_batch=4, n_queries=9)
    for key in ("latency_ns", "energy_pj", "area_um2", "edp_pj_ns"):
        assert got[key] == pytest.approx(want[key])


# ---------------------------------------------------------------------------
# autotune == exhaustive estimator sweep, zero writes
# ---------------------------------------------------------------------------
def _mcam_cfg():
    return CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=16, cols=16, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig())


def test_autotune_argmin_matches_hand_rolled_exhaustive_sweep(monkeypatch):
    """The ranked sweep IS the exhaustive loop: same argmin knobs/metric
    as an independently hand-rolled product over the same pinned space —
    and it never constructs a backend or writes."""
    writes = []
    monkeypatch.setattr(FunctionalSimulator, "write",
                        lambda self, *a, **k: writes.append("fun"))
    monkeypatch.setattr(ShardedCAMSimulator, "write",
                        lambda self, *a, **k: writes.append("sh"))
    cfg = _mcam_cfg()
    entries, dims, qpb = 128, 16, 8
    space = {"q_tile": [None, 32], "devices": [1, 2],
             "link": ["on_package", "pcb"], "top_p_banks": [None]}
    res = autotune(cfg, entries, dims, space=space, objective="latency",
                   queries_per_batch=qpb)
    assert writes == []

    best = None
    count = 0
    for q_tile, dev, link in itertools.product(
            [None, 32], [1, 2], ["on_package", "pcb"]):
        if dev <= 1 and link != "on_package":
            continue               # single chip: the link never fires
        cand = cfg.replace(sim=dict(
            q_tile=q_tile, c2c_query_tile=1,
            devices=dev if dev > 1 else 0, query_shards=1,
            backend="sharded" if dev > 1 else "functional",
            top_p_banks=None, signature_bits=0))
        cand.validate()
        rep = perf_report(cand, estimate_arch(cand, entries, dims),
                          mesh=MeshSpec(dev, link) if dev > 1 else None,
                          queries_per_batch=qpb)
        count += 1
        if best is None or rep["latency_ns"] < best[0]:
            best = (rep["latency_ns"], dict(q_tile=q_tile, devices=dev,
                                            link=link))
    assert len(res.candidates) == count
    assert res.best.metrics["latency_ns"] == pytest.approx(best[0])
    for k, v in best[1].items():
        assert res.best.knobs[k] == v
    # ranked ascending in the objective
    lats = [c.metrics["latency_ns"] for c in res.candidates]
    assert lats == sorted(lats)
    # the winning config is complete and loadable
    CAMASim(res.config)


def test_autotune_objectives_and_unknown_knob():
    cfg = _mcam_cfg()
    space = {"devices": [1], "link": ["on_package"]}
    by_energy = autotune(cfg, 64, 16, space=space, objective="energy")
    assert by_energy.best.metrics["energy_pj"] == min(
        c.metrics["energy_pj"] for c in by_energy.candidates)
    by_qps = autotune(cfg, 64, 16, space=space, objective="qps")
    assert by_qps.best.metrics["sim_qps"] == max(
        c.metrics["sim_qps"] for c in by_qps.candidates)
    with pytest.raises(ValueError, match="unknown sweep knobs"):
        autotune(cfg, 64, 16, space={"voltage": [1.2]})
    with pytest.raises(ValueError, match="objective"):
        autotune(cfg, 64, 16, objective="speed")


def test_autotune_table_and_facade_do_not_mutate_config():
    sim = CAMASim(_mcam_cfg())
    before = sim.config.to_json()
    res = sim.autotune(64, 16, space={"devices": [1, 2]},
                       queries_per_batch=4)
    assert sim.config.to_json() == before
    table = res.table(top=3)
    assert "lat_ns" in table and len(table.splitlines()) == 4


# ---------------------------------------------------------------------------
# sim.q_tile: ladder validation + result identity
# ---------------------------------------------------------------------------
def test_q_tile_validates_power_of_two_ladder():
    for q in (None, 1, 2, 4, 8, 16, 32, 64, 128, 256):
        SimConfig(q_tile=q)
    for q in (0, 3, 6, 48, 512, -8):
        with pytest.raises(ValueError, match="power of two"):
            SimConfig(q_tile=q)


@pytest.mark.parametrize("q_tile", [1, 4, 64])
def test_q_tile_identical_results_on_kernel_path(q_tile):
    """An explicit query tile re-chunks the fused kernel's batch loop but
    never changes what it computes."""
    base = CAMASim(_mcam_cfg().replace(sim=dict(use_kernel=True)))
    tiled = CAMASim(_mcam_cfg().replace(sim=dict(use_kernel=True,
                                                 q_tile=q_tile)))
    rng = np.random.default_rng(9)
    stored = jnp.asarray(rng.uniform(0, 1, (20, 8)).astype(np.float32))
    queries = jnp.asarray(rng.uniform(0, 1, (10, 8)).astype(np.float32))
    rb = base.query(base.write(stored), queries)
    rt = tiled.query(tiled.write(stored), queries)
    np.testing.assert_array_equal(np.asarray(rb.indices),
                                  np.asarray(rt.indices))
    np.testing.assert_array_equal(np.asarray(rb.mask), np.asarray(rt.mask))
