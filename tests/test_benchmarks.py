"""Benchmark harness sanity: Table IV calibration via the bench path and
Fig 4/5 trend checks on minimal sweeps."""
import pytest


def test_table4_bench_fast():
    from benchmarks.table4_validation import run
    rows, _ = run(fast=True)
    assert len(rows) == 3
    for name, lat, plat, dl, en, pen, de, _ in rows:
        assert abs(dl) < 8.0, (name, dl)
        assert abs(de) < 8.0, (name, de)


def test_sharded_perf_sweep_rows():
    """The mesh perf sweep emits one row per (d, match) point; the d=1
    point is the single-chip prediction exactly (no mesh tax) and the
    payload carries the fields README documents."""
    from benchmarks.sharded_perf import DEVICE_SWEEP, sweep
    rows = sweep()
    names = [name for name, _, _ in rows]
    for match in ("exact", "best", "threshold"):
        for d in DEVICE_SWEEP:
            assert f"perf_sharded_d{d}_{match}" in names
    assert len(rows) == 3 * len(DEVICE_SWEEP)

    def field(derived, key):
        return derived.split(f"{key}=")[1].split("_")[0]

    for name, _, derived in rows:
        assert float(field(derived, "lat_ns")) > 0, name
        assert float(field(derived, "bytes_dev")) > 0, name
        assert "link=on_package" in derived, name
        if name.startswith("perf_sharded_d1_"):
            # d=1: sharded prediction degenerates to the 1-chip reference
            assert field(derived, "lat_ns") == field(derived,
                                                     "lat_1chip_ns"), name
            assert field(derived, "energy_pj") == field(
                derived, "energy_1chip_pj"), name


def test_check_floors_latency_and_parity_guards():
    """CI gate semantics: match=False fails, recall below floor fails,
    serve p99 above its declared floor_p99_us ceiling fails; rows without
    those fields (or within bounds) pass."""
    from benchmarks.run import check_floors
    ok = [
        {"name": "a", "us_per_call": 1.0, "derived": "match=True"},
        {"name": "b", "us_per_call": 1.0,
         "derived": "recall=0.95_floor=0.90"},
        {"name": "c", "us_per_call": 1.0,
         "derived": "p99_us=5000_floor_p99_us=2000000_match=True"},
        {"name": "d", "us_per_call": 1.0, "derived": "no_guards_here"},
    ]
    check_floors(ok)    # no raise
    for bad, msg in (
            ({"derived": "match=False"}, "match=False"),
            ({"derived": "recall=0.80_floor=0.90"}, "recall"),
            ({"derived": "p99_us=3000000_floor_p99_us=2000000"}, "p99")):
        with pytest.raises(RuntimeError, match=msg):
            check_floors(ok + [dict({"name": "x", "us_per_call": 0.0},
                                    **bad)])


def test_serve_bench_engine_rows_smoke(capsys, monkeypatch):
    """The serve-engine bench emits parseable CSV rows whose guard fields
    check_floors understands, with match=True on a healthy build."""
    import benchmarks.serve_bench as sb
    from benchmarks.run import check_floors
    monkeypatch.setattr(sb, "ENGINE_K", 256)
    monkeypatch.setattr(sb, "ENGINE_BATCH", 8)
    sb.main(backend="functional", tail=False)
    out = capsys.readouterr().out
    rows = []
    for line in out.splitlines():
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    names = {r["name"] for r in rows}
    assert {"serve_engine_p50p99_functional",
            "serve_inserts_functional"} <= names
    assert all("match=True" in r["derived"] for r in rows)
    assert any("floor_p99_us=" in r["derived"] for r in rows)
    assert any("inserts_per_s=" in r["derived"] for r in rows)
    check_floors(rows)  # guards hold on a healthy run


def test_autotune_bench_rows_smoke(capsys, monkeypatch):
    """The autotune bench's q_tile sweep is result-preserving: every
    measured candidate row carries match=True, and the rank summary is a
    parseable agree-count (reported, never floored)."""
    import re

    import benchmarks.autotune_bench as ab
    from benchmarks.run import check_floors
    monkeypatch.setattr(ab, "K", 256)
    monkeypatch.setattr(ab, "N", 16)
    monkeypatch.setattr(ab, "Q", 32)
    monkeypatch.setattr(ab, "REPS", 1)
    monkeypatch.setattr(ab, "Q_TILE_SPACE", (None, 8, 32))
    ab.main(backend="functional")
    out = capsys.readouterr().out
    rows = []
    for line in out.splitlines():
        name, us, derived = line.split(",", 2)
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": derived})
    cand = [r for r in rows if r["name"].startswith("autotune_cand_")]
    assert len(cand) == ab.TOP
    assert all("match=True" in r["derived"] for r in cand)
    assert all(re.search(r"pred_qps=\d+_meas_qps=\d+", r["derived"])
               for r in cand)
    summary = [r for r in rows if r["name"] == "autotune_rank_functional"]
    assert len(summary) == 1
    assert re.search(r"pairs_agree=\d+/\d+", summary[0]["derived"])
    check_floors(rows)  # the match= guard holds on a healthy run


@pytest.mark.slow
def test_fig4_trends_minimal():
    from benchmarks.fig4_sweep import check_trends, run
    res = run(dims=(64, 128), bits=(2, 3), cols=(64,), episodes=3,
              steps=120)
    tr = check_trends(res)
    assert tr["2b_worse_than_3b"]
    assert tr["edp_grows_with_dim"]


@pytest.mark.slow
def test_fig5_trends_minimal():
    from benchmarks.fig5_nonidealities import check_trends, run
    out = run(stds=(0.0, 2.0), sls=(0.0, 5.0), episodes=3, steps=120,
              cols=(64,))
    tr = check_trends(out)
    assert all(tr.values()), tr
