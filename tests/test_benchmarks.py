"""Benchmark harness sanity: Table IV calibration via the bench path and
Fig 4/5 trend checks on minimal sweeps."""
import pytest


def test_table4_bench_fast():
    from benchmarks.table4_validation import run
    rows, _ = run(fast=True)
    assert len(rows) == 3
    for name, lat, plat, dl, en, pen, de, _ in rows:
        assert abs(dl) < 8.0, (name, dl)
        assert abs(de) < 8.0, (name, de)


@pytest.mark.slow
def test_fig4_trends_minimal():
    from benchmarks.fig4_sweep import check_trends, run
    res = run(dims=(64, 128), bits=(2, 3), cols=(64,), episodes=3,
              steps=120)
    tr = check_trends(res)
    assert tr["2b_worse_than_3b"]
    assert tr["edp_grows_with_dim"]


@pytest.mark.slow
def test_fig5_trends_minimal():
    from benchmarks.fig5_nonidealities import check_trends, run
    out = run(stds=(0.0, 2.0), sls=(0.0, 5.0), episodes=3, steps=120,
              cols=(64,))
    tr = check_trends(out)
    assert all(tr.values()), tr
