"""Benchmark harness sanity: Table IV calibration via the bench path and
Fig 4/5 trend checks on minimal sweeps."""
import pytest


def test_table4_bench_fast():
    from benchmarks.table4_validation import run
    rows, _ = run(fast=True)
    assert len(rows) == 3
    for name, lat, plat, dl, en, pen, de, _ in rows:
        assert abs(dl) < 8.0, (name, dl)
        assert abs(de) < 8.0, (name, de)


def test_sharded_perf_sweep_rows():
    """The mesh perf sweep emits one row per (d, match) point; the d=1
    point is the single-chip prediction exactly (no mesh tax) and the
    payload carries the fields README documents."""
    from benchmarks.sharded_perf import DEVICE_SWEEP, sweep
    rows = sweep()
    names = [name for name, _, _ in rows]
    for match in ("exact", "best", "threshold"):
        for d in DEVICE_SWEEP:
            assert f"perf_sharded_d{d}_{match}" in names
    assert len(rows) == 3 * len(DEVICE_SWEEP)

    def field(derived, key):
        return derived.split(f"{key}=")[1].split("_")[0]

    for name, _, derived in rows:
        assert float(field(derived, "lat_ns")) > 0, name
        assert float(field(derived, "bytes_dev")) > 0, name
        assert "link=on_package" in derived, name
        if name.startswith("perf_sharded_d1_"):
            # d=1: sharded prediction degenerates to the 1-chip reference
            assert field(derived, "lat_ns") == field(derived,
                                                     "lat_1chip_ns"), name
            assert field(derived, "energy_pj") == field(
                derived, "energy_1chip_pj"), name


@pytest.mark.slow
def test_fig4_trends_minimal():
    from benchmarks.fig4_sweep import check_trends, run
    res = run(dims=(64, 128), bits=(2, 3), cols=(64,), episodes=3,
              steps=120)
    tr = check_trends(res)
    assert tr["2b_worse_than_3b"]
    assert tr["edp_grows_with_dim"]


@pytest.mark.slow
def test_fig5_trends_minimal():
    from benchmarks.fig5_nonidealities import check_trends, run
    out = run(stds=(0.0, 2.0), sls=(0.0, 5.0), episodes=3, steps=120,
              cols=(64,))
    tr = check_trends(out)
    assert all(tr.values()), tr
