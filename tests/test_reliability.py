"""Device reliability subsystem: fault injection, drift, self-healing.

The load-bearing guarantees:
  * ``reliability`` off (section absent OR ``enabled=False``) is
    BIT-IDENTICAL to the pre-reliability code — grids, queries, mutable
    store, both backends;
  * fault maps are deterministic functions of ``fault_seed`` keyed per
    global row slot, so the same config always injects the same faults
    and insert == fresh-write parity survives fault injection;
  * mitigation is invisible at the API: spare-row healing remaps failed
    rows without changing any returned id, and with noiseless writes the
    healed store answers EXACTLY like a fault-free one;
  * drift decays the sensed grid with logical age and scrubbing restores
    it, driven by the serve engine without perturbing the search RNG
    schedule;
  * the estimator bills write-verify retries and the scrub duty cycle
    only when the subsystem is on (the off-report stays key-for-key
    identical — Table IV golden safe).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CAMASim, CAMConfig
from repro.core.config import ReliabilityConfig
from repro.core.perf.estimator import (estimate_arch, expected_row_programs,
                                       perf_report, predict_scrub)
from repro.runtime.serve_loop import CAMSearchServer

jax.config.update("jax_platforms", "cpu")


def _cfg(backend="functional", variation="none", std=0.05, cell="mcam",
         rel=None, **sim):
    base = dict(capacity=40, c2c_fold="bank", d2d_fold="row",
                backend=backend)
    base.update(sim)
    d = dict(
        app=dict(distance="l2", match_type="best", match_param=1,
                 data_bits=3),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=8, cols=8, cell_type=cell, sensing="best"),
        device=dict(device="fefet", variation=variation,
                    variation_std=std),
        sim=base)
    if rel is not None:
        d["reliability"] = rel
    return CAMConfig.from_dict(d)


def _data(k=24, n=8, seed=0):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (k, n))
    return x.at[0].set(0.0).at[1].set(1.0)


WKEY = jax.random.PRNGKey(5)
QKEY = jax.random.PRNGKey(3)


def _q(q=6, n=8):
    return jax.random.uniform(jax.random.PRNGKey(9), (q, n))


def _run(cfg, stored=None, queries=None):
    sim = CAMASim(cfg)
    st = sim.write(stored if stored is not None else _data(), WKEY)
    idx, mask = sim.query(st, queries if queries is not None else _q(),
                          QKEY)
    return np.asarray(idx), np.asarray(mask), st


# ---------------------------------------------------------------------------
# off-switch bit-identity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [
    dict(),                                          # noiseless mcam
    dict(variation="both", std=0.3),                 # D2D + C2C noise
    dict(prefilter="signature", top_p_banks=2),      # search cascade
    dict(backend="sharded"),                         # 1-device sharded
])
def test_disabled_section_is_bit_identical(kw):
    sim_kw = {k: v for k, v in kw.items()
              if k in ("prefilter", "top_p_banks", "backend")}
    dev_kw = {k: v for k, v in kw.items() if k in ("variation", "std")}
    a = _run(_cfg(**dev_kw, **sim_kw))
    b = _run(_cfg(**dev_kw, **sim_kw,
                  rel=dict(enabled=False, stuck_frac=0.5,
                           dead_row_frac=0.5, drift_rate=1.0)))
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    np.testing.assert_array_equal(np.asarray(a[2].grid),
                                  np.asarray(b[2].grid))
    assert b[2].rel is None


def test_disabled_mutable_store_bit_identical():
    extra = jax.random.uniform(jax.random.PRNGKey(7), (4, 8))
    outs = []
    for rel in (None, dict(enabled=False, stuck_frac=0.9)):
        sim = CAMASim(_cfg(rel=rel))
        st = sim.write(_data(), WKEY)
        st, ids = sim.insert(st, extra, jax.random.PRNGKey(11))
        st = sim.delete(st, ids[:1])
        idx, mask = sim.query(st, _q(), QKEY)
        outs.append((np.asarray(ids), np.asarray(idx), np.asarray(mask)))
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_enabled_zero_faults_zero_verify_matches_legacy_grid():
    """All knobs zero: the verified-programming path's attempt-0 draw is
    EXACTLY the legacy per-slot noise, so the grid is bit-identical."""
    a = _run(_cfg(variation="d2d", std=0.3))
    b = _run(_cfg(variation="d2d", std=0.3, rel=dict(enabled=True)))
    np.testing.assert_array_equal(np.asarray(a[2].grid),
                                  np.asarray(b[2].grid))
    np.testing.assert_array_equal(a[0], b[0])


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------
def test_fault_maps_deterministic_in_fault_seed():
    rel = dict(enabled=True, stuck_frac=0.2, dead_row_frac=0.2)
    a = _run(_cfg(rel=dict(rel, fault_seed=1)))
    b = _run(_cfg(rel=dict(rel, fault_seed=1)))
    c = _run(_cfg(rel=dict(rel, fault_seed=2)))
    np.testing.assert_array_equal(a[0], b[0])
    assert not np.array_equal(a[0], c[0]) or not np.array_equal(a[1], c[1])


def test_all_rows_dead_nothing_matches():
    cfg = _cfg(rel=dict(enabled=True, dead_row_frac=1.0))
    cfg = cfg.replace(app=dict(match_type="threshold", match_param=2.0),
                      circuit=dict(sensing="threshold"),
                      arch=dict(v_merge="gather"))
    idx, mask, _ = _run(cfg)
    assert (mask == 0).all()


def test_faults_perturb_results_unmitigated():
    clean = _run(_cfg())
    faulty = _run(_cfg(rel=dict(enabled=True, dead_row_frac=0.5,
                                fault_seed=3)))
    assert not np.array_equal(clean[0], faulty[0])


def test_drift_decays_then_scrub_recovers():
    """Self-retrieval under heavy drift: aged store mismatches, scrubbed
    store answers exactly like the fresh one (noiseless writes)."""
    stored = _data()
    rel = dict(enabled=True, drift_rate=0.05, scrub_rows=40,
               verify_retries=1, verify_tol=0.4)
    sim = CAMASim(_cfg(rel=rel))
    st = sim.write(stored, WKEY)
    fresh_idx, _ = sim.query(st, stored, QKEY)
    aged = sim.age_tick(st, 60)
    aged_idx, _ = sim.query(aged, stored, QKEY)
    assert not np.array_equal(np.asarray(fresh_idx), np.asarray(aged_idx))
    healed = sim.scrub(aged, jax.random.PRNGKey(21))
    healed_idx, _ = sim.query(healed, stored, QKEY)
    np.testing.assert_array_equal(np.asarray(fresh_idx),
                                  np.asarray(healed_idx))


# ---------------------------------------------------------------------------
# mitigation
# ---------------------------------------------------------------------------
def test_spare_healing_invisible_noiseless():
    """Dead rows + write-verify + spares, noiseless writes: the healed
    store must answer EXACTLY like a fault-free store — same ids, same
    masks — because every failed row was remapped behind the perm.
    Spares are same-bank, so the store keeps per-bank head-room."""
    rel = dict(enabled=True, dead_row_frac=0.25, verify_retries=1,
               verify_tol=0.4, spares_per_bank=8, fault_seed=5)
    data = _data(5)
    clean = _run(_cfg(capacity=8), stored=data)
    healed = _run(_cfg(capacity=8, rel=rel), stored=data)
    np.testing.assert_array_equal(clean[0], healed[0])
    np.testing.assert_array_equal(clean[1], healed[1])
    assert int(np.asarray(healed[2].rel.retired).sum()) > 0


def test_insert_matches_fresh_write_under_reliability():
    base, extra = _data(16), jax.random.uniform(jax.random.PRNGKey(7),
                                                (6, 8))
    rel = dict(enabled=True, stuck_frac=0.02, dead_row_frac=0.1,
               verify_retries=2, verify_tol=0.3, spares_per_bank=4,
               fault_seed=9)
    cfg = _cfg(variation="d2d", std=0.2, rel=rel)
    sim = CAMASim(cfg)
    st_inc, _ = sim.insert(sim.write(base, WKEY), extra, WKEY)
    st_fresh = sim.write(jnp.concatenate([base, extra]), WKEY)
    ia, ma = sim.query(st_inc, _q(), QKEY)
    ib, mb = sim.query(st_fresh, _q(), QKEY)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))


def test_endurance_freeze_keeps_old_data():
    """A worn row (writes >= endurance_writes) freezes: updates burn
    retries but the cells keep the OLD values, so the old row still
    matches and the new one does not."""
    base = _data(16)
    rel = dict(enabled=True, endurance_writes=1, verify_retries=1,
               verify_tol=0.4)
    sim = CAMASim(_cfg(rel=rel))
    st = sim.write(base, WKEY)
    old_row = base[3:4]
    new_row = 1.0 - old_row
    st2 = sim.update(st, jnp.asarray([3]), new_row, jax.random.PRNGKey(31))
    idx, _ = sim.query(st2, old_row, QKEY)
    assert int(np.asarray(idx)[0, 0]) == 3      # old data still wins
    assert int(np.asarray(st2.rel.writes).reshape(-1)[3]) > 1


def test_wear_aware_free_slots_prefer_least_worn():
    rel = dict(enabled=True, endurance_writes=0, verify_retries=0)
    sim = CAMASim(_cfg(rel=rel))
    st = sim.write(_data(16), WKEY)
    # artificially wear one free slot; the allocator must skip past it
    worn_slot = 16
    from repro.core.reliability import ReliabilityState
    r = st.rel
    writes = r.writes.at[worn_slot // 8, worn_slot % 8].add(10)
    st = type(st)(grid=st.grid, lo=st.lo, hi=st.hi,
                  col_valid=st.col_valid, row_valid=st.row_valid,
                  spec=st.spec, sigs=st.sigs, sig_thr=st.sig_thr,
                  perm=st.perm, codes=st.codes,
                  rel=ReliabilityState(age=r.age, prog_age=r.prog_age,
                                       writes=writes, retired=r.retired,
                                       failed=r.failed))
    free = sim.backend.free_slots(st)
    assert free[0] == 17 and worn_slot == free[-1]


def test_retired_slots_never_reallocated():
    rel = dict(enabled=True, dead_row_frac=0.25, verify_retries=1,
               verify_tol=0.4, spares_per_bank=8, fault_seed=5)
    sim = CAMASim(_cfg(capacity=8, rel=rel))
    st = sim.write(_data(5), WKEY)
    retired = set(np.flatnonzero(np.asarray(st.rel.retired).reshape(-1)))
    assert retired
    free = set(int(s) for s in sim.backend.free_slots(st))
    assert not (free & retired)


def test_insert_ids_stay_valid_after_heal():
    """Ids returned by insert must name the inserted rows wherever they
    physically land (heal swaps the perm entry with the data)."""
    base, extra = _data(6), jax.random.uniform(jax.random.PRNGKey(7),
                                               (4, 8))
    rel = dict(enabled=True, dead_row_frac=0.3, verify_retries=1,
               verify_tol=0.4, spares_per_bank=8, fault_seed=13)
    sim = CAMASim(_cfg(capacity=16, rel=rel))
    st, ids = sim.insert(sim.write(base, WKEY), extra, WKEY)
    idx, _ = sim.query(st, extra, QKEY)
    np.testing.assert_array_equal(np.asarray(idx)[:, 0], np.asarray(ids))


# ---------------------------------------------------------------------------
# serve engine
# ---------------------------------------------------------------------------
def test_server_scrub_preserves_search_schedule():
    """Scrub runs on the mutation lane: with zero drift and noiseless
    re-programming the scrubbing server's answers are bit-identical to a
    non-scrubbing one — the search fold_in(key, step) schedule is
    untouched."""
    stored = _data()
    outs = []
    for scrub_every in (0, 3):
        rel = dict(enabled=True, scrub_every=scrub_every, scrub_rows=8)
        sim = CAMASim(_cfg(rel=rel))
        srv = CAMSearchServer(sim=sim, state=sim.write(stored, WKEY),
                              key=jax.random.PRNGKey(2), batch=4)
        reqs = [srv.submit(np.asarray(stored[i])) for i in range(8)]
        srv.run()
        outs.append([int(r.indices[0]) for r in reqs])
    assert outs[0] == outs[1]


def test_server_ages_store_every_step():
    rel = dict(enabled=True, drift_rate=0.01)
    sim = CAMASim(_cfg(rel=rel))
    srv = CAMSearchServer(sim=sim, state=sim.write(_data(), WKEY),
                          key=jax.random.PRNGKey(2))
    for _ in range(7):
        srv.step()                      # idle steps still age the store
    assert int(np.asarray(srv.state.rel.age)) == 7


# ---------------------------------------------------------------------------
# config + estimator
# ---------------------------------------------------------------------------
def test_config_round_trip_and_validation():
    cfg = _cfg(rel=dict(enabled=True, stuck_frac=0.1, verify_retries=2,
                        verify_tol=0.3, spares_per_bank=2, scrub_every=5,
                        drift_rate=0.02, endurance_writes=100,
                        fault_seed=42))
    cfg2 = CAMConfig.from_json(cfg.to_json())
    assert cfg2.reliability == cfg.reliability
    with pytest.raises(ValueError):
        ReliabilityConfig(stuck_frac=1.5)
    with pytest.raises(ValueError):
        ReliabilityConfig(verify_retries=-1)
    with pytest.raises(ValueError):
        # reliability + D2D variation requires the per-row-slot fold
        _cfg(variation="d2d", d2d_fold="grid",
             rel=dict(enabled=True)).validate()


def test_estimator_keys_gated_on_enabled():
    cfg_off = _cfg()
    cfg_on = _cfg(variation="d2d", std=0.2,
                  rel=dict(enabled=True, verify_retries=2, verify_tol=0.2,
                           scrub_every=10, scrub_rows=4))
    arch_off = estimate_arch(cfg_off, 256, 32)
    arch_on = estimate_arch(cfg_on, 256, 32)
    rep_off = perf_report(cfg_off, arch_off, include_write=True)
    rep_on = perf_report(cfg_on, arch_on, include_write=True)
    assert "expected_row_programs" not in rep_off
    assert "scrub" not in rep_off
    E = rep_on["expected_row_programs"]
    assert E > 1.0
    assert rep_on["scrub_energy_pj_per_step"] > 0
    # verified writes bill E row programs each
    assert (rep_on["write"].energy_pj
            == pytest.approx(rep_off["write"].energy_pj * E))


def test_expected_row_programs_model():
    assert expected_row_programs(_cfg(), 64) == 1.0
    # retries off -> exactly 1 even with faults configured
    cfg0 = _cfg(rel=dict(enabled=True, stuck_frac=0.1))
    assert expected_row_programs(cfg0, 64) == 1.0
    # huge tolerance + no hard faults -> no retries expected
    cfg1 = _cfg(variation="d2d", std=0.01,
                rel=dict(enabled=True, verify_retries=3, verify_tol=10.0))
    assert expected_row_programs(cfg1, 64) == pytest.approx(1.0)
    # zero tolerance + noise -> every attempt fails, 1 + retries
    cfg2 = _cfg(variation="d2d", std=0.5,
                rel=dict(enabled=True, verify_retries=3, verify_tol=0.0))
    assert expected_row_programs(cfg2, 64) == pytest.approx(4.0)
    # monotone in stuck fraction
    es = [expected_row_programs(
        _cfg(rel=dict(enabled=True, verify_retries=2, verify_tol=0.5,
                      stuck_frac=f)), 64) for f in (0.0, 0.01, 0.1)]
    assert es[0] <= es[1] <= es[2]


def test_predict_scrub_bills_partial_write():
    cfg = _cfg(rel=dict(enabled=True, scrub_rows=4, verify_retries=1,
                        verify_tol=0.2))
    arch = estimate_arch(cfg, 256, 32)
    s = predict_scrub(cfg, arch)
    assert s.energy_pj > 0 and s.latency_ns > 0
