"""Search cascade (bank prefilter + IVF routing): correctness battery.

Guarantee layers:
  * bit-identity: ``top_p_banks = nv`` (and ``prefilter='off'``) reproduce
    the full scan bit-for-bit across match types, kernel on/off, and the
    C2C bank fold — the cascade's disabled/degenerate modes cost nothing
    in fidelity;
  * permutation correctness: IVF clustered placement returns indices and
    masks in the caller's ORIGINAL row order (ties aside, asserted with a
    tie-free fp store);
  * routing properties: bank selections are nested in ``top_p_banks``
    (hypothesis), so recall is monotone; every query's best-scoring bank
    is always selected;
  * dispatch/tiling satellites: interpret-mode batches below
    ``SMALL_Q_CROSSOVER`` take the jnp reference path (and match the
    kernel path bitwise); ``default_q_tile`` reproduces the historical
    float (32) and hamming (8) defaults from the VMEM working-set formula;
  * estimator: ``searched_fraction=1.0 / prefilter_bits=0`` is bitwise
    the full-scan prediction; energy scales with the fraction; cascade
    configs auto-bill through ``cascade_billing``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import merge, prefilter
from repro.core.camasim import CAMASim
from repro.core.config import CAMConfig
from repro.core.mapping import cluster_permutation, grid_spec, placement_perm
from repro.core.perf import (cascade_billing, estimate_arch, perf_report,
                             predict_search, predict_search_sharded)
from repro.kernels import ops as kops
from repro.kernels.cam_search import SMALL_Q_CROSSOVER, default_q_tile


def _cfg(app=None, arch=None, circuit=None, device=None, sim=None):
    d = dict(
        app=dict(distance="l2", match_type="best", match_param=3,
                 data_bits=4),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=8, cols=8, cell_type="mcam", sensing="best"),
        device=dict(device="fefet", variation="none"),
        sim=dict(use_kernel=True))
    for k, v in (("app", app), ("arch", arch), ("circuit", circuit),
                 ("device", device), ("sim", sim)):
        if v:
            d[k].update(v)
    return CAMConfig.from_dict(d)


def _data(K=100, N=12, Q=9, seed=0):
    rng = np.random.default_rng(seed)
    stored = rng.normal(size=(K, N)).astype(np.float32)
    q = stored[rng.integers(0, K, Q)] + 0.01 * rng.normal(
        size=(Q, N)).astype(np.float32)
    return jnp.asarray(stored), jnp.asarray(q)


def _run(cfg, stored, queries, wkey=0, qkey=1):
    sim = CAMASim(cfg)
    state = sim.write(stored, jax.random.PRNGKey(wkey))
    idx, mask = sim.query(state, queries, jax.random.PRNGKey(qkey))
    return np.asarray(idx), np.asarray(mask), state


# ---------------------------------------------------------------------------
# bit-identity of the degenerate cascade
# ---------------------------------------------------------------------------
_COMBOS = [
    dict(app=dict(match_type="exact", distance="hamming", match_param=2),
         arch=dict(h_merge="and", v_merge="gather"),
         circuit=dict(sensing="exact", sensing_limit=0.5)),
    dict(app=dict(match_type="best", distance="l2"),
         arch=dict(h_merge="adder", v_merge="comparator")),
    dict(app=dict(match_type="best", distance="l2"),
         arch=dict(h_merge="voting", v_merge="comparator")),
    dict(app=dict(match_type="threshold", distance="l1", match_param=6),
         arch=dict(h_merge="adder", v_merge="gather"),
         circuit=dict(sensing="threshold")),
]


@pytest.mark.parametrize("combo", range(len(_COMBOS)))
@pytest.mark.parametrize("use_kernel", [False, True])
def test_top_p_nv_bit_identical(combo, use_kernel):
    c = _COMBOS[combo]
    stored, q = _data()
    base = _cfg(app=c.get("app"), arch=c.get("arch"),
                circuit=c.get("circuit"), sim=dict(use_kernel=use_kernel))
    i0, m0, st0 = _run(base, stored, q)
    nv = st0.spec.nv
    cas = base.replace(sim=dict(prefilter="signature", top_p_banks=nv))
    i1, m1, _ = _run(cas, stored, q)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(m0, m1)


def test_top_p_nv_bit_identical_c2c_bank_fold():
    stored, q = _data()
    base = _cfg(device=dict(variation="both", variation_std=0.1),
                sim=dict(c2c_fold="bank"))
    i0, m0, st0 = _run(base, stored, q)
    cas = base.replace(sim=dict(prefilter="signature",
                                top_p_banks=st0.spec.nv))
    i1, m1, _ = _run(cas, stored, q)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(m0, m1)


def test_cascade_c2c_grid_fold_rejected():
    cfg = _cfg(device=dict(variation="c2c", variation_std=0.1),
               sim=dict(prefilter="signature", top_p_banks=2,
                        c2c_fold="grid"))
    with pytest.raises(ValueError, match="c2c_fold"):
        CAMASim(cfg)


def test_ivf_top_p_nv_equals_top_p_none():
    """Same clustered placement either way: the bank budget alone must not
    change results when it covers every bank."""
    stored, q = _data()
    full = _cfg(sim=dict(prefilter="ivf", signature_bits=8))
    i0, m0, st0 = _run(full, stored, q)
    assert st0.perm is not None
    cas = full.replace(sim=dict(top_p_banks=st0.spec.nv))
    i1, m1, _ = _run(cas, stored, q)
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(m0, m1)


def test_ivf_placement_returns_original_indices():
    """Tie-free fp store: clustered placement must be invisible to the
    caller — identical indices AND mask to the unclustered store."""
    stored, q = _data(K=80, N=10, Q=7, seed=3)
    base = _cfg(app=dict(data_bits=0))    # fp: no quantization ties
    i0, m0, _ = _run(base, stored, q)
    ivf = base.replace(sim=dict(prefilter="ivf"))
    i1, m1, st1 = _run(ivf, stored, q)
    perm = np.asarray(st1.perm)
    assert sorted(perm.tolist()) == list(range(st1.spec.padded_K))
    np.testing.assert_array_equal(i0, i1)
    np.testing.assert_array_equal(m0, m1)


# ---------------------------------------------------------------------------
# routing properties
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6), st.integers(2, 10), st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_select_banks_nested_in_p(seed, nv, q):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.integers(0, 50, size=(q, nv)), jnp.int32)
    sel = [set(np.asarray(prefilter.select_banks(scores, p)).tolist())
           for p in range(1, nv + 1)]
    for a, b in zip(sel, sel[1:]):
        assert a <= b, (a, b)
    assert sel[-1] == set(range(nv))


@given(st.integers(0, 10 ** 6))
@settings(max_examples=20, deadline=None)
def test_select_banks_covers_every_querys_argmin(seed):
    rng = np.random.default_rng(seed)
    q, nv = 5, 9
    scores = jnp.asarray(rng.integers(0, 50, size=(q, nv)), jnp.int32)
    p = len(set(np.asarray(scores).argmin(-1).tolist()))
    sel = set(np.asarray(prefilter.select_banks(scores, p + 2)).tolist())
    for qi in range(q):
        row = np.asarray(scores)[qi]
        assert int(row.argmin()) in sel or \
            any(row[b] == row.min() for b in sel)


def test_recall_monotone_in_top_p():
    stored, q = _data(K=200, N=16, Q=6, seed=5)
    base = _cfg()
    i0, _, st0 = _run(base, stored, q)
    truth = [set(r[r >= 0].tolist()) for r in i0]
    last = -1.0
    for p in (1, 2, 4, st0.spec.nv):
        cas = base.replace(sim=dict(prefilter="ivf", top_p_banks=p))
        i1, _, _ = _run(cas, stored, q)
        rec = np.mean([len(set(r[r >= 0].tolist()) & t) / max(1, len(t))
                       for r, t in zip(i1, truth)])
        assert rec >= last - 1e-9, (p, rec, last)
        last = rec
    assert last >= 0.99     # full budget recovers the full scan (mod ties)


def test_cluster_permutation_is_permutation():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(57, 6)).astype(np.float32))
    perm = np.asarray(cluster_permutation(x, nv=5))
    assert sorted(perm.tolist()) == list(range(57))
    spec = grid_spec(57, 6, 8, 8)
    full = np.asarray(placement_perm(x, spec))
    assert sorted(full.tolist()) == list(range(spec.padded_K))
    # padding rows stay in place so row_valid_mask still holds
    np.testing.assert_array_equal(full[57:], np.arange(57, spec.padded_K))


# ---------------------------------------------------------------------------
# selected-bank merge helpers degenerate to the full-scan ones
# ---------------------------------------------------------------------------
def test_scatter_match_rows_identity_at_p_nv():
    rng = np.random.default_rng(1)
    row = jnp.asarray((rng.random((4, 6, 8)) < 0.3).astype(np.float32))
    out = merge.scatter_match_rows(row, jnp.arange(6), 6)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(merge.v_merge_gather(row)))


def test_selected_topk_matches_local_topk_at_arange():
    rng = np.random.default_rng(2)
    vals = jnp.asarray(rng.random((3, 5, 8)).astype(np.float32))
    for largest in (False, True):
        v0, i0 = merge.local_topk_candidates(vals, 7, largest=largest,
                                             row_offset=2 * 5 * 8)
        v1, i1 = merge.selected_topk(vals, 7, largest=largest,
                                     bank_ids=jnp.arange(5), bank_offset=10)
        np.testing.assert_array_equal(np.asarray(v0), np.asarray(v1))
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))


# ---------------------------------------------------------------------------
# small-Q dispatch + q_tile derivation satellites
# ---------------------------------------------------------------------------
def test_small_q_takes_reference_path(monkeypatch):
    """Interpret-mode batches below the crossover must never enter the
    Pallas kernels (BENCH: q1 kernel at 0.18x of the jnp path)."""
    def boom(*a, **k):
        raise AssertionError("Pallas kernel entered for a small batch")
    monkeypatch.setattr(kops, "cam_search_fused_pallas", boom)
    monkeypatch.setattr(kops, "cam_range_fused_pallas", boom)
    rng = np.random.default_rng(0)
    stored = jnp.asarray(rng.random((2, 2, 8, 8)).astype(np.float32))
    small = jnp.asarray(rng.random(
        (SMALL_Q_CROSSOVER - 1, 2, 8)).astype(np.float32))
    d, m = kops.cam_search_fused(stored, small, distance="l2",
                                 sensing="best", interpret=True)
    assert d.shape == (SMALL_Q_CROSSOVER - 1, 2, 2, 8)
    big = jnp.asarray(rng.random(
        (SMALL_Q_CROSSOVER, 2, 8)).astype(np.float32))
    with pytest.raises(AssertionError, match="small batch"):
        kops.cam_search_fused(stored, big, distance="l2", sensing="best",
                              interpret=True)


def test_small_q_takes_reference_path_compiled(monkeypatch):
    """The crossover must fire on the COMPILED path too: the old guard was
    ``interpret and Q < SMALL_Q_CROSSOVER``, so a TPU deployment paid a
    full Mosaic kernel launch for 1-3 query batches.  Monkeypatched
    kernels prove the Pallas entry points are never reached with
    interpret=False either."""
    def boom(*a, **k):
        raise AssertionError("Pallas kernel entered for a small batch")
    monkeypatch.setattr(kops, "cam_search_fused_pallas", boom)
    monkeypatch.setattr(kops, "cam_range_fused_pallas", boom)
    rng = np.random.default_rng(1)
    stored = jnp.asarray(rng.random((2, 2, 8, 8)).astype(np.float32))
    small = jnp.asarray(rng.random(
        (SMALL_Q_CROSSOVER - 1, 2, 8)).astype(np.float32))
    d, m = kops.cam_search_fused(stored, small, distance="l2",
                                 sensing="best", interpret=False)
    assert d.shape == (SMALL_Q_CROSSOVER - 1, 2, 2, 8)
    lo = rng.random((2, 2, 8, 8)).astype(np.float32)
    rgrid = jnp.asarray(np.stack([lo, lo + 0.3], axis=-1))
    m = kops.cam_search_fused(rgrid, small, distance="range",
                              sensing="exact", want_dist=False,
                              interpret=False)
    assert m.shape == (SMALL_Q_CROSSOVER - 1, 2, 2, 8)
    big = jnp.asarray(rng.random(
        (SMALL_Q_CROSSOVER, 2, 8)).astype(np.float32))
    with pytest.raises(AssertionError, match="small batch"):
        kops.cam_search_fused(stored, big, distance="l2", sensing="best",
                              interpret=False)


@pytest.mark.parametrize("distance", ["l2", "hamming", "range"])
def test_small_q_reference_bit_identical_to_kernel(distance):
    rng = np.random.default_rng(4)
    if distance == "range":
        lo = rng.random((2, 2, 8, 8)).astype(np.float32)
        stored = jnp.asarray(np.stack([lo, lo + 0.3], axis=-1))
    else:
        stored = jnp.asarray(rng.random((2, 2, 8, 8)).astype(np.float32))
    queries = jnp.asarray(rng.random((8, 2, 8)).astype(np.float32))
    rv = jnp.asarray((rng.random((2, 8)) < 0.8).astype(np.float32))
    kw = dict(distance=distance, sensing="best", row_valid=rv,
              interpret=True)
    dk, mk = kops.cam_search_fused(stored, queries, **kw)     # kernel (Q=8)
    for qn in range(1, SMALL_Q_CROSSOVER):
        dr, mr = kops.cam_search_fused(stored, queries[:qn], **kw)
        np.testing.assert_array_equal(np.asarray(dr), np.asarray(dk[:qn]))
        np.testing.assert_array_equal(np.asarray(mr), np.asarray(mk[:qn]))


def test_default_q_tile_reproduces_historical_defaults():
    # float fused kernel on a 64x64 subarray: the old hardcoded 32
    assert default_q_tile(64, 64, 1) == 32
    # hamming packed kernel, 256-row tile x 2 words: the old hardcoded 8
    assert default_q_tile(256, 2) == 8
    # ACAM range kernel (2 planes) streams twice the stored bytes per
    # step, so a larger query tile amortizes it
    assert default_q_tile(64, 64, 2) == 64
    # always a power of two within [1, 256]
    for r, c in ((8, 8), (128, 64), (512, 512), (1024, 128)):
        qt = default_q_tile(r, c)
        assert 1 <= qt <= 256 and (qt & (qt - 1)) == 0, (r, c, qt)


# ---------------------------------------------------------------------------
# estimator billing
# ---------------------------------------------------------------------------
def test_fraction_one_is_bitwise_full_scan():
    cfg = _cfg()
    arch = estimate_arch(cfg, 4096, 64)
    a = predict_search(cfg, arch)
    b = predict_search(cfg, arch, searched_fraction=1.0, prefilter_bits=0)
    assert (a.latency_ns, a.energy_pj, a.area_um2) == \
        (b.latency_ns, b.energy_pj, b.area_um2)
    s = predict_search_sharded(cfg, arch, 1, searched_fraction=1.0,
                               prefilter_bits=0)
    assert (s.latency_ns, s.energy_pj, s.area_um2) == \
        (a.latency_ns, a.energy_pj, a.area_um2)


def test_fraction_scales_search_energy_not_latency():
    cfg = _cfg()
    arch = estimate_arch(cfg, 4096, 64)
    full = predict_search(cfg, arch)
    half = predict_search(cfg, arch, searched_fraction=0.5)
    assert half.energy_pj == pytest.approx(full.energy_pj * 0.5, rel=1e-12)
    assert half.latency_ns == full.latency_ns
    assert half.area_um2 == full.area_um2


def test_prefilter_slab_billed_in_series():
    cfg = _cfg()
    arch = estimate_arch(cfg, 4096, 64)
    full = predict_search(cfg, arch)
    cas = predict_search(cfg, arch, searched_fraction=0.25,
                         prefilter_bits=64)
    assert "prefilter" in cas.breakdown
    pre = cas.breakdown["prefilter"]
    assert cas.latency_ns == pytest.approx(
        full.latency_ns + pre["latency_ns"], rel=1e-12)
    assert cas.energy_pj == pytest.approx(
        full.energy_pj * 0.25 + pre["energy_pj"], rel=1e-12)
    assert cas.area_um2 > full.area_um2


def test_cascade_billing_from_config():
    cfg = _cfg()
    arch = estimate_arch(cfg, 4096, 64)
    assert cascade_billing(cfg, arch) == (1.0, 0)
    nv = arch.spec.nv
    cas = cfg.replace(sim=dict(prefilter="ivf", top_p_banks=max(1, nv // 4),
                               signature_bits=16))
    f, b = cascade_billing(cas, arch)
    assert f == pytest.approx(max(1, nv // 4) / nv) and b == 16
    # derived but disabled: prefilter set, no budget -> full-scan billing
    derived = cfg.replace(sim=dict(prefilter="ivf"))
    assert cascade_billing(derived, arch) == (1.0, 0)
    # perf_report auto-derives: cascade config bills less search energy
    pf = perf_report(cfg, arch)
    pc = perf_report(cas, arch)
    assert pc["search"].breakdown["subarray"]["energy_pj"] < \
        pf["search"].breakdown["subarray"]["energy_pj"]
    assert "prefilter" in pc["search"].breakdown


def test_eval_perf_cascade_knobs_via_facade():
    stored, q = _data()
    cfg = _cfg(sim=dict(prefilter="signature", top_p_banks=2))
    sim = CAMASim(cfg)
    sim.plan(4096, 64)
    auto = sim.eval_perf()
    assert "prefilter" in auto["search"].breakdown
    full = sim.eval_perf(searched_fraction=1.0, prefilter_bits=0)
    ref = CAMASim(_cfg())
    ref.plan(4096, 64)
    base = ref.eval_perf()
    assert full["energy_pj"] == base["energy_pj"]
    assert full["latency_ns"] == base["latency_ns"]
    sweep = sim.sweep_cascade([None, 1, 2], entries=4096, dims=64)
    assert sweep[1]["energy_pj"] < sweep[2]["energy_pj"] \
        < sweep[None]["energy_pj"] + sweep[2]["search"].breakdown[
            "prefilter"]["energy_pj"] + 1e9  # sanity ordering on fractions
    assert sweep[1]["energy_pj"] < sweep[None]["energy_pj"]


def test_select_cascade_clamps_predicted_loss_at_n2048():
    """Regression (BENCH cascade_route_n2048): the recall ladder on the
    n=2048 / 64-dim / 64x64-subarray geometry only clears the floor at
    p = nv = 32, where the rung's own billing is a predicted LOSS
    (pred_e_frac = 1.186 — the signature slab costs more than the zero
    banks it skips).  ``select_cascade`` must refuse to ship it and fall
    back to prefilter='off' (returns None)."""
    cfg = _cfg(app=dict(match_param=4),
               circuit=dict(rows=64, cols=64))
    sim = CAMASim(cfg)
    nv = sim.plan(2048, 64).spec.nv
    assert nv == 32
    sel, rep = sim.select_cascade([nv], entries=2048, dims=64)
    assert rep[nv]["energy_pj"] >= rep[None]["energy_pj"]
    assert sel is None                     # never ship a predicted loss
    # a genuinely cheaper rung on the same geometry IS selected, and the
    # winner among mixed rungs skips the losing one
    sel2, rep2 = sim.select_cascade([4, nv], entries=2048, dims=64)
    assert sel2 == 4
    assert rep2[4]["energy_pj"] < rep2[None]["energy_pj"]
