"""CAMASim functional simulator: unit + property tests.

The key invariants (paper Fig. 3b):
  * exact match + AND/gather merge over a partitioned store == direct
    full-vector exact match (lossless);
  * best match + adder/comparator merge == global argmin (lossless);
  * best match + voting merge == argmin when no horizontal partitioning;
  * threshold match + adder merge == all entries within the threshold;
  * padding (partition remainders) never changes results.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig)
from repro.core import distance as dist_mod
from repro.core import mapping


def make_cfg(distance="l2", match="best", k=1, bits=3, rows=8, cols=8,
             h_merge="adder", v_merge="comparator", sensing=None,
             sl=0.0, variation="none", std=0.0):
    return CAMConfig(
        app=AppConfig(distance=distance, match_type=match, match_param=k,
                      data_bits=bits),
        arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
        circuit=CircuitConfig(rows=rows, cols=cols, cell_type="mcam",
                              sensing=sensing or match, sensing_limit=sl),
        device=DeviceConfig(device="fefet", variation=variation,
                            variation_std=std))


# ---------------------------------------------------------------------------
# exact match
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("K,N,rows,cols", [
    (10, 12, 4, 4), (32, 8, 8, 8), (7, 20, 8, 6), (16, 16, 16, 16)])
def test_exact_match_lossless(K, N, rows, cols):
    cfg = make_cfg(distance="hamming", match="exact", bits=1,
                   rows=rows, cols=cols, h_merge="and", v_merge="gather")
    cfg = cfg.replace(circuit=dict(cell_type="tcam"))
    sim = CAMASim(cfg)
    key = jax.random.PRNGKey(0)
    stored = (jax.random.uniform(key, (K, N)) > 0.5).astype(jnp.float32)
    state = sim.write(stored)
    # query every stored row: row i must match at least itself
    idx, mask = sim.query(state, stored)
    for i in range(K):
        matches = np.where(np.asarray(mask[i]) > 0)[0]
        assert i in matches
        # all matched rows are true duplicates
        for j in matches:
            if j < K:
                assert (np.asarray(stored[i]) == np.asarray(stored[j])).all()


def test_exact_match_no_false_positive():
    cfg = make_cfg(distance="hamming", match="exact", bits=1, rows=4,
                   cols=4, h_merge="and", v_merge="gather")
    cfg = cfg.replace(circuit=dict(cell_type="tcam"))
    sim = CAMASim(cfg)
    stored = jnp.eye(6, 10)
    state = sim.write(stored)
    q = jnp.zeros((1, 10))
    idx, mask = sim.query(state, q)
    assert np.asarray(mask[0]).sum() == 0
    assert (np.asarray(idx[0]) == -1).all()


# ---------------------------------------------------------------------------
# best match
# ---------------------------------------------------------------------------
@given(st.integers(2, 40), st.integers(2, 24), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_best_match_adder_is_global_argmin(K, N, seed):
    """adder h-merge + comparator v-merge == exact nearest neighbour."""
    cfg = make_cfg(distance="l2", match="best", k=1, bits=0,
                   rows=8, cols=8)
    cfg = cfg.replace(circuit=dict(cell_type="acam"))
    sim = CAMASim(cfg)
    key = jax.random.PRNGKey(seed % (2 ** 31))
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (K, N))
    q = jax.random.uniform(k2, (3, N))
    state = sim.write(stored)
    idx, _ = sim.query(state, q)
    d = np.square(np.asarray(stored)[None] - np.asarray(q)[:, None]
                  ).sum(-1)
    want = d.argmin(1)
    got = np.asarray(idx[:, 0])
    # ties: accept any argmin-equivalent answer
    for g, w, drow in zip(got, want, d):
        assert drow[g] == pytest.approx(drow[w], rel=1e-5, abs=1e-6)


def test_best_match_voting_no_hpartition_is_exact():
    """With nh == 1 voting degenerates to per-subarray best == argmin."""
    cfg = make_cfg(distance="l2", match="best", k=1, bits=0, rows=4,
                   cols=16, h_merge="voting")
    cfg = cfg.replace(circuit=dict(cell_type="acam"))
    sim = CAMASim(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (12, 16))
    q = stored[jnp.array([3, 7])] + 0.001
    state = sim.write(stored)
    idx, _ = sim.query(state, q)
    assert list(np.asarray(idx[:, 0])) == [3, 7]


def test_best_match_topk_ordering():
    cfg = make_cfg(distance="l2", match="best", k=3, bits=0, rows=8,
                   cols=8)
    cfg = cfg.replace(circuit=dict(cell_type="acam"))
    sim = CAMASim(cfg)
    stored = jnp.arange(10.0)[:, None] * jnp.ones((1, 8))
    q = jnp.full((1, 8), 4.2)
    idx, _ = sim.query(sim.write(stored), q)
    assert list(np.asarray(idx[0])) == [4, 5, 3]


# ---------------------------------------------------------------------------
# threshold match
# ---------------------------------------------------------------------------
def test_threshold_match_adder():
    cfg = make_cfg(distance="hamming", match="threshold", k=2, bits=1,
                   rows=4, cols=4, h_merge="adder", v_merge="gather")
    cfg = cfg.replace(circuit=dict(cell_type="tcam", sensing="threshold"))
    sim = CAMASim(cfg)
    base = jnp.zeros((1, 12))
    rows = []
    for flips in [0, 1, 2, 3, 5]:
        r = np.zeros(12)
        r[:flips] = 1.0
        rows.append(r)
    stored = jnp.asarray(np.stack(rows))
    idx, mask = sim.query(sim.write(stored), base)
    got = set(np.where(np.asarray(mask[0]) > 0)[0].tolist())
    assert got == {0, 1, 2}  # hamming distance <= 2


def test_threshold_hpartition_without_adder_raises():
    cfg = make_cfg(distance="hamming", match="threshold", k=1, bits=1,
                   rows=4, cols=4, h_merge="and", v_merge="gather")
    cfg = cfg.replace(circuit=dict(cell_type="tcam", sensing="threshold"))
    sim = CAMASim(cfg)
    stored = jnp.zeros((4, 8))   # nh = 2 > 1
    with pytest.raises(ValueError, match="no AND/voting merge"):
        sim.query(sim.write(stored), jnp.zeros((1, 8)))


# ---------------------------------------------------------------------------
# padding / partition invariance (property)
# ---------------------------------------------------------------------------
@given(st.integers(2, 30), st.integers(2, 20), st.integers(2, 16),
       st.integers(2, 16), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_partition_invariance(K, N, rows, cols, seed):
    """Best-match result is independent of the subarray tiling."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (K, N))
    q = jax.random.uniform(k2, (2, N))

    def run(r, c):
        cfg = make_cfg(distance="l1", match="best", k=1, bits=0,
                       rows=r, cols=c)
        cfg = cfg.replace(circuit=dict(cell_type="acam"))
        sim = CAMASim(cfg)
        idx, _ = sim.query(sim.write(stored), q)
        return np.asarray(idx[:, 0])

    a = run(rows, cols)
    b = run(K, N)        # single subarray, no partitioning
    d = np.abs(np.asarray(stored)[None] - np.asarray(q)[:, None]).sum(-1)
    for i in range(2):
        assert d[i, a[i]] == pytest.approx(d[i, b[i]], rel=1e-5, abs=1e-6)


# ---------------------------------------------------------------------------
# distances + mapping units
# ---------------------------------------------------------------------------
@given(st.integers(1, 6), st.integers(1, 12), st.integers(0, 10 ** 6))
@settings(max_examples=25, deadline=None)
def test_distance_axioms(R, C, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (R, C))
    q = jax.random.uniform(k2, (C,))
    for name in ("hamming", "l1", "l2"):
        fn = dist_mod.get_distance(name)
        d = np.asarray(fn(stored, q))
        assert (d >= 0).all()
        d_self = np.asarray(fn(q[None, :], q))
        assert d_self[0] == pytest.approx(0.0, abs=1e-6)


def test_mapping_roundtrip():
    spec = mapping.grid_spec(K=10, N=12, R=4, C=5)
    assert (spec.nv, spec.nh) == (3, 3)
    data = jnp.arange(120.0).reshape(10, 12)
    grid = mapping.partition_stored(data, spec)
    assert grid.shape == (3, 3, 4, 5)
    # reassemble and compare
    back = grid.transpose(0, 2, 1, 3).reshape(spec.padded_K, spec.padded_N)
    np.testing.assert_array_equal(np.asarray(back[:10, :12]),
                                  np.asarray(data))
    cv = mapping.col_valid_mask(spec)
    rv = mapping.row_valid_mask(spec)
    assert cv.sum() == 12 and rv.sum() == 10


# ---------------------------------------------------------------------------
# variation + sensing limit behaviour
# ---------------------------------------------------------------------------
def test_d2d_variation_is_write_time_only():
    cfg = make_cfg(variation="d2d", std=0.3)
    sim = CAMASim(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (20, 16))
    s1 = sim.write(stored, key=jax.random.PRNGKey(1))
    s2 = sim.write(stored, key=jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(s1.grid), np.asarray(s2.grid))
    s3 = sim.write(stored, key=jax.random.PRNGKey(2))
    assert np.abs(np.asarray(s1.grid) - np.asarray(s3.grid)).max() > 0


def test_c2c_variation_changes_between_queries():
    cfg = make_cfg(variation="c2c", std=0.5, k=1)
    sim = CAMASim(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (30, 16))
    state = sim.write(stored)
    q = jnp.tile(jax.random.uniform(jax.random.PRNGKey(1), (1, 16)), (8, 1))
    idx, _ = sim.query(state, q, key=jax.random.PRNGKey(2))
    # identical queries under per-cycle noise need not agree everywhere
    # (statistically, with std=0.5 LSB some flip); at minimum: valid output
    assert ((np.asarray(idx) >= 0) & (np.asarray(idx) < 32)).all()


def test_exper_variation_table():
    table = tuple([0.0] * 7 + [5.0])   # only top level is noisy
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=1,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet", variation="d2d",
                            variation_spec="exper", exper_table=table))
    sim = CAMASim(cfg)
    stored = jnp.zeros((4, 8)).at[2].set(1.0)   # row 2 quantizes to level 7
    state = sim.write(stored, key=jax.random.PRNGKey(3))
    g = np.asarray(state.grid).reshape(-1, 8)
    assert np.abs(g[2] - 7.0).max() > 0.5       # noisy level
    assert np.abs(g[0] - 0.0).max() < 1e-6      # quiet level


def test_sensing_limit_widens_match_set():
    cfg0 = make_cfg(distance="l2", match="best", k=4, bits=0, sl=0.0)
    cfg1 = make_cfg(distance="l2", match="best", k=4, bits=0, sl=10.0)
    cfg0 = cfg0.replace(circuit=dict(cell_type="acam"))
    cfg1 = cfg1.replace(circuit=dict(cell_type="acam"))
    stored = jnp.asarray([[0.0] * 8, [0.1] * 8, [0.2] * 8, [5.0] * 8])
    q = jnp.zeros((1, 8))
    # with a huge SL, the sense amp can't distinguish close rows: for
    # voting-free config the match mask from sense() includes more rows.
    from repro.core.functional import FunctionalSimulator
    import jax as _jax
    f0, f1 = FunctionalSimulator(cfg0), FunctionalSimulator(cfg1)
    st0, st1 = f0.write(stored), f1.write(stored)
    _, m0 = f0.query(st0, q)
    _, m1 = f1.query(st1, q)
    assert np.asarray(m1).sum() >= np.asarray(m0).sum()


def test_config_json_roundtrip():
    cfg = make_cfg(variation="both", std=0.1)
    s = cfg.to_json()
    cfg2 = CAMConfig.from_json(s)
    assert cfg == cfg2


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        AppConfig(distance="cosine")
    with pytest.raises(ValueError):
        make_cfg(match="exact", h_merge="voting").validate()
    with pytest.raises(ValueError):
        make_cfg(match="best", v_merge="gather").validate()


# ---------------------------------------------------------------------------
# ACAM range matching (X-TIME-style)
# ---------------------------------------------------------------------------
def test_acam_range_exact_match():
    cfg = CAMConfig(
        app=AppConfig(distance="range", match_type="exact", match_param=4,
                      data_bits=0),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=4, cols=4, cell_type="acam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"))
    lo = jnp.asarray([[0, 0, 0, 0, 0, 0],
                      [0.5, 0, 0, 0, 0, 0],
                      [0, 0, 0.8, 0, 0, 0]], jnp.float32)
    hi = jnp.asarray([[1, 1, 1, 1, 1, 1],
                      [1, 0.4, 1, 1, 1, 1],
                      [1, 1, 1, 1, 1, 0.2]], jnp.float32)
    sim = CAMASim(cfg)
    state = sim.write(jnp.stack([lo, hi], axis=-1))
    q = jnp.asarray([[0.6, 0.3, 0.9, 0.5, 0.5, 0.1],
                     [0.4, 0.5, 0.5, 0.5, 0.5, 0.5]])
    _, mask = sim.query(state, q)
    assert set(np.where(np.asarray(mask[0]) > 0)[0]) == {0, 1, 2}
    assert set(np.where(np.asarray(mask[1]) > 0)[0]) == {0}


@given(st.integers(4, 20), st.integers(3, 10), st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_acam_range_match_property(K, N, seed):
    """A query strictly inside a row's ranges always matches it; a query
    strictly outside one cell's range never matches that row."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    lo = jax.random.uniform(k1, (K, N), minval=0.0, maxval=0.4)
    hi = lo + 0.2 + jax.random.uniform(k2, (K, N)) * 0.4
    cfg = CAMConfig(
        app=AppConfig(distance="range", match_type="exact",
                      match_param=1, data_bits=0),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=4, cols=4, cell_type="acam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"))
    sim = CAMASim(cfg)
    state = sim.write(jnp.stack([lo, hi], axis=-1))
    mid = (lo[2] + hi[2]) / 2.0
    _, mask = sim.query(state, mid[None])
    assert np.asarray(mask[0])[2] > 0
    outside = mid.at[0].set(hi[2, 0] + 1.0)
    _, mask2 = sim.query(state, outside[None])
    assert np.asarray(mask2[0])[2] == 0
