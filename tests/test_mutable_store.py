"""Mutability contract: online insert/delete/update/compact of the
resident store.

The load-bearing guarantees:
  * insert-then-search is BIT-IDENTICAL to a fresh write of the combined
    data (both backends, incl. the cascade prefilter and the c2c 'bank'
    fold) — the per-row-slot D2D fold (`sim.d2d_fold='row'`) is what makes
    the incremental programming noise reproducible;
  * deleted ids never match again and their slots return to the free list;
  * `compact(state)` is bit-identical to a fresh `write` of the live rows
    (incl. the IVF re-clustering);
  * the estimator bills partial writes and reports an inserts/sec figure.

Quantization-scale caveat the tests arrange for: a fresh write derives
lo/hi from ITS data, while the mutable store keeps the original scale, so
parity legs pin the data extremes inside the never-deleted prefix.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CAMASim, CAMConfig

jax.config.update("jax_platforms", "cpu")


def _cfg(backend="functional", **sim):
    base = dict(capacity=40, c2c_fold="bank", d2d_fold="row",
                backend=backend)
    base.update(sim)
    return CAMConfig.from_dict(dict(
        app=dict(distance="l2", match_type="best", match_param=1,
                 data_bits=3),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=8, cols=8, cell_type="mcam", sensing="best"),
        device=dict(device="fefet", variation="none", variation_std=0.05),
        sim=base))


def _data(k_base=24, k_extra=8, n=8):
    base = jax.random.uniform(jax.random.PRNGKey(0), (k_base, n))
    # pin the quantization extremes in the base rows so a fresh write of
    # any superset derives the same shared scale as the mutable store
    base = base.at[0].set(0.0).at[1].set(1.0)
    extra = jax.random.uniform(jax.random.PRNGKey(7), (k_extra, n))
    return base, extra


WKEY = jax.random.PRNGKey(5)
QKEY = jax.random.PRNGKey(3)


def _queries(q=5, n=8):
    return jax.random.uniform(jax.random.PRNGKey(9), (q, n))


def _assert_result_equal(ra, rb):
    np.testing.assert_array_equal(np.asarray(ra.indices),
                                  np.asarray(rb.indices))
    np.testing.assert_array_equal(np.asarray(ra.mask), np.asarray(rb.mask))


# ---------------------------------------------------------------------------
# insert-then-search == fresh write
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["functional", "sharded"])
@pytest.mark.parametrize("prefilter,variation", [
    ("off", "none"),
    ("signature", "none"),
    ("off", "d2d"),
    ("signature", "both"),     # cascade + c2c bank fold + d2d row fold
])
def test_insert_then_search_matches_fresh_write(backend, prefilter,
                                                variation):
    base, extra = _data()
    full = jnp.concatenate([base, extra])
    cfg = _cfg(backend, prefilter=prefilter,
               top_p_banks=2 if prefilter != "off" else None)
    cfg = cfg.replace(device=dict(variation=variation))
    sim = CAMASim(cfg)
    s_full = sim.write(full, WKEY)
    s_ins, ids = sim.insert(sim.write(base, WKEY), extra, WKEY)
    # inserted rows answer to the ids a fresh write gives them
    np.testing.assert_array_equal(
        np.asarray(ids), np.arange(base.shape[0], full.shape[0]))
    np.testing.assert_array_equal(np.asarray(s_full.grid),
                                  np.asarray(s_ins.grid))
    np.testing.assert_array_equal(np.asarray(s_full.row_valid),
                                  np.asarray(s_ins.row_valid))
    if s_full.sigs is not None:
        np.testing.assert_array_equal(np.asarray(s_full.sigs),
                                      np.asarray(s_ins.sigs))
    _assert_result_equal(sim.query(s_full, _queries(), key=QKEY),
                         sim.query(s_ins, _queries(), key=QKEY))


def test_insert_parity_acam_ranges():
    lo = jax.random.uniform(jax.random.PRNGKey(2), (24, 8)) * 0.4
    ranges = jnp.stack([lo, lo + 0.3], axis=-1)
    extra = jnp.stack([lo[:6] + 0.1, lo[:6] + 0.5], axis=-1)
    cfg = CAMConfig.from_dict(dict(
        app=dict(distance="range", match_type="exact", match_param=0,
                 data_bits=0),
        arch=dict(h_merge="and", v_merge="gather"),
        circuit=dict(rows=8, cols=8, cell_type="acam", sensing="exact"),
        device=dict(device="fefet", variation="d2d", variation_std=0.02),
        sim=dict(capacity=32, d2d_fold="row")))
    sim = CAMASim(cfg)
    s_full = sim.write(jnp.concatenate([ranges, extra]), WKEY)
    s_ins, _ = sim.insert(sim.write(ranges, WKEY), extra, WKEY)
    np.testing.assert_array_equal(np.asarray(s_full.grid),
                                  np.asarray(s_ins.grid))
    _assert_result_equal(sim.query(s_full, lo[:4] + 0.15, key=QKEY),
                         sim.query(s_ins, lo[:4] + 0.15, key=QKEY))


# ---------------------------------------------------------------------------
# delete / free-list reuse
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("prefilter", ["off", "signature", "ivf"])
def test_deleted_ids_never_match_and_slots_are_reused(prefilter):
    base, extra = _data()
    cfg = _cfg(prefilter=prefilter,
               top_p_banks=2 if prefilter != "off" else None)
    sim = CAMASim(cfg)
    state = sim.write(jnp.concatenate([base, extra]), WKEY)
    victims = np.arange(4, 9)
    state = sim.delete(state, victims)
    # query each deleted row's own data: the winner must not be a victim
    res = sim.query(state, jnp.concatenate([base, extra])[victims],
                    key=QKEY)
    assert not np.isin(np.asarray(res.indices), victims).any()
    assert np.asarray(res.mask)[:, victims].sum() == 0
    # their slots come back out of the free list, same ids
    state, ids = sim.insert(state, base[victims], WKEY)
    assert sorted(np.asarray(ids).tolist()) == victims.tolist()
    # double delete of a dead id fails loudly
    with pytest.raises(ValueError, match="not live"):
        sim.delete(sim.delete(state, [3]), [3])


def test_insert_overflow_raises():
    base, extra = _data()
    sim = CAMASim(_cfg(capacity=0))
    state = sim.write(base, WKEY)     # 24 rows in a 24-capacity store
    with pytest.raises(ValueError, match="store full"):
        sim.insert(state, extra, WKEY)


def test_mutation_with_grid_d2d_fold_rejected():
    base, extra = _data()
    cfg = _cfg(d2d_fold="grid").replace(device=dict(variation="d2d"))
    sim = CAMASim(cfg)
    state = sim.write(base, WKEY)
    with pytest.raises(ValueError, match="d2d_fold='row'"):
        sim.insert(state, extra, WKEY)


def test_row_shape_validation():
    base, extra = _data()
    sim = CAMASim(_cfg())
    state = sim.write(base, WKEY)
    with pytest.raises(ValueError, match="width"):
        sim.insert(state, jnp.ones((2, 5)), WKEY)
    with pytest.raises(ValueError, match="rows"):
        sim.insert(state, jnp.ones((8,)), WKEY)


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------
def test_update_rewrites_rows_in_place():
    base, extra = _data()
    sim = CAMASim(_cfg())
    state = sim.write(base, WKEY)
    # in-place update is bit-identical to a fresh write of the modified
    # data (slot noise depends only on the slot, not on write history)
    new = sim.update(state, [5], base[20][None], WKEY)
    fresh = sim.write(base.at[5].set(base[20]), WKEY)
    np.testing.assert_array_equal(np.asarray(new.grid),
                                  np.asarray(fresh.grid))
    _assert_result_equal(sim.query(new, _queries(), key=QKEY),
                         sim.query(fresh, _queries(), key=QKEY))
    # shapes/perm untouched
    assert new.grid.shape == state.grid.shape
    with pytest.raises(ValueError, match="ids but"):
        sim.update(state, [1, 2], base[:1], WKEY)


# ---------------------------------------------------------------------------
# compact == fresh write
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["functional", "sharded"])
@pytest.mark.parametrize("prefilter,variation", [
    ("off", "none"),
    ("signature", "both"),
    ("ivf", "none"),           # compact re-runs the k-means placement
    ("ivf", "both"),
])
def test_compact_is_bit_identical_to_fresh_write(backend, prefilter,
                                                 variation):
    base, extra = _data()
    cfg = _cfg(backend, prefilter=prefilter,
               top_p_banks=2 if prefilter != "off" else None)
    cfg = cfg.replace(device=dict(variation=variation))
    sim = CAMASim(cfg)
    state, _ = sim.insert(sim.write(base, WKEY), extra, WKEY)
    state = sim.delete(state, np.arange(4, 8))   # extremes (rows 0/1) live
    compacted = sim.compact(state, WKEY)
    live = jnp.concatenate([base[:4], base[8:], extra])
    fresh = sim.write(live, WKEY)
    np.testing.assert_array_equal(np.asarray(compacted.grid),
                                  np.asarray(fresh.grid))
    np.testing.assert_array_equal(np.asarray(compacted.row_valid),
                                  np.asarray(fresh.row_valid))
    if fresh.sigs is not None:
        np.testing.assert_array_equal(np.asarray(compacted.sigs),
                                      np.asarray(fresh.sigs))
    if fresh.perm is not None:
        np.testing.assert_array_equal(np.asarray(compacted.perm),
                                      np.asarray(fresh.perm))
    _assert_result_equal(sim.query(compacted, _queries(), key=QKEY),
                         sim.query(fresh, _queries(), key=QKEY))


def test_compact_empty_store_raises():
    base, _ = _data()
    sim = CAMASim(_cfg())
    state = sim.write(base, WKEY)
    state = sim.delete(state, np.arange(base.shape[0]))
    with pytest.raises(ValueError, match="empty"):
        sim.compact(state, WKEY)


# ---------------------------------------------------------------------------
# IVF insert routes to the inserted row (semantic, not bit-exact: an
# incremental insert cannot re-run the fresh write's k-means placement)
# ---------------------------------------------------------------------------
def test_ivf_insert_is_searchable_through_the_cascade():
    base, extra = _data()
    sim = CAMASim(_cfg(prefilter="ivf", top_p_banks=2))
    state, ids = sim.insert(sim.write(base, WKEY), extra, WKEY)
    res = sim.query(state, extra, key=QKEY)
    np.testing.assert_array_equal(np.asarray(res.indices)[:, 0],
                                  np.asarray(ids))


# ---------------------------------------------------------------------------
# multi-device parity (subprocess: XLA host-device trick must precede
# jax init)
# ---------------------------------------------------------------------------
_SHARDED_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ["JAX_PLATFORMS"] = "cpu"
import jax, jax.numpy as jnp, numpy as np
from repro.core import CAMASim, CAMConfig

base = jax.random.uniform(jax.random.PRNGKey(0), (24, 8))
base = base.at[0].set(0.0).at[1].set(1.0)
extra = jax.random.uniform(jax.random.PRNGKey(7), (8, 8))
full = jnp.concatenate([base, extra])
cfg = CAMConfig.from_dict(dict(
    app=dict(distance="l2", match_type="best", match_param=1, data_bits=3),
    arch=dict(h_merge="adder", v_merge="comparator"),
    circuit=dict(rows=8, cols=8, cell_type="mcam", sensing="best"),
    device=dict(device="fefet", variation="both", variation_std=0.05),
    sim=dict(backend="sharded", devices=2, capacity=40,
             prefilter="signature", top_p_banks=2, c2c_fold="bank",
             d2d_fold="row")))
sim = CAMASim(cfg)
wkey, qkey = jax.random.PRNGKey(5), jax.random.PRNGKey(3)
q = jax.random.uniform(jax.random.PRNGKey(9), (4, 8))
s_full = sim.write(full, wkey)
s_ins, ids = sim.insert(sim.write(base, wkey), extra, wkey)
ra, rb = sim.query(s_full, q, key=qkey), sim.query(s_ins, q, key=qkey)
assert np.array_equal(np.asarray(ra.indices), np.asarray(rb.indices))
assert np.array_equal(np.asarray(ra.mask), np.asarray(rb.mask))
sc = sim.compact(sim.delete(s_ins, np.arange(4, 8)), wkey)
fresh = sim.write(jnp.concatenate([base[:4], base[8:], extra]), wkey)
assert np.array_equal(np.asarray(sc.grid), np.asarray(fresh.grid))
assert np.array_equal(np.asarray(sc.row_valid), np.asarray(fresh.row_valid))
print("MUTABLE_SHARDED_OK")
'''


def test_mutations_parity_on_two_device_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..",
                                      "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0 and "MUTABLE_SHARDED_OK" in proc.stdout, \
        proc.stdout + proc.stderr


# ---------------------------------------------------------------------------
# estimator: partial-write billing + inserts/sec
# ---------------------------------------------------------------------------
def test_predict_write_partial_rows_billing():
    from repro.core import estimate_arch, predict_write
    cfg = _cfg()
    arch = estimate_arch(cfg, 512, 64)
    full = predict_write(cfg, arch)
    one = predict_write(cfg, arch, rows=1)
    some = predict_write(cfg, arch, rows=4)
    # latency row-serial in touched rows, capped at R
    assert one.latency_ns <= some.latency_ns <= full.latency_ns
    assert predict_write(cfg, arch, rows=10**6).latency_ns \
        == pytest.approx(full.latency_ns)
    # energy scales with touched rows
    assert 0 < one.energy_pj < some.energy_pj < full.energy_pj
    assert some.energy_pj == pytest.approx(4 * one.energy_pj)
    with pytest.raises(ValueError):
        predict_write(cfg, arch, rows=-1)


def test_perf_report_has_inserts_per_s():
    """``inserts_per_s`` is the honest SERVING proxy (device write + host
    engine-step overhead — the quantity serve_bench's wall clock measures,
    once off by 8800x when it was the raw device figure); the device-only
    rate rides along as ``device_inserts_per_s``."""
    from repro.core import estimate_arch, predict_write
    from repro.core.perf.estimator import HOST_STEP_OVERHEAD_NS
    sim = CAMASim(_cfg())
    sim.plan(512, 64)
    rep = sim.eval_perf()
    arch = estimate_arch(sim.config, 512, 64)
    w1 = predict_write(sim.config, arch, rows=1).latency_ns
    assert rep["device_inserts_per_s"] == pytest.approx(1e9 / w1)
    assert rep["inserts_per_s"] == pytest.approx(
        1e9 / (w1 + HOST_STEP_OVERHEAD_NS))
    # the serving proxy is always the smaller figure, and on this geometry
    # the engine step dominates by orders of magnitude
    assert 0 < rep["inserts_per_s"] < rep["device_inserts_per_s"]
    assert rep["device_inserts_per_s"] / rep["inserts_per_s"] > 100


def test_capacity_reserves_headroom_in_plan_and_write():
    base, extra = _data()
    sim = CAMASim(_cfg(capacity=40))
    state = sim.write(base, WKEY)
    assert state.spec.padded_K == 40          # ceil(40/8)*8
    assert state.spec.K == base.shape[0]
    arch = sim.plan(base.shape[0], base.shape[1])
    assert arch.spec.padded_K == 40           # estimator sees the headroom
    free = np.asarray(sim.backend.free_slots(state))
    assert free.size == 40 - base.shape[0]
