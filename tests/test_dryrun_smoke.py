"""Dry-run machinery smoke test.

Runs in a SUBPROCESS because the dry-run forces 512 host devices via
XLA_FLAGS before jax initializes (the main pytest process stays at 1
device).  One small cell per step-kind proves lower+compile+probe works;
the full 40-cell x 2-mesh sweep is executed by ``python -m
repro.launch.dryrun --all --mesh both`` (see EXPERIMENTS.md).
"""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"   # host-device trick needs the CPU backend
import json
import jax
from repro.launch.dryrun import lower_kind, probe_costs
from repro.launch.mesh import compat_make_mesh
from repro.configs import get_config
from repro.runtime import ShardingRules

mesh = compat_make_mesh((2, 4), ("data", "model"))
rules = ShardingRules()
out = {}
cfg = get_config("qwen2-1.5b").replace(n_layers=2, d_model=256,
                                       n_heads=4, n_kv_heads=2, d_head=64,
                                       d_ff=512, vocab_size=2048)
for kind, batch, seq in (("train", 8, 256), ("prefill", 4, 256),
                         ("decode", 8, 256)):
    lowered = lower_kind(cfg, kind, batch, seq, mesh, rules)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # jax <= 0.4.x: per-device dicts
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    costs, colls = probe_costs(cfg, kind, batch, seq, mesh, rules, "tp")
    out[kind] = {
        "flops": float(cost.get("flops", 0.0)),
        "probe_flops": costs["flops"],
        "arg_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        "collective_ops": sorted(colls),
    }
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_lowers_all_step_kinds():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    out = json.loads(line[len("RESULT:"):])
    for kind in ("train", "prefill", "decode"):
        assert out[kind]["probe_flops"] > 0, out[kind]
        assert out[kind]["arg_bytes"] > 0
    # probe-corrected flops exceed the scanned artifact's body-once count
    assert out["train"]["probe_flops"] > out["train"]["flops"] * 1.2
    # sharded compute must induce collectives
    assert out["train"]["collective_ops"], out["train"]


def test_collective_parser():
    from repro.roofline import parse_collectives
    hlo = """
  %ar = f32[16,128]{1,0} all-reduce(f32[16,128]{1,0} %x), replica_groups={{0,1,2,3}}
  %ag.1 = bf16[4,256]{1,0} all-gather(bf16[1,256]{1,0} %y), replica_groups=[2,4]<=[8]
  %rs = f32[8]{0} reduce-scatter(f32[32]{0} %z), replica_groups={{0,1,2,3}}
  %cp = f32[64]{0} collective-permute(f32[64]{0} %w)
  %dot = f32[8,8]{1,0} dot(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
"""
    stats = parse_collectives(hlo, 8)
    assert set(stats.ops) == {"all-reduce", "all-gather",
                              "reduce-scatter", "collective-permute"}
    ar = stats.ops["all-reduce"]
    assert ar["result_bytes"] == 16 * 128 * 4
    assert ar["wire_bytes"] == pytest.approx(2 * 16 * 128 * 4 * 3 / 4)
    ag = stats.ops["all-gather"]
    assert ag["result_bytes"] == 4 * 256 * 2
    rs = stats.ops["reduce-scatter"]
    assert rs["wire_bytes"] == pytest.approx(8 * 4 * 3)


def test_roofline_terms():
    from repro.roofline import Roofline
    r = Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                 flops_per_device=197e12 * 0.01,       # 10 ms compute
                 bytes_per_device=819e9 * 0.002,       # 2 ms memory
                 wire_bytes_per_device=50e9 * 0.02,    # 20 ms collective
                 model_flops_global=197e12 * 0.01 * 256 * 0.5)
    assert r.bottleneck == "collective"
    assert r.t_bound == pytest.approx(0.02)
    assert r.useful_flops_ratio == pytest.approx(0.5)
    assert r.roofline_fraction == pytest.approx(0.01 * 0.5 / 0.02)
