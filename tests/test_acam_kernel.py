"""Fused batched ACAM range-search kernel + the bugfix sweep that shipped
with it.

Layers of guarantees:
  * ``cam_range_fused_pallas`` (via ``subarray_query_batched`` use_kernel)
    is bit-identical to the jnp ``range_violations`` + ``sense`` oracle for
    all {exact, best, threshold} x {want_dist, match-only} x
    padded/unpadded combos;
  * the kernel result is invariant to the Q-tiling and to the column
    partitioning (nh split) — same properties the point-code kernels hold;
  * ``FunctionalSimulator(use_kernel=True)`` on ACAM range stores is
    bit-identical to the jnp pipeline end to end;
  * regression tests for the satellite bugfixes: best-match merge with
    ``match_param > padded_K`` (clamp + -1 pad instead of a top_k crash),
    bcam/tcam query binarization at the STORE's threshold (codes must not
    drift with batch composition), D2D/C2C noise never inverting ACAM
    ranges (lo <= hi always; exper table a no-op for analog cells), and
    the ``CAMASim`` facade plumbing ``c2c_fold``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig)
from repro.core import mapping, merge, subarray, variation
from repro.core.distance import range_violations
from repro.core.functional import FunctionalSimulator
from repro.kernels import ops


def _range_grid(K, N, rng, width=0.4):
    lo = rng.random((K, N)).astype(np.float32) * 0.6
    hi = lo + rng.random((K, N)).astype(np.float32) * width
    return jnp.asarray(np.stack([lo, hi], axis=-1))


def _acam_cfg(match="exact", h_merge="and", v_merge="gather",
              sensing="exact", k=2, sl=0.0, rows=8, cols=4,
              variation="none", std=0.0):
    return CAMConfig(
        app=AppConfig(distance="range", match_type=match, match_param=k,
                      data_bits=0),
        arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
        circuit=CircuitConfig(rows=rows, cols=cols, cell_type="acam",
                              sensing=sensing, sensing_limit=sl),
        device=DeviceConfig(device="fefet", variation=variation,
                            variation_std=std))


# ---------------------------------------------------------------------------
# kernel vs jnp oracle: full parity matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("K,N,R,C", [
    (16, 8, 8, 4),     # aligned (no padding rows/cols)
    (21, 10, 8, 4),    # padded rows AND cols
    (5, 3, 8, 16),     # single subarray, heavy padding
])
@pytest.mark.parametrize("sensing", ["exact", "best", "threshold"])
@pytest.mark.parametrize("want_dist", [True, False])
def test_range_kernel_parity_matrix(K, N, R, C, sensing, want_dist):
    rng = np.random.default_rng(K * 100 + R + (sensing == "best"))
    stored = _range_grid(K, N, rng)
    spec = mapping.grid_spec(K, N, R, C)
    grid = mapping.partition_stored(stored, spec)
    assert grid.ndim == 5
    queries = jnp.asarray(rng.random((7, N)).astype(np.float32))
    qseg = mapping.partition_query(queries, spec)
    kw = dict(distance="range", sensing=sensing, sensing_limit=0.5,
              threshold=2.0, col_valid=mapping.col_valid_mask(spec),
              row_valid=mapping.row_valid_mask(spec))
    dk, mk = subarray.subarray_query_batched(
        grid, qseg, use_kernel=True, want_dist=want_dist, **kw)
    dj, mj = subarray.subarray_query_batched(
        grid, qseg, use_kernel=False, **kw)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(mj))
    if want_dist:
        dj_, dk_ = np.asarray(dj), np.asarray(dk)
        finite = np.isfinite(dj_)
        # padding rows carry +inf in both pipelines; violation counts are
        # small ints in f32, so equality is exact, not approx
        assert (finite == np.isfinite(dk_)).all()
        np.testing.assert_array_equal(dk_[finite], dj_[finite])
    else:
        assert dk is None


def test_range_kernel_q_tile_invariance():
    rng = np.random.default_rng(3)
    stored = _range_grid(21, 10, rng)
    spec = mapping.grid_spec(21, 10, 8, 4)
    grid = mapping.partition_stored(stored, spec)
    queries = jnp.asarray(rng.random((13, 10)).astype(np.float32))
    qseg = mapping.partition_query(queries, spec)
    outs = [ops.cam_search_fused(
        grid, qseg, distance="range", sensing="best", sensing_limit=0.0,
        col_valid=mapping.col_valid_mask(spec),
        row_valid=mapping.row_valid_mask(spec), q_tile=qt)
        for qt in (1, 4, 8, 13, 64)]
    for d, m in outs[1:]:
        np.testing.assert_array_equal(np.asarray(d), np.asarray(outs[0][0]))
        np.testing.assert_array_equal(np.asarray(m), np.asarray(outs[0][1]))


def test_range_kernel_rejects_mismatched_distance():
    """distance='range' needs a 5-D grid and vice versa (no silent path)."""
    grid4 = jnp.zeros((1, 1, 4, 4))
    grid5 = jnp.zeros((1, 1, 4, 4, 2))
    q = jnp.zeros((2, 1, 4))
    with pytest.raises(ValueError, match="range"):
        ops.cam_search_fused(grid4, q, distance="range", sensing="exact")
    with pytest.raises(ValueError, match="range"):
        ops.cam_search_fused(grid5, q, distance="l2", sensing="exact")


def test_write_rejects_range_store_distance_mismatch():
    """The store shape ⟺ distance coupling fails loudly at WRITE time on
    both paths (the jnp path used to compute range violations silently
    mislabeled as the configured distance)."""
    cfg = _acam_cfg()
    bad = cfg.replace(app=dict(distance="l2", match_type="best"),
                      arch=dict(v_merge="comparator"))
    rng = np.random.default_rng(0)
    ranges = _range_grid(9, 5, rng)
    for use_kernel in (False, True):
        with pytest.raises(ValueError, match="distance='range'"):
            FunctionalSimulator(bad, use_kernel=use_kernel).write(ranges)
        with pytest.raises(ValueError, match="range store"):
            FunctionalSimulator(cfg, use_kernel=use_kernel).write(
                jnp.asarray(rng.random((9, 5), dtype=np.float32)))


@given(st.integers(0, 10 ** 6), st.sampled_from([3, 4, 5, 10]))
@settings(max_examples=10, deadline=None)
def test_range_kernel_column_partition_invariant(seed, cols):
    """Like the point-code kernels: splitting the N columns into different
    nh segmentations never changes the (adder-merged) violation totals —
    they always equal the unpartitioned oracle."""
    rng = np.random.default_rng(seed)
    K, N, Q = 13, 10, 5
    stored = _range_grid(K, N, rng)
    queries = jnp.asarray(rng.random((Q, N)).astype(np.float32))
    want = np.asarray(range_violations(stored, queries, None))
    spec = mapping.grid_spec(K, N, 8, cols)
    grid = mapping.partition_stored(stored, spec)
    qseg = mapping.partition_query(queries, spec)
    d, _ = subarray.subarray_query_batched(
        grid, qseg, distance="range", sensing="exact", sensing_limit=0.0,
        col_valid=mapping.col_valid_mask(spec),
        row_valid=mapping.row_valid_mask(spec), use_kernel=True)
    total = np.asarray(d).sum(axis=-2).reshape(Q, -1)[:, :K]
    np.testing.assert_array_equal(total, want)


# ---------------------------------------------------------------------------
# FunctionalSimulator: ACAM kernel path == jnp path, end to end
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("match,h_merge,v_merge,sensing", [
    ("exact", "and", "gather", "exact"),
    ("best", "adder", "comparator", "best"),
    ("threshold", "adder", "gather", "threshold"),
])
def test_acam_query_kernel_path_matches_jnp_path(match, h_merge, v_merge,
                                                 sensing):
    cfg = _acam_cfg(match=match, h_merge=h_merge, v_merge=v_merge,
                    sensing=sensing, sl=0.5)
    rng = np.random.default_rng(11)
    stored = _range_grid(21, 10, rng)
    queries = jnp.asarray(rng.random((9, 10)).astype(np.float32))
    a = FunctionalSimulator(cfg, use_kernel=False)
    b = FunctionalSimulator(cfg, use_kernel=True)
    ia, ma = a.query(a.write(stored), queries)
    ib, mb = b.query(b.write(stored), queries)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))


def test_acam_kernel_path_with_c2c_noise_matches_jnp_path():
    """Same RNG stream on both paths: the noisy grids are identical, so the
    kernel/jnp results must still be bit-identical under C2C noise (both
    the grid fold and the shard-invariant bank fold, on 5-D grids)."""
    for fold in ("grid", "bank"):
        cfg = _acam_cfg(variation="c2c", std=0.02)
        rng = np.random.default_rng(7)
        stored = _range_grid(17, 6, rng)
        queries = jnp.asarray(rng.random((6, 6)).astype(np.float32))
        qkey = jax.random.PRNGKey(3)
        a = FunctionalSimulator(cfg, use_kernel=False, c2c_fold=fold)
        b = FunctionalSimulator(cfg, use_kernel=True, c2c_fold=fold)
        ia, ma = a.query(a.write(stored), queries, key=qkey)
        ib, mb = b.query(b.write(stored), queries, key=qkey)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib),
                                      err_msg=fold)
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb),
                                      err_msg=fold)


# ---------------------------------------------------------------------------
# bugfix: best-match merge with match_param > padded_K
# ---------------------------------------------------------------------------
def test_best_match_k_beyond_padded_K_pads_with_minus_one():
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=50,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"))
    sim = FunctionalSimulator(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (21, 12))
    queries = jax.random.uniform(jax.random.PRNGKey(1), (5, 12))
    idx, mask = sim.query(sim.write(stored), queries)   # crashed before
    idx = np.asarray(idx)
    assert idx.shape == (5, 50)
    # padded_K = ceil(21/8)*8 = 24 real+padding rows; the rest is -1 pad
    assert (idx[:, 24:] == -1).all()
    # every real entry appears exactly once among the first 21 winners
    for row in idx:
        assert sorted(r for r in row.tolist() if r >= 0) == list(range(21))


def test_comparator_topk_clamps_and_pads():
    values = jnp.asarray([[[3.0, 1.0], [2.0, 0.5]]])     # (1, nv=2, R=2)
    v, i = merge.v_merge_comparator_topk(values, 7, largest=False)
    assert v.shape == (1, 7) and i.shape == (1, 7)
    np.testing.assert_array_equal(np.asarray(i[0, :4]), [3, 1, 2, 0])
    assert (np.asarray(i[0, 4:]) == -1).all()
    assert np.isinf(np.asarray(v[0, 4:])).all()
    v, i = merge.v_merge_comparator_topk(values, 7, largest=True)
    np.testing.assert_array_equal(np.asarray(i[0, :4]), [0, 2, 1, 3])
    assert (np.asarray(v[0, 4:]) == 0.0).all()


def test_first_k_indices_pads_beyond_row_count():
    mask = jnp.asarray([[1.0, 0.0, 1.0]])
    idx = merge.first_k_indices(mask, 6)
    np.testing.assert_array_equal(np.asarray(idx),
                                  [[0, 2, -1, -1, -1, -1]])


# ---------------------------------------------------------------------------
# bugfix: bcam/tcam queries binarize at the store's threshold
# ---------------------------------------------------------------------------
def test_binary_query_codes_do_not_drift_with_batch_composition():
    cfg = CAMConfig(
        app=AppConfig(distance="hamming", match_type="exact", match_param=1,
                      data_bits=1),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=4, cols=4, cell_type="tcam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"))
    sim = FunctionalSimulator(cfg)
    stored = jax.random.uniform(jax.random.PRNGKey(0), (10, 8))
    state = sim.write(stored)
    # CAMState.lo carries the store's binarization threshold
    np.testing.assert_allclose(float(state.lo),
                               float(jnp.mean(stored)), rtol=1e-6)
    q = jax.random.uniform(jax.random.PRNGKey(1), (8,))
    batch_a = jnp.stack([q, jnp.zeros(8)])          # batch mean pulled low
    batch_b = jnp.stack([q, jnp.ones(8) * 0.95])    # batch mean pulled high
    _, ma = sim.query(state, batch_a)
    _, mb = sim.query(state, batch_b)
    np.testing.assert_array_equal(np.asarray(ma[0]), np.asarray(mb[0]))
    # and the shared threshold makes stored-row self-queries exact matches
    _, mm = sim.query(state, stored)
    assert (np.asarray(mm)[np.arange(10), np.arange(10)] == 1.0).all()


# ---------------------------------------------------------------------------
# bugfix: variation never inverts ACAM ranges; exper table no-op on analog
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6), st.sampled_from(["stat", "exper"]))
@settings(max_examples=10, deadline=None)
def test_noisy_acam_ranges_keep_lo_below_hi(seed, spec):
    rng = np.random.default_rng(seed)
    lo = rng.random((2, 2, 4, 4)).astype(np.float32)
    grid = jnp.asarray(np.stack([lo, lo + 0.01], axis=-1))  # narrow ranges
    cfg = DeviceConfig(device="fefet", variation="both", variation_std=0.5,
                       variation_spec=spec,
                       exper_table=(0.3,) * 8 if spec == "exper" else None)
    key = jax.random.PRNGKey(seed % (2 ** 31))
    d2d = variation.apply_d2d(grid, cfg, 0, key)
    assert (np.asarray(d2d[..., 0]) <= np.asarray(d2d[..., 1])).all()
    keys = variation.split_for_queries(key, 3)
    banked = variation.apply_c2c_banked(grid, cfg, 0, keys, 1)
    assert (np.asarray(banked[..., 0]) <= np.asarray(banked[..., 1])).all()
    batched = variation.apply_c2c_batched(grid, cfg, 0, keys)
    assert (np.asarray(batched[..., 0]) <= np.asarray(batched[..., 1])).all()
    # noise must actually be applied (the sort must not freeze the grid)
    assert not np.array_equal(np.asarray(d2d), np.asarray(grid))


def test_exper_table_is_noop_for_analog_cells():
    """bits == 0 (analog): sigma falls back to the stat STD instead of
    binning analog values through the integer level table."""
    cfg_t = DeviceConfig(device="fefet", variation="d2d", variation_std=0.25,
                         variation_spec="exper",
                         exper_table=(99.0,) * 8)
    cfg_s = DeviceConfig(device="fefet", variation="d2d", variation_std=0.25,
                         variation_spec="stat")
    grid = jnp.ones((1, 1, 2, 2, 2)) * 0.5
    key = jax.random.PRNGKey(0)
    np.testing.assert_array_equal(
        np.asarray(variation.apply_d2d(grid, cfg_t, 0, key)),
        np.asarray(variation.apply_d2d(grid, cfg_s, 0, key)))
    # integer-coded cells (bits > 0) still use the table
    codes = jnp.ones((1, 1, 2, 2)) * 3.0
    with_table = variation.apply_d2d(codes, cfg_t, 3, key)
    without = variation.apply_d2d(codes, cfg_s, 3, key)
    assert not np.array_equal(np.asarray(with_table), np.asarray(without))


def test_noisy_acam_end_to_end_still_matches_wide_ranges():
    """A query at the center of a wide range must still match under noise
    (the old inverted-range bug made exactly these cells go dark)."""
    cfg = _acam_cfg(variation="both", std=0.01)
    rng = np.random.default_rng(5)
    K, N = 11, 6
    centers = rng.random((K, N)).astype(np.float32)
    lo, hi = centers - 0.3, centers + 0.3
    sim = FunctionalSimulator(cfg, use_kernel=True)
    state = sim.write(jnp.asarray(np.stack([lo, hi], axis=-1)))
    idx, mask = sim.query(state, jnp.asarray(centers[[2, 8]]),
                          key=jax.random.PRNGKey(1))
    m = np.asarray(mask)
    assert m[0, 2] == 1.0 and m[1, 8] == 1.0


# ---------------------------------------------------------------------------
# facade: c2c_fold plumbs through (sharded-parity reference)
# ---------------------------------------------------------------------------
def test_camasim_plumbs_c2c_fold():
    cfg = _acam_cfg(variation="c2c", std=0.05)
    sim = CAMASim(cfg, use_kernel=True, c2c_fold="bank")
    assert sim.functional.c2c_fold == "bank"
    ref = FunctionalSimulator(cfg, use_kernel=True, c2c_fold="bank")
    rng = np.random.default_rng(9)
    stored = _range_grid(13, 6, rng)
    queries = jnp.asarray(rng.random((4, 6)).astype(np.float32))
    qkey = jax.random.PRNGKey(2)
    ia, ma = sim.query(sim.write(stored), queries, key=qkey)
    ib, mb = ref.query(ref.write(stored), queries, key=qkey)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb))
    with pytest.raises(ValueError, match="c2c_fold"):
        CAMASim(cfg, c2c_fold="nope")


def test_jnp_path_honors_want_dist_false():
    rng = np.random.default_rng(1)
    stored = _range_grid(9, 5, rng)
    spec = mapping.grid_spec(9, 5, 4, 5)
    grid = mapping.partition_stored(stored, spec)
    qseg = mapping.partition_query(
        jnp.asarray(rng.random((3, 5)).astype(np.float32)), spec)
    kw = dict(distance="range", sensing="exact", sensing_limit=0.0,
              col_valid=mapping.col_valid_mask(spec),
              row_valid=mapping.row_valid_mask(spec))
    d, m = subarray.subarray_query_batched(grid, qseg, use_kernel=False,
                                           want_dist=False, **kw)
    assert d is None
    _, want = subarray.subarray_query_batched(grid, qseg, use_kernel=False,
                                              **kw)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(want))


# ---------------------------------------------------------------------------
# pipelined (bank-blocked) schedule: off-switch bit-identity on range grids
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sensing", ["exact", "best", "threshold"])
@pytest.mark.parametrize("want_dist", [True, False])
def test_range_pipeline_off_bit_identical(sensing, want_dist):
    """The bank-blocked pipelined schedule and the historical per-tile
    grid (sim.pipeline=False) vmap the SAME range tile function, so the
    fused ACAM kernel must agree bitwise across the sensing matrix."""
    rng = np.random.default_rng(29)
    stored = _range_grid(21, 10, rng)
    spec = mapping.grid_spec(21, 10, 8, 4)
    grid = mapping.partition_stored(stored, spec)
    qseg = mapping.partition_query(
        jnp.asarray(rng.random((9, 10)).astype(np.float32)), spec)
    kw = dict(distance="range", sensing=sensing, sensing_limit=0.5,
              threshold=2.0, col_valid=mapping.col_valid_mask(spec),
              row_valid=mapping.row_valid_mask(spec), want_dist=want_dist)
    on = ops.cam_search_fused(grid, qseg, pipeline=True, **kw)
    off = ops.cam_search_fused(grid, qseg, pipeline=False, **kw)
    if want_dist:
        np.testing.assert_array_equal(np.asarray(on[0]), np.asarray(off[0]))
        np.testing.assert_array_equal(np.asarray(on[1]), np.asarray(off[1]))
    else:
        np.testing.assert_array_equal(np.asarray(on), np.asarray(off))


@pytest.mark.parametrize("match,h_merge,v_merge,sensing", [
    ("exact", "and", "gather", "exact"),
    ("best", "adder", "comparator", "best"),
    ("threshold", "adder", "gather", "threshold"),
])
def test_acam_query_pipeline_off_bit_identical(match, h_merge, v_merge,
                                               sensing):
    """End-to-end ACAM FunctionalSimulator: sim.pipeline=False reproduces
    the default pipelined query bit-for-bit."""
    rng = np.random.default_rng(31)
    stored = _range_grid(21, 10, rng)
    queries = jnp.asarray(rng.random((9, 10)).astype(np.float32))
    def mk(pipeline):
        cfg = _acam_cfg(match=match, h_merge=h_merge, v_merge=v_merge,
                        sensing=sensing, sl=0.5, k=3)
        return FunctionalSimulator(
            cfg.replace(sim=dict(use_kernel=True, pipeline=pipeline)))
    son, soff = mk(True), mk(False)
    ion, mon = son.query(son.write(stored), queries)
    ioff, moff = soff.query(soff.write(stored), queries)
    np.testing.assert_array_equal(np.asarray(ion), np.asarray(ioff))
    np.testing.assert_array_equal(np.asarray(mon), np.asarray(moff))
