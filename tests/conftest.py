import os

# Tests run on the single real CPU device (the dry-run sets its own flags
# in its own process; never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
