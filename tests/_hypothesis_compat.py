"""Offline fallback for ``hypothesis``.

The property tests only use a tiny slice of the hypothesis API:
``@given(st.integers(lo, hi), ...)`` with ``@settings(max_examples=...,
deadline=...)``.  This container has no network access, so when the real
package is missing we substitute a deterministic mini-driver that runs each
property over a small, fixed sample of the strategy space (always including
both bounds).  It is NOT a shrinking fuzzer — just enough to keep the
properties executable and meaningful offline.

Usage in tests:  ``from _hypothesis_compat import given, settings,
strategies as st``  (drop-in for the real import; the real package is
preferred when importable).
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # type: ignore

except ImportError:
    import functools
    import random

    # Examples per @given when the fallback driver runs.  Kept small: every
    # example of the jax property tests pays a trace/compile.
    _FALLBACK_EXAMPLES = 5

    class _IntStrategy:
        def __init__(self, lo: int, hi: int):
            self.lo, self.hi = lo, hi

        def samples(self, rng: random.Random, n: int):
            out = [self.lo, self.hi]
            while len(out) < n:
                out.append(rng.randint(self.lo, self.hi))
            return out[:n]

    class _SampledStrategy:
        def __init__(self, elements):
            self.elements = list(elements)

        def samples(self, rng: random.Random, n: int):
            out = list(self.elements)
            while len(out) < n:
                out.append(rng.choice(self.elements))
            return out[:n]

    class strategies:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _IntStrategy:
            return _IntStrategy(min_value, max_value)

        @staticmethod
        def sampled_from(elements) -> "_SampledStrategy":
            return _SampledStrategy(elements)

    def settings(max_examples: int = 100, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats: _IntStrategy):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)
            # deterministic per-test sample set, seeded by the test name
            rng = random.Random(fn.__name__)
            columns = [s.samples(rng, n) for s in strats]
            # rotate each column so examples aren't all-lo / all-hi tuples
            cases = []
            for i in range(n):
                cases.append(tuple(col[(i + j) % n]
                                   for j, col in enumerate(columns)))

            @functools.wraps(fn)
            def runner(*args, **kwargs):
                for case in cases:
                    fn(*args, *case, **kwargs)

            # pytest must not see the original signature, or it would try to
            # inject the strategy-bound parameters as fixtures
            del runner.__wrapped__
            return runner
        return deco
