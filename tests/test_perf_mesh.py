"""Mesh-level perf estimation: degeneracy anchor, link-model properties,
and byte-count parity with the sharded simulator.

Three layers of guarantees:
  * the d=1 mesh prediction degenerates bit-for-bit to the single-chip
    Table IV rollup for every validation target (the calibration anchor);
  * hypothesis properties (offline shim) for the interconnect models:
    H-tree and mesh-link costs are zero below two children/devices and
    monotone non-decreasing in fan-in, footprint, bit widths, device
    count, and payload bytes;
  * a 4-host-device subprocess asserting the per-sensing payload shapes
    the model bills (``merge.shard_merge_payload``) are exactly the
    arrays ``ShardedCAMSimulator._combine`` hands to ``lax.all_gather`` /
    ``lax.pmax`` at d in {2, 4}.
"""
import os
import subprocess
import sys

import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import merge
from repro.core.perf import (MESH_LINKS, MeshSpec, estimate_arch,
                             interconnect, mesh_all_gather, perf_report,
                             predict_search_sharded, sharded_merge_bytes)
from repro.core.validation import TARGETS, mesh_anchor


# ---------------------------------------------------------------------------
# d=1 degeneracy: the calibration anchor
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("target", TARGETS, ids=lambda t: t.name)
def test_mesh_size_1_degenerates_to_single_chip(target):
    """predict_search_sharded at mesh size 1 reproduces the single-chip
    prediction EXACTLY (same floats that pass test_table4_within_8pct)."""
    single, sharded = mesh_anchor(target, devices=1)
    assert sharded.latency_ns == single.latency_ns
    assert sharded.energy_pj == single.energy_pj
    assert sharded.area_um2 == single.area_um2
    # and the mesh contribution is identically zero
    m = sharded.breakdown["mesh"]
    assert m["latency_ns"] == 0.0 and m["energy_pj"] == 0.0
    assert m["area_um2"] == 0.0


@pytest.mark.parametrize("target", TARGETS, ids=lambda t: t.name)
def test_eval_perf_mesh_1_matches_plain_eval_perf(target):
    """The CAMASim facade: eval_perf(mesh=1) == eval_perf(), incl. the
    clock quantization and ops_per_query handling."""
    import jax.numpy as jnp

    from repro.core import CAMASim
    sim = CAMASim(target.config)
    sim.write(jnp.zeros((target.K, target.N)))
    p0 = sim.eval_perf(ops_per_query=target.ops_per_query,
                       clock_hz=target.clock_hz)
    p1 = sim.eval_perf(ops_per_query=target.ops_per_query,
                       clock_hz=target.clock_hz, mesh=1)
    for key in ("latency_ns", "energy_pj", "area_um2", "edp_pj_ns"):
        assert p1[key] == p0[key], key


def test_mesh_prediction_every_link_preset_and_q_amortization():
    """Bigger batches amortize the per-query merge cost; every preset is
    usable; slower links never predict faster merges."""
    t = TARGETS[0]   # DRL: gather path, biggest payload
    arch = estimate_arch(t.config, t.K, t.N)
    for link in ("on_package", "nvlink", "pcb"):
        p1 = predict_search_sharded(t.config, arch, MeshSpec(4, link),
                                    queries_per_batch=1)
        p128 = predict_search_sharded(t.config, arch, MeshSpec(4, link),
                                      queries_per_batch=128)
        m1, m128 = p1.breakdown["mesh"], p128.breakdown["mesh"]
        assert m1["latency_ns"] > 0.0
        # per-query amortized mesh latency shrinks with the batch
        assert m128["latency_ns"] < m1["latency_ns"]
    # ordering of the presets by bandwidth shows up in the serial term
    lat = {name: mesh_all_gather(4, 1 << 20, name)["latency_ns"]
           for name in MESH_LINKS}
    assert lat["on_package"] < lat["nvlink"] < lat["pcb"]


# ---------------------------------------------------------------------------
# per-sensing byte accounting (model side; executed shapes below)
# ---------------------------------------------------------------------------
def test_sharded_merge_bytes_per_sensing_fields():
    gather = sharded_merge_bytes(TARGETS[0].config,
                                 estimate_arch(TARGETS[0].config,
                                               TARGETS[0].K, TARGETS[0].N),
                                 devices=4, queries_per_batch=8)
    assert "match_rows" in gather and "cand_vals" not in gather
    # match lines travel as single bits: Q * nv_local * R / 8 bytes
    assert gather["match_rows"] == 8 * gather["nv_local"] * 64 / 8.0

    voting = sharded_merge_bytes(TARGETS[1].config,
                                 estimate_arch(TARGETS[1].config,
                                               TARGETS[1].K, TARGETS[1].N),
                                 devices=2, queries_per_batch=8)
    assert {"cand_vals", "cand_idx", "dmax"} <= set(voting)
    assert voting["total"] == (voting["cand_vals"] + voting["cand_idx"]
                               + voting["dmax"])


def test_match_k_single_source_of_truth():
    from repro.core import FunctionalSimulator
    for cfg in (TARGETS[0].config, TARGETS[1].config):
        sim = FunctionalSimulator(cfg)
        for padded_K in (8, 64, 4096):
            assert sim.match_k(padded_K) == merge.match_k(
                cfg.app.match_type, cfg.app.match_param, padded_K)


# ---------------------------------------------------------------------------
# interconnect model properties (hypothesis, offline shim)
# ---------------------------------------------------------------------------
@given(st.integers(0, 64), st.integers(0, 5000))
@settings(max_examples=20, deadline=None)
def test_htree_zero_below_two_children_and_monotone(children, area_i):
    area = area_i * 3.7
    w = interconnect.htree_level(children, area)
    if children <= 1 or area <= 0:
        assert (w.length_um, w.latency_ns, w.energy_pj_per_bit) == (0, 0, 0)
    w2 = interconnect.htree_level(children + 1, area + 1.0)
    assert w2.latency_ns >= w.latency_ns
    assert w2.energy_pj_per_bit >= w.energy_pj_per_bit
    assert w2.length_um >= w.length_um


@given(st.integers(0, 32), st.integers(1, 4000), st.integers(1, 512),
       st.integers(1, 64))
@settings(max_examples=20, deadline=None)
def test_level_interconnect_monotone(children, area_i, bits_down, bits_up):
    area = float(area_i)
    ic = interconnect.level_interconnect(children, area, bits_down, bits_up)
    if children <= 1:
        assert ic["latency_ns"] == 0.0 and ic["energy_pj"] == 0.0
        assert ic["area_um2"] == 0.0
    for kids2, area2, bd2, bu2 in ((children + 1, area, bits_down, bits_up),
                                   (children, area + 9.0, bits_down, bits_up),
                                   (children, area, 2 * bits_down, bits_up),
                                   (children, area, bits_down, 2 * bits_up)):
        ic2 = interconnect.level_interconnect(kids2, area2, bd2, bu2)
        for key in ("latency_ns", "energy_pj", "area_um2"):
            assert ic2[key] >= ic[key], (key, kids2, area2, bd2, bu2)


@given(st.integers(1, 64), st.integers(0, 1 << 20))
@settings(max_examples=20, deadline=None)
def test_mesh_link_cost_zero_at_one_device_and_monotone(devices, nbytes):
    for link in MESH_LINKS:
        c = mesh_all_gather(devices, nbytes, link)
        if devices <= 1 or nbytes <= 0:
            assert c["latency_ns"] == 0.0 and c["energy_pj"] == 0.0
        c_d = mesh_all_gather(devices + 1, nbytes, link)
        c_b = mesh_all_gather(devices, nbytes + 4096, link)
        for key in ("latency_ns", "energy_pj", "bytes_on_wire"):
            assert c_d[key] >= c[key], (key, "devices")
            assert c_b[key] >= c[key], (key, "bytes")


def test_bad_mesh_inputs_raise():
    with pytest.raises(KeyError):
        interconnect.get_mesh_link("carrier-pigeon")
    with pytest.raises(ValueError):
        MeshSpec(0)


# ---------------------------------------------------------------------------
# executed-shape parity: the model's payload == what the simulator gathers
# (subprocess: XLA host-device trick must precede jax init)
# ---------------------------------------------------------------------------
_SHAPES_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import math
import jax, jax.numpy as jnp, numpy as np
from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                        DeviceConfig, ShardedCAMSimulator, merge)
from repro.core.perf import estimate_arch, sharded_merge_bytes
from repro.launch.mesh import make_cam_mesh

assert len(jax.devices()) == 4, jax.devices()
K, N, Q, R = 37, 12, 9, 8

rec = []
orig_ag, orig_pmax = jax.lax.all_gather, jax.lax.pmax
def ag(x, *a, **k):
    rec.append(("all_gather", tuple(x.shape)))
    return orig_ag(x, *a, **k)
def pm(x, *a, **k):
    rec.append(("pmax", tuple(x.shape)))
    return orig_pmax(x, *a, **k)
jax.lax.all_gather, jax.lax.pmax = ag, pm

def cfg_for(match, h_merge, v_merge, sensing):
    return CAMConfig(
        app=AppConfig(distance="l2", match_type=match, match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
        circuit=CircuitConfig(rows=R, cols=8, cell_type="mcam",
                              sensing=sensing),
        device=DeviceConfig(device="fefet"))

checks = 0
for d in (2, 4):
    for tag, cfg in (
            ("exact", cfg_for("exact", "and", "gather", "exact")),
            ("threshold", cfg_for("threshold", "adder", "gather",
                                  "threshold")),
            ("best", cfg_for("best", "adder", "comparator", "best")),
            ("voting", cfg_for("best", "voting", "comparator", "best"))):
        sim = ShardedCAMSimulator(cfg, make_cam_mesh(d))
        state = sim.write(jax.random.uniform(jax.random.PRNGKey(0), (K, N)))
        arch = estimate_arch(cfg, K, N)
        traffic = sharded_merge_bytes(cfg, arch, d, Q)
        # model shard geometry == the placed grid's
        nv_pad = state.grid.shape[0]
        assert nv_pad % d == 0 and traffic["nv_local"] == nv_pad // d, \
            (tag, d, traffic["nv_local"], nv_pad)
        assert traffic["rows_pad"] == nv_pad * R, (tag, d)
        rec.clear()
        sim.query(state, jax.random.uniform(jax.random.PRNGKey(1), (Q, N)))
        got = sorted(rec)
        k = sim.sim.match_k(state.spec.padded_K)
        payload = merge.shard_merge_payload(
            cfg.app.match_type, cfg.arch.h_merge, Q=Q,
            nv_local=nv_pad // d, R=R, k=k)
        want = sorted(
            [("all_gather", payload["match_rows"])]
            if "match_rows" in payload else
            [("all_gather", payload["cand_vals"]),
             ("all_gather", payload["cand_idx"])]
            + ([("pmax", payload["dmax"])] if "dmax" in payload else []))
        assert got == want, (tag, d, got, want)
        # and the billed byte count is exactly these shapes x wire widths
        idx_bits = max(1, math.ceil(math.log2(max(2, nv_pad * R))))
        bits = {"match_rows": 1, "cand_vals": 32, "cand_idx": idx_bits,
                "dmax": 32}
        total = sum(math.prod(s) * bits[f] / 8.0
                    for f, s in payload.items())
        assert traffic["total"] == total, (tag, d, traffic["total"], total)
        checks += 1
print(f"SHAPES_OK {checks}")
'''


def _run_subprocess(script: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.multidevice
def test_model_payload_matches_executed_gather_shapes():
    proc = _run_subprocess(_SHAPES_SCRIPT)
    assert proc.returncode == 0 and "SHAPES_OK 8" in proc.stdout, \
        (proc.stdout[-2000:], proc.stderr[-4000:])


# ---------------------------------------------------------------------------
# ShardedCAMSimulator.eval_perf wiring
# ---------------------------------------------------------------------------
def test_sharded_eval_perf_single_device_mesh_matches_camasim():
    import jax
    import jax.numpy as jnp

    from repro.core import CAMASim, ShardedCAMSimulator
    from repro.launch.mesh import make_cam_mesh
    cfg = TARGETS[1].config
    stored = jax.random.uniform(jax.random.PRNGKey(0),
                                (TARGETS[1].K, TARGETS[1].N))
    ref = CAMASim(cfg)
    ref.write(stored)
    sharded = ShardedCAMSimulator(cfg, make_cam_mesh(1))
    with pytest.raises(RuntimeError):
        sharded.eval_perf()
    sharded.write(stored)
    a, b = ref.eval_perf(), sharded.eval_perf()
    for key in ("latency_ns", "energy_pj", "area_um2", "edp_pj_ns", "arch"):
        assert a[key] == b[key], key
    # breakdown carries the (zero) mesh level
    assert b["mesh"]["devices"] == 1.0


def test_perf_report_mesh_entry_scales_with_ops_per_query():
    """out['mesh'] sits next to the ops-scaled latency_ns/energy_pj and
    must scale with them (regression: it used to stay at the 1-op value,
    under-reporting the mesh share by ops_per_query x)."""
    t = TARGETS[2]
    arch = estimate_arch(t.config, t.K, t.N)
    p1 = perf_report(t.config, arch, mesh=4, queries_per_batch=8)
    p10 = perf_report(t.config, arch, mesh=4, queries_per_batch=8,
                      ops_per_query=10)
    assert p10["mesh"]["latency_ns"] == pytest.approx(
        10 * p1["mesh"]["latency_ns"])
    assert p10["mesh"]["energy_pj"] == pytest.approx(
        10 * p1["mesh"]["energy_pj"])
    assert p10["latency_ns"] == pytest.approx(10 * p1["latency_ns"])


def test_perf_report_mesh_energy_grows_with_devices():
    """More chips never search for free: total energy is monotone
    non-decreasing in the mesh size (padding banks + link traffic)."""
    t = TARGETS[2]
    arch = estimate_arch(t.config, t.K, t.N)
    prev = None
    for d in (1, 2, 4, 8):
        p = perf_report(t.config, arch, mesh=d, queries_per_batch=16)
        if prev is not None:
            assert p["energy_pj"] >= prev
        prev = p["energy_pj"]
