"""Sharded CAM search: multi-device parity + merge/sense properties.

Three layers of guarantees:
  * a 4-host-device subprocess sweep asserting ``ShardedCAMSimulator`` is
    bit-identical to the single-device ``FunctionalSimulator`` across all
    {exact, best, threshold} x {l2, l1, hamming, dot} combos, including
    C2C noise (per-bank RNG folding), the Pallas kernel path, ACAM 5-D
    [lo, hi] range grids on the fused range kernel, best-match with
    match_param > padded_K (clamp + -1 pad parity), and the device
    reliability subsystem (slot-keyed fault maps, drift aging, write-verify
    + spare healing, scrub — with and without the mutable-store path);
  * property tests (hypothesis, offline shim) for the cross-device merge
    invariants: the local-top-k + re-rank comparator is split-invariant,
    associative, and (absent score ties) shard-order permutation
    invariant; the gather merge is split-invariant;
  * sense-amplifier monotonicity: loosening ``sensing_limit`` never
    removes a match, for every sensing mode.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import merge, subarray


# ---------------------------------------------------------------------------
# multi-device parity (subprocess: XLA host-device trick must precede
# jax init, reusing the JAX_PLATFORMS=cpu pattern from the batched-search PR)
# ---------------------------------------------------------------------------
_PARITY_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["JAX_PLATFORMS"] = "cpu"
import zlib
import jax, jax.numpy as jnp, numpy as np
from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig, FunctionalSimulator,
                        ShardedCAMSimulator)
from repro.launch.mesh import make_cam_mesh

assert len(jax.devices()) == 4, jax.devices()
mesh = make_cam_mesh(4)
mesh_q = make_cam_mesh(2, 2)

def check(cfg, K=37, N=12, Q=9, use_kernel=False, query_axis=None,
          c2c_tile=1, tag=""):
    m = mesh_q if query_axis else mesh
    # the config-driven facade must be bit-identical to constructing the
    # backends directly: run the whole matrix a third time through
    # CAMASim with sim.backend='sharded' (same mesh geometry via config)
    base_sim = dict(use_kernel=use_kernel, c2c_query_tile=c2c_tile,
                    c2c_fold="bank")
    sim = FunctionalSimulator(cfg.replace(sim=base_sim))
    ssim = ShardedCAMSimulator(cfg.replace(sim=base_sim), m,
                               query_axis=query_axis)
    fac = CAMASim(cfg.replace(sim=dict(
        base_sim, backend="sharded",
        devices=2 if query_axis else 4,
        query_shards=2 if query_axis else 1)))
    k1, k2 = jax.random.split(jax.random.PRNGKey(zlib.crc32(tag.encode())))
    stored = jax.random.uniform(k1, (K, N))
    if cfg.circuit.cell_type == "acam":     # 5-D [lo, hi] range grid
        stored = jnp.stack([stored, stored + 0.2], axis=-1)
    queries = jax.random.uniform(k2, (Q, N))
    qkey = jax.random.PRNGKey(7)
    ia, ma = sim.query(sim.write(stored), queries, key=qkey)
    ib, mb = ssim.query(ssim.write(stored), queries, key=qkey)
    ic, mc = fac.query(fac.write(stored), queries, key=qkey)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib), err_msg=tag)
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb), err_msg=tag)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ic),
                                  err_msg="facade-" + tag)
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mc),
                                  err_msg="facade-" + tag)
    print("OK", tag)

def cfg_for(match, distance, h_merge, v_merge, sensing, variation="none"):
    return CAMConfig(
        app=AppConfig(distance=distance, match_type=match, match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam",
                              sensing=sensing, sensing_limit=0.5),
        device=DeviceConfig(device="fefet", variation=variation,
                            variation_std=0.4))

n = 0
for distance in ("l2", "l1", "hamming", "dot"):
    check(cfg_for("exact", distance, "and", "gather", "exact"),
          tag=f"exact-{distance}")
    check(cfg_for("best", distance, "adder", "comparator", "best"),
          tag=f"best-{distance}")
    check(cfg_for("threshold", distance, "adder", "gather", "threshold"),
          tag=f"threshold-{distance}")
    n += 3

# voting h-merge (the approximate paper merge; global pmax tie-break)
check(cfg_for("best", "l2", "voting", "comparator", "best"), tag="voting")
# C2C noise with per-shard RNG folding, one per match type
check(cfg_for("exact", "hamming", "and", "gather", "exact", "c2c"),
      tag="c2c-exact")
check(cfg_for("best", "l2", "adder", "comparator", "best", "c2c"),
      tag="c2c-best")
check(cfg_for("threshold", "l1", "adder", "gather", "threshold", "c2c"),
      tag="c2c-threshold")
# Pallas fused kernel path (interpret mode on CPU)
check(cfg_for("best", "l2", "adder", "comparator", "best"),
      use_kernel=True, tag="kernel-best")
check(cfg_for("exact", "hamming", "and", "gather", "exact"),
      use_kernel=True, tag="kernel-exact")
# query-axis sharding (2 banks x 2 query shards), incl. c2c cycle slicing
check(cfg_for("best", "l2", "adder", "comparator", "best"), Q=8,
      query_axis="query", tag="qshard-best")
check(cfg_for("best", "l2", "adder", "comparator", "best", "c2c"), Q=8,
      query_axis="query", c2c_tile=2, tag="qshard-c2c")
n += 9

# ACAM 5-D [lo, hi] range grids on the fused range kernel, all sensings,
# jnp path, and C2C on the per-bank fold over the 5-D grid
def acam_cfg(match, h_merge, v_merge, sensing, variation="none"):
    return CAMConfig(
        app=AppConfig(distance="range", match_type=match, match_param=3,
                      data_bits=0),
        arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="acam",
                              sensing=sensing, sensing_limit=0.5),
        device=DeviceConfig(device="fefet", variation=variation,
                            variation_std=0.05))

check(acam_cfg("exact", "and", "gather", "exact"), use_kernel=True,
      tag="acam-kernel-exact")
check(acam_cfg("best", "adder", "comparator", "best"), use_kernel=True,
      tag="acam-kernel-best")
check(acam_cfg("threshold", "adder", "gather", "threshold"),
      use_kernel=True, tag="acam-kernel-threshold")
check(acam_cfg("exact", "and", "gather", "exact"), tag="acam-jnp-exact")
check(acam_cfg("exact", "and", "gather", "exact", "c2c"), use_kernel=True,
      tag="acam-kernel-c2c")
n += 5

# pipelined (bank-blocked) schedule off-switch: sim.pipeline=False on the
# sharded backend must be bit-identical BOTH to the pipelined sharded run
# and to the single-device reference — covering the fused point kernel
# (with the quantized-code int fast path) and the ACAM range kernel
def check_pipeline(cfg, tag=""):
    base = dict(use_kernel=True, c2c_fold="bank")
    k1, k2 = jax.random.split(jax.random.PRNGKey(zlib.crc32(tag.encode())))
    stored = jax.random.uniform(k1, (37, 12))
    if cfg.circuit.cell_type == "acam":
        stored = jnp.stack([stored, stored + 0.2], axis=-1)
    queries = jax.random.uniform(k2, (9, 12))
    ref = FunctionalSimulator(cfg.replace(sim=dict(base, pipeline=True)))
    ia, ma = ref.query(ref.write(stored), queries)
    for pipe in (True, False):
        s = ShardedCAMSimulator(cfg.replace(sim=dict(base, pipeline=pipe)),
                                mesh)
        ib, mb = s.query(s.write(stored), queries)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib),
                                      err_msg=f"pipe-{pipe}-{tag}")
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb),
                                      err_msg=f"pipe-{pipe}-{tag}")
    print("OK pipeline", tag)

check_pipeline(cfg_for("best", "l2", "adder", "comparator", "best"),
               tag="point-best")
check_pipeline(cfg_for("exact", "hamming", "and", "gather", "exact"),
               tag="point-hamming")
check_pipeline(acam_cfg("best", "adder", "comparator", "best"),
               tag="acam-best")
n += 3

# best-match merge with match_param > padded_K: the single-device clamp
# + -1 pad must agree with the sharded candidate re-rank (regression for
# the unclamped jax.lax.top_k crash in v_merge_comparator_topk)
big_k = CAMConfig(
    app=AppConfig(distance="l2", match_type="best", match_param=64,
                  data_bits=3),
    arch=ArchConfig(h_merge="adder", v_merge="comparator"),
    circuit=CircuitConfig(rows=8, cols=8, cell_type="mcam", sensing="best"),
    device=DeviceConfig(device="fefet"))
check(big_k, tag="bigk-best")
n += 1

# search cascade: signature prefilter with top_p_banks = nv must be
# bit-identical to prefilter=off on BOTH backends (per-device routing with
# p_loc = nv_loc degenerates to the full scan), incl. the C2C bank fold
# and the kernel path
def check_cascade(cfg, use_kernel=False, c2c_tile=1, tag=""):
    base_sim = dict(use_kernel=use_kernel, c2c_query_tile=c2c_tile,
                    c2c_fold="bank")
    K, N, Q = 37, 12, 9
    k1, k2 = jax.random.split(jax.random.PRNGKey(zlib.crc32(tag.encode())))
    stored = jax.random.uniform(k1, (K, N))
    queries = jax.random.uniform(k2, (Q, N))
    qkey = jax.random.PRNGKey(7)
    ref = FunctionalSimulator(cfg.replace(sim=base_sim))
    st = ref.write(stored)
    ia, ma = ref.query(st, queries, key=qkey)
    cas = dict(base_sim, prefilter="signature", top_p_banks=st.spec.nv)
    for mk, sim_kw in (("func", {}),
                       ("shard", dict(backend="sharded", devices=4))):
        c = CAMASim(cfg.replace(sim=dict(cas, **sim_kw)))
        ib, mb = c.query(c.write(stored), queries, key=qkey)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib),
                                      err_msg=f"cascade-{mk}-{tag}")
        np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb),
                                      err_msg=f"cascade-{mk}-{tag}")
    print("OK cascade", tag)

check_cascade(cfg_for("exact", "hamming", "and", "gather", "exact"),
              use_kernel=True, tag="exact-kernel")
check_cascade(cfg_for("best", "l2", "adder", "comparator", "best"),
              use_kernel=True, tag="best-kernel")
check_cascade(cfg_for("best", "l2", "voting", "comparator", "best"),
              tag="voting")
check_cascade(cfg_for("threshold", "l1", "adder", "gather", "threshold",
                      "c2c"), c2c_tile=2, tag="threshold-c2c")
n += 4

# device reliability: slot-keyed fault maps, drift aging, write-verify +
# spare-row healing, and background scrub must all be bit-identical across
# shardings (fault maps fold per global row slot; every host-side decision
# — spare planning, scrub-row picks, free-slot order — reads replicated
# data), including the mutable-store insert/delete path
REL = dict(enabled=True, stuck_frac=0.02, dead_row_frac=0.05,
           verify_retries=2, verify_tol=0.3, spares_per_bank=2,
           drift_rate=0.01, scrub_rows=4, fault_seed=11)

def check_reliability(cfg, tag="", mutate=False, query_axis=None,
                      c2c_tile=1, Q=9):
    m = mesh_q if query_axis else mesh
    base_sim = dict(c2c_fold="bank", d2d_fold="row", capacity=64,
                    c2c_query_tile=c2c_tile)
    cfg = cfg.replace(reliability=dict(REL))
    K, N = 37, 12
    k1, k2 = jax.random.split(jax.random.PRNGKey(zlib.crc32(tag.encode())))
    stored = jax.random.uniform(k1, (K, N))
    if cfg.circuit.cell_type == "acam":
        stored = jnp.stack([stored, stored + 0.2], axis=-1)
    queries = jax.random.uniform(k2, (Q, N))
    wkey, qkey, mkey = (jax.random.PRNGKey(3), jax.random.PRNGKey(7),
                        jax.random.PRNGKey(5))
    sim = FunctionalSimulator(cfg.replace(sim=base_sim))
    ssim = ShardedCAMSimulator(cfg.replace(sim=base_sim), m,
                               query_axis=query_axis)
    sa, sb = sim.write(stored, wkey), ssim.write(stored, wkey)
    if mutate:
        extra = jax.random.uniform(jax.random.PRNGKey(13), (5, N))
        sa, ida = sim.insert(sa, extra, mkey)
        sb, idb = ssim.insert(sb, extra, mkey)
        np.testing.assert_array_equal(np.asarray(ida), np.asarray(idb),
                                      err_msg="ids-" + tag)
        sa, sb = sim.delete(sa, ida[:2]), ssim.delete(sb, idb[:2])
    sa, sb = sim.age_tick(sa, 10), ssim.age_tick(sb, 10)
    sa, sb = sim.scrub(sa, mkey), ssim.scrub(sb, mkey)
    ia, ma = sim.query(sa, queries, key=qkey)
    ib, mb = ssim.query(sb, queries, key=qkey)
    np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib), err_msg=tag)
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(mb), err_msg=tag)
    print("OK reliability", tag)

check_reliability(cfg_for("best", "l2", "adder", "comparator", "best"),
                  tag="rel-best")
check_reliability(cfg_for("exact", "hamming", "and", "gather", "exact",
                          "both"), tag="rel-noise-exact")
check_reliability(cfg_for("best", "l2", "adder", "comparator", "best",
                          "d2d"), mutate=True, tag="rel-mutate")
check_reliability(acam_cfg("best", "adder", "comparator", "best"),
                  tag="rel-acam")
check_reliability(cfg_for("best", "l2", "adder", "comparator", "best"),
                  Q=8, query_axis="query", tag="rel-qshard")
n += 5
print(f"PARITY_OK {n}")
'''


def _run_subprocess(script: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.multidevice
def test_sharded_parity_4_devices():
    proc = _run_subprocess(_PARITY_SCRIPT)
    assert proc.returncode == 0 and "PARITY_OK 39" in proc.stdout, \
        (proc.stdout[-2000:], proc.stderr[-4000:])


# ---------------------------------------------------------------------------
# merge invariants (pure functions — no devices needed)
# ---------------------------------------------------------------------------
def _merge_candidates(values: np.ndarray, splits, k: int, largest: bool):
    """Reference two-level comparator: local top-k per shard (global row
    indices tracked), concat in shard order, stable re-rank."""
    vals, idxs = [], []
    offset = 0
    for block in np.split(values, splits, axis=-2):
        v, i = merge.local_topk_candidates(
            jnp.asarray(block), k, largest=largest,
            row_offset=offset)
        vals.append(np.asarray(v))
        idxs.append(np.asarray(i))
        offset += block.shape[-2] * block.shape[-1]
    av = np.concatenate(vals, axis=-1)
    ai = np.concatenate(idxs, axis=-1)
    bv, bi = merge.rerank_candidates(jnp.asarray(av), jnp.asarray(ai), k,
                                     largest=largest)
    return np.asarray(bv), np.asarray(bi)


@given(st.integers(0, 10 ** 6), st.integers(1, 4), st.integers(1, 5))
@settings(max_examples=10, deadline=None)
def test_comparator_merge_split_invariant(seed, n_shards, k):
    """Local-k + gathered re-rank == global comparator, for ANY nv split
    (1 shard == the unsharded path), both directions."""
    rng = np.random.default_rng(seed)
    nv, R = 8, 5
    values = rng.standard_normal((3, nv, R)).astype(np.float32)
    splits = np.cumsum([nv // n_shards] * (n_shards - 1)).tolist()
    for largest in (False, True):
        gv, gi = merge.v_merge_comparator_topk(
            jnp.asarray(values), k, largest=largest)
        sv, si = _merge_candidates(values, splits, k, largest)
        np.testing.assert_array_equal(np.asarray(gi), si)
        np.testing.assert_allclose(np.asarray(gv), sv, rtol=0, atol=0)


@given(st.integers(0, 10 ** 6))
@settings(max_examples=8, deadline=None)
def test_comparator_merge_associative(seed):
    """Tree-reducing candidate lists == flat re-rank (associativity):
    rerank(rerank(A ++ B) ++ C) == rerank(A ++ B ++ C), ties included."""
    rng = np.random.default_rng(seed)
    k = 3
    # quantized values force ties across shards
    blocks = [np.round(rng.standard_normal((2, 4, 4)) * 2) / 2 for _ in
              range(3)]
    cands = []
    offset = 0
    for b in blocks:
        v, i = merge.local_topk_candidates(jnp.asarray(b.astype(np.float32)),
                                           k, largest=False,
                                           row_offset=offset)
        cands.append((np.asarray(v), np.asarray(i)))
        offset += b.shape[-2] * b.shape[-1]
    flat_v = jnp.asarray(np.concatenate([c[0] for c in cands], axis=-1))
    flat_i = jnp.asarray(np.concatenate([c[1] for c in cands], axis=-1))
    fv, fi = merge.rerank_candidates(flat_v, flat_i, k, largest=False)
    # tree: (A ++ B) first, then ++ C
    ab_v = jnp.asarray(np.concatenate([cands[0][0], cands[1][0]], axis=-1))
    ab_i = jnp.asarray(np.concatenate([cands[0][1], cands[1][1]], axis=-1))
    tv, ti = merge.rerank_candidates(ab_v, ab_i, k, largest=False)
    tv2 = jnp.concatenate([tv, jnp.asarray(cands[2][0])], axis=-1)
    ti2 = jnp.concatenate([ti, jnp.asarray(cands[2][1])], axis=-1)
    tv3, ti3 = merge.rerank_candidates(tv2, ti2, k, largest=False)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(ti3))
    np.testing.assert_array_equal(np.asarray(fv), np.asarray(tv3))


@given(st.integers(0, 10 ** 6), st.integers(2, 4))
@settings(max_examples=8, deadline=None)
def test_comparator_merge_shard_order_permutation_invariant(seed, n_shards):
    """With continuous (tie-free) scores the merged winner set does not
    depend on the order shards contribute their candidates."""
    rng = np.random.default_rng(seed)
    nv, R, k = 8, 4, 4
    values = rng.standard_normal((nv, R)).astype(np.float32)
    splits = np.split(np.arange(nv), n_shards)
    cands = []
    for shard in splits:
        v, i = merge.local_topk_candidates(
            jnp.asarray(values[shard]), k, largest=False,
            row_offset=int(shard[0]) * R)
        cands.append((np.asarray(v), np.asarray(i)))
    perm = rng.permutation(n_shards)
    v0 = jnp.asarray(np.concatenate([cands[j][0] for j in range(n_shards)]))
    i0 = jnp.asarray(np.concatenate([cands[j][1] for j in range(n_shards)]))
    vp = jnp.asarray(np.concatenate([cands[j][0] for j in perm]))
    ip = jnp.asarray(np.concatenate([cands[j][1] for j in perm]))
    bv0, bi0 = merge.rerank_candidates(v0, i0, k, largest=False)
    bvp, bip = merge.rerank_candidates(vp, ip, k, largest=False)
    np.testing.assert_array_equal(np.asarray(bi0), np.asarray(bip))
    np.testing.assert_allclose(np.asarray(bv0), np.asarray(bvp), atol=0)


@given(st.integers(0, 10 ** 6), st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_gather_merge_split_invariant(seed, n_shards):
    """Concatenating per-shard match-line blocks in bank order == the
    unsharded gather, and first-k indices agree for every k."""
    rng = np.random.default_rng(seed)
    nv, R = 8, 5
    rows = (rng.random((2, nv, R)) < 0.3).astype(np.float32)
    full = merge.v_merge_gather(jnp.asarray(rows))
    splits = np.cumsum([nv // n_shards] * (n_shards - 1)).tolist()
    parts = [np.asarray(merge.v_merge_gather(jnp.asarray(b)))
             for b in np.split(rows, splits, axis=-2)]
    np.testing.assert_array_equal(np.asarray(full),
                                  np.concatenate(parts, axis=-1))
    for k in (1, 3, nv * R):
        ia = merge.first_k_indices(jnp.asarray(full), k)
        ib = merge.first_k_indices(
            jnp.asarray(np.concatenate(parts, axis=-1)), k)
        np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))


def test_first_k_indices_ignores_trailing_zero_banks():
    """Bank padding appends always-zero match lines; indices must not
    move (the sharded simulator slices the mask but reuses the indices)."""
    mask = jnp.asarray([[0.0, 1.0, 0.0, 1.0, 1.0, 0.0]])
    padded = jnp.pad(mask, ((0, 0), (0, 10)))
    for k in (1, 2, 4):
        np.testing.assert_array_equal(
            np.asarray(merge.first_k_indices(mask, k)),
            np.asarray(merge.first_k_indices(padded, k)))


# ---------------------------------------------------------------------------
# sense monotonicity: loosening the sensing limit never removes a match
# ---------------------------------------------------------------------------
@given(st.integers(0, 10 ** 6))
@settings(max_examples=10, deadline=None)
def test_sense_monotone_in_sensing_limit(seed):
    rng = np.random.default_rng(seed)
    dist = jnp.asarray(rng.random((2, 3, 2, 8)).astype(np.float32) * 4)
    row_valid = jnp.asarray((rng.random((3, 8)) < 0.9).astype(np.float32))
    limits = sorted(rng.random(4) * 3)
    for sensing in ("exact", "best", "threshold"):
        prev = None
        for sl in limits:
            m = np.asarray(subarray.sense(dist, sensing, float(sl),
                                          threshold=1.0,
                                          row_valid=row_valid))
            if prev is not None:
                assert (m >= prev).all(), (sensing, sl)
            prev = m
