"""Sublinear-search cascade benchmark: recall / latency / qps vs store size.

For each store size K the sweep records three rows:

  ``cascade_full_n{K}``   exact full scan over every bank (the baseline);
  ``cascade_route_n{K}``  IVF-clustered placement + signature prefilter at
                          the smallest ``top_p_banks`` on the ladder whose
                          recall vs the full scan clears the floor (0.95);
  ``cascade_pnv_n{K}``    signature prefilter with ``top_p_banks = nv`` —
                          the degenerate cascade, which must match the full
                          scan bit-for-bit (``match=True``);

plus one ``cascade_scaling`` summary row asserting the point of the PR:
full-scan qps decays ~1/K while routed qps decays sublinearly (the routed
qps ratio across the size ladder stays well under the store-size ratio).
The route row also carries the estimator's end-to-end billing for the same
knobs (``pred_e_frac``) so measured wall-time and predicted energy move
together, plus the ``CAMASim.select_cascade`` clamp verdict: when the
rung's own billing predicts a LOSS vs the full scan (``pred_e_frac`` >= 1)
the shipped deployment falls back to ``prefilter='off'``
(``clamped=True``, ``shipped=off``).

Store: a ~64-center gaussian mixture (cluster structure for IVF to find);
queries perturb stored rows, so each query's true row is its own best
match and recall is measured against the full scan's top-k per query.
"""
from __future__ import annotations

import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.camasim import CAMASim
from repro.core.config import CAMConfig

RECALL_FLOOR = 0.95
P_LADDER = (4, 8, 16, 32, 64, 128, 256, 512)


def _time(f, *args, n=2, reps=2):
    for _ in range(1):
        jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*args))
        best = min(best, (time.perf_counter() - t0) / n * 1e6)
    return best


def _cfg(backend, prefilter="off", top_p=None):
    sim = dict(use_kernel=True)
    if backend == "sharded":
        sim.update(backend="sharded", devices=len(jax.devices()))
    if prefilter != "off":
        sim.update(prefilter=prefilter, top_p_banks=top_p)
    return CAMConfig.from_dict(dict(
        app=dict(distance="l2", match_type="best", match_param=4,
                 data_bits=4),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=64, cols=64, cell_type="mcam", sensing="best"),
        device=dict(device="fefet", variation="none"),
        sim=sim))


def make_data(K, N, Q, centers=64, seed=0):
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(centers, N)).astype(np.float32)
    stored = (c[rng.integers(0, centers, K)]
              + 0.15 * rng.normal(size=(K, N))).astype(np.float32)
    queries = (stored[rng.integers(0, K, Q)]
               + 0.02 * rng.normal(size=(Q, N))).astype(np.float32)
    return jnp.asarray(stored), jnp.asarray(queries)


def _recall(route_idx, full_idx):
    per_q = []
    for r, f in zip(np.asarray(route_idx), np.asarray(full_idx)):
        truth = set(f[f >= 0].tolist())
        if truth:
            per_q.append(len(set(r[r >= 0].tolist()) & truth) / len(truth))
    return float(np.mean(per_q)) if per_q else 1.0


def run_size(K, N, Q, backend):
    stored, queries = make_data(K, N, Q)

    full = CAMASim(_cfg(backend))
    st_full = full.write(stored)
    fi, fm = full.query(st_full, queries)
    us_full = _time(lambda q: full.query(st_full, q)[0], queries)
    qps_full = Q / (us_full * 1e-6)
    nv = st_full.spec.nv
    print(f"cascade_full_n{K},{us_full:.0f},"
          f"qps={qps_full:.1f}_rows={K}_banks={nv}")

    # degenerate cascade: top_p = nv must be bit-identical to the scan
    pnv = CAMASim(_cfg(backend, prefilter="signature", top_p=nv))
    st_pnv = pnv.write(stored)
    pi, pm = pnv.query(st_pnv, queries)
    ok = bool(np.array_equal(np.asarray(pi), np.asarray(fi))
              and np.array_equal(np.asarray(pm), np.asarray(fm)))
    us_pnv = _time(lambda q: pnv.query(st_pnv, q)[0], queries)
    print(f"cascade_pnv_n{K},{us_pnv:.0f},p={nv}_match={ok}")

    # IVF routing: one clustered write, then walk the bank-budget ladder
    # (top_p only affects the query) to the smallest p clearing the floor
    route = CAMASim(_cfg(backend, prefilter="ivf", top_p=P_LADDER[0]))
    st_route = route.write(stored)
    p_star, rec, us_route = nv, 1.0, us_full
    for p in [p for p in P_LADDER if p < nv] + [nv]:
        sim_p = CAMASim(_cfg(backend, prefilter="ivf", top_p=p))
        ri, _ = sim_p.query(st_route, queries)
        rec = _recall(ri, fi)
        if rec >= RECALL_FLOOR:
            p_star = p
            us_route = _time(lambda q: sim_p.query(st_route, q)[0],
                             queries)
            break
    qps_route = Q / (us_route * 1e-6)
    # estimator clamp (CAMASim.select_cascade): a rung whose own billing
    # says the cascade costs >= the full scan (the signature slab on a
    # small grid: n=2048 billed e_frac=1.186) is never shipped — the
    # deployment falls back to prefilter='off'.  The measured routed qps
    # stays on the row (it's what the scaling trend is computed from);
    # ``shipped``/``shipped_qps`` are what the clamp actually deploys.
    sel, pred = full.select_cascade([p_star], entries=K, dims=N)
    e_frac = pred[p_star]["energy_pj"] / pred[None]["energy_pj"]
    clamped = sel is None
    ship_p = "off" if clamped else sel
    ship_qps = qps_full if clamped else qps_route
    print(f"cascade_route_n{K},{us_route:.0f},"
          f"recall={rec:.3f}_floor={RECALL_FLOOR:.3f}_p={p_star}_"
          f"qps={qps_route:.1f}_speedup={us_full / us_route:.2f}x_"
          f"pred_e_frac={e_frac:.3f}_clamped={clamped}_"
          f"shipped={ship_p}_shipped_qps={ship_qps:.1f}")
    return dict(K=K, qps_full=qps_full, qps_route=qps_route,
                p=p_star, recall=rec, match=ok,
                speedup=us_full / us_route)


def main(ci: bool = True, backend: str = "functional"):
    sizes = (2048, 8192) if ci else (4096, 16384, 65536)
    N, Q = 64, 16
    out = [run_size(K, N, Q, backend) for K in sizes]
    ratio_k = out[-1]["K"] / out[0]["K"]
    ratio_full = out[0]["qps_full"] / max(out[-1]["qps_full"], 1e-9)
    ratio_route = out[0]["qps_route"] / max(out[-1]["qps_route"], 1e-9)
    # the sublinear signature on this interpret-mode proxy: routed qps
    # decays much slower than the full scan's, i.e. the cascade's
    # advantage GROWS with store size (the speedup trend)
    sub = bool(ratio_route < 0.5 * ratio_full)
    trend = ":".join(f"{o['speedup']:.2f}x" for o in out)
    print(f"cascade_scaling,0,backend={backend}_sizes={len(out)}_"
          f"kx={ratio_k:.0f}_full_qps_decay={ratio_full:.1f}x_"
          f"route_qps_decay={ratio_route:.1f}x_speedup_trend={trend}_"
          f"sublinear={sub}")


if __name__ == "__main__":
    be = "functional"
    if "--backend" in sys.argv:
        be = sys.argv[sys.argv.index("--backend") + 1]
    main(ci="--full" not in sys.argv, backend=be)
