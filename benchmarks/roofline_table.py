"""Format the dry-run roofline table (reads experiments/dryrun/<tag>/)."""
from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def load(tag: str = "baseline", mesh: str = "single") -> List[dict]:
    d = os.path.join(RESULTS_DIR, tag, mesh)
    if not os.path.isdir(d):
        return []
    out = []
    for f in sorted(os.listdir(d)):
        if f.endswith(".json"):
            with open(os.path.join(d, f)) as fh:
                out.append(json.load(fh))
    return out


def fmt_row(e: dict) -> str:
    r = e["roofline"]
    ms = lambda s: f"{s * 1e3:9.2f}"
    return (f"{e['arch']:22s} {e['shape']:12s} {e['kind']:8s} "
            f"{ms(r['t_compute'])} {ms(r['t_memory'])} "
            f"{ms(r['t_collective'])}  {r['bottleneck'][:4]:4s} "
            f"{r['useful_flops_ratio']:6.3f} {r['roofline_fraction']:6.3f}")


HEADER = (f"{'arch':22s} {'shape':12s} {'kind':8s} "
          f"{'t_comp_ms':>9s} {'t_mem_ms':>9s} {'t_coll_ms':>9s}  "
          f"{'bott':4s} {'useful':>6s} {'frac':>6s}")


def table(tag: str = "baseline", mesh: str = "single") -> str:
    rows = load(tag, mesh)
    lines = [f"## Roofline ({tag}, {mesh} mesh, "
             f"{rows[0]['chips'] if rows else '?'} chips)", HEADER]
    lines += [fmt_row(e) for e in rows]
    return "\n".join(lines)


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else "baseline"
    for mesh in ("single", "multi"):
        rows = load(tag, mesh)
        if rows:
            print(table(tag, mesh))
            print()


if __name__ == "__main__":
    main()
