"""Serve-loop benchmarks: tail autoscaling, SLO latency percentiles,
streaming insert throughput.

Three measurements over ``CAMSearchServer`` against a resident store:

* ``serve_autoscale_tail`` — a 1-request tail step with query-axis
  autoscaling vs fixed-batch padding (bit-identical answers asserted).
* ``serve_engine_p50p99_<backend>`` — a mixed stream of SLO-tagged
  searches and mutations through the continuous-batching loop;
  per-request submit→finish p50/p99 (microseconds) per SLO tag, with a
  ``floor_p99_us=`` ceiling ``check_floors`` enforces in CI and a
  ``match`` bit proving the whole interleaved trace replays
  bit-identically on a second server (determinism + routing parity).
* ``serve_inserts_<backend>`` — measured single-row streaming insert
  rate next to the estimator's like-for-like serving proxy
  (``perf_report()['inserts_per_s']``, device write + host engine-step
  overhead) and the raw device figure
  (``device_inserts_per_s``); the ``est_ratio``/``ratio_ceil`` pair is
  a ``check_floors`` guard on estimate-vs-measurement drift.

    PYTHONPATH=src python -m benchmarks.serve_bench [--backend B]

``--backend`` is ``functional`` (default), ``sharded``, or ``both``.
"""
from __future__ import annotations

import sys
import time

K, N = 4096, 128          # resident store (autoscale-tail measurement)
SERVE_BATCH = 64          # fixed-batch padding width
REPS = 7

ENGINE_K, ENGINE_N = 2048, 64      # serve-engine stream measurement
ENGINE_BATCH = 16
# generous CI ceiling: p99 request latency through the serve loop (the
# loop adds queueing on top of one jitted batched search, so this is a
# regression tripwire, not a performance claim)
FLOOR_P99_US = 2_000_000
# drift tripwire for the insert-rate estimate: measured vs estimated may
# disagree by this factor either way (CI wall clocks are noisy and the
# host-overhead constant is a one-point calibration) — but never again by
# the 8800x the device-only figure was off by
RATIO_CEIL = 50


def _tail_step_time(srv, query, reps: int = REPS) -> float:
    """Median wall time of a 1-request step (tail of the stream)."""
    for _ in range(2):                        # warm the jit cache
        srv.submit(query)
        srv.step()
    ts = []
    for _ in range(reps):
        srv.submit(query)
        t0 = time.perf_counter()
        srv.step()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _autoscale_tail_row() -> None:
    import jax
    import numpy as np

    from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                            CircuitConfig, DeviceConfig, SimConfig)
    from repro.runtime import CAMSearchServer

    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=128, cols=128, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig(serve_batch=SERVE_BATCH))
    sim = CAMASim(cfg)
    state = sim.write(jax.random.uniform(jax.random.PRNGKey(0), (K, N)))
    query = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (N,)))

    fixed = CAMSearchServer(sim, state)
    auto = CAMSearchServer(sim, state, autoscale=True)
    t_fixed = _tail_step_time(fixed, query)
    t_auto = _tail_step_time(auto, query)

    # the autoscaled tail answers must equal the fixed-batch ones
    ok = all(
        np.array_equal(a.indices, b.indices)
        and np.array_equal(a.mask, b.mask)
        for a, b in zip(fixed.finished, auto.finished))

    print(f"serve_autoscale_tail,{t_auto * 1e6:.0f},"
          f"fixed_us={t_fixed * 1e6:.0f}_speedup={t_fixed / t_auto:.2f}x_"
          f"batch={SERVE_BATCH}_rows={K}_match={ok}")


def _engine_cfg(backend: str):
    from repro.core import CAMConfig
    return CAMConfig.from_dict(dict(
        app=dict(distance="l2", match_type="best", match_param=3,
                 data_bits=3),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=64, cols=64, cell_type="mcam", sensing="best"),
        device=dict(device="fefet"),
        sim=dict(backend=backend, serve_batch=ENGINE_BATCH,
                 serve_queue=4096, capacity=ENGINE_K + 512,
                 d2d_fold="row", prefilter="signature", top_p_banks=8)))


def _drive_stream(srv, queries, extra) -> None:
    """Interleaved SLO-tagged searches + mutations (4 searches : 1 mut)."""
    import numpy as np
    mut = 0
    for i, q in enumerate(queries):
        srv.submit(q, slo="interactive" if i % 2 else "batch")
        if i % 4 == 3:
            if mut % 2 == 0:
                srv.submit_insert(extra[mut % len(extra)][None])
            else:
                srv.submit_delete(np.asarray([(7 * mut) % ENGINE_K]))
            mut += 1
        if i % ENGINE_BATCH == ENGINE_BATCH - 1:
            srv.step()
    srv.run()


def _serve_engine_rows(backend: str) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import CAMASim
    from repro.runtime import CAMSearchServer

    sim = CAMASim(_engine_cfg(backend))
    stored = jax.random.uniform(jax.random.PRNGKey(0), (ENGINE_K, ENGINE_N))
    stored = stored.at[0].set(0.0).at[1].set(1.0)   # pin the quant scale
    wkey = jax.random.PRNGKey(5)
    queries = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(1), (96, ENGINE_N)))
    extra = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(2), (16, ENGINE_N)))

    def fresh_server():
        return CAMSearchServer(sim, sim.write(jnp.asarray(stored), wkey),
                               key=jax.random.PRNGKey(9))

    warm = fresh_server()                    # warm every jit shape first
    _drive_stream(warm, queries[:32], extra)

    srv = fresh_server()
    _drive_stream(srv, queries, extra)
    stats = srv.latency_stats()

    # determinism/parity bit: the identical stream on a second server
    # replays bit-identically (covers mutation keys AND pad routing)
    rep = fresh_server()
    _drive_stream(rep, queries, extra)
    ok = len(srv.finished) == len(rep.finished) and all(
        a.rid == b.rid
        and (not hasattr(a, "query")
             or (np.array_equal(a.indices, b.indices)
                 and np.array_equal(a.mask, b.mask)))
        for a, b in zip(srv.finished, rep.finished))

    s = stats.get("interactive", {"p50_us": 0.0, "p99_us": 0.0, "n": 0})
    m = stats.get("mutation", {"p50_us": 0.0, "p99_us": 0.0, "n": 0})
    print(f"serve_engine_p50p99_{backend},{s['p50_us']:.0f},"
          f"p99_us={s['p99_us']:.0f}_floor_p99_us={FLOOR_P99_US}_"
          f"batch_p50_us={stats['batch']['p50_us']:.0f}_"
          f"mut_p50_us={m['p50_us']:.0f}_mut_p99_us={m['p99_us']:.0f}_"
          f"n={len(srv.finished)}_batch={ENGINE_BATCH}_rows={ENGINE_K}_"
          f"match={ok}")

    # streaming single-row insert rate vs the estimator's figures.
    # Like-for-like: ``est_inserts_per_s`` is the estimator's SERVING
    # proxy (device write + engine-step overhead) — the same quantity the
    # wall clock measures here; ``device_inserts_per_s`` (device write
    # alone, the old inflated figure) rides along labeled for what it is.
    # ``est_ratio`` = max(measured/est, est/measured) with ``ratio_ceil``
    # enforced by check_floors, so the estimate can't silently drift
    # 8800x absurd again.
    ins_srv = fresh_server()
    ins_srv.submit_insert(extra[0][None]); ins_srv.step()   # warm
    t0 = time.perf_counter()
    n_ins = 12
    for i in range(n_ins):
        ins_srv.submit_insert(extra[(1 + i) % len(extra)][None])
        ins_srv.step()
    dt = time.perf_counter() - t0
    measured = n_ins / dt
    perf = sim.eval_perf()
    est = perf["inserts_per_s"]
    dev = perf["device_inserts_per_s"]
    ratio = max(measured / est, est / measured) if measured and est else 0.0
    ok_ins = measured > 0 and est > 0
    print(f"serve_inserts_{backend},{dt / n_ins * 1e6:.0f},"
          f"inserts_per_s={measured:.0f}_est_inserts_per_s={est:.0f}_"
          f"device_inserts_per_s={dev:.0f}_est_ratio={ratio:.1f}_"
          f"ratio_ceil={RATIO_CEIL}_rows={ENGINE_K}_match={ok_ins}")


def main(backend: str = "functional", tail: bool = True) -> None:
    if tail:
        _autoscale_tail_row()
    for b in (("functional", "sharded") if backend == "both"
              else (backend,)):
        _serve_engine_rows(b)


if __name__ == "__main__":
    be = "functional"
    if "--backend" in sys.argv:
        be = sys.argv[sys.argv.index("--backend") + 1]
    main(backend=be, tail="--no-tail" not in sys.argv)
