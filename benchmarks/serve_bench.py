"""Serve-loop tail latency: query-axis autoscaling vs fixed-batch padding.

``CAMSearchServer`` pads every step to one compiled batch shape.  For a
mostly-idle server that means a 1-request tail still streams the full
``serve_batch``-wide query block through the grid.  With
``autoscale=True`` the padded width comes from the power-of-two ladder
{1, ..., serve_batch} by queue depth, so the tail step shrinks to width
1.  This benchmark measures that tail step (one resident request) both
ways and asserts the answers stayed bit-identical.

    PYTHONPATH=src python -m benchmarks.serve_bench
"""
from __future__ import annotations

import time

K, N = 4096, 128          # resident store
SERVE_BATCH = 64          # fixed-batch padding width
REPS = 7


def _tail_step_time(srv, query, reps: int = REPS) -> float:
    """Median wall time of a 1-request step (tail of the stream)."""
    for _ in range(2):                        # warm the jit cache
        srv.submit(query)
        srv.step()
    ts = []
    for _ in range(reps):
        srv.submit(query)
        t0 = time.perf_counter()
        srv.step()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def main() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                            CircuitConfig, DeviceConfig, SimConfig)
    from repro.runtime import CAMSearchServer

    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=128, cols=128, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig(serve_batch=SERVE_BATCH))
    sim = CAMASim(cfg)
    state = sim.write(jax.random.uniform(jax.random.PRNGKey(0), (K, N)))
    query = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (N,)))

    fixed = CAMSearchServer(sim, state)
    auto = CAMSearchServer(sim, state, autoscale=True)
    t_fixed = _tail_step_time(fixed, query)
    t_auto = _tail_step_time(auto, query)

    # the autoscaled tail answers must equal the fixed-batch ones
    ok = all(
        np.array_equal(a.indices, b.indices)
        and np.array_equal(a.mask, b.mask)
        for a, b in zip(fixed.finished, auto.finished))

    print(f"serve_autoscale_tail,{t_auto * 1e6:.0f},"
          f"fixed_us={t_fixed * 1e6:.0f}_speedup={t_fixed / t_auto:.2f}x_"
          f"batch={SERVE_BATCH}_rows={K}_match={ok}")


if __name__ == "__main__":
    main()
