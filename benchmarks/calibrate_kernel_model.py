"""Fit the kernel measured-model constants on THIS machine.

``kernels.cam_search.choose_q_tile`` ranks Q-tile ladder rungs with two
machine constants: ``STEP_OVERHEAD_S`` (per-grid-step dispatch seconds)
and ``BCAST_BUDGET_BYTES`` (the VPU broadcast-block cache cliff for the
no-matmul distances).  The shipped defaults were measured on the CI
container; on different hardware re-fit them here and pin the results via
the ``CAMASIM_STEP_OVERHEAD_S`` / ``CAMASIM_BCAST_BUDGET_BYTES``
environment variables, ``sim.step_overhead_s`` / ``sim.bcast_budget_bytes``
config fields, or ``cam_search.set_kernel_model``.

Two fits:

1. **Step overhead** — time the pipelined fused search at every feasible
   rung of the Q-tile ladder on a residency-friendly geometry.  With the
   store VMEM-resident the streamed traffic is rung-independent, so the
   wall-clock model reduces to ``t(qt) = a + steps(qt) * overhead`` and
   ``overhead`` falls out of a least-squares line over the rungs.
2. **Broadcast cliff** — walk the ladder on the no-matmul (l1) geometry
   and find the first rung whose per-query time jumps past the cliff
   ratio; the recommended budget sits just under that rung's broadcast
   block.  On machines with no observable cliff the default is kept.

The fit only moves the RANKING constants — ``kernel_bench.py``'s
qps-monotone contract and the ranking check below stay the regression
guard: the rung the fitted model picks must be within the measured
top-3 (model and measurement agree on what matters).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import cam_search, ops


def _time(f, *args, n=3, reps=5):
    for _ in range(2):
        jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*args))
        best = min(best, (time.perf_counter() - t0) / n)
    return best


def _feasible_rungs(banks, segs, R, C, Q):
    return [qt for qt in cam_search.Q_TILES if 8 <= qt <= Q]


def fit_step_overhead(banks=4, segs=1, R=128, C=64, Q=256):
    """Least-squares STEP_OVERHEAD_S from the rung sweep (seconds)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    stored = jax.random.uniform(k1, (banks, segs, R, C))
    queries = jax.random.uniform(k2, (Q, segs, C))
    vb = cam_search.resident_banks(banks, segs, R, C)
    blocks = banks // vb if vb else banks * segs
    xs, ys = [], []
    for qt in _feasible_rungs(banks, segs, R, C, Q):
        t = _time(lambda s, q, qt=qt: ops.cam_search(
            s, q, distance="l2", q_tile=qt), stored, queries)
        steps = blocks * (-(-Q // qt))
        xs.append(float(steps))
        ys.append(t)
        print(f"calibrate_step_q{qt},{t * 1e6:.0f},steps={steps}_"
              f"s_per_q={t / Q:.2e}")
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    var = sum((x - mx) ** 2 for x in xs)
    cov = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
    return max(cov / var, 1e-7) if var > 0 else cam_search.STEP_OVERHEAD_S


def find_bcast_cliff(banks=8, segs=1, R=512, C=128, Q=256, ratio=2.0):
    """First ladder rung whose per-query l1 time jumps past ``ratio``x the
    best rung so far; returns the recommended byte budget (the block one
    rung under the cliff) or None when no cliff shows."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    stored = jax.random.uniform(k1, (banks, segs, R, C))
    queries = jax.random.uniform(k2, (Q, segs, C))
    vb = cam_search.resident_banks(banks, segs, R, C) or 1
    best, prev_bytes = float("inf"), None
    for qt in _feasible_rungs(banks, segs, R, C, Q):
        t = _time(lambda s, q, qt=qt: ops.cam_search(
            s, q, distance="l1", q_tile=qt), stored, queries, n=1, reps=3)
        per_q = t / Q
        bcast = 4 * qt * vb * segs * R * C
        print(f"calibrate_bcast_q{qt},{t * 1e6:.0f},"
              f"bcast_bytes={bcast}_s_per_q={per_q:.2e}")
        if per_q > ratio * best and prev_bytes is not None:
            return prev_bytes
        best = min(best, per_q)
        prev_bytes = bcast
    return None


def check_ranking(overhead_s, banks=4, segs=1, R=128, C=64, Q=256):
    """The fitted model's rung must land in the measured top-3."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    stored = jax.random.uniform(k1, (banks, segs, R, C))
    queries = jax.random.uniform(k2, (Q, segs, C))
    measured = {}
    for qt in _feasible_rungs(banks, segs, R, C, Q):
        measured[qt] = _time(lambda s, q, qt=qt: ops.cam_search(
            s, q, distance="l2", q_tile=qt), stored, queries, n=1, reps=3)
    top3 = sorted(measured, key=measured.get)[:3]
    pick = cam_search.choose_q_tile(R, C, 1, banks=banks, segs=segs,
                                    step_overhead_s=overhead_s)
    pick = min(pick, Q)
    ok = pick in top3
    print(f"calibrate_ranking,0,pick={pick}_top3={'/'.join(map(str, top3))}_"
          f"rank_ok={ok}")
    return ok


def main():
    overhead = fit_step_overhead()
    print(f"calibrate_fit,0,step_overhead_s={overhead:.3e}_"
          f"default={cam_search.STEP_OVERHEAD_S:.3e}")
    budget = find_bcast_cliff()
    if budget is None:
        budget = cam_search.BCAST_BUDGET_BYTES
        print(f"calibrate_cliff,0,found=False_kept_default={budget}")
    else:
        print(f"calibrate_cliff,0,found=True_bcast_budget_bytes={budget}")
    check_ranking(overhead)
    print()
    print("# pin the fitted constants for this machine:")
    print(f"export CAMASIM_STEP_OVERHEAD_S={overhead:.3e}")
    print(f"export CAMASIM_BCAST_BUDGET_BYTES={budget}")


if __name__ == "__main__":
    main()
