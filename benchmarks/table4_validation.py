"""Paper Table IV validation: DRL / MANN / HDC latency+energy (+accuracy).

Perf: the circuit LUT (core/perf/devices.py) is calibrated so the
hierarchical rollup reproduces the paper's own simulated numbers; this
benchmark asserts the deviation stays within +-8%.

Accuracy: the real tasks need external datasets (Omniglot / UCI / Atari);
we run the structurally-faithful synthetic MANN analogue (mann_task.py)
through the full functional pipeline and report it next to the paper's
value.  DRL's test score (169.5) needs an RL environment — noted as n/a.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import CAMASim
from repro.core.validation import TARGETS

from . import mann_task


def run(fast: bool = False):
    rows = []
    for t in TARGETS:
        sim = CAMASim(t.config)
        sim.write(jnp.zeros((t.K, t.N)))
        t0 = time.perf_counter()
        perf = sim.eval_perf(ops_per_query=t.ops_per_query,
                             clock_hz=t.clock_hz)
        dt_us = (time.perf_counter() - t0) * 1e6
        lat, en = perf["latency_ns"], perf["energy_pj"]
        dev_lat = 100 * (lat / t.sim_latency_ns - 1)
        dev_en = 100 * (en / t.sim_energy_pj - 1)
        rows.append((t.name, lat, t.sim_latency_ns, dev_lat, en,
                     t.sim_energy_pj, dev_en, dt_us))

    acc_cam = acc_fp = float("nan")
    if not fast:
        net = mann_task.train_embedding(dim=128, steps=400)
        acc_fp = mann_task.eval_mann(net, None, use_cam=False, episodes=12)
        acc_cam = mann_task.eval_mann(
            net, mann_task.mann_cam_config(128, 3), episodes=12)

    print("# Table IV validation (sim. vs paper's reported sim.)")
    print(f"{'design':10s} {'lat_ns':>12s} {'paper':>10s} {'dev%':>7s} "
          f"{'energy_pj':>14s} {'paper':>14s} {'dev%':>7s}")
    for name, lat, plat, dl, en, pen, de, _ in rows:
        print(f"{name:10s} {lat:12.2f} {plat:10.1f} {dl:+7.1f} "
              f"{en:14.1f} {pen:14.1f} {de:+7.1f}")
    if not fast:
        print(f"MANN accuracy: fp32={acc_fp:.3f} CAM-3b={acc_cam:.3f} "
              f"(paper: no-quant 0.983, pub 0.945, sim 0.950)")
        print("DRL accuracy: n/a offline (needs RL environment; paper "
              "169.50 vs 173.25 pub)")
    return rows, acc_cam


def main():
    rows, _ = run(fast=True)
    for name, lat, plat, dl, en, pen, de, dt_us in rows:
        nm = name.split()[0].lower()
        print(f"table4_{nm}_latency,{dt_us:.1f},{lat:.2f}ns(dev{dl:+.1f}%)")
        print(f"table4_{nm}_energy,{dt_us:.1f},{en:.1f}pJ(dev{de:+.1f}%)")


if __name__ == "__main__":
    main()
