"""Paper Fig. 4 case study: accuracy + EDP vs embedding dim x quantization
bits x subarray column size (MANN task).

Reproduced trends (paper §IV-B1):
  * 2-bit quantization hurts accuracy much more than 3-bit;
  * for the same column size, smaller dims tend to higher accuracy
    (fewer voting segments -> less voting error);
  * for the same dim, larger subarrays have higher accuracy but worse EDP;
  * EDP grows with embedding dimension.
"""
from __future__ import annotations

import time

import jax.numpy as jnp

from repro.core import CAMASim

from . import mann_task


def run(dims=(64, 128, 256), bits=(2, 3), cols=(64, 128),
        episodes: int = 8, steps: int = 300):
    results = []
    nets = {d: mann_task.train_embedding(dim=d, steps=steps) for d in dims}
    for d in dims:
        fp = mann_task.eval_mann(nets[d], None, use_cam=False,
                                 episodes=episodes)
        for b in bits:
            for c in cols:
                if c > d:       # column wider than the vector: same as c=d
                    continue
                cfg = mann_task.mann_cam_config(d, b, rows=32, cols=c)
                acc = mann_task.eval_mann(nets[d], cfg, episodes=episodes)
                sim = CAMASim(cfg)
                sim.write(jnp.zeros((32, d)))
                perf = sim.eval_perf()
                edp_ajs = perf["latency_ns"] * perf["energy_pj"] * 1e-3
                results.append(dict(dim=d, bits=b, cols=c, acc=acc,
                                    acc_fp=fp, edp_aj_s=edp_ajs,
                                    latency_ns=perf["latency_ns"],
                                    energy_pj=perf["energy_pj"]))
    return results


def check_trends(results) -> dict:
    """Assert the paper's qualitative findings hold."""
    import statistics as st
    by = lambda **kw: [r for r in results
                       if all(r[k] == v for k, v in kw.items())]
    drop = lambda r: r["acc_fp"] - r["acc"]
    mean = lambda xs: st.mean(xs) if xs else float("nan")
    dims = sorted(set(r["dim"] for r in results))
    out = {
        "drop_2b": mean([drop(r) for r in by(bits=2)]),
        "drop_3b": mean([drop(r) for r in by(bits=3)]),
        # EDP increases with dim at fixed bits/cols (min vs max dim present)
        "edp_lo": mean([r["edp_aj_s"] for r in by(dim=dims[0])]),
        "edp_hi": mean([r["edp_aj_s"] for r in by(dim=dims[-1])]),
    }
    out["2b_worse_than_3b"] = out["drop_2b"] > out["drop_3b"]
    out["edp_grows_with_dim"] = out["edp_hi"] > out["edp_lo"]
    return out


def main():
    t0 = time.perf_counter()
    results = run(dims=(64, 128), bits=(2, 3), cols=(64,), episodes=4,
                  steps=150)
    dt = (time.perf_counter() - t0) * 1e6
    tr = check_trends(results)
    for r in results:
        print(f"fig4_d{r['dim']}_b{r['bits']}_c{r['cols']},{dt/len(results):.0f},"
              f"acc={r['acc']:.3f}(fp{r['acc_fp']:.3f})_edp={r['edp_aj_s']:.3f}aJs")
    print(f"fig4_trend_2b_worse,{dt:.0f},{tr['2b_worse_than_3b']}")
    print(f"fig4_trend_edp_dim,{dt:.0f},{tr['edp_grows_with_dim']}")


if __name__ == "__main__":
    main()
