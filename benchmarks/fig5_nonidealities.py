"""Paper Fig. 5: accuracy vs D2D variation STD and sensing limit.

Reproduced trends (paper §IV-B2): accuracy degrades monotonically-ish with
both non-idealities, and smaller subarrays / smaller dims are LESS
resilient (the voting scheme degrades faster under noise).
"""
from __future__ import annotations

import time

from . import mann_task


def run(dim: int = 128, stds=(0.0, 0.5, 1.0, 2.0, 4.0),
        sls=(0.0, 2.0, 5.0, 10.0), episodes: int = 8, steps: int = 300,
        cols=(32, 64)):
    net = mann_task.train_embedding(dim=dim, steps=steps)
    out = {"variation": [], "sensing_limit": []}
    for c in cols:
        for s in stds:
            cfg = mann_task.mann_cam_config(dim, 3, cols=c, d2d_std=s)
            acc = mann_task.eval_mann(net, cfg, episodes=episodes)
            out["variation"].append(dict(cols=c, std=s, acc=acc))
        for sl in sls:
            cfg = mann_task.mann_cam_config(dim, 3, cols=c, sl=sl)
            acc = mann_task.eval_mann(net, cfg, episodes=episodes)
            out["sensing_limit"].append(dict(cols=c, sl=sl, acc=acc))
    return out


def check_trends(out) -> dict:
    acc_at = lambda kind, key, v, c: [r["acc"] for r in out[kind]
                                      if r[key] == v and r["cols"] == c]
    res = {}
    for c in set(r["cols"] for r in out["variation"]):
        stds = sorted(set(r["std"] for r in out["variation"]))
        res[f"var_degrades_c{c}"] = (
            acc_at("variation", "std", stds[0], c)[0]
            >= acc_at("variation", "std", stds[-1], c)[0] - 0.02)
        sls = sorted(set(r["sl"] for r in out["sensing_limit"]))
        res[f"sl_degrades_c{c}"] = (
            acc_at("sensing_limit", "sl", sls[0], c)[0]
            >= acc_at("sensing_limit", "sl", sls[-1], c)[0] - 0.02)
    return res


# CI accuracy floors (check_floors: acc= must clear acc_floor=), pinned
# ~0.05-0.1 under the measured CI values so the non-ideality model can't
# silently regress: clean/sl legs measured 0.887-0.900; the std=2.0 leg
# measured 0.498 (noise hurts, but the CAM must stay far above the 0.10
# random-guess line).
FLOORS = {("var", 0.0): 0.80, ("var", 2.0): 0.30,
          ("sl", 0.0): 0.80, ("sl", 5.0): 0.78}


def main():
    t0 = time.perf_counter()
    out = run(stds=(0.0, 2.0), sls=(0.0, 5.0), episodes=4, steps=150,
              cols=(64,))
    dt = (time.perf_counter() - t0) * 1e6
    for r in out["variation"]:
        fl = FLOORS.get(("var", r["std"]))
        guard = f"_acc_floor={fl}" if fl is not None else ""
        print(f"fig5_var_std{r['std']}_c{r['cols']},{dt/4:.0f},"
              f"acc={r['acc']:.3f}{guard}")
    for r in out["sensing_limit"]:
        fl = FLOORS.get(("sl", r["sl"]))
        guard = f"_acc_floor={fl}" if fl is not None else ""
        print(f"fig5_sl{r['sl']}_c{r['cols']},{dt/4:.0f},"
              f"acc={r['acc']:.3f}{guard}")


if __name__ == "__main__":
    main()
