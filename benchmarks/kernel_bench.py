"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall-time +
the structural numbers that matter on TPU (VMEM working set per tile).

On this CPU container interpret-mode wall-time is NOT the TPU story; the
reported derived column is the VMEM tile footprint (the quantity BlockSpec
tiling controls) and the oracle-match check.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, n=3, reps=5):
    """Best-of-``reps`` mean over ``n`` calls (after 2 warm calls: the first
    dispatches after compilation still pay background-compilation jitter).
    ``reps=5``: the Q-sweep monotone contract rides on ~1-2% fixed-overhead
    amortization margins, so the best-of filter needs enough draws to shed
    scheduler jitter."""
    for _ in range(2):
        jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*args))
        best = min(best, (time.perf_counter() - t0) / n * 1e6)
    return best


def _monotone_row(name: str, sweep: dict):
    """Emit the Q-sweep monotone-qps contract row: qps must be
    nondecreasing from Q=16 up (Q=1 is excluded — the small-Q crossover
    legitimately serves it at reference speed).  ``check_floors`` fails on
    ``qps_monotone=False``."""
    qs = sorted(q for q in sweep if q >= 16)
    vals = [sweep[q] for q in qs]
    mono = all(b >= a for a, b in zip(vals, vals[1:]))
    trend = "/".join(f"{v:.0f}" for v in vals)
    print(f"{name},0,qs={'/'.join(str(q) for q in qs)}_qps={trend}_"
          f"qps_monotone={mono}")


def bench_batched_vs_vmap():
    """Store-once / search-many: the query-batched kernel streams the grid
    from HBM once per batch; the old path re-streams it once per query.
    Reported: queries/sec for both paths (interpret-mode CPU proxy).  The
    trailing qsweep row asserts the pipelined kernel's monotone-qps
    contract over Q=16..256."""
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (4, 4, 32, 64))
    sweep = {}
    for Q in (1, 16, 64, 256):
        qb = jax.random.uniform(k2, (Q, 4, 64))
        us_b = _time(lambda s, q: ops.cam_search(s, q, distance="l2"),
                     stored, qb)
        us_v = _time(lambda s, q: ops.cam_search_vmap(s, q, distance="l2"),
                     stored, qb)
        got = ops.cam_search(stored, qb, distance="l2")
        want = ref.cam_search_batched_ref(stored, qb, "l2")
        ok = np.allclose(np.asarray(got), np.asarray(want), atol=1e-4)
        qps_b = Q / (us_b * 1e-6)
        qps_v = Q / (us_v * 1e-6)
        sweep[Q] = qps_b
        print(f"kernel_cam_search_batched_q{Q},{us_b:.0f},"
              f"qps_batched={qps_b:.0f}_qps_vmap={qps_v:.0f}_"
              f"speedup={us_v / us_b:.2f}x_match={ok}")
    _monotone_row("kernel_cam_search_qsweep", sweep)


def bench_acam_range():
    """ACAM range search Q-sweep: the fused batched range kernel
    (``cam_range_fused_pallas``, match-only AND-merge path) vs the jnp
    broadcast path it replaces (``subarray_query_batched`` use_kernel=False,
    which materializes the (Q, nv, nh, R, C) violation block).  The grid is
    sized so the broadcast intermediate blows past cache at Q>=16 — the
    regime the kernel exists for; at Q=1 the jnp path wins (no batch to
    amortize the interpret-mode grid overhead over) and the row records the
    crossover honestly."""
    from repro.core import subarray

    nv, nh, R, C = 8, 1, 512, 128
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    lo = jax.random.uniform(k1, (nv, nh, R, C))
    grid = jnp.stack([lo, lo + 0.05], axis=-1)        # (nv, nh, R, C, 2)
    centers = lo + 0.025                              # exact-match queries
    cv = jnp.ones((nh, C))
    rv = jnp.ones((nv, R))
    kw = dict(distance="range", sensing="exact", sensing_limit=0.0,
              col_valid=cv, row_valid=rv)
    jnp_f = jax.jit(lambda g, q: subarray.subarray_query_batched(
        g, q, use_kernel=False, **kw)[1])
    ker_f = jax.jit(lambda g, q: subarray.subarray_query_batched(
        g, q, use_kernel=True, want_dist=False, **kw)[1])
    sweep = {}
    for Q in (1, 16, 64, 256):
        # half the batch queries stored-row centers (guaranteed in-range
        # for every cell of that row), half random misses — so the parity
        # bit compares real match lines, not two all-zero tensors
        qb = jax.random.uniform(k2, (Q, nh, C))
        hit = centers[jnp.arange(Q) % nv, :, jnp.arange(Q) % R, :]
        qb = jnp.where((jnp.arange(Q) % 2 == 0)[:, None, None], hit, qb)
        mk, mj = ker_f(grid, qb), jnp_f(grid, qb)
        ok = bool(np.array_equal(np.asarray(mk), np.asarray(mj)))
        hit_q = int((np.asarray(mj).reshape(Q, -1).sum(-1) > 0).sum())
        us_k = _time(ker_f, grid, qb)
        us_j = _time(jnp_f, grid, qb)
        qps_k = Q / (us_k * 1e-6)
        qps_j = Q / (us_j * 1e-6)
        sweep[Q] = qps_k
        print(f"kernel_acam_range_q{Q},{us_k:.0f},"
              f"qps_kernel={qps_k:.0f}_qps_jnp={qps_j:.0f}_"
              f"speedup={us_j / us_k:.2f}x_rows={nv * R}_"
              f"hit_q={hit_q}_match={ok}")
    _monotone_row("kernel_acam_range_qsweep", sweep)


def main():
    key = jax.random.PRNGKey(0)
    # cam_search: MANN-like grid
    stored = jax.random.uniform(key, (8, 8, 32, 64))
    q = jax.random.uniform(key, (8, 64))
    us_k = _time(lambda s, qq: ops.cam_search(s, qq, distance="l2"),
                 stored, q)
    us_r = _time(lambda s, qq: ref.cam_search_ref(s, qq, "l2"), stored, q)
    vmem_kb = (32 * 64 + 64 + 64 + 32) * 4 / 1024
    ok = np.allclose(ops.cam_search(stored, q, distance="l2"),
                     ref.cam_search_ref(stored, q, "l2"), atol=1e-4)
    print(f"kernel_cam_search,{us_k:.0f},vmem_tile={vmem_kb:.1f}KiB_"
          f"ref_us={us_r:.0f}_match={ok}")

    bench_batched_vs_vmap()
    bench_acam_range()

    # cam_topk: retrieval attention hot loop
    keys = jax.random.normal(key, (8192, 128))
    qq = jax.random.normal(key, (128,))
    us_k = _time(lambda a, b: ops.cam_topk(a, b, k=128, chunk=1024)[0],
                 keys, qq)
    us_r = _time(lambda a, b: ref.cam_topk_ref(a, b, 128)[0], keys, qq)
    v, i = ops.cam_topk(keys, qq, k=128, chunk=1024)
    rv, ri = ref.cam_topk_ref(keys, qq, 128)
    ok = np.allclose(np.asarray(v), np.asarray(rv), atol=1e-3)
    vmem_kb = (1024 * 128 + 128 + 2 * 128) * 4 / 1024
    print(f"kernel_cam_topk,{us_k:.0f},vmem_tile={vmem_kb:.1f}KiB_"
          f"ref_us={us_r:.0f}_match={ok}")

    # hamming_pack: 32x density win
    bits = (jax.random.uniform(key, (4096, 2048)) > 0.5
            ).astype(jnp.float32)
    qb = (jax.random.uniform(key, (2048,)) > 0.5).astype(jnp.float32)
    sp, qp = ops.pack_bits(bits), ops.pack_bits(qb)
    us_k = _time(lambda a, b: ops.hamming_packed(a, b, n_valid_bits=2048),
                 sp, qp)
    us_r = _time(lambda a, b: ref.hamming_packed_ref(a, b, 2048), sp, qp)
    got = ops.hamming_packed(sp, qp, n_valid_bits=2048)
    want = (bits != qb[None]).sum(-1)
    ok = bool((np.asarray(got) == np.asarray(want)).all())
    density = bits.nbytes / sp.nbytes
    print(f"kernel_hamming_pack,{us_k:.0f},density_win={density:.0f}x_"
          f"ref_us={us_r:.0f}_match={ok}")


if __name__ == "__main__":
    main()
