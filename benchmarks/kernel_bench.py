"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall-time +
the structural numbers that matter on TPU (VMEM working set per tile).

On this CPU container interpret-mode wall-time is NOT the TPU story; the
reported derived column is the VMEM tile footprint (the quantity BlockSpec
tiling controls) and the oracle-match check.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(f, *args, n=3, reps=3):
    """Best-of-``reps`` mean over ``n`` calls (after 2 warm calls: the first
    dispatches after compilation still pay background-compilation jitter)."""
    for _ in range(2):
        jax.block_until_ready(f(*args))
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(f(*args))
        best = min(best, (time.perf_counter() - t0) / n * 1e6)
    return best


def bench_batched_vs_vmap():
    """Store-once / search-many: the query-batched kernel streams the grid
    from HBM once per batch; the old path re-streams it once per query.
    Reported: queries/sec for both paths (interpret-mode CPU proxy)."""
    key = jax.random.PRNGKey(1)
    k1, k2 = jax.random.split(key)
    stored = jax.random.uniform(k1, (4, 4, 32, 64))
    for Q in (1, 16, 256):
        qb = jax.random.uniform(k2, (Q, 4, 64))
        us_b = _time(lambda s, q: ops.cam_search(s, q, distance="l2"),
                     stored, qb)
        us_v = _time(lambda s, q: ops.cam_search_vmap(s, q, distance="l2"),
                     stored, qb)
        got = ops.cam_search(stored, qb, distance="l2")
        want = ref.cam_search_batched_ref(stored, qb, "l2")
        ok = np.allclose(np.asarray(got), np.asarray(want), atol=1e-4)
        qps_b = Q / (us_b * 1e-6)
        qps_v = Q / (us_v * 1e-6)
        print(f"kernel_cam_search_batched_q{Q},{us_b:.0f},"
              f"qps_batched={qps_b:.0f}_qps_vmap={qps_v:.0f}_"
              f"speedup={us_v / us_b:.2f}x_match={ok}")


def main():
    key = jax.random.PRNGKey(0)
    # cam_search: MANN-like grid
    stored = jax.random.uniform(key, (8, 8, 32, 64))
    q = jax.random.uniform(key, (8, 64))
    us_k = _time(lambda s, qq: ops.cam_search(s, qq, distance="l2"),
                 stored, q)
    us_r = _time(lambda s, qq: ref.cam_search_ref(s, qq, "l2"), stored, q)
    vmem_kb = (32 * 64 + 64 + 64 + 32) * 4 / 1024
    ok = np.allclose(ops.cam_search(stored, q, distance="l2"),
                     ref.cam_search_ref(stored, q, "l2"), atol=1e-4)
    print(f"kernel_cam_search,{us_k:.0f},vmem_tile={vmem_kb:.1f}KiB_"
          f"ref_us={us_r:.0f}_match={ok}")

    bench_batched_vs_vmap()

    # cam_topk: retrieval attention hot loop
    keys = jax.random.normal(key, (8192, 128))
    qq = jax.random.normal(key, (128,))
    us_k = _time(lambda a, b: ops.cam_topk(a, b, k=128, chunk=1024)[0],
                 keys, qq)
    us_r = _time(lambda a, b: ref.cam_topk_ref(a, b, 128)[0], keys, qq)
    v, i = ops.cam_topk(keys, qq, k=128, chunk=1024)
    rv, ri = ref.cam_topk_ref(keys, qq, 128)
    ok = np.allclose(np.asarray(v), np.asarray(rv), atol=1e-3)
    vmem_kb = (1024 * 128 + 128 + 2 * 128) * 4 / 1024
    print(f"kernel_cam_topk,{us_k:.0f},vmem_tile={vmem_kb:.1f}KiB_"
          f"ref_us={us_r:.0f}_match={ok}")

    # hamming_pack: 32x density win
    bits = (jax.random.uniform(key, (4096, 2048)) > 0.5
            ).astype(jnp.float32)
    qb = (jax.random.uniform(key, (2048,)) > 0.5).astype(jnp.float32)
    sp, qp = ops.pack_bits(bits), ops.pack_bits(qb)
    us_k = _time(lambda a, b: ops.hamming_packed(a, b, n_valid_bits=2048),
                 sp, qp)
    us_r = _time(lambda a, b: ref.hamming_packed_ref(a, b, 2048), sp, qp)
    got = ops.hamming_packed(sp, qp, n_valid_bits=2048)
    want = (bits != qb[None]).sum(-1)
    ok = bool((np.asarray(got) == np.asarray(want)).all())
    density = bits.nbytes / sp.nbytes
    print(f"kernel_hamming_pack,{us_k:.0f},density_win={density:.0f}x_"
          f"ref_us={us_r:.0f}_match={ok}")


if __name__ == "__main__":
    main()
