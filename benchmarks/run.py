"""Benchmark entrypoint: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--devices N]

Prints ``name,us_per_call,derived`` CSV per benchmark.  ``--full`` runs the
larger sweeps (the default is sized for CI).  ``--devices N`` caps the
sharded weak-scaling sweep's device counts (subprocesses with N forced
host devices; default 4, 0 skips the sweep).  The dry-run roofline table
is produced separately by repro.launch.dryrun (512 fake devices) and read
back here if present.

Every CSV row is also dumped to ``BENCH_kernels.json`` next to the repo
root, so successive PRs leave a machine-readable perf trajectory.
"""
from __future__ import annotations

import contextlib
import io
import json
import pathlib
import sys
import time

BENCH_JSON = pathlib.Path(__file__).resolve().parent.parent /     "BENCH_kernels.json"


def merge_bench_rows(rows: list, path: pathlib.Path = BENCH_JSON) -> list:
    """Replace-by-name merge into the JSON perf trajectory.

    A partial run (e.g. ``--devices 0``, or the standalone
    ``sharded_perf`` sweep) must refresh its own rows without destroying
    rows only other sweeps emit; a corrupt/truncated file self-heals."""
    existing = []
    if path.exists():
        try:
            existing = json.loads(path.read_text())
        except ValueError:
            existing = []
    fresh = {r["name"] for r in rows}
    merged = [r for r in existing if r.get("name") not in fresh] + rows
    path.write_text(json.dumps(merged, indent=1))
    return merged


def check_floors(rows: list) -> None:
    """Fail loudly when a row records a broken guarantee: any parity bit
    ``match=False``, a ``recall=`` that fell below the ``floor=`` the
    same row declares, a serve-loop ``p99_us=`` tail latency that blew
    through the row's ``floor_p99_us=`` ceiling, a kernel Q-sweep whose
    qps is not monotone nondecreasing (``qps_monotone=False``; the
    pipelined kernels' contract — the plain ``monotone=`` field some
    sharded rows record is informational, not floored), or a
    measured-vs-estimated drift ``est_ratio=`` above the ``ratio_ceil=``
    the row declares (the insert-rate estimate was once silently 8800x
    off).  Run in CI so a perf row can't silently regress from
    "bit-identical"/"recall cleared"/"SLO met" to "close enough"."""
    import re
    bad = []
    for r in rows:
        d = str(r.get("derived", ""))
        if re.search(r"\bmatch=False\b", d):
            bad.append(f"{r['name']}: match=False ({d})")
        # fields are '_'-separated key=value runs, so \b can't anchor the
        # key starts (the '_' before a key is itself a word character)
        m = re.search(r"(?:^|_)recall=([0-9.]+)", d)
        f = re.search(r"(?:^|_)floor=([0-9.]+)", d)
        if m and f and float(m.group(1)) < float(f.group(1)):
            bad.append(f"{r['name']}: recall {m.group(1)} < floor "
                       f"{f.group(1)} ({d})")
        p = re.search(r"(?<!floor_)p99_us=([0-9.]+)", d)
        pf = re.search(r"floor_p99_us=([0-9.]+)", d)
        if p and pf and float(p.group(1)) > float(pf.group(1)):
            bad.append(f"{r['name']}: p99 {p.group(1)}us > floor "
                       f"{pf.group(1)}us ({d})")
        if re.search(r"(?:^|_)qps_monotone=False\b", d):
            bad.append(f"{r['name']}: qps_monotone=False ({d})")
        er = re.search(r"(?:^|_)est_ratio=([0-9.]+)", d)
        rc = re.search(r"(?:^|_)ratio_ceil=([0-9.]+)", d)
        if er and rc and float(er.group(1)) > float(rc.group(1)):
            bad.append(f"{r['name']}: est_ratio {er.group(1)} > ceiling "
                       f"{rc.group(1)} ({d})")
        # accuracy guards (fig5 / reliability_bench): a row's acc= must
        # clear its own acc_floor= (mitigated/self-healing legs) and stay
        # under its acc_ceil= (unmitigated legs — proves the injected
        # faults are real, not a silent no-op)
        a = re.search(r"(?:^|_)acc=([0-9.]+)", d)
        af = re.search(r"(?:^|_)acc_floor=([0-9.]+)", d)
        ac = re.search(r"(?:^|_)acc_ceil=([0-9.]+)", d)
        if a and af and float(a.group(1)) < float(af.group(1)):
            bad.append(f"{r['name']}: acc {a.group(1)} < floor "
                       f"{af.group(1)} ({d})")
        if a and ac and float(a.group(1)) > float(ac.group(1)):
            bad.append(f"{r['name']}: acc {a.group(1)} > ceiling "
                       f"{ac.group(1)} ({d})")
    if bad:
        raise RuntimeError("benchmark floor violations:\n  "
                           + "\n  ".join(bad))


def _run_and_collect(fn, rows: list) -> None:
    """Run a benchmark main, echo its stdout, and parse the CSV rows."""
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        fn()
    text = buf.getvalue()
    print(text, end="")
    for line in text.splitlines():
        parts = line.strip().split(",", 2)
        if len(parts) == 3:
            name, us, derived = parts
            try:
                rows.append({"name": name, "us_per_call": float(us),
                             "derived": derived})
            except ValueError:
                pass  # not a CSV row (stray print)


def main() -> None:
    full = "--full" in sys.argv
    devices = 4
    if "--devices" in sys.argv:
        devices = int(sys.argv[sys.argv.index("--devices") + 1])
    from . import (autotune_bench, cascade_bench, fig4_sweep,
                   fig5_nonidealities, kernel_bench, reliability_bench,
                   serve_bench, sharded_bench, sharded_perf,
                   table4_validation)

    rows: list = []

    def emit(name, us, derived):
        print(f"{name},{us},{derived}")
        rows.append({"name": name, "us_per_call": float(us),
                     "derived": str(derived)})

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    _run_and_collect(table4_validation.main, rows)
    _run_and_collect(sharded_perf.main, rows)
    _run_and_collect(fig4_sweep.main, rows)
    _run_and_collect(fig5_nonidealities.main, rows)
    _run_and_collect(lambda: reliability_bench.main(backend="functional"),
                     rows)
    _run_and_collect(kernel_bench.main, rows)
    _run_and_collect(lambda: cascade_bench.main(ci=not full), rows)
    _run_and_collect(lambda: serve_bench.main(backend="both"), rows)
    _run_and_collect(lambda: autotune_bench.main(backend="functional"),
                     rows)
    if devices > 0:
        _run_and_collect(lambda: sharded_bench.main(devices), rows)

    # roofline summary (if the dry-run has produced results)
    try:
        from . import roofline_table
        cells = roofline_table.load("baseline", "single")
        if cells:
            bounds = {}
            for e in cells:
                b = e["roofline"]["bottleneck"]
                bounds[b] = bounds.get(b, 0) + 1
            emit("dryrun_cells_single", 0,
                 f"n={len(cells)}_bottlenecks={bounds}")
        cells_m = roofline_table.load("baseline", "multi")
        if cells_m:
            emit("dryrun_cells_multi", 0, f"n={len(cells_m)}")
    except Exception as e:  # pragma: no cover
        emit("dryrun_cells", 0, f"unavailable({e})")

    if full:
        res = fig4_sweep.run()
        emit("fig4_full", 0, fig4_sweep.check_trends(res))
        out = fig5_nonidealities.run()
        emit("fig5_full", 0, fig5_nonidealities.check_trends(out))
    emit("total_wall_s", round((time.perf_counter() - t0) * 1e6),
         f"{time.perf_counter() - t0:.1f}s")
    check_floors(rows)
    merged = merge_bench_rows(rows)
    print(f"bench_json,0,rows={len(merged)}_path={BENCH_JSON.name}")


if __name__ == "__main__":
    main()
