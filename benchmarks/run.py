"""Benchmark entrypoint: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV per benchmark.  ``--full`` runs the
larger sweeps (the default is sized for CI).  The dry-run roofline table is
produced separately by repro.launch.dryrun (512 fake devices) and read back
here if present.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    full = "--full" in sys.argv
    from . import (fig4_sweep, fig5_nonidealities, kernel_bench,
                   table4_validation)

    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    table4_validation.main()
    fig4_sweep.main()
    fig5_nonidealities.main()
    kernel_bench.main()

    # roofline summary (if the dry-run has produced results)
    try:
        from . import roofline_table
        rows = roofline_table.load("baseline", "single")
        if rows:
            bounds = {}
            for e in rows:
                b = e["roofline"]["bottleneck"]
                bounds[b] = bounds.get(b, 0) + 1
            print(f"dryrun_cells_single,0,"
                  f"n={len(rows)}_bottlenecks={bounds}")
        rows_m = roofline_table.load("baseline", "multi")
        if rows_m:
            print(f"dryrun_cells_multi,0,n={len(rows_m)}")
    except Exception as e:  # pragma: no cover
        print(f"dryrun_cells,0,unavailable({e})")

    if full:
        res = fig4_sweep.run()
        tr = fig4_sweep.check_trends(res)
        print(f"fig4_full,0,{tr}")
        out = fig5_nonidealities.run()
        print(f"fig5_full,0,{fig5_nonidealities.check_trends(out)}")
    print(f"total_wall_s,{(time.perf_counter()-t0)*1e6:.0f},"
          f"{time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
