"""Reliability benchmark: accuracy vs fault rate and vs drift age (MANN).

Two curves, mitigation on vs off, on the shared MANN few-shot substrate:

1. **Fault curve** — stuck cells + dead rows injected at increasing rates
   into the support store.  Unmitigated (``verify_retries=0``, no spares)
   the dead support entries silently never match and accuracy decays;
   mitigated, write-verify detects the bad rows at program time and heals
   them onto same-bank spare rows, so accuracy holds at the clean level.

2. **Aging curve** — conductance drift decays the stored rows as the
   serve engine steps.  Without scrubbing the store ages to garbage;
   with background scrubbing the engine re-programs the most-drifted
   rows every ``scrub_every`` steps through the mutation lane and
   accuracy holds.

The headline rows carry ``acc_floor=`` (mitigated must stay above) and
``acc_ceil=`` (unmitigated must stay BELOW — the fault injection is real,
not a no-op), both enforced by ``benchmarks.run.check_floors``.  Floors
are pinned ~0.05 under the measured CI values; the ceilings sit between
the two curves.

``main(backend=...)`` runs the whole bench on the functional or sharded
backend (CI smoke-runs both; the sharded leg under forced host devices).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.serve_loop import CAMSearchServer

from . import mann_task

# Measured on the CI container (functional backend; the sharded backend is
# bit-identical).  Mitigated accuracy at the headline fault rate / final
# age must clear the floor; unmitigated must sit below the ceiling.
FAULT_RATES = (0.0, 0.1, 0.3)
HEADLINE_FAULT = 0.3
FAULT_ACC_FLOOR = 0.72     # measured 0.784 mitigated (clean 0.787)
FAULT_ACC_CEIL = 0.60      # measured 0.444 unmitigated
AGES = (0, 150, 300)
AGE_ACC_FLOOR = 0.80       # measured 0.900 scrubbed (fresh 0.927)
AGE_ACC_CEIL = 0.60        # measured 0.273 unscrubbed


def _rel_cfg(dim: int, *, mitigated: bool, stuck: float = 0.0,
             dead_rows: float = 0.0, drift: float = 0.0,
             scrub_every: int = 0, backend: str = "functional"):
    """MANN config with one 64-row bank per 50-row support set plus spare
    head-room, reliability on, mitigation knobs on/off."""
    cfg = mann_task.mann_cam_config(dim, 3, rows=64, cols=64)
    mit = dict(verify_retries=2, verify_tol=0.5, spares_per_bank=16,
               scrub_every=scrub_every, scrub_rows=16) if mitigated else {}
    return cfg.replace(
        sim=dict(backend=backend, capacity=128),
        reliability=dict(enabled=True, stuck_frac=stuck,
                         dead_row_frac=dead_rows, drift_rate=drift,
                         fault_seed=7, **mit))


def fault_curve(net, dim: int, episodes: int = 3, backend="functional"):
    """10-way 1-SHOT episodes: every class rides on one support row, so an
    unhealed dead row loses its whole class — the regime where spare-row
    healing is the difference between working and broken."""
    out = []
    for f in FAULT_RATES:
        for mitigated in (True, False):
            cfg = _rel_cfg(dim, mitigated=mitigated, stuck=f / 100,
                           dead_rows=f, backend=backend)
            acc = mann_task.eval_mann(net, cfg, episodes=episodes,
                                      n_shot=1, n_query=15)
            out.append(dict(rate=f, mitigated=mitigated, acc=acc))
    return out


def aging_curve(net, dim: int, backend="functional", drift: float = 0.01,
                n_way: int = 10, n_shot: int = 5, n_query: int = 15):
    """Self-retrieval accuracy of one episode's support store as the serve
    engine steps: the engine's reliability tick ages the store every step
    and (scrub leg only) re-programs the most-drifted rows on schedule.
    Accuracy is probed through the same search path the server runs."""
    from repro.core import CAMASim
    from repro.models.cam_memory import CAMMemory

    sup, sup_y, qry, qry_y = mann_task.make_episode(
        jax.random.PRNGKey(42), n_way, n_shot, n_query)
    es, eq = mann_task.embed(net, sup), mann_task.embed(net, qry)
    s = jnp.std(es) * 3.0
    es, eq = jnp.clip(es, -s, s), jnp.clip(eq, -s, s)

    out = []
    for scrub in (True, False):
        cfg = _rel_cfg(dim, mitigated=scrub, drift=drift,
                       scrub_every=5 if scrub else 0, backend=backend)
        sim = CAMASim(cfg)
        state = sim.write(es, jax.random.PRNGKey(3))
        srv = CAMSearchServer(sim=sim, state=state,
                              key=jax.random.PRNGKey(4))
        age = 0
        for target in AGES:
            while age < target:
                srv.step()          # idle steps still age (and scrub)
                age += 1
            idx, _ = sim.query(srv.state, eq, jax.random.PRNGKey(5))
            pred = np.asarray(jnp.take(sup_y, jnp.maximum(idx[:, 0], 0)))
            acc = float((pred == np.asarray(qry_y)).mean())
            out.append(dict(age=target, scrub=scrub, acc=acc))
    return out


def main(backend: str = "functional", episodes: int = 3,
         train_steps: int = 120, dim: int = 64):
    t0 = time.perf_counter()
    net = mann_task.train_embedding(dim=dim, steps=train_steps)
    rows = fault_curve(net, dim, episodes=episodes, backend=backend)
    dt = (time.perf_counter() - t0) * 1e6 / max(1, len(rows))
    tag = "" if backend == "functional" else f"_{backend}"
    for r in rows:
        kind = "mit" if r["mitigated"] else "unmit"
        guard = ""
        if r["rate"] == HEADLINE_FAULT:
            guard = (f"_acc_floor={FAULT_ACC_FLOOR}" if r["mitigated"]
                     else f"_acc_ceil={FAULT_ACC_CEIL}")
        print(f"reliability_fault{r['rate']}_{kind}{tag},{dt:.0f},"
              f"acc={r['acc']:.3f}{guard}")
    t1 = time.perf_counter()
    ages = aging_curve(net, dim, backend=backend)
    dt = (time.perf_counter() - t1) * 1e6 / max(1, len(ages))
    for r in ages:
        kind = "scrub" if r["scrub"] else "noscrub"
        guard = ""
        if r["age"] == AGES[-1]:
            guard = (f"_acc_floor={AGE_ACC_FLOOR}" if r["scrub"]
                     else f"_acc_ceil={AGE_ACC_CEIL}")
        print(f"reliability_age{r['age']}_{kind}{tag},{dt:.0f},"
              f"acc={r['acc']:.3f}{guard}")


if __name__ == "__main__":
    import sys
    main(backend=sys.argv[1] if len(sys.argv) > 1 else "functional")
