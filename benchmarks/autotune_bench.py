"""Autotuner validation: predicted vs measured throughput, rank agreement.

``CAMASim.autotune`` ranks deployments on a simulator-throughput proxy
(``sim_qps``: fused-kernel HBM traffic over a nominal bandwidth) without
ever writing.  This benchmark closes the loop on a RESULT-PRESERVING
sweep — only ``sim.q_tile`` moves, so every candidate must return
bit-identical search results — by actually running the top candidates:

  ``autotune_cand_<backend>_q<tile>``  one row per measured candidate:
        predicted-rank position, proxy qps, measured qps, and a
        ``match=`` bit (candidate results vs the untuned baseline,
        bit-for-bit — ``check_floors`` fails CI on ``match=False``);
  ``autotune_rank_<backend>``          the honest summary: how many of
        the predicted pairwise orderings the measurement confirms
        (``pairs_agree=a/p`` — reported, NOT floored: the proxy is a
        ranking heuristic, and this row is its scorecard).

    PYTHONPATH=src python -m benchmarks.autotune_bench [--backend B]

``--backend`` is ``functional`` (default), ``sharded`` (uses every
visible device), or ``both``.
"""
from __future__ import annotations

import sys
import time

K, N, Q = 2048, 64, 256
REPS = 3
TOP = 3
Q_TILE_SPACE = (None, 8, 32, 128)


def _cfg(backend: str):
    import jax

    from repro.core import CAMConfig
    sim = dict(use_kernel=True)
    if backend == "sharded":
        sim.update(backend="sharded", devices=len(jax.devices()))
    return CAMConfig.from_dict(dict(
        app=dict(distance="l2", match_type="best", match_param=4,
                 data_bits=4),
        arch=dict(h_merge="adder", v_merge="comparator"),
        circuit=dict(rows=64, cols=64, cell_type="mcam", sensing="best"),
        device=dict(device="fefet", variation="none"),
        sim=sim))


def _measure(config, stored, queries):
    """Best-of wall time (us) for one Q-batch + the results it returns."""
    import jax

    from repro.core import CAMASim
    sim = CAMASim(config)
    state = sim.write(stored)
    res = sim.query(state, queries)
    jax.block_until_ready(res.mask)             # warm the jit cache
    best = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        r = sim.query(state, queries)
        jax.block_until_ready(r.mask)
        best = min(best, time.perf_counter() - t0)
    import numpy as np
    return best * 1e6, np.asarray(res.indices), np.asarray(res.mask)


def _qlabel(q) -> str:
    return "auto" if q is None else str(q)


def _bench_backend(backend: str) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import CAMASim

    cfg = _cfg(backend)
    n_dev = len(jax.devices()) if backend == "sharded" else 1
    # result-preserving space: ONLY the fused-kernel query tile moves
    # (devices pinned to the leg's real mesh so candidates are runnable)
    space = {"q_tile": list(Q_TILE_SPACE), "devices": [n_dev],
             "link": ["on_package"], "top_p_banks": [None]}
    tuned = CAMASim(cfg).autotune(K, N, space=space, objective="qps",
                                  queries_per_batch=Q)

    rng = np.random.default_rng(0)
    stored = jnp.asarray(rng.uniform(0, 1, (K, N)).astype(np.float32))
    queries = jnp.asarray(rng.uniform(0, 1, (Q, N)).astype(np.float32))
    _, base_idx, base_mask = _measure(cfg, stored, queries)

    measured = []
    for rank, cand in enumerate(tuned.candidates[:TOP]):
        us, idx, mask = _measure(cand.config, stored, queries)
        ok = bool((idx == base_idx).all() and (mask == base_mask).all())
        meas_qps = Q / (us * 1e-6)
        measured.append((cand.knobs["q_tile"], cand.metrics["sim_qps"],
                         meas_qps))
        print(f"autotune_cand_{backend}_q{_qlabel(cand.knobs['q_tile'])},"
              f"{us:.0f},rank={rank}_pred_qps="
              f"{cand.metrics['sim_qps']:.0f}_meas_qps={meas_qps:.0f}"
              f"_match={ok}")

    # honest rank-agreement scorecard: predicted order vs measured order
    agree, pairs = 0, 0
    for i in range(len(measured)):
        for j in range(i + 1, len(measured)):
            pairs += 1
            if measured[i][2] >= measured[j][2]:
                agree += 1      # prediction said i >= j; measurement agrees
    pred_best = _qlabel(measured[0][0])
    meas_best = _qlabel(max(measured, key=lambda m: m[2])[0])
    print(f"autotune_rank_{backend},0,pairs_agree={agree}/{pairs}"
          f"_pred_best=q{pred_best}_meas_best=q{meas_best}"
          f"_candidates={len(tuned.candidates)}")


def main(backend: str = "functional") -> None:
    for b in (("functional", "sharded") if backend == "both"
              else (backend,)):
        _bench_backend(b)


if __name__ == "__main__":
    be = "functional"
    if "--backend" in sys.argv:
        be = sys.argv[sys.argv.index("--backend") + 1]
    main(backend=be)
