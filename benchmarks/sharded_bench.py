"""Weak-scaling benchmark for the sharded CAM search subsystem.

Fixed rows/device, growing nv: every device count N holds the same
(BANKS_PER_DEV x ROWS) rows per device, so the dataset grows with the
mesh (the scale-out story: capacity bounded by the mesh, not one HBM).
Each sweep point reports the sharded wall time at N devices AND a
single-device (1-bank mesh) reference over the *same* N-shard dataset —
``speedup`` is therefore the cross-device parallelism win on identical
data, and ``match`` asserts the merge stayed bit-identical.

Device counts need ``XLA_FLAGS=--xla_force_host_platform_device_count``
set before jax initializes, so the parent spawns one worker subprocess
per point:

    PYTHONPATH=src python -m benchmarks.sharded_bench [--devices N]
    PYTHONPATH=src python -m benchmarks.sharded_bench --worker N  (internal)

Interpret-mode CPU numbers are a proxy (the container has no TPU): the
structural claim is that per-device work is fixed while total rows grow.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import time

BANKS_PER_DEV = 8     # nv shards resident per device
ROWS = 128            # R: rows per subarray (rows/device = 8 * 128)
COLS = 128            # C
NDIM = 256            # application dims -> nh = 2 segments
Q = 128               # query batch per search
DEVICE_SWEEP = (1, 2, 4)


def worker(n_devices: int) -> None:
    """One sweep point (runs in a subprocess with N host devices)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                            DeviceConfig, ShardedCAMSimulator)
    from repro.launch.mesh import make_cam_mesh

    assert len(jax.devices()) >= n_devices, jax.devices()
    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=ROWS, cols=COLS, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"))

    K = n_devices * BANKS_PER_DEV * ROWS          # fixed rows/device
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    stored = jax.random.uniform(k1, (K, NDIM))
    queries = jax.random.uniform(k2, (Q, NDIM))

    def timeit(f, n=7):
        for _ in range(2):
            jax.block_until_ready(f())
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    sharded = ShardedCAMSimulator(cfg, make_cam_mesh(n_devices),
                                  use_kernel=True)
    s_state = sharded.write(stored)
    t_n = timeit(lambda: sharded.query(s_state, queries))

    single = ShardedCAMSimulator(cfg, make_cam_mesh(1), use_kernel=True)
    o_state = single.write(stored)
    t_1 = timeit(lambda: single.query(o_state, queries))

    ia, _ = single.query(o_state, queries)
    ib, _ = sharded.query(s_state, queries)
    ok = bool((np.asarray(ia) == np.asarray(ib)).all())
    qps_n, qps_1 = Q / t_n, Q / t_1
    print(f"kernel_cam_search_sharded_d{n_devices},{t_n * 1e6:.0f},"
          f"qps={qps_n:.0f}_qps_1dev={qps_1:.0f}_"
          f"speedup={t_1 / t_n:.2f}x_rows={K}_"
          f"rows_per_dev={BANKS_PER_DEV * ROWS}_match={ok}")


def main(max_devices: int = 4) -> None:
    """Spawn one worker per device count <= ``max_devices``, echo CSV."""
    root = pathlib.Path(__file__).resolve().parent.parent
    for n in DEVICE_SWEEP:
        if n > max_devices:
            continue
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["JAX_PLATFORMS"] = "cpu"    # skip the libtpu-init stall
        env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.sharded_bench",
             "--worker", str(n)],
            env=env, cwd=str(root), capture_output=True, text=True,
            timeout=1800)
        if proc.returncode != 0:
            print(f"kernel_cam_search_sharded_d{n},0,"
                  f"failed({proc.stderr.strip()[-200:]!r})")
            continue
        for line in proc.stdout.splitlines():
            if line.startswith("kernel_cam_search_sharded"):
                print(line)


if __name__ == "__main__":
    if "--worker" in sys.argv:
        worker(int(sys.argv[sys.argv.index("--worker") + 1]))
    else:
        devs = 4
        if "--devices" in sys.argv:
            devs = int(sys.argv[sys.argv.index("--devices") + 1])
        main(devs)
