"""Weak-scaling benchmark for the sharded CAM search subsystem.

Fixed rows/device, growing nv: every device count N holds the same
(BANKS_PER_DEV x ROWS) rows per device, so the dataset grows with the
mesh (the scale-out story: capacity bounded by the mesh, not one HBM).
Each sweep point reports the sharded wall time at N devices AND a
single-device (1-bank mesh) reference over the *same* N-shard dataset —
``speedup`` is therefore the cross-device parallelism win on identical
data, and ``match`` asserts the merge stayed bit-identical.

Device counts need ``XLA_FLAGS=--xla_force_host_platform_device_count``
set before jax initializes, so the parent spawns one worker subprocess
per point:

    PYTHONPATH=src python -m benchmarks.sharded_bench [--devices N]
    PYTHONPATH=src python -m benchmarks.sharded_bench --worker N  (internal)

Interpret-mode CPU numbers are a proxy (the container has no TPU): the
structural claim is that per-device work is fixed while total rows grow.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import time

BANKS_PER_DEV = 8     # nv shards resident per device
ROWS = 128            # R: rows per subarray (rows/device = 8 * 128)
COLS = 128            # C
NDIM = 256            # application dims -> nh = 2 segments
Q = 128               # query batch per search
DEVICE_SWEEP = (1, 2, 4)


def worker(n_devices: int) -> None:
    """One sweep point (runs in a subprocess with N host devices): the
    point-code (mcam/l2) row and the ACAM range-search row, both at fixed
    rows/device."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                            DeviceConfig, ShardedCAMSimulator, SimConfig)
    from repro.launch.mesh import make_cam_mesh

    assert len(jax.devices()) >= n_devices, jax.devices()

    def timeit(f, n=7):
        for _ in range(2):
            jax.block_until_ready(f())
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            jax.block_until_ready(f())
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    def one(cfg, stored, name: str) -> None:
        queries = jax.random.uniform(jax.random.PRNGKey(1), (Q, NDIM))
        if stored.ndim == 3:
            # ACAM: half the batch queries stored-row centers (guaranteed
            # exact range matches) so the parity bit compares real match
            # results, not two all-miss tensors
            centers = stored.mean(-1)
            rows = (jnp.arange(Q) * 7) % stored.shape[0]
            queries = jnp.where((jnp.arange(Q) % 2 == 0)[:, None],
                                centers[rows], queries)
        sharded = ShardedCAMSimulator(cfg, make_cam_mesh(n_devices))
        s_state = sharded.write(stored)
        t_n = timeit(lambda: sharded.query(s_state, queries))

        single = ShardedCAMSimulator(cfg, make_cam_mesh(1))
        o_state = single.write(stored)
        t_1 = timeit(lambda: single.query(o_state, queries))

        ia, _ = single.query(o_state, queries)
        ib, _ = sharded.query(s_state, queries)
        ok = bool((np.asarray(ia) == np.asarray(ib)).all())
        K = stored.shape[0]
        qps_n, qps_1 = Q / t_n, Q / t_1
        print(f"{name}_d{n_devices},{t_n * 1e6:.0f},"
              f"qps={qps_n:.0f}_qps_1dev={qps_1:.0f}_"
              f"speedup={t_1 / t_n:.2f}x_rows={K}_"
              f"rows_per_dev={BANKS_PER_DEV * ROWS}_match={ok}")

    K = n_devices * BANKS_PER_DEV * ROWS          # fixed rows/device
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))

    cfg = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="adder", v_merge="comparator"),
        circuit=CircuitConfig(rows=ROWS, cols=COLS, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig(use_kernel=True))
    one(cfg, jax.random.uniform(k1, (K, NDIM)), "kernel_cam_search_sharded")

    # ACAM: same grid geometry, [lo, hi] range rows, exact range match on
    # the fused range kernel's match-only path
    acam_cfg = CAMConfig(
        app=AppConfig(distance="range", match_type="exact", match_param=3,
                      data_bits=0),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=ROWS, cols=COLS, cell_type="acam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig(use_kernel=True))
    lo = jax.random.uniform(k2, (K, NDIM))
    ranges = jnp.stack([lo, lo + 0.05], axis=-1)
    one(acam_cfg, ranges, "kernel_acam_range_sharded")


def main(max_devices: int = 4) -> None:
    """Spawn one worker per device count <= ``max_devices``, echo CSV."""
    root = pathlib.Path(__file__).resolve().parent.parent
    for n in DEVICE_SWEEP:
        if n > max_devices:
            continue
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["JAX_PLATFORMS"] = "cpu"    # skip the libtpu-init stall
        env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                             + env.get("PYTHONPATH", ""))
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.sharded_bench",
             "--worker", str(n)],
            env=env, cwd=str(root), capture_output=True, text=True,
            timeout=1800)
        # forward whatever rows the worker managed to print; only rows it
        # never reached are marked failed (a crash in the later ACAM
        # measurement must not discard the point-code result)
        printed = set()
        for line in proc.stdout.splitlines():
            for prefix in ("kernel_cam_search_sharded",
                           "kernel_acam_range_sharded"):
                if line.startswith(prefix):
                    printed.add(prefix)
                    print(line)
        if proc.returncode != 0:
            err = proc.stderr.strip()[-200:]
            for prefix in ("kernel_cam_search_sharded",
                           "kernel_acam_range_sharded"):
                if prefix not in printed:
                    print(f"{prefix}_d{n},0,failed({err!r})")


if __name__ == "__main__":
    if "--worker" in sys.argv:
        worker(int(sys.argv[sys.argv.index("--worker") + 1]))
    else:
        devs = 4
        if "--devices" in sys.argv:
            devs = int(sys.argv[sys.argv.index("--devices") + 1])
        main(devs)
