"""Mesh-level perf-model sweep for sharded CAM topologies.

Runs ``predict_search_sharded`` over the SAME weak-scaling geometry the
measured sweep (``sharded_bench``) executes — fixed 8 banks x 128 rows per
device, Q=128 query batches — at d in {1, 2, 4} devices for each match
family, emitting one ``perf_sharded_d{d}_{match}`` row per point.  This is
the hardware-prediction counterpart of the ``kernel_*_sharded_d{d}``
wall-time rows: the model is pure arithmetic (no devices needed), so the
sweep also runs in CI and on machines without forced host devices.

    PYTHONPATH=src python -m benchmarks.sharded_perf

Standalone runs merge their rows into ``BENCH_kernels.json`` (replacing
stale rows of the same name); under ``benchmarks.run`` the parent collects
the CSV like every other benchmark.
"""
from __future__ import annotations

import pathlib
import time

BANKS_PER_DEV = 8     # nv shards resident per device (matches sharded_bench)
ROWS = 128
COLS = 128
NDIM = 256
Q = 128               # queries amortizing one merge collective
DEVICE_SWEEP = (1, 2, 4)
LINK = "on_package"


def _configs():
    from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                            DeviceConfig)

    def cam(match, h_merge, v_merge, sensing):
        return CAMConfig(
            app=AppConfig(distance="l2", match_type=match, match_param=3,
                          data_bits=3),
            arch=ArchConfig(h_merge=h_merge, v_merge=v_merge),
            circuit=CircuitConfig(rows=ROWS, cols=COLS, cell_type="mcam",
                                  sensing=sensing),
            device=DeviceConfig(device="fefet"))

    return (("exact", cam("exact", "and", "gather", "exact")),
            ("best", cam("best", "adder", "comparator", "best")),
            ("threshold", cam("threshold", "adder", "gather", "threshold")))


def sweep() -> list:
    """All sweep points as ``(name, us_per_call, derived)`` rows."""
    from repro.core.perf import (MeshSpec, estimate_arch, predict_search,
                                 predict_search_sharded)

    out = []
    for match, cfg in _configs():
        lat_prev = None
        for d in DEVICE_SWEEP:
            K = d * BANKS_PER_DEV * ROWS          # fixed rows/device
            arch = estimate_arch(cfg, K, NDIM)
            t0 = time.perf_counter()
            p = predict_search_sharded(cfg, arch, MeshSpec(d, LINK),
                                       queries_per_batch=Q)
            dt_us = (time.perf_counter() - t0) * 1e6
            one_chip = predict_search(cfg, arch)  # same K on a single chip
            mesh = p.breakdown["mesh"]
            mono = lat_prev is None or p.latency_ns <= lat_prev
            lat_prev = p.latency_ns
            out.append((
                f"perf_sharded_d{d}_{match}", f"{dt_us:.1f}",
                f"lat_ns={p.latency_ns:.4f}_"
                f"lat_1chip_ns={one_chip.latency_ns:.4f}_"
                f"energy_pj={p.energy_pj:.1f}_"
                f"energy_1chip_pj={one_chip.energy_pj:.1f}_"
                f"bytes_dev={mesh['bytes_per_device_batch']:.0f}_"
                f"rows={K}_link={LINK}_monotone={mono}"))
    return out


def main() -> None:
    for name, us, derived in sweep():
        print(f"{name},{us},{derived}")


def merge_into_json(rows) -> pathlib.Path:
    """Replace/append our rows in BENCH_kernels.json (standalone mode)."""
    from .run import BENCH_JSON, merge_bench_rows
    merge_bench_rows([{"name": name, "us_per_call": float(us),
                       "derived": derived} for name, us, derived in rows])
    return BENCH_JSON


if __name__ == "__main__":
    got = sweep()
    for name, us, derived in got:
        print(f"{name},{us},{derived}")
    p = merge_into_json(got)
    print(f"bench_json,0,rows={len(got)}_merged_into={p.name}")
