"""Synthetic MANN few-shot task (shared substrate for Table IV / Fig 4 / 5).

Structurally faithful to the paper's MANN setup [8]: an embedding network
maps raw inputs to d-dim vectors; support embeddings are written into the
CAM; queries classify by best-match search.  The real task (Omniglot) needs
external data, so we use a synthetic analogue — clustered raw vectors with
nuisance noise — and validate the paper's *trends* (quantization bits,
dimension, subarray size, non-idealities); the perf numbers are calibrated
against Table IV exactly (see table4_validation.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                        DeviceConfig)
from repro.models.cam_memory import CAMMemory, accuracy

RAW_DIM = 128


# ---------------------------------------------------------------------------
# Synthetic episodic data
# ---------------------------------------------------------------------------
def make_episode(key, n_way: int, n_shot: int, n_query: int,
                 noise: float = 1.1):
    """Returns (support_x, support_y, query_x, query_y)."""
    kp, ks, kq = jax.random.split(key, 3)
    protos = jax.random.normal(kp, (n_way, RAW_DIM))
    sup = (protos[:, None] + noise * jax.random.normal(
        ks, (n_way, n_shot, RAW_DIM))).reshape(-1, RAW_DIM)
    qry = (protos[:, None] + noise * jax.random.normal(
        kq, (n_way, n_query, RAW_DIM))).reshape(-1, RAW_DIM)
    sup_y = jnp.repeat(jnp.arange(n_way), n_shot)
    qry_y = jnp.repeat(jnp.arange(n_way), n_query)
    return sup, sup_y, qry, qry_y


# ---------------------------------------------------------------------------
# Embedding network (2-layer MLP, prototypical-style training)
# ---------------------------------------------------------------------------
def init_net(key, dim: int):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (RAW_DIM, 256)) / RAW_DIM ** 0.5,
        "b1": jnp.zeros((256,)),
        "w2": jax.random.normal(k2, (256, dim)) / 16.0,
        "b2": jnp.zeros((dim,)),
    }


def embed(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    e = h @ params["w2"] + params["b2"]
    return e / (jnp.linalg.norm(e, axis=-1, keepdims=True) + 1e-6)


def _proto_loss(params, sup, sup_y, qry, qry_y, n_way):
    es = embed(params, sup)
    eq = embed(params, qry)
    protos = jax.ops.segment_sum(es, sup_y, n_way)
    protos = protos / (jnp.linalg.norm(protos, axis=-1, keepdims=True)
                       + 1e-6)
    logits = -jnp.sum(
        jnp.square(eq[:, None] - protos[None]), axis=-1) * 8.0
    return -jnp.mean(jax.nn.log_softmax(logits)[
        jnp.arange(qry_y.shape[0]), qry_y])


@partial(jax.jit, static_argnums=(3,))
def _train_step(params, key, lr, n_way):
    sup, sup_y, qry, qry_y = make_episode(key, n_way, 5, 5)
    loss, g = jax.value_and_grad(_proto_loss)(params, sup, sup_y, qry,
                                              qry_y, n_way)
    params = jax.tree_util.tree_map(lambda p, gg: p - lr * gg, params, g)
    return params, loss


def train_embedding(dim: int, steps: int = 400, n_way: int = 10,
                    seed: int = 0):
    key = jax.random.PRNGKey(seed)
    params = init_net(key, dim)
    for i in range(steps):
        key, sub = jax.random.split(key)
        params, loss = _train_step(params, sub, 0.05, n_way)
    return params


# ---------------------------------------------------------------------------
# CAM-backed evaluation
# ---------------------------------------------------------------------------
def mann_cam_config(dim: int, bits: int, rows: int = 32, cols: int = 64,
                    sl: float = 0.0, d2d_std: float = 0.0) -> CAMConfig:
    return CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=1,
                      data_bits=bits),
        arch=ArchConfig(h_merge="voting", v_merge="comparator"),
        circuit=CircuitConfig(rows=rows, cols=cols, cell_type="mcam",
                              sensing="best", sensing_limit=sl),
        device=DeviceConfig(device="fefet",
                            variation="d2d" if d2d_std > 0 else "none",
                            variation_std=d2d_std))


def eval_mann(net_params, cfg: CAMConfig, *, n_way: int = 10,
              n_shot: int = 5, n_query: int = 15, episodes: int = 12,
              seed: int = 100, use_cam: bool = True,
              clip_sigma: float = 3.0) -> float:
    """Few-shot accuracy through the CAM (or fp32 reference).

    Embeddings are clipped at ``clip_sigma`` std before the CAM write so
    outliers don't stretch the linear-quantization range (application-level
    data prep, as in the quantization-aware MANN design [8])."""
    accs = []
    key = jax.random.PRNGKey(seed)
    for ep in range(episodes):
        key, sub = jax.random.split(key)
        sup, sup_y, qry, qry_y = make_episode(sub, n_way, n_shot, n_query)
        es, eq = embed(net_params, sup), embed(net_params, qry)
        s = jnp.std(es) * clip_sigma
        es, eq = jnp.clip(es, -s, s), jnp.clip(eq, -s, s)
        if use_cam:
            mem = CAMMemory(cfg)
            mem.write(es, sup_y, rng=jax.random.fold_in(sub, 1))
            accs.append(accuracy(mem, eq, qry_y,
                                 rng=jax.random.fold_in(sub, 2)))
        else:
            d = jnp.sum(jnp.square(eq[:, None] - es[None]), -1)
            pred = jnp.take(sup_y, jnp.argmin(d, -1))
            accs.append(float(jnp.mean((pred == qry_y).astype(
                jnp.float32))))
    return float(sum(accs) / len(accs))
