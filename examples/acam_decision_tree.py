"""Decision-tree inference on an ACAM (the X-TIME [12] use-case the paper
cites): every root-to-leaf path becomes one row of analog [lo, hi] ranges;
a sample classifies by EXACT range-match — one CAM search replaces the
whole tree traversal.

    PYTHONPATH=src python examples/acam_decision_tree.py [--kernel]

This is now a thin client of the query compiler (``core.plan``): the tree
goes in as an IR program (``tree_from_paths``) and ``CAMASim.compile``
lowers it onto the ACAM — the same leaf-per-row placement this example
used to hand-roll (``tests/test_plan.py`` proves the compiled schedule
bit-identical to the historical hand lowering on both backends).

``--kernel`` routes the batched classification through the fused ACAM
range-search Pallas kernel (``cam_range_fused_pallas``) instead of the jnp
broadcast path — same results, one HBM pass over the stored ranges for the
whole query batch.
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig, SimConfig)
from repro.core.plan import tree_from_paths

N_FEAT, DEPTH = 6, 3


# ---------------------------------------------------------------------------
# fit a tiny greedy decision tree on synthetic tabular data
# ---------------------------------------------------------------------------
def fit(X, y, depth):
    if depth == 0 or len(set(y.tolist())) == 1 or len(y) < 8:
        return int(round(y.mean()))
    best = None
    for f in range(X.shape[1]):
        for t in np.quantile(X[:, f], [0.25, 0.5, 0.75]):
            l = y[X[:, f] <= t]
            r = y[X[:, f] > t]
            if len(l) == 0 or len(r) == 0:
                continue
            gini = (len(l) * l.mean() * (1 - l.mean())
                    + len(r) * r.mean() * (1 - r.mean()))
            if best is None or gini < best[0]:
                best = (gini, f, t)
    if best is None:
        return int(round(y.mean()))
    _, f, t = best
    mask = X[:, f] <= t
    return (f, t, fit(X[mask], y[mask], depth - 1),
            fit(X[~mask], y[~mask], depth - 1))


def tree_paths(node, lo, hi):
    """Flatten the tree into per-leaf feature ranges."""
    if isinstance(node, int):
        return [(lo.copy(), hi.copy(), node)]
    f, t, left, right = node
    out = []
    lo2, hi2 = lo.copy(), hi.copy()
    hi2[f] = min(hi2[f], t)
    out += tree_paths(left, lo2, hi2)
    lo3, hi3 = lo.copy(), hi.copy()
    lo3[f] = max(lo3[f], t)
    out += tree_paths(right, lo3, hi3)
    return out


def tree_predict(node, x):
    while not isinstance(node, int):
        f, t, l, r = node
        node = l if x[f] <= t else r
    return node


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--kernel", action="store_true",
                    help="use the fused batched ACAM range Pallas kernel")
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, (600, N_FEAT))
    w = rng.normal(size=N_FEAT)
    y = ((X @ w + 0.3 * np.sin(7 * X[:, 0])) > np.median(X @ w)).astype(int)

    tree = fit(X, y, DEPTH)
    paths = tree_paths(tree, np.zeros(N_FEAT), np.ones(N_FEAT))
    print(f"tree with {len(paths)} leaves -> {len(paths)} ACAM rows "
          f"x {N_FEAT} range cells")

    # -----------------------------------------------------------------
    # compile the tree program onto the ACAM (leaf-per-row lowering) and
    # classify with one exact range-match per pass
    # -----------------------------------------------------------------
    cfg = CAMConfig(
        app=AppConfig(distance="range", match_type="exact", match_param=1,
                      data_bits=0),
        arch=ArchConfig(h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=8, cols=8, cell_type="acam",
                              sensing="exact"),
        device=DeviceConfig(device="fefet"),
        sim=SimConfig(use_kernel=args.kernel))
    sim = CAMASim(cfg)
    program = tree_from_paths(paths)
    compiled = sim.compile(program)

    Xt = rng.uniform(0, 1, (200, N_FEAT)).astype(np.float32)
    cam_pred = compiled.run(jnp.asarray(Xt))
    sw_pred = np.asarray([tree_predict(tree, x) for x in Xt])

    agree = (cam_pred == sw_pred).mean()
    res = compiled.query_raw(jnp.asarray(Xt))[0]
    matches_per_query = np.asarray(res.mask).sum(1)
    perf = compiled.estimate()
    path = "fused range kernel" if args.kernel else "jnp broadcast"
    print(f"search path: {path}")
    print(f"CAM vs software-tree agreement: {agree:.3f} (expect 1.0 — leaf "
          f"ranges tile the feature space)")
    print(f"matches per query: min={matches_per_query.min():.0f} "
          f"max={matches_per_query.max():.0f} (expect exactly 1)")
    print(f"modeled ACAM search: {perf['latency_ns']:.2f} ns, "
          f"{perf['energy_pj']:.2f} pJ")
    assert agree == 1.0
    assert (matches_per_query == 1).all()
    print("OK: one ACAM search == full decision-tree inference.")


if __name__ == "__main__":
    main()
