"""Quickstart: CAMASim in 30 lines — write data, search it, get hardware
numbers, all from ONE config.

The config now has five sections: the paper's four design levels
(app/arch/circuit/device, Table III) plus ``sim``, which says how the
experiment *executes* (backend, kernels, serving batch).  Swapping the
single-chip simulator for the mesh-sharded one is the one-line change
``sim=SimConfig(backend="sharded")`` — same results, and the whole
experiment can live in a JSON file (``CAMASim.from_json(path)``; see
examples/configs/ and the ``camasim-run`` console script).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (AppConfig, ArchConfig, CAMASim, CAMConfig,
                        CircuitConfig, DeviceConfig, SimConfig)


def main() -> None:
    # 1. describe the experiment (4 design levels + execution)
    config = CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=3,
                      data_bits=3),
        arch=ArchConfig(h_merge="voting", v_merge="comparator"),
        circuit=CircuitConfig(rows=32, cols=64, cell_type="mcam",
                              sensing="best", sensing_limit=0.0),
        device=DeviceConfig(device="fefet", variation="d2d",
                            variation_std=0.1),
        sim=SimConfig(backend="functional"))   # "sharded" = device mesh

    sim = CAMASim(config)

    # 2. write stored data (K entries x N dims) ONCE, then search many:
    # the whole batch goes through one fused batched grid pass
    key = jax.random.PRNGKey(0)
    stored = jax.random.uniform(key, (200, 256))
    state = sim.write(stored, key=jax.random.PRNGKey(1))

    queries = stored[jnp.array([17, 42, 133])] + 0.01
    result = sim.query(state, queries)        # typed SearchResult;
    indices, mask = result                    # ...still unpacks as a tuple
    print("top-3 matches per query:\n", indices)
    assert (jnp.asarray([17, 42, 133]) == result.topk(1)[:, 0]).all()

    # 3. hardware performance (EvaCAM-calibrated circuit models).
    # eval_perf also works BEFORE write: sim.plan(entries, dims) derives
    # the architecture from shapes alone (pure-model design sweeps).
    perf = sim.eval_perf(n_queries=queries.shape[0])
    print(f"architecture : {perf['arch']}")
    print(f"search latency: {perf.latency_ns:.2f} ns")
    print(f"energy (3 q) : {perf.energy_pj:.2f} pJ")
    print(f"area         : {perf.area_um2/1e3:.1f} x10^3 um^2")
    print(f"EDP          : {perf['edp_pj_ns']:.1f} pJ*ns")

    # 4. sublinear search: the two-stage cascade routes each query batch
    # to its top-p banks (bit-packed signature prefilter + IVF-clustered
    # placement) instead of streaming the whole grid; `top_p_banks=nv`
    # (or prefilter="off") is bit-identical to the full scan, and the
    # estimator bills only the searched-bank fraction — sweep the knob
    # BEFORE any write to pick the recall/energy point:
    cascade = CAMASim(config.replace(sim=dict(prefilter="ivf",
                                              top_p_banks=4,
                                              signature_bits=64)))
    routed = cascade.search(stored, queries, key=jax.random.PRNGKey(1))
    assert (jnp.asarray([17, 42, 133]) == routed.topk(1)[:, 0]).all()
    print("routed top-3 :\n", routed.indices)
    for p, rep in cascade.sweep_cascade([None, 2, 4],
                                        entries=200, dims=256).items():
        print(f"top_p={p}: {rep.energy_pj:.2f} pJ")

    # 5. streaming mutable store: reserve capacity head-room, then edit
    # the resident state online — insert/delete/update/compact — instead
    # of re-writing the whole grid (sim.d2d_fold="row" makes the
    # programming noise per-SLOT, so an insert is bit-identical to the
    # row having been in the fresh write).  examples/configs/serve.json
    # is this config as a file; CAMSearchServer serves and mutates the
    # same store with continuous batching + SLO latency stats.
    from repro.runtime import CAMSearchServer

    serve = CAMASim(config.replace(sim=dict(capacity=256, d2d_fold="row",
                                            serve_batch=8, serve_queue=64)))
    state = serve.write(stored, key=jax.random.PRNGKey(1))
    srv = CAMSearchServer(serve, state)
    ins = srv.submit_insert(jax.random.uniform(key, (2, 256)))  # new rows
    hit = srv.submit(stored[17])            # sees the inserts (order!)
    srv.submit_delete([42])                 # row 42 never matches again
    srv.run()
    print(f"inserted ids  : {ins.ids}")     # [200, 201]
    print(f"still found 17: {hit.indices[0] == 17}")
    print(f"latency stats : {srv.latency_stats()}")

    # 6. device reliability: inject faults, let the store heal itself.
    # Dead rows are detected by write-verify and remapped onto same-bank
    # spare rows (ids never change); conductance drift ages the store as
    # the server steps, and background scrubbing re-programs the most-
    # drifted rows every `scrub_every` steps.  `enabled=False` (or no
    # reliability section at all) is bit-identical to everything above.
    rel = CAMASim(config.replace(
        sim=dict(capacity=32, d2d_fold="row", serve_batch=8),
        reliability=dict(enabled=True, dead_row_frac=0.2, drift_rate=0.005,
                         verify_retries=2, verify_tol=0.5,
                         spares_per_bank=8, scrub_every=5, scrub_rows=16,
                         fault_seed=7)))
    # spares are SAME-BANK free slots, so leave head-room: 24 rows in a
    # 32-row bank keeps 8 slots for the healer to remap dead rows onto
    state = rel.write(stored[:24], key=jax.random.PRNGKey(1))
    healed = int(state.rel.retired.sum())
    print(f"rows healed onto spares: {healed}")   # dead rows, remapped
    srv = CAMSearchServer(rel, state)
    hit = srv.submit(stored[17])
    srv.run()                                # steps age + scrub the store
    for _ in range(20):
        srv.step()                           # idle steps keep scrubbing
    aged = rel.query(srv.state, stored[:3] + 0.01,
                     key=jax.random.PRNGKey(2))
    print(f"found 17 on faulty aged store: {hit.indices[0] == 17}")
    print(f"top-1 after 20 aged steps    : {aged.topk(1)[:, 0]}")
    # the estimator bills the mitigation: write energy scales by the
    # expected verify re-programs, scrub shows up per serve step
    rep = rel.eval_perf(n_queries=3)
    print(f"E[programs/row]: {rep['expected_row_programs']:.2f}, "
          f"scrub: {rep['scrub_energy_pj_per_step']:.1f} pJ/step")


if __name__ == "__main__":
    main()
