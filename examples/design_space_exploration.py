"""Design-space exploration (the paper's §IV-B case study, condensed).

Two nested searches, cleanly split since the query-compiler PR:

* the DESIGN space (quantization bits x subarray columns x embedding dim)
  still needs functional simulation — accuracy is measured by running the
  MANN task per design point;
* the DEPLOYMENT space (fused-kernel q_tile, device mesh + link preset,
  cascade bank budget) is swept by ``CAMASim.autotune`` — an exhaustive
  estimator-only ranking that picks the best ``sim`` section for each
  design BEFORE any write (no hand-rolled nested loop, no fabricated
  stores).

    PYTHONPATH=src:. python examples/design_space_exploration.py
"""
from benchmarks import mann_task
from repro.core import CAMASim

DIMS = (64, 128)
BITS = (2, 3)
COLS = (32, 64)
ENTRIES = 32          # support-set rows planned into the CAM
BATCH = 16            # serving batch the deployment is tuned for


def main() -> None:
    print("training embedding nets...")
    nets = {d: mann_task.train_embedding(dim=d, steps=250) for d in DIMS}

    print(f"{'dim':>4} {'bits':>4} {'cols':>4} {'acc':>6} {'lat_ns':>8} "
          f"{'en_pJ':>8} {'EDP_aJs':>8}  tuned deployment")
    best = None
    for d in DIMS:
        for b in BITS:
            for c in COLS:
                cfg = mann_task.mann_cam_config(d, b, rows=32, cols=c)
                acc = mann_task.eval_mann(nets[d], cfg, episodes=5)
                sim = CAMASim(cfg)
                # estimator-only deployment sweep: no write happens
                tuned = sim.autotune(ENTRIES, d, objective="edp",
                                     queries_per_batch=BATCH)
                m = tuned.best.metrics
                k = tuned.best.knobs
                edp = m["edp_pj_ns"] * 1e-3
                knobs = (f"dev={k['devices']} link={k['link']} "
                         f"top_p={k['top_p_banks']} q_tile={k['q_tile']}")
                print(f"{d:4d} {b:4d} {c:4d} {acc:6.3f} "
                      f"{m['latency_ns']:8.2f} {m['energy_pj']:8.2f} "
                      f"{edp:8.3f}  {knobs}")
                score = acc - 0.002 * edp
                if best is None or score > best[0]:
                    best = (score, d, b, c, acc, edp, tuned)

    _, d, b, c, acc, edp, tuned = best
    print(f"\nbest accuracy/EDP trade-off: dim={d} bits={b} cols={c} "
          f"(acc={acc:.3f}, EDP={edp:.3f} aJ*s)")
    print(f"its deployment space, ranked by the estimator "
          f"({len(tuned.candidates)} candidates, {tuned.skipped} invalid):")
    print(tuned.table(top=5))
    print("\nwinning sim section (loadable as-is in a JSON config):")
    print(" ", tuned.config.sim)


if __name__ == "__main__":
    main()
