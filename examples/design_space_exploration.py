"""Design-space exploration (the paper's §IV-B case study, condensed).

Sweeps quantization bits x subarray columns x device variation for the
MANN task and prints an accuracy / EDP Pareto view — the workflow CAMASim
exists to enable.

The hardware side is PURE-MODEL planning: ``CAMASim.plan(entries, dims)``
derives the architecture specifics from the store SHAPE alone, so
``eval_perf`` runs before (and here, without) any ``write`` — the sweep
no longer fabricates zero-filled stores just to bill area.

    PYTHONPATH=src:. python examples/design_space_exploration.py
"""
from benchmarks import mann_task
from repro.core import CAMASim

DIMS = (64, 128)
BITS = (2, 3)
COLS = (32, 64)
STD = (0.0, 1.0)
ENTRIES = 32          # support-set rows planned into the CAM


def main() -> None:
    print("training embedding nets...")
    nets = {d: mann_task.train_embedding(dim=d, steps=250) for d in DIMS}

    print(f"{'dim':>4} {'bits':>4} {'cols':>4} {'d2d':>4} "
          f"{'acc':>6} {'lat_ns':>8} {'en_pJ':>8} {'EDP_aJs':>8}")
    best = None
    for d in DIMS:
        for b in BITS:
            for c in COLS:
                for s in STD:
                    cfg = mann_task.mann_cam_config(d, b, rows=32, cols=c,
                                                    d2d_std=s)
                    acc = mann_task.eval_mann(nets[d], cfg, episodes=5)
                    sim = CAMASim(cfg)
                    sim.plan(ENTRIES, d)        # estimator-only: no write
                    perf = sim.eval_perf()
                    edp = perf.latency_ns * perf.energy_pj * 1e-3
                    print(f"{d:4d} {b:4d} {c:4d} {s:4.1f} {acc:6.3f} "
                          f"{perf.latency_ns:8.2f} "
                          f"{perf.energy_pj:8.2f} {edp:8.3f}")
                    score = acc - 0.002 * edp
                    if best is None or score > best[0]:
                        best = (score, d, b, c, s, acc, edp)

    _, d, b, c, s, acc, edp = best
    print(f"\nbest accuracy/EDP trade-off: dim={d} bits={b} cols={c} "
          f"(acc={acc:.3f}, EDP={edp:.3f} aJ*s)"
          f"{' under variation' if s else ''}")


if __name__ == "__main__":
    main()
