"""End-to-end LM training driver example: trains a reduced-config model
(same code path as the production launcher: sharding ctx, fault-tolerant
supervisor, checkpoints, synthetic data pipeline) and prints the loss
curve.

    PYTHONPATH=src python examples/train_lm.py [--arch deepseek-moe-16b]

For a ~100M-parameter run use e.g.:
    python examples/train_lm.py --arch qwen2-1.5b --d-model 512 \
        --layers 8 --steps 200
(sized for real accelerators; on this CPU container keep defaults small).
"""
import argparse
import sys

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamW, warmup_cosine
from repro.runtime import init_state, make_train_step, sharding_ctx

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
ap.add_argument("--steps", type=int, default=40)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--d-model", type=int, default=0)
ap.add_argument("--layers", type=int, default=0)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
if args.d_model:
    cfg = cfg.replace(d_model=args.d_model,
                      n_heads=max(4, args.d_model // 64),
                      n_kv_heads=max(1, args.d_model // 128), d_head=64)
if args.layers:
    cfg = cfg.replace(n_layers=args.layers)

opt = AdamW(lr=warmup_cosine(1e-3, 5, args.steps))
data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                   input_mode=cfg.input_mode, d_model=cfg.d_model)
mesh = make_local_mesh()

with sharding_ctx(mesh):
    state = init_state(jax.random.PRNGKey(0), cfg, opt)
    from repro.models import param_count
    print(f"{args.arch} (reduced): {param_count(state.params):,} params")
    step = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))
    for i in range(args.steps):
        state, m = step(state, data.batch(i))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}")
print("done.")
