"""CAM-retrieval attention on a long context (the long_500k story, scaled
to CPU): a needle-in-a-haystack retrieval demo.

A reduced model decodes against a long KV cache; with CAM retrieval ON the
attention only touches the top-k best-match entries — we verify the
planted "needle" key is retrieved from far back in the cache and compare
the bytes touched vs dense attention.  The retrieval itself is the
batched entry point: all (batch, head) searches over the cache run in one
``cam_decode_attention`` call, not a per-query loop.

    PYTHONPATH=src python examples/long_context_retrieval.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.attention import decode_attention
from repro.models.cam_attention import cam_decode_attention

S = 8192                 # long cache (500k in the production dry-run)
B, KVH, G, D = 1, 2, 2, 32
H = KVH * G
# 16 of 8192 entries: tight enough that the needle's softmax weight
# dominates the retrieved set (at 64 the 63 near-zero competitors dilute
# it to ~0.27 and the demo's recovery threshold is unreachable)
TOPK = 16


def main() -> None:
    cfg = get_config("granite-8b").reduced().replace(cam_topk=TOPK)
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)

    # a haystack of near-orthogonal keys + one planted needle at pos 1234
    k_cache = 0.1 * jax.random.normal(k1, (B, S, KVH, D))
    v_cache = 0.1 * jax.random.normal(k2, (B, S, KVH, D))
    needle = jax.random.normal(k3, (D,))
    k_cache = k_cache.at[0, 1234].set(jnp.stack([needle, needle]))
    v_cache = v_cache.at[0, 1234].set(7.0)

    q = jnp.broadcast_to(needle, (B, H, D)) * 0.9   # query ~ the needle
    pos = jnp.full((B,), S - 1, jnp.int32)

    dense = decode_attention(q, k_cache, v_cache, pos)
    cam = cam_decode_attention(q, k_cache, v_cache, pos, cfg)

    print(f"cache length        : {S} entries")
    print(f"CAM retrieval top-k : {TOPK} ({100*TOPK/S:.1f}% of the cache)")
    print(f"needle value found  : dense={float(dense.mean()):.3f} "
          f"cam={float(cam.mean()):.3f} (planted 7.0)")

    bytes_dense = S * KVH * D * 2 * 2          # read all K and V
    bytes_cam = S * KVH * D * 2 + TOPK * G * KVH * D * 2  # K scan + k of V
    print(f"value bytes touched : dense={bytes_dense/1e6:.2f} MB "
          f"cam={bytes_cam/1e6:.2f} MB "
          f"({bytes_dense/bytes_cam:.1f}x reduction)")

    # the interesting part: softmax over 8192 near-zero scores DILUTES the
    # needle (weight ~exp(s)/(exp(s)+S)), while the CAM best-match search
    # concentrates attention on the retrieved set — exactly the MANN
    # behaviour the paper validates, inside an LM decode step.
    assert float(cam.mean()) > 3.0, "CAM retrieval must recover the needle"
    assert float(cam.mean()) > float(dense.mean()) + 1.0
    print("OK: CAM best-match retrieval recovered the needle that dense "
          "attention diluted.")


if __name__ == "__main__":
    main()
