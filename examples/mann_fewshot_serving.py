"""End-to-end MANN few-shot classification service (the paper's own
validation application [8], served with batched requests).

Flow: train an embedding net -> write support-set embeddings into the CAM
through the ``CAMASim`` facade -> serve classification requests through
``runtime.CAMSearchServer`` (micro-batching; the batch ceiling comes from
``config.sim.serve_batch``, and query-axis autoscaling picks each step's
padded width from the power-of-two ladder by queue depth, so the tail of
the request stream doesn't pay the full-batch grid pass) -> report
accuracy and the accelerator's modeled latency/energy.

    PYTHONPATH=src:. python examples/mann_fewshot_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import mann_task
from repro.core import CAMASim
from repro.runtime import CAMSearchServer

DIM, BITS = 128, 3
N_WAY, N_SHOT = 10, 5
BATCHES, BATCH_SIZE = 8, 32


def main() -> None:
    print("training embedding net (prototypical loss, synthetic episodes)...")
    net = mann_task.train_embedding(dim=DIM, steps=300)

    # one config describes the whole experiment, serving batch included
    cfg = mann_task.mann_cam_config(DIM, BITS, rows=32, cols=64).replace(
        sim=dict(serve_batch=BATCH_SIZE))
    sim = CAMASim(cfg)

    # one episode acts as the serving corpus
    key = jax.random.PRNGKey(7)
    sup, sup_y, qry, qry_y = mann_task.make_episode(
        key, N_WAY, N_SHOT, BATCHES * BATCH_SIZE // N_WAY)
    es = mann_task.embed(net, sup)
    s = jnp.std(es) * 3.0
    state = sim.write(jnp.clip(es, -s, s))
    print(f"wrote {es.shape[0]} support embeddings into the CAM "
          f"({sim.arch_specifics().describe()})")

    # serving loop: requests stream in, the server micro-batches them
    # (batch read from cfg.sim.serve_batch; autoscale shrinks tail steps)
    eq = np.asarray(jnp.clip(mann_task.embed(net, qry), -s, s))
    labels = np.asarray(sup_y)
    srv = CAMSearchServer(sim, state, autoscale=True)
    t0 = time.perf_counter()
    reqs = [srv.submit(q) for q in eq]
    done = srv.run()
    wall = time.perf_counter() - t0

    # MANN config is 1-NN (match_param=1): label = nearest match's label
    pred = labels[np.maximum(np.stack([r.indices[0] for r in done]), 0)]
    correct = int((pred == np.asarray(qry_y)[[r.rid for r in done]]).sum())
    total = len(done)

    perf = sim.eval_perf(n_queries=BATCH_SIZE)
    print(f"served {total} queries in {wall*1e3:.0f} ms "
          f"(simulation wall-time, batch<={srv.batch})")
    print(f"accuracy: {correct/total:.3f}")
    print(f"modeled accelerator: {perf.latency_ns:.2f} ns/query, "
          f"{perf.energy_pj/BATCH_SIZE:.2f} pJ/query")


if __name__ == "__main__":
    main()
