"""End-to-end MANN few-shot classification service (the paper's own
validation application [8], served with batched requests).

Flow: train an embedding net -> write support-set embeddings into the CAM
-> serve batched classification queries through the functional simulator
-> report accuracy and the accelerator's latency/energy per batch.

    PYTHONPATH=src:. python examples/mann_fewshot_serving.py
"""
import time

import jax
import jax.numpy as jnp

from benchmarks import mann_task
from repro.models.cam_memory import CAMMemory

DIM, BITS = 128, 3
N_WAY, N_SHOT = 10, 5
BATCHES, BATCH_SIZE = 8, 32

print("training embedding net (prototypical loss, synthetic episodes)...")
net = mann_task.train_embedding(dim=DIM, steps=300)

cfg = mann_task.mann_cam_config(DIM, BITS, rows=32, cols=64)
mem = CAMMemory(cfg)

# one episode acts as the serving corpus
key = jax.random.PRNGKey(7)
sup, sup_y, qry, qry_y = mann_task.make_episode(
    key, N_WAY, N_SHOT, BATCHES * BATCH_SIZE // N_WAY)
es = mann_task.embed(net, sup)
s = jnp.std(es) * 3.0
mem.write(jnp.clip(es, -s, s), sup_y)
print(f"wrote {es.shape[0]} support embeddings into the CAM "
      f"({mem.sim.arch_specifics().describe()})")

# batched serving loop
eq = jnp.clip(mann_task.embed(net, qry), -s, s)
correct = total = 0
t0 = time.perf_counter()
for b in range(eq.shape[0] // BATCH_SIZE):
    xb = eq[b * BATCH_SIZE:(b + 1) * BATCH_SIZE]
    yb = qry_y[b * BATCH_SIZE:(b + 1) * BATCH_SIZE]
    pred, _ = mem.query(xb, rng=jax.random.fold_in(key, b))
    correct += int((pred == yb).sum())
    total += BATCH_SIZE
wall = time.perf_counter() - t0

perf = mem.perf(n_queries=BATCH_SIZE)
print(f"served {total} queries in {wall*1e3:.0f} ms "
      f"(simulation wall-time)")
print(f"accuracy: {correct/total:.3f}")
print(f"modeled accelerator: {perf['latency_ns']:.2f} ns/query, "
      f"{perf['energy_pj']/BATCH_SIZE:.2f} pJ/query")
