"""``camasim-run``: execute one JSON experiment config end to end.

    camasim-run CONFIG.json [--entries K] [--dims N] [--queries Q]
                            [--seed S] [--include-write] [--plan-only]

The config is the FULL experiment description (app/arch/circuit/device
design levels + the sim execution section); the CLI drives
``CAMASim.from_json`` through write -> query -> eval_perf on synthetic
data and prints the performance report as JSON to stdout.  With
``--plan-only`` no data is ever written: the architecture is derived from
the (entries, dims) shape alone (estimator-only planning).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Optional


def _jsonable(obj):
    """Report -> plain JSON: PerfResult leaves become their field dicts."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="camasim-run", description=__doc__)
    ap.add_argument("config", help="path to the JSON experiment config")
    ap.add_argument("--entries", type=int, default=64,
                    help="stored entries K (default 64)")
    ap.add_argument("--dims", type=int, default=32,
                    help="entry dims N (default 32)")
    ap.add_argument("--queries", type=int, default=8,
                    help="query batch size (default 8)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--include-write", action="store_true",
                    help="add the write-path prediction to the report")
    ap.add_argument("--plan-only", action="store_true",
                    help="estimator-only: no functional simulation at all")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.core import CAMASim

    sim = CAMASim.from_json(args.config)
    cfg = sim.config
    print(f"config : {args.config}", file=sys.stderr)
    print(f"backend: {cfg.sim.backend} (use_kernel={cfg.sim.use_kernel})",
          file=sys.stderr)

    if args.plan_only:
        sim.plan(args.entries, args.dims)
    else:
        key = jax.random.PRNGKey(args.seed)
        k1, k2, k3 = jax.random.split(key, 3)
        stored = jax.random.uniform(k1, (args.entries, args.dims))
        if cfg.app.distance == "range":      # ACAM [lo, hi] range store
            stored = jnp.stack([stored, stored + 0.2], axis=-1)
        queries = jax.random.uniform(k2, (args.queries, args.dims))
        state = sim.write(stored, key=k3)
        res = sim.query(state, queries)
        hits = int((jnp.asarray(res.mask) > 0).any(-1).sum())
        print(f"search : {args.queries} queries against "
              f"{args.entries}x{args.dims} store, "
              f"{hits} with >=1 match", file=sys.stderr)
        print(f"arch   : {sim.arch_specifics().describe()}", file=sys.stderr)

    perf = sim.eval_perf(n_queries=args.queries,
                         include_write=args.include_write)
    json.dump(_jsonable(perf.to_dict()), sys.stdout, indent=1)
    print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
