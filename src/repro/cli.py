"""``camasim-run``: execute one JSON experiment config end to end.

    camasim-run CONFIG.json [--entries K] [--dims N] [--queries Q]
                            [--seed S] [--include-write] [--plan-only]
    camasim-run CONFIG.json --autotune [--objective edp] [--top T]

The config is the FULL experiment description (app/arch/circuit/device
design levels + the sim execution section); the CLI drives
``CAMASim.from_json`` through write -> query -> eval_perf on synthetic
data and prints the performance report as JSON to stdout.  With
``--plan-only`` no data is ever written: the architecture is derived from
the (entries, dims) shape alone (estimator-only planning).

``--autotune`` extends plan-only semantics to the whole DEPLOYMENT space:
it sweeps the ``sim``-section knobs (q_tile / devices / link /
top_p_banks / ...) purely on the estimator, prints the ranked candidate
table to stderr, writes the winning full config as
``CONFIG.tuned.json`` next to the input, and emits a JSON summary
(objective, winning knobs/metrics, tuned path) to stdout.  Still zero
writes — the tuned config deploys by re-running with it.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import Optional


def _jsonable(obj):
    """Report -> plain JSON: PerfResult leaves become their field dicts."""
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return _jsonable(dataclasses.asdict(obj))
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    return obj


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="camasim-run", description=__doc__)
    ap.add_argument("config", help="path to the JSON experiment config")
    ap.add_argument("--entries", type=int, default=64,
                    help="stored entries K (default 64)")
    ap.add_argument("--dims", type=int, default=32,
                    help="entry dims N (default 32)")
    ap.add_argument("--queries", type=int, default=8,
                    help="query batch size (default 8)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--include-write", action="store_true",
                    help="add the write-path prediction to the report")
    ap.add_argument("--plan-only", action="store_true",
                    help="estimator-only: no functional simulation at all")
    ap.add_argument("--autotune", action="store_true",
                    help="estimator-only deployment sweep: rank sim-section "
                         "candidates, write CONFIG.tuned.json next to the "
                         "input")
    ap.add_argument("--objective", default="edp",
                    help="autotune ranking objective "
                         "(latency|energy|area|edp|qps; default edp)")
    ap.add_argument("--top", type=int, default=10,
                    help="rows of the ranked table to print (default 10)")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.core import CAMASim

    sim = CAMASim.from_json(args.config)
    cfg = sim.config
    print(f"config : {args.config}", file=sys.stderr)
    print(f"backend: {cfg.sim.backend} (use_kernel={cfg.sim.use_kernel})",
          file=sys.stderr)

    if args.autotune:
        res = sim.autotune(args.entries, args.dims,
                           objective=args.objective,
                           queries_per_batch=args.queries)
        print(f"autotune: {len(res.candidates)} candidates ranked by "
              f"{res.objective} ({res.skipped} invalid skipped)",
              file=sys.stderr)
        print(res.table(top=args.top), file=sys.stderr)
        tuned_path = (args.config[:-len(".json")]
                      if args.config.endswith(".json")
                      else args.config) + ".tuned.json"
        with open(tuned_path, "w") as f:
            f.write(res.config.to_json(indent=1))
            f.write("\n")
        print(f"tuned  : {tuned_path}", file=sys.stderr)
        best = res.best
        json.dump({
            "objective": res.objective,
            "entries": res.entries,
            "dims": res.dims,
            "queries_per_batch": res.queries_per_batch,
            "candidates": len(res.candidates),
            "skipped": res.skipped,
            "tuned_config": tuned_path,
            "best": {"knobs": _jsonable(best.knobs),
                     "metrics": _jsonable(best.metrics)},
        }, sys.stdout, indent=1)
        print()
        return 0

    if args.plan_only:
        sim.plan(args.entries, args.dims)
    else:
        key = jax.random.PRNGKey(args.seed)
        k1, k2, k3 = jax.random.split(key, 3)
        stored = jax.random.uniform(k1, (args.entries, args.dims))
        if cfg.app.distance == "range":      # ACAM [lo, hi] range store
            stored = jnp.stack([stored, stored + 0.2], axis=-1)
        queries = jax.random.uniform(k2, (args.queries, args.dims))
        state = sim.write(stored, key=k3)
        res = sim.query(state, queries)
        hits = int((jnp.asarray(res.mask) > 0).any(-1).sum())
        print(f"search : {args.queries} queries against "
              f"{args.entries}x{args.dims} store, "
              f"{hits} with >=1 match", file=sys.stderr)
        print(f"arch   : {sim.arch_specifics().describe()}", file=sys.stderr)
        if getattr(state, "rel", None) is not None:
            import numpy as np
            healed = int(np.asarray(state.rel.retired).sum())
            unhealed = int(np.asarray(state.rel.failed).sum())
            print(f"reliab : {healed} rows healed onto spares, "
                  f"{unhealed} failed unhealed", file=sys.stderr)

    perf = sim.eval_perf(n_queries=args.queries,
                         include_write=args.include_write)
    json.dump(_jsonable(perf.to_dict()), sys.stdout, indent=1)
    print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
