from .ckpt import committed_steps, restore, restore_sharded, save

__all__ = ["save", "restore", "restore_sharded", "committed_steps"]
