"""Fault-tolerant checkpointing: atomic, keep-N, async, mesh-agnostic.

Layout (one directory per step):

    <dir>/step_000123/
        manifest.json        tree structure, shapes, dtypes, leaf files
        leaf_00000.npy ...   one .npy per leaf (written tmp + atomic rename)
    <dir>/step_000123.COMMITTED   commit marker (written last)

Restore picks the newest *committed* step, so a crash mid-write can never
yield a torn checkpoint.  Arrays are saved device-agnostic (gathered to
host) and resharded on load to whatever mesh the restarted job runs on —
this is what makes elastic re-scaling work (runtime/elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Tuple[List[Any], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


# numpy can't natively (de)serialize ml_dtypes (bfloat16, fp8, ...): store
# them as a same-width uint view and record the true dtype in the manifest.
_VIEW_WIDTH = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _to_storable(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    dtype_name = str(arr.dtype)
    try:
        np.dtype(dtype_name)
        native = arr.dtype.kind in "biufc?SUO"
    except TypeError:
        native = False
    if native:
        return arr, dtype_name
    return arr.view(_VIEW_WIDTH[arr.dtype.itemsize]), dtype_name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if str(arr.dtype) == dtype_name:
        return arr
    import ml_dtypes  # ships with jax
    return arr.view(np.dtype(getattr(ml_dtypes, dtype_name)))


def save(directory: str, step: int, tree, keep: int = 3,
         async_write: bool = False) -> str:
    """Write a checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    host = [np.asarray(jax.device_get(l)) for l in leaves]

    def write():
        name = f"step_{step:08d}"
        final = os.path.join(directory, name)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "treedef_str": str(treedef),   # debugging aid only; restore
            "leaves": [],                  # maps leaves by flatten order
        }
        for i, arr in enumerate(host):
            fname = f"leaf_{i:05d}.npy"
            storable, dtype_name = _to_storable(arr)
            np.save(os.path.join(tmp, fname), storable)
            manifest["leaves"].append(
                {"file": fname, "shape": list(arr.shape),
                 "dtype": dtype_name})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        # commit marker written last: restore only trusts committed steps
        with open(final + ".COMMITTED", "w") as f:
            f.write(str(step))
        _gc(directory, keep)
        return final

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return os.path.join(directory, f"step_{step:08d}")
    return write()


def committed_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for f in os.listdir(directory):
        if f.endswith(".COMMITTED"):
            out.append(int(f[len("step_"):-len(".COMMITTED")]))
    return sorted(out)


def _gc(directory: str, keep: int) -> None:
    steps = committed_steps(directory)
    for s in steps[:-keep] if keep > 0 else []:
        name = os.path.join(directory, f"step_{s:08d}")
        for p in (name, name + ".COMMITTED"):
            if os.path.isdir(p):
                shutil.rmtree(p)
            elif os.path.exists(p):
                os.remove(p)


def restore(directory: str, treedef_example, step: Optional[int] = None
            ) -> Tuple[int, Any]:
    """Restore the newest committed checkpoint as host numpy arrays.

    ``treedef_example``: any pytree with the same structure (e.g. the
    freshly-initialized state) — leaf order defines file mapping.
    """
    steps = committed_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    _, treedef = jax.tree_util.tree_flatten(treedef_example)
    leaves = [_from_storable(np.load(os.path.join(path, e["file"])),
                             e["dtype"])
              for e in manifest["leaves"]]
    if treedef.num_leaves != len(leaves):
        raise ValueError(
            f"checkpoint has {len(leaves)} leaves, expected "
            f"{treedef.num_leaves} — structure mismatch")
    return step, jax.tree_util.tree_unflatten(treedef, leaves)


def restore_sharded(directory: str, example_tree, shardings,
                    step: Optional[int] = None) -> Tuple[int, Any]:
    """Restore + place each leaf with the given NamedSharding tree
    (elastic re-shard: the target mesh may differ from the writer's)."""
    step, host = restore(directory, example_tree, step)
    flat_h, treedef = jax.tree_util.tree_flatten(host)
    flat_s = treedef.flatten_up_to(shardings)
    placed = [jax.device_put(h, s) for h, s in zip(flat_h, flat_s)]
    return step, jax.tree_util.tree_unflatten(treedef, placed)
