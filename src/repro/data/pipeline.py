"""Deterministic synthetic LM data pipeline (shard-aware, checkpointable).

Batches are a pure function of (seed, step) — identical on every host, so
a restarted/elastically-resized job regenerates exactly the batch stream it
left off at (resume-by-construction; no data state to gather).  Each host
can also materialize only its addressable shard via `global_batch_for`.

Tokens follow a Zipf-ish distribution over the vocab (more realistic
collision structure than uniform for embedding-gradient sparsity).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    input_mode: str = "tokens"      # tokens | embeddings
    d_model: int = 0                # for embeddings mode

    # ------------------------------------------------------------------
    def batch(self, step: int) -> Dict[str, jax.Array]:
        """Global batch for a step (device-agnostic, deterministic)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B, S = self.global_batch, self.seq_len
        kt, ke = jax.random.split(key)
        # Zipf-ish: exponentiate a uniform, scale to vocab
        u = jax.random.uniform(kt, (B, S + 1), minval=1e-6, maxval=1.0)
        toks = jnp.minimum(
            (u ** 3.0 * self.vocab_size).astype(jnp.int32),
            self.vocab_size - 1)
        out: Dict[str, jax.Array] = {
            "labels": toks[:, 1:],
        }
        if self.input_mode == "tokens":
            out["tokens"] = toks[:, :-1]
        else:
            out["embeds"] = jax.random.normal(
                ke, (B, S, self.d_model), jnp.bfloat16)
        return out

    # ------------------------------------------------------------------
    def batch_np(self, step: int) -> Dict[str, np.ndarray]:
        return {k: np.asarray(v) for k, v in self.batch(step).items()}

    def state(self, step: int) -> Dict:
        """Checkpointable pipeline state."""
        return {"seed": self.seed, "step": step,
                "vocab_size": self.vocab_size,
                "global_batch": self.global_batch, "seq_len": self.seq_len}

    @classmethod
    def from_state(cls, state: Dict, **kw) -> "SyntheticLM":
        return cls(vocab_size=state["vocab_size"], seed=state["seed"],
                   global_batch=state["global_batch"],
                   seq_len=state["seq_len"], **kw)


def shard_batch(batch: Dict, mesh, rules=None) -> Dict:
    """Place a host-global batch onto the mesh with batch-axis sharding."""
    from jax.sharding import NamedSharding
    from repro.runtime.sharding import ShardingRules
    rules = rules or ShardingRules()

    def put(x):
        axes = ("batch",) + (None,) * (x.ndim - 1)
        spec = rules.spec_for(x.shape, axes, mesh)
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map(put, batch)
