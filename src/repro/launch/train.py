"""Training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --steps 50 --reduced --batch 8 --seq 128

On this CPU container use --reduced (same code path as production; the
full configs are exercised by the dry-run).  On a real slice, omit
--reduced and the mesh comes from the runtime's device set.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import committed_steps
from repro.configs import ARCH_IDS, get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamW, warmup_cosine
from repro.runtime import (elastic, init_state, make_train_step,
                           sharding_ctx, state_axes)
from repro.runtime.fault import Supervisor


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--moe-mode", default="tp", choices=["tp", "ep"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_local_mesh()
    opt = AdamW(lr=warmup_cosine(args.lr, max(2, args.steps // 10),
                                 args.steps))
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch,
                       input_mode=cfg.input_mode, d_model=cfg.d_model)

    with sharding_ctx(mesh):
        state = init_state(jax.random.PRNGKey(0), cfg, opt)
        start = 0
        if args.resume and committed_steps(args.ckpt_dir):
            start, state = elastic.elastic_restore(
                args.ckpt_dir, state, state_axes(cfg), mesh)
            print(f"resumed from step {start}")
        step_fn = jax.jit(make_train_step(cfg, opt, moe_mode=args.moe_mode,
                                          microbatch=args.microbatch),
                          donate_argnums=(0,))

        def wrapped(state, batch):
            state, m = step_fn(state, batch)
            return state, m

        sup = Supervisor(step_fn=wrapped, batch_fn=data.batch,
                         ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every)
        t0 = time.time()
        final_step, state = sup.run(state, start, args.steps)
        dt = time.time() - t0

    print(f"trained {args.steps} steps in {dt:.1f}s "
          f"({dt / max(1, args.steps):.2f} s/step); final step "
          f"{final_step}; events: {sup.events[-3:]}")
    return state


if __name__ == "__main__":
    main()
