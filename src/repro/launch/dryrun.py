import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
os.environ.setdefault("JAX_PLATFORMS", "cpu")  # fake host devices need CPU

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, prove memory fits, and extract roofline inputs.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch granite-8b --shape train_4k --mesh single

Cost accounting: XLA's cost_analysis counts a lax.scan body ONCE, so a
scanned L-layer stack under-reports by ~L.  Each cell therefore runs:

  1. the FULL config (flash attention, scanned, microbatched) — this is the
     artifact that must compile and fit memory (memory_analysis), and
  2. two cheap cost PROBES at L1/L2 layers with attn_impl='naive' (identical
     FLOPs to our flash, but no inner scans) — per-layer costs are the
     (L2-L1) delta, extrapolated to the real depth; constant-in-L terms
     (embeddings, loss, optimizer intercept) live in the intercept.

Results are cached incrementally under experiments/dryrun/<tag>/ as JSON;
EXPERIMENTS.md §Dry-run / §Roofline and the perf loop read from there.
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_input_specs, input_specs
from repro.optim import AdamW, constant
from repro.roofline import Roofline, model_flops, parse_collectives
from repro.runtime import (ShardingRules, abstract_state, make_train_step,
                           sharding_ctx, state_axes, tree_shardings)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

# per-(arch, shape) microbatch so the big dense archs fit HBM at train_4k
MICROBATCH: Dict[tuple, int] = {
    ("chameleon-34b", "train_4k"): 4,
    ("granite-20b", "train_4k"): 4,
    ("zamba2-7b", "train_4k"): 2,
    ("granite-8b", "train_4k"): 2,
    ("minicpm3-4b", "train_4k"): 2,
}


# ---------------------------------------------------------------------------
# Lowering one step function for an explicit config
# ---------------------------------------------------------------------------
def lower_kind(cfg: ModelConfig, kind: str, batch: int, seq: int, mesh,
               rules: ShardingRules, moe_mode: str = "tp",
               microbatch: Optional[int] = None):
    with sharding_ctx(mesh, rules):
        if kind == "train":
            inputs, axes = batch_specs(cfg, batch, seq)
            opt = AdamW(lr=constant(1e-4))
            step = make_train_step(cfg, opt, moe_mode=moe_mode,
                                   microbatch=microbatch)
            state = abstract_state(cfg)
            st_sh = tree_shardings(state_axes(cfg), state, mesh, rules,
                                   fsdp=True)
            in_sh = tree_shardings(axes, inputs, mesh, rules, fsdp=False)
            return jax.jit(
                step, in_shardings=(st_sh, in_sh),
                out_shardings=(st_sh, None), donate_argnums=(0,),
            ).lower(state, inputs)
        if kind == "prefill":
            inputs, axes = batch_specs(cfg, batch, seq)
            inputs.pop("labels"), axes.pop("labels")
            params = models.abstract_params(cfg)
            p_sh = tree_shardings(models.param_axes(cfg), params, mesh,
                                  rules, fsdp=True)
            in_sh = tree_shardings(axes, inputs, mesh, rules, fsdp=False)
            cache_s, cache_axes = models.cache_specs(cfg, batch, seq)
            c_sh = tree_shardings(cache_axes, cache_s, mesh, rules,
                                  fsdp=False)

            def prefill(params, b):
                return models.forward_prefill(params, cfg, b,
                                              moe_mode=moe_mode)

            return jax.jit(prefill, in_shardings=(p_sh, in_sh),
                           out_shardings=(None, c_sh)
                           ).lower(params, inputs)
        # decode
        inputs, axes = decode_input_specs(cfg, batch, seq)
        params = models.abstract_params(cfg)
        p_sh = tree_shardings(models.param_axes(cfg), params, mesh, rules,
                              fsdp=False)
        tok_sh = tree_shardings(axes["inputs"], inputs["inputs"], mesh,
                                rules, fsdp=False)
        pos_sh = tree_shardings({"p": axes["pos"]}, {"p": inputs["pos"]},
                                mesh, rules, fsdp=False)["p"]
        c_sh = tree_shardings(axes["cache"], inputs["cache"], mesh, rules,
                              fsdp=False)

        def serve(params, cache, inp, pos):
            return models.forward_decode(params, cfg, inp, pos, cache,
                                         moe_mode=moe_mode)

        return jax.jit(serve, in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
                       out_shardings=(None, c_sh), donate_argnums=(1,),
                       ).lower(params, inputs["cache"], inputs["inputs"],
                               inputs["pos"])


# ---------------------------------------------------------------------------
# Cost probes (scan-body correction)
# ---------------------------------------------------------------------------
def _extract_costs(compiled, chips: int) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax <= 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text(), chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire_bytes": coll.wire_bytes,
        "collectives": coll.ops,
    }


def probe_costs(cfg: ModelConfig, kind: str, batch: int, seq: int, mesh,
                rules: ShardingRules, moe_mode: str
                ) -> Tuple[Dict[str, float], Dict]:
    """Two-point UNROLLED probe -> per-layer extrapolation to real depth.

    FLOPs + collectives come from attn_impl='naive' probes (identical
    FLOPs to flash, no inner scans to undercount); bytes come from
    attn_impl='flash' probes (no fake S^2 HBM traffic).  Scanned configs
    can't be probed directly: XLA counts a while body once regardless of
    trip count (verified empirically — see EXPERIMENTS.md §Dry-run).
    """
    if cfg.family == "hybrid":
        L1, L2 = cfg.hybrid_attn_every, 2 * cfg.hybrid_attn_every
    else:
        L1, L2 = 1, 2
    chips = mesh.devices.size
    Lfull = cfg.n_layers
    scale = (Lfull - L1) / (L2 - L1)

    def extrap(a, b):
        return max(0.0, a + (b - a) * scale)

    def probe_pair(attn_impl: str):
        out = []
        for L in (L1, L2):
            pcfg = cfg.replace(n_layers=L, attn_impl=attn_impl,
                               scan_layers=False, moe_probe_balanced=True)
            lowered = lower_kind(pcfg, kind, batch, seq, mesh, rules,
                                 moe_mode=moe_mode, microbatch=None)
            out.append(_extract_costs(lowered.compile(), chips))
        return out

    # naive probes are honest for BOTH flops and bytes: the pure-JAX flash
    # path spills its score tiles to HBM between ops, so its true traffic
    # matches the naive S^2 count (the Pallas fused-attention §Perf change
    # is what cuts it — measured there with its own probe).
    flop_probes = probe_pair(cfg.attn_impl if cfg.attn_impl != "flash"
                             else "naive")  # 'skip' passes through

    out = {
        "flops": extrap(flop_probes[0]["flops"], flop_probes[1]["flops"]),
        "bytes": extrap(flop_probes[0]["bytes"], flop_probes[1]["bytes"]),
        "wire_bytes": extrap(flop_probes[0]["wire_bytes"],
                             flop_probes[1]["wire_bytes"]),
    }
    colls = {}
    ops = set(flop_probes[0]["collectives"]) | set(
        flop_probes[1]["collectives"])
    for op in ops:
        e1 = flop_probes[0]["collectives"].get(
            op, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
        e2 = flop_probes[1]["collectives"].get(
            op, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
        colls[op] = {k: extrap(e1[k], e2[k]) for k in e1}
    return out, colls


# ---------------------------------------------------------------------------
# One full cell
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape: str, mesh_kind: str = "single",
             moe_mode: str = "tp", microbatch: Optional[int] = None,
             rules: Optional[ShardingRules] = None,
             cfg_override=None, fused_attn: bool = False,
             tag: str = "baseline", save: bool = True,
             verbose: bool = True, probe: bool = True) -> dict:
    cell = input_specs(arch, shape)
    cfg = cfg_override(cell.cfg) if cfg_override else cell.cfg
    rules = rules or ShardingRules()
    if microbatch is None:
        microbatch = MICROBATCH.get((arch, shape))
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size

    # 1) the full artifact: must lower, compile, and fit
    t0 = time.time()
    lowered = lower_kind(cfg, cell.kind, cell.batch, cell.seq, mesh, rules,
                         moe_mode=moe_mode, microbatch=microbatch)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes",
                                  None),
        }
    except Exception as e:  # pragma: no cover
        mem_d = {"error": str(e)}

    # 2) probe-corrected costs
    if probe:
        pcfg = cfg.replace(attn_impl="skip") if fused_attn else cfg
        costs, colls = probe_costs(pcfg, cell.kind, cell.batch, cell.seq,
                                   mesh, rules, moe_mode)
        if fused_attn:
            inj = fused_attention_cost(cfg, cell.kind, cell.batch,
                                       cell.seq, mesh)
            costs["flops"] += inj["flops"]
            costs["bytes"] += inj["bytes"]
    else:
        costs = _extract_costs(compiled, chips)
        colls = costs.pop("collectives")

    mf = model_flops(cfg, cell.kind, cell.tokens_per_step)
    roof = Roofline(
        arch=arch, shape=shape, mesh=mesh_kind, chips=chips,
        flops_per_device=costs["flops"],
        bytes_per_device=costs["bytes"],
        wire_bytes_per_device=costs["wire_bytes"],
        model_flops_global=mf,
        collectives=colls,
        memory_per_device=mem_d,
    )
    out = {
        "tag": tag, "arch": arch, "shape": shape, "mesh": mesh_kind,
        "chips": chips, "kind": cell.kind, "moe_mode": moe_mode,
        "microbatch": microbatch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "roofline": roof.to_dict(),
    }
    if save:
        d = os.path.join(RESULTS_DIR, tag, mesh_kind)
        os.makedirs(d, exist_ok=True)
        with open(os.path.join(d, f"{arch}__{shape}.json"), "w") as f:
            json.dump(out, f, indent=1)
    if verbose:
        r = roof
        mem_gb = (mem_d.get("argument_bytes") or 0) / 2 ** 30
        print(f"[{tag}/{mesh_kind}] {arch} x {shape} ({cell.kind}): OK "
              f"lower={t_lower:.0f}s compile={t_compile:.0f}s "
              f"args={mem_gb:.2f}GiB/dev | "
              f"t_comp={r.t_compute*1e3:.2f}ms t_mem={r.t_memory*1e3:.2f}ms "
              f"t_coll={r.t_collective*1e3:.2f}ms -> {r.bottleneck} "
              f"useful={r.useful_flops_ratio:.2f} "
              f"frac={r.roofline_fraction:.3f}", flush=True)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-mode", default="tp", choices=["tp", "ep"])
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                path = os.path.join(RESULTS_DIR, args.tag, mesh_kind,
                                    f"{arch}__{shape}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"skip {arch} x {shape} ({mesh_kind})",
                          flush=True)
                    continue
                try:
                    run_cell(arch, shape, mesh_kind,
                             moe_mode=args.moe_mode,
                             microbatch=args.microbatch, tag=args.tag,
                             probe=not args.no_probe)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_kind, str(e)[:200]))
                    print(f"[{mesh_kind}] {arch} x {shape}: FAIL {e}",
                          flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()


# ---------------------------------------------------------------------------
# Fused-attention cost injection (§Perf: the Pallas flash kernel)
# ---------------------------------------------------------------------------
def fused_attention_cost(cfg: ModelConfig, kind: str, batch: int, seq: int,
                         mesh) -> Dict[str, float]:
    """Per-device flops/bytes of kernels/flash_attention.py, injected when
    probes run attn_impl='skip' (the kernel is a custom call XLA cannot
    cost).  Causal tiles above the diagonal are skipped by the kernel
    (0.5x), K/V restream once per q tile, and train counts fwd + remat
    re-fwd + bwd(~2x fwd).
    """
    if cfg.n_heads == 0 or kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    m = sizes.get("model", 1)
    dp = 1
    for a in ("pod", "data"):
        dp *= sizes.get(a, 1)
    B = batch // dp if batch % dp == 0 else batch
    H = cfg.n_heads // m if cfg.n_heads % m == 0 else cfg.n_heads
    KVH = (cfg.n_kv_heads // m if cfg.n_kv_heads % m == 0
           else cfg.n_kv_heads)
    if cfg.attention == "mla":
        Dk = cfg.qk_nope_dim + cfg.qk_rope_dim
        Dv = cfg.v_head_dim
        KVH = H
    else:
        Dk = Dv = cfg.head_dim
    S = seq
    n_attn = (cfg.n_layers // cfg.hybrid_attn_every
              if cfg.family == "hybrid" else cfg.n_layers)
    fwd_flops = 2.0 * B * S * S * (H * Dk + H * Dv) * 0.5   # qk + pv, causal
    mult_f = 4.0 if kind == "train" else 1.0                # fwd+refwd+2bwd
    q_tile = 512
    nq = max(1, S // q_tile)
    qkvo = B * S * (2 * H * Dk + KVH * (Dk + Dv)) * 2.0     # q,o + k,v HBM
    restream = nq * B * S * KVH * (Dk + Dv) * 2.0           # k,v per q tile
    mult_b = 3.0 if kind == "train" else 1.0
    return {"flops": n_attn * fwd_flops * mult_f,
            "bytes": n_attn * (qkvo + restream) * mult_b}
