"""ShapeDtypeStruct stand-ins for every model input, per (arch x shape).

`input_specs(arch, shape)` returns everything the dry-run needs to lower a
step without allocating: abstract arrays + their logical axes, plus which
step function the shape exercises (train / prefill / decode).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import models
from repro.configs import SHAPE_SPECS, get_config
from repro.configs.base import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str                    # train | prefill | decode
    cfg: ModelConfig
    seq: int
    batch: int
    inputs: Dict[str, Any]       # abstract arrays (kwargs of the step)
    input_axes: Dict[str, Any]   # logical axes matching `inputs`

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.batch
        return self.batch * self.seq


def batch_specs(cfg: ModelConfig, batch: int, seq: int
                ) -> Tuple[Dict, Dict]:
    if cfg.input_mode == "tokens":
        inputs = {"tokens": SDS((batch, seq), jnp.int32),
                  "labels": SDS((batch, seq), jnp.int32)}
        axes = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    else:
        inputs = {"embeds": SDS((batch, seq, cfg.d_model), jnp.bfloat16),
                  "labels": SDS((batch, seq), jnp.int32)}
        axes = {"embeds": ("batch", "seq", None),
                "labels": ("batch", "seq")}
    return inputs, axes


def decode_input_specs(cfg: ModelConfig, batch: int, seq: int
                       ) -> Tuple[Dict, Dict]:
    if cfg.input_mode == "tokens":
        tok = {"token": SDS((batch,), jnp.int32)}
        tok_axes = {"token": ("batch",)}
    else:
        tok = {"embed": SDS((batch, cfg.d_model), jnp.bfloat16)}
        tok_axes = {"embed": ("batch", None)}
    cache, cache_axes = models.cache_specs(cfg, batch, seq)
    inputs = {"inputs": tok, "pos": SDS((batch,), jnp.int32),
              "cache": cache}
    axes = {"inputs": tok_axes, "pos": ("batch",), "cache": cache_axes}
    return inputs, axes


def input_specs(arch: str, shape: str) -> CellSpec:
    cfg = get_config(arch)
    spec = SHAPE_SPECS[shape]
    seq, batch, kind = spec["seq"], spec["batch"], spec["kind"]

    if kind == "train":
        inputs, axes = batch_specs(cfg, batch, seq)
    elif kind == "prefill":
        inputs, axes = batch_specs(cfg, batch, seq)
        inputs.pop("labels"), axes.pop("labels")
    else:  # decode
        inputs, axes = decode_input_specs(cfg, batch, seq)
    return CellSpec(arch=arch, shape=shape, kind=kind, cfg=cfg, seq=seq,
                    batch=batch, inputs=inputs, input_axes=axes)
