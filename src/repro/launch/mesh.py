"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS for 512 host devices
*before* calling it.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes):
    """``jax.make_mesh`` across jax versions.

    Newer jax wants explicit ``axis_types`` (Auto); jax <= 0.4.x has neither
    the kwarg nor ``jax.sharding.AxisType``.  All repo call sites go through
    here so the version probe lives in one place.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def compat_shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions.

    jax <= 0.4.x only ships ``jax.experimental.shard_map``; there we disable
    ``check_rep`` (its replication checker predates several collectives we
    use and rejects valid programs the stable API accepts).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as esm
    return esm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def compat_abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across jax versions: newer jax takes
    (sizes, names); jax <= 0.4.x takes ((name, size), ...) pairs."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, axes)
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Whatever this host has (used by smoke tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return compat_make_mesh((data, model), ("data", "model"))


def make_cam_mesh(banks: int | None = None, queries: int = 1):
    """Device mesh for sharded CAM search (core.sharded).

    The 'bank' axis carries the stored grid's nv (vertical/bank) dimension
    — the bank level of the paper's subarray→array→mat→bank hierarchy as a
    physical parallelism axis; the optional 'query' axis splits the search
    batch.  Defaults to all local devices on 'bank'.
    """
    n = len(jax.devices())
    if banks is None:
        banks = max(1, n // max(1, queries))
    return compat_make_mesh((banks, queries), ("bank", "query"))
