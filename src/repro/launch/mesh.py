"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS for 512 host devices
*before* calling it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(model: int = 1):
    """Whatever this host has (used by smoke tests / examples)."""
    n = len(jax.devices())
    data = max(1, n // model)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
