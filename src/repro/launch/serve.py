"""Serving driver: batched continuous-batching decode over a model.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro import models
from repro.configs import ARCH_IDS, get_config
from repro.runtime.serve_loop import Request, Server


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = models.init_params(jax.random.PRNGKey(0), cfg)
    srv = Server(cfg=cfg, params=params, batch_slots=args.slots,
                 max_seq=args.max_seq)

    for r in range(args.requests):
        srv.submit(Request(rid=r, prompt=[1 + r % 7, 2, 3],
                           max_new=args.max_new))
    t0 = time.time()
    done = srv.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests / {toks} tokens in {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s simulated)")
    for r in done[:3]:
        print(f"  req {r.rid}: {r.out}")


if __name__ == "__main__":
    main()
