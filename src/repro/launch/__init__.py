"""Launch layer: production mesh, dry-run, train/serve drivers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time
(512 host devices) and must only be imported as the main module.
"""
from .mesh import make_local_mesh, make_production_mesh

__all__ = ["make_production_mesh", "make_local_mesh"]
