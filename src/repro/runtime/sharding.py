"""Logical-axis sharding resolver.

Every parameter/activation declares *logical* axes ('embed', 'heads',
'batch', ...).  Rules map logical axes to preference-ordered mesh axes; an
axis is only used when it divides the dimension and is not already taken by
another dim of the same tensor — so e.g. qwen2's 12 heads silently fall back
to replicated on a model=16 mesh while its d_ff=8960 still shards (see
DESIGN.md §5).

Params additionally get FSDP sharding over the data axes on their largest
eligible dim, so optimizer state for the 34B archs fits HBM.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# logical axis -> tuple of mesh axes to try (in order, combined greedily)
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "vocab": ("model",),
    "mlp": ("model",),
    "moe_mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "q_lora": ("model",),
    "kv_lora": (),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "experts": (),            # TP-style baseline: experts replicated,
                              # moe_mlp sharded. EP hillclimb flips this.
    "kv_seq": ("model", "data"),  # when kv_heads could not shard; claims
                                  # the data axes too if batch left them
                                  # idle (batch=1 long-context decode)
    "attn_seq": (),           # REFUTED experiment (EXPERIMENTS.md §Perf):
                              # mapping this to ('model',) seq-shards q
                              # when heads don't divide, but XLA SPMD
                              # reshards at every constraint boundary
                              # (t_coll 1.4s -> 25.5s on qwen2 train_4k).
                              # A shard_map ring-attention would be needed;
                              # head padding won instead (opt-headpad).
    "seq": (),                # training seq replicated in baseline
    "embed": (),              # d_model of activations replicated
    "layers": (),             # scanned axis never sharded
    "head_dim": (),
    "ssm_state": (),
    "conv": (),
    "ssm_groups": (),
    # --- CAM grid axes (core.sharded): the stored grid's nv (bank) dim
    # maps onto the 'bank' mesh axis — the bank level of the paper's
    # subarray→array→mat→bank hierarchy as a physical parallelism axis —
    # and the query batch optionally splits over 'query'.
    "cam_bank": ("bank",),
    "cam_query": ("query",),
    "cam_row": (),            # R rows stay whole: sensing 'best' reduces
                              # over them inside one subarray/kernel tile
    "cam_col": (),            # C cols stay whole for the same reason
}

# priority: dims earlier in this list claim mesh axes first (batch before
# kv_seq so the cache stays batch-major whenever batch can shard; heads
# before attn_seq so seq-parallel attention only kicks in as a fallback;
# cam_bank before cam_query so the grid always claims its axis)
_PRIORITY = ("experts", "heads", "q_lora", "vocab", "mlp", "moe_mlp",
             "ssm_inner", "ssm_heads", "kv_heads", "batch", "kv_seq",
             "attn_seq", "seq", "embed", "cam_bank", "cam_query")
# dims eligible to carry FSDP (data-axis) sharding for parameters
_FSDP_ELIGIBLE = ("embed", "vocab", "mlp", "moe_mlp", "ssm_inner", "heads",
                  "q_lora", "kv_lora", "experts")


@dataclass(frozen=True)
class ShardingRules:
    rules: Dict[str, Tuple[str, ...]] = field(
        default_factory=lambda: dict(DEFAULT_RULES))
    fsdp_axes: Tuple[str, ...] = ("data",)   # mesh axes used for param FSDP

    def replace_rule(self, **kw) -> "ShardingRules":
        r = dict(self.rules)
        for k, v in kw.items():
            r[k] = tuple(v)
        return ShardingRules(rules=r, fsdp_axes=self.fsdp_axes)

    # ------------------------------------------------------------------
    def spec_for(self, shape: Sequence[int], axes: Sequence[Optional[str]],
                 mesh: Mesh, fsdp: bool = False) -> PartitionSpec:
        """Resolve logical axes to a PartitionSpec for this mesh."""
        assert len(shape) == len(axes), (shape, axes)
        mesh_sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
        used: set = set()
        assignment: Dict[int, Tuple[str, ...]] = {}

        order = sorted(
            range(len(axes)),
            key=lambda i: _PRIORITY.index(axes[i])
            if axes[i] in _PRIORITY else len(_PRIORITY))
        for i in order:
            name = axes[i]
            if name is None:
                continue
            cands = self.rules.get(name, ())
            picked = []
            size = shape[i]
            for m in cands:
                if m in used or m not in mesh_sizes:
                    continue
                if size % (int(np.prod([mesh_sizes[p] for p in picked]
                                       or [1])) * mesh_sizes[m]) == 0:
                    picked.append(m)
            if picked:
                assignment[i] = tuple(picked)
                used.update(picked)

        if fsdp:
            self._add_fsdp(shape, axes, mesh_sizes, used, assignment)

        entries = []
        for i in range(len(shape)):
            a = assignment.get(i)
            if not a:
                entries.append(None)
            elif len(a) == 1:
                entries.append(a[0])
            else:
                entries.append(tuple(a))
        while entries and entries[-1] is None:
            entries.pop()
        return PartitionSpec(*entries)

    def _add_fsdp(self, shape, axes, mesh_sizes, used, assignment):
        """Shard the largest eligible parameter dim over the data axes."""
        free = [m for m in self.fsdp_axes
                if m in mesh_sizes and m not in used]
        if not free:
            return
        best, best_size = None, 0
        for i, name in enumerate(axes):
            if name not in _FSDP_ELIGIBLE:
                continue
            cur = int(np.prod([mesh_sizes[p]
                               for p in assignment.get(i, ())] or [1]))
            need = cur * int(np.prod([mesh_sizes[m] for m in free]))
            if shape[i] % need == 0 and shape[i] // cur > best_size:
                best, best_size = i, shape[i] // cur
        if best is not None:
            assignment[best] = assignment.get(best, ()) + tuple(free)
            used.update(free)


# ---------------------------------------------------------------------------
# Activation-sharding context (threaded into model code as `shard(x, ...)`)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ShardCtx:
    mesh: Mesh
    rules: ShardingRules


_ctx: contextvars.ContextVar[Optional[ShardCtx]] = contextvars.ContextVar(
    "repro_shard_ctx", default=None)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, rules: Optional[ShardingRules] = None):
    token = _ctx.set(ShardCtx(mesh, rules or ShardingRules()))
    try:
        yield
    finally:
        _ctx.reset(token)


def model_axis_size() -> int:
    """Size of the 'model' mesh axis in the active sharding context (1 if
    no context or no model axis)."""
    ctx = _ctx.get()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        return 1
    return dict(zip(ctx.mesh.axis_names, ctx.mesh.axis_sizes))["model"]


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical-axis sharding constraint (no-op without context)."""
    ctx = _ctx.get()
    if ctx is None:
        return x
    spec = ctx.rules.spec_for(x.shape, axes, ctx.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# CAM grid placement (core.sharded)
# ---------------------------------------------------------------------------
def cam_state_shardings(mesh: Mesh, grid_ndim: int = 4,
                        rules: Optional[ShardingRules] = None) -> Dict:
    """NamedShardings for the CAMState pytree fields.

    The grid's leading nv axis follows the 'cam_bank' rule; row_valid
    shards with it (it is the (nv, R) mask of the same rows); quantization
    scales and the (nh, C) column mask replicate.  ``grid_ndim`` is 4 for
    value grids and 5 for ACAM [lo, hi] range grids.

    Divisibility is the caller's contract (the sharded simulator pads nv
    to a bank-axis multiple before placing), so specs are resolved
    directly rather than through the size-probing ``spec_for``.
    """
    rules = rules or ShardingRules()
    bank = rules.rules.get("cam_bank", ())
    axis = next((a for a in bank if a in mesh.axis_names), None)
    gspec = PartitionSpec(axis) if axis else PartitionSpec()
    return {
        "grid": NamedSharding(mesh, gspec),
        "row_valid": NamedSharding(mesh, gspec),
        "col_valid": NamedSharding(mesh, PartitionSpec()),
        "lo": NamedSharding(mesh, PartitionSpec()),
        "hi": NamedSharding(mesh, PartitionSpec()),
        # search-cascade fields: bank signatures shard with their banks;
        # the scalar threshold and the (padded_K,) placement permutation
        # replicate (the perm is consumed on the host-side result path)
        "sigs": NamedSharding(mesh, gspec),
        "sig_thr": NamedSharding(mesh, PartitionSpec()),
        "perm": NamedSharding(mesh, PartitionSpec()),
        # mutable-store field: the clean (pre-noise) codes grid shards
        # exactly like the noisy grid it shadows
        "codes": NamedSharding(mesh, gspec),
        # reliability fields: the (nv, R) wear/age/health masks shard
        # with their rows; the scalar store age replicates
        "rel_age": NamedSharding(mesh, PartitionSpec()),
        "rel_rows": NamedSharding(mesh, gspec),
    }


def cam_query_spec(mesh: Mesh, q_shape: Sequence[int],
                   rules: Optional[ShardingRules] = None) -> PartitionSpec:
    """PartitionSpec for a (Q, ...) query batch: Q follows 'cam_query'
    (replicated when the mesh has no query axis or Q does not divide)."""
    rules = rules or ShardingRules()
    axes = ("cam_query",) + (None,) * (len(q_shape) - 1)
    return rules.spec_for(q_shape, axes, mesh)


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------
def tree_shardings(axes_tree, shapes_tree, mesh: Mesh,
                   rules: Optional[ShardingRules] = None,
                   fsdp: bool = True):
    """NamedSharding tree for a parameter tree (with FSDP for params)."""
    rules = rules or ShardingRules()

    def one(axes, shaped):
        spec = rules.spec_for(shaped.shape, axes, mesh, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        one, axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
