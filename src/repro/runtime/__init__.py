from . import elastic, fault, serve_loop, sharding, train_loop
from .sharding import (ShardingRules, cam_query_spec, cam_state_shardings,
                       shard, sharding_ctx, tree_shardings)
from .train_loop import TrainState, abstract_state, init_state, make_train_step, state_axes
from .serve_loop import (CAMSearchServer, MutationRequest, QueueFull,
                         SearchRequest, Server, make_serve_step)

__all__ = [
    "sharding", "train_loop", "serve_loop", "fault", "elastic",
    "ShardingRules", "shard", "sharding_ctx", "tree_shardings",
    "cam_query_spec", "cam_state_shardings",
    "TrainState", "abstract_state", "init_state", "make_train_step",
    "state_axes", "Server", "make_serve_step",
    "CAMSearchServer", "SearchRequest", "MutationRequest", "QueueFull",
]
