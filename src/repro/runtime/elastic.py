"""Elastic re-scaling: checkpoints are mesh-agnostic, so a job can restart
on a different device count / mesh shape.

The flow: the writer saves host-gathered arrays (checkpoint/ckpt.py); on
restart the new job builds its own mesh, re-resolves every leaf's logical
axes against the *new* mesh (divisibility-checked, so shrinking from 512 to
256 chips just changes which axes shard), and device_puts each leaf with
the new NamedSharding.  Data-pipeline determinism (pure function of step)
makes the resumed stream identical regardless of the new data-parallel
degree.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.checkpoint import restore_sharded
from repro.runtime.sharding import ShardingRules, tree_shardings


def reshard(tree, axes_tree, mesh, rules: Optional[ShardingRules] = None,
            fsdp: bool = True):
    """Place (or re-place) a pytree onto ``mesh`` per its logical axes."""
    rules = rules or ShardingRules()
    shardings = tree_shardings(axes_tree, tree, mesh, rules, fsdp=fsdp)
    flat_t, treedef = jax.tree_util.tree_flatten(tree)
    flat_s = treedef.flatten_up_to(shardings)
    placed = [jax.device_put(x, s) for x, s in zip(flat_t, flat_s)]
    return jax.tree_util.tree_unflatten(treedef, placed)


def elastic_restore(ckpt_dir: str, example_state, axes_tree, mesh,
                    rules: Optional[ShardingRules] = None,
                    fsdp: bool = True) -> Tuple[int, Any]:
    """Restore the newest checkpoint onto a (possibly different) mesh."""
    rules = rules or ShardingRules()
    shardings = tree_shardings(axes_tree, example_state, mesh, rules,
                               fsdp=fsdp)
    return restore_sharded(ckpt_dir, example_state, shardings)
