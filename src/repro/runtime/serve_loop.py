"""Serve-step factory + small continuous-batching serving loops.

``serve_step`` is the unit the decode dry-run shapes lower: one new token
for every sequence in the batch against a seq_len KV cache.  The
``Server`` driver adds slot management (requests join/leave the batch
between steps) for the serving example.

``CAMSearchServer`` is the CAM-side counterpart: a micro-batching
front-end over the store-once / search-many simulators.  Search requests
accumulate into fixed-size query batches (padded so the jit cache stays
warm at a single shape) and every step drives ONE fused batched search —
on the sharded simulator that is one grid pass per device plus the
cross-device merge, regardless of how many requests rode the batch.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig


def make_serve_step(cfg: ModelConfig, *, moe_mode: str = "tp",
                    greedy: bool = True):
    """serve_step(params, cache, inputs, pos) -> (next_token/logits, cache)."""

    def serve_step(params, cache, inputs: Dict, pos: jax.Array):
        logits, cache = models.forward_decode(params, cfg, inputs, pos,
                                              cache, moe_mode=moe_mode)
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        return logits, cache

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class Server:
    """Minimal continuous-batching server over a fixed slot batch."""
    cfg: ModelConfig
    params: Any
    batch_slots: int
    max_seq: int

    def __post_init__(self):
        self.cache = models.init_cache(self.cfg, self.batch_slots,
                                       self.max_seq)
        self.step_fn = jax.jit(make_serve_step(self.cfg))
        self.slot_req: List[Optional[Request]] = [None] * self.batch_slots
        self.slot_pos = np.zeros(self.batch_slots, np.int32)
        self.slot_next = np.zeros(self.batch_slots, np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.batch_slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.slot_pos[i] = 0
                self.slot_next[i] = req.prompt[0]

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.slot_next)
        pos = jnp.asarray(self.slot_pos)
        next_tok, self.cache = self.step_fn(
            self.params, self.cache, {"token": tokens}, pos)
        next_np = np.asarray(next_tok)
        for i in active:
            req = self.slot_req[i]
            p = int(self.slot_pos[i])
            if p + 1 < len(req.prompt):       # still consuming the prompt
                self.slot_next[i] = req.prompt[p + 1]
            else:
                tok = int(next_np[i])
                req.out.append(tok)
                self.slot_next[i] = tok
            self.slot_pos[i] = p + 1
            if (len(req.out) >= req.max_new
                    or self.slot_pos[i] >= self.max_seq - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished


# ---------------------------------------------------------------------------
# CAM search serving
# ---------------------------------------------------------------------------
class QueueFull(RuntimeError):
    """Admission control: the server's bounded queue rejected a submit."""


@dataclass
class SearchRequest:
    """One in-memory-search request against the resident CAM store."""
    rid: int
    query: np.ndarray
    indices: Optional[np.ndarray] = None   # (k,) matched entries, -1 padded
    mask: Optional[np.ndarray] = None      # (padded_K,) match lines
    slo: str = "default"                   # latency-percentile bucket
    t_submit: float = 0.0                  # perf_counter seconds
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return self.indices is not None


@dataclass
class MutationRequest:
    """One store mutation riding the serve loop's continuous batch.

    ``kind`` is 'insert' / 'delete' / 'update'; consecutive requests of
    the same kind coalesce into ONE engine call per step.  After an
    insert completes, ``ids`` holds the caller-order row indices the new
    rows answer to in search results.
    """
    rid: int
    kind: str
    rows: Optional[np.ndarray] = None      # insert/update payload
    ids: Optional[np.ndarray] = None       # delete/update target ids
    slo: str = "mutation"
    t_submit: float = 0.0
    t_done: float = 0.0
    done: bool = False


@dataclass
class CAMSearchServer:
    """Continuous-batching CAM serve engine (store once, serve *and
    mutate* many).

    ``sim`` is a ``CAMASim`` facade, ``FunctionalSimulator``, or
    ``ShardedCAMSimulator``; ``state`` its written — and, for the sharded
    backend, mesh-placed — store.  Search requests are answered in
    submission order in groups of up to ``batch`` queries; ``batch``
    defaults to the simulator config's ``sim.serve_batch``.  Per-batch
    C2C keys are folded from ``key`` by search-step index, matching the
    simulator's one-draw-per-search-cycle model.

    Mutations (``submit_insert`` / ``submit_delete`` / ``submit_update``)
    ride the same queue: each ``step`` first applies the queue's leading
    mutation requests (consecutive same-kind requests coalesce into ONE
    engine call) and then serves one search batch, so a mutation is
    visible to every search submitted after it.  Mutation programming
    keys fold from a separate lane (``fold_in(key, 'muta')`` then by
    mutation-step index), so the search key schedule is untouched by
    interleaved mutations and the whole trace replays deterministically.

    Admission control: ``max_queue`` bounds the pending queue (default
    ``sim.serve_queue``; 0 = unbounded) — submits beyond it raise
    ``QueueFull`` (backpressure).  Malformed requests (wrong query length
    or non-numeric dtype against the written store) are rejected at
    submit with a ``ValueError`` and never enter the queue; if a step
    fails anyway, its popped requests are restored to the queue front
    before the error propagates, so no request is ever silently lost.

    Every request carries an ``slo`` tag and submit/finish timestamps;
    ``latency_stats()`` reports per-tag p50/p99 request latency.

    ``autoscale=False`` (default) pads every step to exactly ``batch``
    queries, so each step hits one compiled search shape.  With
    ``autoscale=True`` the padded width is instead picked per step from
    the fixed power-of-two ladder {1, 2, 4, ..., batch} by queue depth —
    a mostly-idle server stops streaming the full serve_batch through the
    grid for a 1-request tail, at the cost of at most log2(batch)+1
    compiled shapes.  Request grouping and the fold_in(key, step) key
    schedule are identical to fixed-batch serving, so (absent C2C noise,
    whose per-cycle draw count is the padded width) answers are bit-exact
    either way.  Pad queries are excluded from the cascade's bank routing
    (the ``valid_count`` knob), so answers are also bit-exact across pad
    widths and queue depths when the search cascade is on.
    """
    sim: Any
    state: Any
    batch: Optional[int] = None
    key: Optional[jax.Array] = None
    autoscale: bool = False
    max_queue: Optional[int] = None

    def __post_init__(self):
        cfg = getattr(self.sim, "config", None)
        scfg = getattr(cfg, "sim", None)
        if self.batch is None:
            self.batch = getattr(scfg, "serve_batch", 32)
        if self.batch < 1:
            raise ValueError("batch must be >= 1")
        if self.max_queue is None:
            self.max_queue = getattr(scfg, "serve_queue", 0)
        if self.max_queue < 0:
            raise ValueError("max_queue must be >= 0 (0 = unbounded)")
        if self.key is None:
            self.key = jax.random.PRNGKey(0)
        # separate RNG lane for mutation programming noise, so interleaved
        # mutations never shift the search steps' fold_in(key, step) keys
        self._mut_key = jax.random.fold_in(self.key, 0x6D757461)  # 'muta'
        self.queue: List[Any] = []
        self.finished: List[Any] = []
        self._next_rid = 0
        self._steps = 0
        self._mut_steps = 0
        self._ticks = 0      # reliability: serve steps = drift age units

    # ----------------------------------------------------------- submit
    def _admit(self, req):
        if self.max_queue and len(self.queue) >= self.max_queue:
            raise QueueFull(
                f"serve queue full ({self.max_queue} pending); retry "
                "after a step() drains it")
        self.queue.append(req)
        return req

    def _spec(self):
        return getattr(self.state, "spec", None)

    def _functional(self):
        """The innermost single-chip simulator (validation helpers)."""
        inner = getattr(self.sim, "backend", self.sim)
        return getattr(inner, "sim", inner)

    def _validate_query(self, q: np.ndarray):
        if not np.issubdtype(q.dtype, np.number):
            raise ValueError(
                f"query dtype {q.dtype} is not numeric — request rejected")
        spec = self._spec()
        if spec is not None and q.shape != (spec.N,):
            raise ValueError(
                f"query shape {q.shape} does not match the written "
                f"store's ({spec.N},) — request rejected")

    def _validate_rows(self, rows: np.ndarray):
        if not np.issubdtype(rows.dtype, np.number):
            raise ValueError(
                f"row dtype {rows.dtype} is not numeric — request rejected")
        sim = self._functional()
        if hasattr(sim, "_check_mutable"):
            sim._check_mutable()
            sim._check_rows(self.state, jnp.asarray(rows))

    def submit(self, query, slo: str = "default") -> SearchRequest:
        """Queue one search; rejects malformed queries at the door (a bad
        request must fail alone, not poison the batch it would ride)."""
        q = np.asarray(query)
        self._validate_query(q)
        req = SearchRequest(self._next_rid, q, slo=slo,
                            t_submit=time.perf_counter())
        self._next_rid += 1
        return self._admit(req)

    def submit_insert(self, rows, slo: str = "mutation") -> MutationRequest:
        """Queue an insert of ``rows`` (M, N[, 2]); ``req.ids`` holds the
        new rows' search ids once the request completes."""
        rows = np.asarray(rows)
        self._validate_rows(rows)
        req = MutationRequest(self._next_rid, "insert", rows=rows, slo=slo,
                              t_submit=time.perf_counter())
        self._next_rid += 1
        return self._admit(req)

    def submit_delete(self, ids, slo: str = "mutation") -> MutationRequest:
        req = MutationRequest(self._next_rid, "delete",
                              ids=np.asarray(ids).reshape(-1), slo=slo,
                              t_submit=time.perf_counter())
        self._next_rid += 1
        return self._admit(req)

    def submit_update(self, ids, rows,
                      slo: str = "mutation") -> MutationRequest:
        rows = np.asarray(rows)
        ids = np.asarray(ids).reshape(-1)
        self._validate_rows(rows)
        if ids.size != rows.shape[0]:
            raise ValueError(f"{ids.size} ids but {rows.shape[0]} rows")
        req = MutationRequest(self._next_rid, "update", rows=rows, ids=ids,
                              slo=slo, t_submit=time.perf_counter())
        self._next_rid += 1
        return self._admit(req)

    # ------------------------------------------------------------- step
    def _padded_width(self, n_reqs: int) -> int:
        """Step width: ``batch`` fixed, or the smallest ladder rung that
        fits the step's requests AND the sharded query-axis divisibility
        contract (padded width % (query_shards * c2c_tile) == 0)."""
        if not self.autoscale:
            return self.batch
        rung = 1
        while rung < n_reqs:
            rung <<= 1
        backend = getattr(self.sim, "backend", self.sim)
        mult = getattr(backend, "n_query", 1)
        if mult > 1:
            inner = getattr(backend, "sim", backend)
            if inner.config.device.variation in ("c2c", "both"):
                mult *= inner.c2c_query_tile
        while rung < self.batch and rung % mult:
            rung <<= 1
        return self.batch if rung > self.batch or rung % mult else rung

    def _apply_mutations(self, run: List[MutationRequest]) -> None:
        """One coalesced engine call for a same-kind mutation run."""
        kind = run[0].kind
        mkey = jax.random.fold_in(self._mut_key, self._mut_steps)
        if kind == "insert":
            rows = np.concatenate([r.rows for r in run])
            self.state, ids = self.sim.insert(self.state,
                                              jnp.asarray(rows), key=mkey)
            ids = np.asarray(ids)
            off = 0
            for r in run:
                r.ids = ids[off: off + r.rows.shape[0]]
                off += r.rows.shape[0]
        elif kind == "delete":
            self.state = self.sim.delete(
                self.state, np.concatenate([r.ids for r in run]))
        elif kind == "update":
            self.state = self.sim.update(
                self.state, np.concatenate([r.ids for r in run]),
                jnp.asarray(np.concatenate([r.rows for r in run])),
                key=mkey)
        else:
            raise ValueError(f"unknown mutation kind {kind!r}")
        self._mut_steps += 1
        now = time.perf_counter()
        for r in run:
            r.done, r.t_done = True, now
            self.finished.append(r)

    def _reliability_tick(self) -> None:
        """Advance the store's drift clock by one serve step and, every
        ``scrub_every`` steps, re-program the most-drifted rows through
        the mutation RNG lane — scrub keys fold exactly like coalesced
        mutations, so the search key schedule is untouched."""
        cfg = getattr(self.sim, "config", None)
        rel = getattr(cfg, "reliability", None)
        if (rel is None or not rel.enabled
                or getattr(self.state, "rel", None) is None
                or not hasattr(self.sim, "age_tick")):
            return
        self.state = self.sim.age_tick(self.state)
        self._ticks += 1
        if rel.scrub_every > 0 and self._ticks % rel.scrub_every == 0:
            mkey = jax.random.fold_in(self._mut_key, self._mut_steps)
            self.state = self.sim.scrub(self.state, key=mkey)
            self._mut_steps += 1

    def step(self) -> int:
        """Apply the queue's leading mutation runs, then serve one search
        batch; returns #requests completed.  A failing unit restores its
        popped requests to the queue front before re-raising.  With
        reliability enabled the store ages (and is scrubbed) every step,
        queue empty or not — drift does not wait for traffic."""
        self._reliability_tick()
        if not self.queue:
            return 0
        served = 0
        # continuous batching: drain leading mutations first so every
        # search in this step sees the store state its submission order
        # implies
        while self.queue and isinstance(self.queue[0], MutationRequest):
            run = [self.queue.pop(0)]
            while (self.queue
                   and isinstance(self.queue[0], MutationRequest)
                   and self.queue[0].kind == run[0].kind):
                run.append(self.queue.pop(0))
            try:
                self._apply_mutations(run)
            except Exception:
                self.queue[:0] = run
                raise
            served += len(run)
        n = 0
        while (n < len(self.queue) and n < self.batch
               and isinstance(self.queue[n], SearchRequest)):
            n += 1
        if n == 0:
            return served
        reqs = self.queue[:n]
        del self.queue[:n]
        try:
            qs = np.stack([r.query for r in reqs]).astype(np.float32)
            pad = self._padded_width(len(reqs)) - len(reqs)
            if pad:
                qs = np.concatenate(
                    [qs, np.zeros((pad, qs.shape[1]), qs.dtype)])
            step_key = jax.random.fold_in(self.key, self._steps)
            # pad queries are real rows of the padded batch but NOT real
            # requests: valid_count keeps them out of the cascade's
            # shared bank routing
            idx, mask = self.sim.query(self.state, jnp.asarray(qs),
                                       key=step_key,
                                       valid_count=len(reqs))
        except Exception:
            self.queue[:0] = reqs
            raise
        self._steps += 1
        idx_np, mask_np = np.asarray(idx), np.asarray(mask)
        now = time.perf_counter()
        for i, req in enumerate(reqs):
            req.indices, req.mask = idx_np[i], mask_np[i]
            req.t_done = now
            self.finished.append(req)
        return served + len(reqs)

    def run(self, max_steps: int = 10_000) -> List[Any]:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return self.finished

    # ------------------------------------------------------------ stats
    def latency_stats(self) -> Dict[str, Dict[str, float]]:
        """Per-SLO-tag request latency percentiles over finished requests:
        ``{tag: {'n': count, 'p50_us': ..., 'p99_us': ...}}`` (submit →
        finish wall time, microseconds)."""
        by: Dict[str, List[float]] = {}
        for r in self.finished:
            by.setdefault(r.slo, []).append((r.t_done - r.t_submit) * 1e6)
        return {
            slo: {"n": float(len(v)),
                  "p50_us": float(np.percentile(np.asarray(v), 50)),
                  "p99_us": float(np.percentile(np.asarray(v), 99))}
            for slo, v in by.items()
        }
