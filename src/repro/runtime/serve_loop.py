"""Serve-step factory + small continuous-batching serving loops.

``serve_step`` is the unit the decode dry-run shapes lower: one new token
for every sequence in the batch against a seq_len KV cache.  The
``Server`` driver adds slot management (requests join/leave the batch
between steps) for the serving example.

``CAMSearchServer`` is the CAM-side counterpart: a micro-batching
front-end over the store-once / search-many simulators.  Search requests
accumulate into fixed-size query batches (padded so the jit cache stays
warm at a single shape) and every step drives ONE fused batched search —
on the sharded simulator that is one grid pass per device plus the
cross-device merge, regardless of how many requests rode the batch.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig


def make_serve_step(cfg: ModelConfig, *, moe_mode: str = "tp",
                    greedy: bool = True):
    """serve_step(params, cache, inputs, pos) -> (next_token/logits, cache)."""

    def serve_step(params, cache, inputs: Dict, pos: jax.Array):
        logits, cache = models.forward_decode(params, cfg, inputs, pos,
                                              cache, moe_mode=moe_mode)
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        return logits, cache

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class Server:
    """Minimal continuous-batching server over a fixed slot batch."""
    cfg: ModelConfig
    params: Any
    batch_slots: int
    max_seq: int

    def __post_init__(self):
        self.cache = models.init_cache(self.cfg, self.batch_slots,
                                       self.max_seq)
        self.step_fn = jax.jit(make_serve_step(self.cfg))
        self.slot_req: List[Optional[Request]] = [None] * self.batch_slots
        self.slot_pos = np.zeros(self.batch_slots, np.int32)
        self.slot_next = np.zeros(self.batch_slots, np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.batch_slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.slot_pos[i] = 0
                self.slot_next[i] = req.prompt[0]

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.slot_next)
        pos = jnp.asarray(self.slot_pos)
        next_tok, self.cache = self.step_fn(
            self.params, self.cache, {"token": tokens}, pos)
        next_np = np.asarray(next_tok)
        for i in active:
            req = self.slot_req[i]
            p = int(self.slot_pos[i])
            if p + 1 < len(req.prompt):       # still consuming the prompt
                self.slot_next[i] = req.prompt[p + 1]
            else:
                tok = int(next_np[i])
                req.out.append(tok)
                self.slot_next[i] = tok
            self.slot_pos[i] = p + 1
            if (len(req.out) >= req.max_new
                    or self.slot_pos[i] >= self.max_seq - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished


# ---------------------------------------------------------------------------
# CAM search serving
# ---------------------------------------------------------------------------
@dataclass
class SearchRequest:
    """One in-memory-search request against the resident CAM store."""
    rid: int
    query: np.ndarray
    indices: Optional[np.ndarray] = None   # (k,) matched entries, -1 padded
    mask: Optional[np.ndarray] = None      # (padded_K,) match lines

    @property
    def done(self) -> bool:
        return self.indices is not None


@dataclass
class CAMSearchServer:
    """Micro-batching CAM search server (store once, serve many).

    ``sim`` is a ``CAMASim`` facade, ``FunctionalSimulator``, or
    ``ShardedCAMSimulator`` (any object with ``query(state, queries,
    key)``); ``state`` its written — and, for the sharded backend,
    mesh-placed — store.  Requests are answered in submission order in
    groups of up to ``batch`` queries; ``batch`` defaults to the
    simulator config's ``sim.serve_batch``.  Per-batch C2C keys are
    folded from ``key`` by step index, matching the simulator's
    one-draw-per-search-cycle model.

    ``autoscale=False`` (default) pads every step to exactly ``batch``
    queries, so each step hits one compiled search shape.  With
    ``autoscale=True`` the padded width is instead picked per step from
    the fixed power-of-two ladder {1, 2, 4, ..., batch} by queue depth —
    a mostly-idle server stops streaming the full serve_batch through the
    grid for a 1-request tail, at the cost of at most log2(batch)+1
    compiled shapes.  Request grouping and the fold_in(key, step) key
    schedule are identical to fixed-batch serving, so (absent C2C noise,
    whose per-cycle draw count is the padded width) answers are bit-exact
    either way.
    """
    sim: Any
    state: Any
    batch: Optional[int] = None
    key: Optional[jax.Array] = None
    autoscale: bool = False

    def __post_init__(self):
        if self.batch is None:
            cfg = getattr(self.sim, "config", None)
            self.batch = getattr(getattr(cfg, "sim", None),
                                 "serve_batch", 32)
        if self.batch < 1:
            raise ValueError("batch must be >= 1")
        if self.key is None:
            self.key = jax.random.PRNGKey(0)
        self.queue: List[SearchRequest] = []
        self.finished: List[SearchRequest] = []
        self._next_rid = 0
        self._steps = 0

    # ------------------------------------------------------------------
    def submit(self, query) -> SearchRequest:
        req = SearchRequest(self._next_rid, np.asarray(query))
        self._next_rid += 1
        self.queue.append(req)
        return req

    def _padded_width(self, n_reqs: int) -> int:
        """Step width: ``batch`` fixed, or the smallest ladder rung that
        fits the step's requests AND the sharded query-axis divisibility
        contract (padded width % (query_shards * c2c_tile) == 0)."""
        if not self.autoscale:
            return self.batch
        rung = 1
        while rung < n_reqs:
            rung <<= 1
        backend = getattr(self.sim, "backend", self.sim)
        mult = getattr(backend, "n_query", 1)
        if mult > 1:
            inner = getattr(backend, "sim", backend)
            if inner.config.device.variation in ("c2c", "both"):
                mult *= inner.c2c_query_tile
        while rung < self.batch and rung % mult:
            rung <<= 1
        return self.batch if rung > self.batch or rung % mult else rung

    def step(self) -> int:
        """Serve one query batch; returns #requests answered."""
        if not self.queue:
            return 0
        reqs = self.queue[: self.batch]
        del self.queue[: len(reqs)]
        qs = np.stack([r.query for r in reqs]).astype(np.float32)
        pad = self._padded_width(len(reqs)) - len(reqs)
        if pad:
            qs = np.concatenate(
                [qs, np.zeros((pad, qs.shape[1]), qs.dtype)])
        step_key = jax.random.fold_in(self.key, self._steps)
        self._steps += 1
        idx, mask = self.sim.query(self.state, jnp.asarray(qs),
                                   key=step_key)
        idx_np, mask_np = np.asarray(idx), np.asarray(mask)
        for i, req in enumerate(reqs):
            req.indices, req.mask = idx_np[i], mask_np[i]
            self.finished.append(req)
        return len(reqs)

    def run(self, max_steps: int = 10_000) -> List[SearchRequest]:
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
