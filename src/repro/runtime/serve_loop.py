"""Serve-step factory + a small continuous-batching serving loop.

``serve_step`` is the unit the decode dry-run shapes lower: one new token
for every sequence in the batch against a seq_len KV cache.  The
``Server`` driver adds slot management (requests join/leave the batch
between steps) for the serving example.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.configs.base import ModelConfig


def make_serve_step(cfg: ModelConfig, *, moe_mode: str = "tp",
                    greedy: bool = True):
    """serve_step(params, cache, inputs, pos) -> (next_token/logits, cache)."""

    def serve_step(params, cache, inputs: Dict, pos: jax.Array):
        logits, cache = models.forward_decode(params, cfg, inputs, pos,
                                              cache, moe_mode=moe_mode)
        if greedy:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        return logits, cache

    return serve_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class Server:
    """Minimal continuous-batching server over a fixed slot batch."""
    cfg: ModelConfig
    params: Any
    batch_slots: int
    max_seq: int

    def __post_init__(self):
        self.cache = models.init_cache(self.cfg, self.batch_slots,
                                       self.max_seq)
        self.step_fn = jax.jit(make_serve_step(self.cfg))
        self.slot_req: List[Optional[Request]] = [None] * self.batch_slots
        self.slot_pos = np.zeros(self.batch_slots, np.int32)
        self.slot_next = np.zeros(self.batch_slots, np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.batch_slots):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slot_req[i] = req
                self.slot_pos[i] = 0
                self.slot_next[i] = req.prompt[0]

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = jnp.asarray(self.slot_next)
        pos = jnp.asarray(self.slot_pos)
        next_tok, self.cache = self.step_fn(
            self.params, self.cache, {"token": tokens}, pos)
        next_np = np.asarray(next_tok)
        for i in active:
            req = self.slot_req[i]
            p = int(self.slot_pos[i])
            if p + 1 < len(req.prompt):       # still consuming the prompt
                self.slot_next[i] = req.prompt[p + 1]
            else:
                tok = int(next_np[i])
                req.out.append(tok)
                self.slot_next[i] = tok
            self.slot_pos[i] = p + 1
            if (len(req.out) >= req.max_new
                    or self.slot_pos[i] >= self.max_seq - 1):
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        steps = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
