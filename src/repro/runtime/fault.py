"""Fault tolerance: supervised step execution with checkpoint/restart,
failure detection, and straggler mitigation.

On real fleets the failure signal comes from the coordinator (missing
heartbeats / NCCL-ICI timeouts); here the ``Supervisor`` exposes the same
control flow with injectable failure/straggler hooks so the logic is
testable on one host:

  * every step runs under a watchdog budget; a straggling step beyond
    ``straggler_factor`` x the rolling median is logged and (configurably)
    retried — the single-host analogue of send-to-redundant-worker;
  * a failed step (exception or injected fault) triggers restore from the
    newest committed checkpoint and replay — since the data pipeline is a
    pure function of step, replay is bit-identical;
  * checkpoints are written every ``ckpt_every`` steps (async, atomic,
    keep-N) so the mean work lost per failure is ckpt_every/2 steps.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from statistics import median
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.checkpoint import restore, save


class StepFailure(RuntimeError):
    pass


@dataclass
class Supervisor:
    step_fn: Callable[[Any, Any], Tuple[Any, Dict]]
    batch_fn: Callable[[int], Any]
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    straggler_factor: float = 3.0
    max_restarts: int = 10
    # test hooks
    fault_hook: Optional[Callable[[int], None]] = None
    # telemetry
    history: List[float] = field(default_factory=list)
    events: List[str] = field(default_factory=list)
    restarts: int = 0

    # ------------------------------------------------------------------
    def run(self, state, start_step: int, num_steps: int):
        """Run ``num_steps`` with checkpoint/restart; returns final state."""
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                t0 = time.monotonic()
                state, metrics = self.step_fn(state, self.batch_fn(step))
                dt = time.monotonic() - t0
                self._watch_straggler(step, dt)
                step += 1
                if step % self.ckpt_every == 0:
                    save(self.ckpt_dir, step, state, keep=self.keep)
                    self.events.append(f"ckpt@{step}")
            except StepFailure as e:
                self.restarts += 1
                self.events.append(f"fail@{step}:{e}")
                if self.restarts > self.max_restarts:
                    raise
                step, state = self._restore(state, start_step)
        save(self.ckpt_dir, step, state, keep=self.keep)
        return step, state

    # ------------------------------------------------------------------
    def _restore(self, example_state, start_step: int):
        try:
            step, state = restore(self.ckpt_dir, example_state)
            self.events.append(f"restore@{step}")
            return step, state
        except FileNotFoundError:
            self.events.append("restore@fresh")
            return start_step, example_state

    def _watch_straggler(self, step: int, dt: float) -> None:
        self.history.append(dt)
        if len(self.history) >= 8:
            med = median(self.history[-32:])
            if dt > self.straggler_factor * med:
                self.events.append(
                    f"straggler@{step}:{dt:.3f}s>{med:.3f}s")
