"""Train-step factory: remat'd scanned model + AdamW + optional
microbatching (gradient accumulation) and int8-EF DP gradient compression.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import models
from repro.configs.base import ModelConfig
from repro.optim import AdamW, AdamWState


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt: AdamWState


def init_state(key: jax.Array, cfg: ModelConfig, opt: AdamW) -> TrainState:
    params = models.init_params(key, cfg)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=opt.init(params))


def abstract_state(cfg: ModelConfig) -> TrainState:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    p = models.abstract_params(cfg)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=p,
        opt=AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32),
                       mu=jax.tree_util.tree_map(f32, p),
                       nu=jax.tree_util.tree_map(f32, p)))


def state_axes(cfg: ModelConfig) -> TrainState:
    """Logical-axes tree matching TrainState (for sharding resolution)."""
    axes = models.param_axes(cfg)
    return TrainState(step=(), params=axes,
                      opt=AdamWState(count=(), mu=axes, nu=axes))


def make_train_step(cfg: ModelConfig, opt: AdamW, *, moe_mode: str = "tp",
                    microbatch: Optional[int] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch``: number of gradient-accumulation chunks; the global
    batch dim must divide evenly.  Accumulation runs as a lax.scan so live
    activation memory is one microbatch's worth.
    """

    def loss_for(params, batch):
        return models.loss_fn(params, cfg, batch, moe_mode=moe_mode)

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def full_grads(params, batch):
        if not microbatch or microbatch <= 1:
            (loss, aux), grads = grad_fn(params, batch)
            return loss, grads

        def split(x):
            B = x.shape[0]
            assert B % microbatch == 0, (B, microbatch)
            return x.reshape(microbatch, B // microbatch, *x.shape[1:])

        chunks = jax.tree_util.tree_map(split, batch)

        def acc_step(carry, chunk):
            loss_acc, gacc = carry
            (loss, aux), grads = grad_fn(params, chunk)
            gacc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), gacc, grads)
            return (loss_acc + loss, gacc), None

        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, gsum), _ = jax.lax.scan(
            acc_step, (jnp.zeros((), jnp.float32), g0), chunks)
        inv = 1.0 / microbatch
        grads = jax.tree_util.tree_map(lambda g: g * inv, gsum)
        return loss_sum * inv, grads

    def train_step(state: TrainState, batch: Dict
                   ) -> Tuple[TrainState, Dict]:
        loss, grads = full_grads(state.params, batch)
        new_params, new_opt, om = opt.update(grads, state.opt, state.params)
        metrics = {"loss": loss, **om}
        return TrainState(step=state.step + 1, params=new_params,
                          opt=new_opt), metrics

    return train_step
