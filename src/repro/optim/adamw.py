"""AdamW with decoupled weight decay, global-norm clipping, f32 master
moments (params may be bf16 — moments and the update math stay f32)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array          # ()
    mu: Dict                  # f32, same tree as params
    nu: Dict                  # f32


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    # ------------------------------------------------------------------
    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree_util.tree_map(z, params),
            nu=jax.tree_util.tree_map(z, params))

    # ------------------------------------------------------------------
    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Dict, AdamWState, Dict]:
        """Returns (new_params, new_state, metrics)."""
        gf = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(gf)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm
                                / jnp.maximum(gnorm, 1e-9))
            gf = jax.tree_util.tree_map(lambda g: g * scale, gf)
        count = state.count + 1
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)
        lr = self.lr(count)

        def upd(p, g, m, v):
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(g)
            mhat = m2 / b1c
            vhat = v2 / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # no decay on norms/bias
                step = step + self.weight_decay * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * step
            return p2.astype(p.dtype), m2, v2

        out = jax.tree_util.tree_map(upd, params, gf, state.mu, state.nu)
        leaves, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3
            and all(isinstance(e, jax.Array) for e in x))
        new_p = jax.tree_util.tree_unflatten(
            treedef, [l[0] for l in leaves])
        new_m = jax.tree_util.tree_unflatten(
            treedef, [l[1] for l in leaves])
        new_v = jax.tree_util.tree_unflatten(
            treedef, [l[2] for l in leaves])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(count, new_m, new_v), metrics


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x))
                        for x in jax.tree_util.tree_leaves(tree)))
