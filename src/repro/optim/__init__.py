from .adamw import AdamW, AdamWState, global_norm
from .grad_compress import (compressed_psum, dequantize_int8, ef_compress,
                            init_error_state, quantize_int8)
from .schedule import constant, warmup_cosine

__all__ = [
    "AdamW", "AdamWState", "global_norm", "warmup_cosine", "constant",
    "quantize_int8", "dequantize_int8", "ef_compress", "init_error_state",
    "compressed_psum",
]
