"""Gradient compression for data-parallel reduction (int8 + error feedback).

At 1000+ node scale the cross-pod gradient all-reduce is DCN-bound; int8
compression cuts those bytes 4x vs f32 (2x vs bf16).  Error feedback keeps
the quantization noise unbiased over steps (Seide et al. / EF-SGD style):

    e      <- residual carried per leaf
    q      = quantize(g + e)
    e'     = (g + e) - dequantize(q)
    reduce = all_reduce(q) (int32 accumulate) -> dequantize / n

`compressed_psum` is used inside shard_map over the data axes; tests verify
the EF recursion drives the mean error to ~0 and the dry-run shows the
collective operand dtype shrink.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: jax.Array, err: jax.Array
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Error-feedback int8 compression of one gradient leaf.

    Returns (q int8, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def init_error_state(grads) -> Dict:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, err_state, axis_name: str):
    """int8 all-reduce of a gradient tree inside shard_map.

    Quantizes each leaf with error feedback, psums the int8 payload in int32
    (exact for <= 2^23 shards), and rescales by the max participating scale
    (scales are psum-maxed so dequantization is consistent across shards).
    Returns (mean_grads_f32, new_err_state).
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, e2 = ef_compress(g, e)
        # consistent scale across shards: use the max, requantize
        smax = jax.lax.pmax(scale, axis_name)
        qr = jnp.clip(jnp.round(dequantize_int8(q, scale) / smax),
                      -127, 127).astype(jnp.int8)
        e2 = e2 + dequantize_int8(q, scale) - dequantize_int8(qr, smax)
        total = jax.lax.psum(qr.astype(jnp.int32), axis_name)
        return total.astype(jnp.float32) * smax / n, e2

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    return mean, err
