"""Jit'd public wrappers for the Pallas kernels.

Each op auto-selects interpret mode off-TPU (this container is CPU-only; on
a real TPU slice the same call sites compile the Mosaic kernels) and pads
inputs to kernel-friendly shapes.  Query batches dispatch to the
query-batched kernels (one HBM pass over the stored grid per batch);
``cam_search_vmap`` keeps the old per-query vmap path as a baseline.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref
from .cam_search import (SMALL_Q_CROSSOVER, cam_fused_reference,
                         cam_range_fused_pallas, cam_search_batched_pallas,
                         cam_search_fused_pallas, cam_search_pallas,
                         default_q_tile)
from .cam_topk import cam_topk_pallas
from .hamming_pack import hamming_packed_batched_pallas, hamming_packed_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------
# cam_search: subarray-grid distances
# --------------------------------------------------------------------------
def cam_search(stored: jax.Array, query: jax.Array, *, distance: str = "l2",
               col_valid: Optional[jax.Array] = None,
               q_tile: Optional[int] = None,
               interpret: Optional[bool] = None,
               pipeline: bool = True) -> jax.Array:
    """stored (nv, nh, R, C); query (..., nh, C) -> dist (..., nv, nh, R).

    Batched queries go through the query-batched kernel, which streams the
    stored grid from HBM once for the whole batch (``pipeline=True``
    upgrades it to the bank-blocked double-buffered schedule); a single
    (nh, C) query uses the resident single-query kernel.
    """
    nv, nh, R, C = stored.shape
    if col_valid is None:
        col_valid = jnp.ones((nh, C), jnp.float32)
    itp = _interpret() if interpret is None else interpret
    if query.ndim == 2:
        return cam_search_pallas(stored, query, col_valid,
                                 distance=distance, interpret=itp)
    batch = query.reshape(-1, nh, C)
    out = cam_search_batched_pallas(stored, batch, col_valid,
                                    distance=distance, q_tile=q_tile,
                                    interpret=itp, pipeline=pipeline)
    return out.reshape(*query.shape[:-2], nv, nh, R)


def cam_search_vmap(stored: jax.Array, query: jax.Array, *,
                    distance: str = "l2",
                    col_valid: Optional[jax.Array] = None,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Per-query vmap over the single-query kernel (the pre-batching hot
    path).  Kept as the benchmark baseline and numerical cross-check: it
    re-streams the stored grid once per query."""
    nv, nh, R, C = stored.shape
    if col_valid is None:
        col_valid = jnp.ones((nh, C), jnp.float32)
    itp = _interpret() if interpret is None else interpret
    call = functools.partial(cam_search_pallas, distance=distance,
                             interpret=itp)
    if query.ndim == 2:
        return call(stored, query, col_valid)
    batch = query.reshape(-1, nh, C)
    out = jax.vmap(lambda q: call(stored, q, col_valid))(batch)
    return out.reshape(*query.shape[:-2], nv, nh, R)


def _int_cast(stored: jax.Array, queries: jax.Array, col_valid: jax.Array,
              *, distance: str, int_codes: int):
    """Lower noise-free integral point codes onto the narrow-int / packed
    fast paths of ``_dist_block_batched``.

    ``int_codes`` is the code width in bits (``app.data_bits``), asserted
    by the caller to describe a grid of exact small integers (no device
    noise).  1-bit hamming codes bit-pack into uint32 words with
    ``col_valid`` folded in as the care mask (both operands masked, so XOR
    contributes 0 on don't-care columns); wider codes cast to int8 (≤7
    bits) or int16 (8 bits).  Returns the (possibly transformed)
    ``(stored, queries, col_valid)`` triple — unchanged when no fast path
    applies.  Every path is bit-exact vs f32: the distances are sums of
    exact small-integer products.
    """
    if not int_codes or stored.ndim != 4:
        return stored, queries, col_valid
    if distance == "hamming" and int_codes == 1:
        # care mask broadcast over (nv, nh, R, C) / (Q, nh, C); the packed
        # word count W replaces C and the mask is already folded in
        nh = col_valid.shape[0]
        sp = pack_bits(stored, col_valid[None, :, None, :])
        qp = pack_bits(queries, col_valid[None])
        return sp, qp, jnp.ones((nh, sp.shape[-1]), jnp.float32)
    if distance in ("hamming", "l1", "l2", "dot") and int_codes <= 8:
        idt = jnp.int8 if int_codes <= 7 else jnp.int16
        return stored.astype(idt), queries.astype(idt), col_valid
    return stored, queries, col_valid


def _fused_call(stored: jax.Array, queries: jax.Array,
                col_valid: jax.Array, row_valid: jax.Array, *,
                distance: str, sensing: str, sensing_limit: float,
                threshold: float, q_tile: Optional[int], want_dist: bool,
                interpret: bool, pipeline: bool = True, int_codes: int = 0):
    """Shape-dispatched fused kernel call (shared with the sharded wrapper).

    5-D stored grids are ACAM [lo, hi] ranges and require
    ``distance='range'``; the trailing dim is split into two dense (R, C)
    planes before ``pallas_call`` (see ``cam_range_fused_pallas``).

    Batches below ``SMALL_Q_CROSSOVER`` route to ``cam_fused_reference`` —
    the jnp twin built from the same tile functions — on BOTH the interpret
    and compiled paths: per-grid-step dispatch (emulated or Mosaic launch)
    dominates tiny batches either way (BENCH: q1 kernel at 0.92x of jnp
    even with the fused epilogue), and the twin is bit-identical by
    construction.

    ``pipeline``/``int_codes`` select the bank-blocked double-buffered
    schedule and the narrow-int/bit-packed distance paths; the fast paths
    only rewrite dtypes/schedules, never values — ``pipeline=False``
    reproduces the historical kernels bit-for-bit and skips the int
    lowering entirely.
    """
    if (stored.ndim == 5) != (distance == "range"):
        raise ValueError(
            f"distance='range' needs a 5-D [lo, hi] grid and vice versa; "
            f"got distance={distance!r} with stored.ndim={stored.ndim}")
    if pipeline:
        stored, queries, col_valid = _int_cast(
            stored, queries, col_valid, distance=distance,
            int_codes=int_codes)
    if queries.shape[0] < SMALL_Q_CROSSOVER:
        planes = ((stored[..., 0], stored[..., 1]) if stored.ndim == 5
                  else (stored,))
        return cam_fused_reference(
            planes, queries, col_valid, row_valid, distance=distance,
            sensing=sensing, sensing_limit=float(sensing_limit),
            threshold=float(threshold), want_dist=want_dist)
    if stored.ndim == 5:
        return cam_range_fused_pallas(
            stored[..., 0], stored[..., 1], queries, col_valid, row_valid,
            sensing=sensing, sensing_limit=float(sensing_limit),
            threshold=float(threshold), q_tile=q_tile, want_dist=want_dist,
            interpret=interpret, pipeline=pipeline)
    return cam_search_fused_pallas(
        stored, queries, col_valid, row_valid, distance=distance,
        sensing=sensing, sensing_limit=float(sensing_limit),
        threshold=float(threshold), q_tile=q_tile, want_dist=want_dist,
        interpret=interpret, pipeline=pipeline)


def cam_search_fused(stored: jax.Array, queries: jax.Array, *,
                     distance: str, sensing: str, sensing_limit: float = 0.0,
                     threshold: float = 0.0,
                     col_valid: Optional[jax.Array] = None,
                     row_valid: Optional[jax.Array] = None,
                     q_tile: Optional[int] = None, want_dist: bool = True,
                     interpret: Optional[bool] = None,
                     pipeline: bool = True, int_codes: int = 0):
    """Batched search with the sense-and-reduce epilogue fused in-kernel.

    stored (nv, nh, R, C) point codes, or (nv, nh, R, C, 2) ACAM [lo, hi]
    ranges with ``distance='range'`` (dispatched to the range kernel).
    queries (Q, nh, C) -> (dist, match) each (Q, nv, nh, R), or match alone
    when ``want_dist=False`` (the distance tensor then never leaves VMEM).

    ``pipeline`` toggles the bank-blocked double-buffered schedule
    (``sim.pipeline``; off-switch is bit- and schedule-identical to the
    historical kernels).  ``int_codes`` (code width in bits) opts
    noise-free integral point codes onto the narrow-int / bit-packed
    distance fast paths — the caller asserts integrality; results stay
    bit-exact.
    """
    nv, nh, R, C = stored.shape[:4]
    if col_valid is None:
        col_valid = jnp.ones((nh, C), jnp.float32)
    if row_valid is None:
        row_valid = jnp.ones((nv, R), jnp.float32)
    itp = _interpret() if interpret is None else interpret
    return _fused_call(
        stored, queries, col_valid, row_valid, distance=distance,
        sensing=sensing, sensing_limit=float(sensing_limit),
        threshold=float(threshold), q_tile=q_tile, want_dist=want_dist,
        interpret=itp, pipeline=pipeline, int_codes=int_codes)


def cam_search_fused_sharded(stored: jax.Array, queries: jax.Array, *,
                             mesh, bank_axis: str = "bank",
                             distance: str, sensing: str,
                             sensing_limit: float = 0.0,
                             threshold: float = 0.0,
                             col_valid: Optional[jax.Array] = None,
                             row_valid: Optional[jax.Array] = None,
                             q_tile: Optional[int] = None,
                             want_dist: bool = True,
                             interpret: Optional[bool] = None,
                             pipeline: bool = True, int_codes: int = 0):
    """``cam_search_fused`` with the stored grid's nv axis sharded over
    ``bank_axis`` of ``mesh``: each device streams only its local
    (nv/n_banks, nh, R, C) shard — the kernel-layer unit the sharded
    simulator (and the weak-scaling benchmark) builds on.  ACAM
    (nv, nh, R, C, 2) range grids take the same route with
    ``distance='range'`` (the trailing [lo, hi] dim is shard-local).

    Outputs keep the bank sharding on their nv axis ((Q, nv, nh, R),
    sharded on dim 1); the cross-device merge lives one layer up in
    ``core.sharded``, which consumes these shard-local results.  nv must
    divide the bank-axis size (``core.sharded`` handles padding).
    """
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import compat_shard_map

    nv, nh, R, C = stored.shape[:4]
    n_banks = dict(zip(mesh.axis_names, mesh.axis_sizes))[bank_axis]
    if nv % n_banks:
        raise ValueError(f"nv={nv} must be a multiple of the bank axis "
                         f"size {n_banks}")
    if col_valid is None:
        col_valid = jnp.ones((nh, C), jnp.float32)
    if row_valid is None:
        row_valid = jnp.ones((nv, R), jnp.float32)
    itp = _interpret() if interpret is None else interpret

    def body(s, rv, cv, q):
        return _fused_call(
            s, q, cv, rv, distance=distance, sensing=sensing,
            sensing_limit=float(sensing_limit), threshold=float(threshold),
            q_tile=q_tile, want_dist=want_dist, interpret=itp,
            pipeline=pipeline, int_codes=int_codes)

    out_spec = P(None, bank_axis)
    return compat_shard_map(
        body, mesh=mesh,
        in_specs=(P(bank_axis), P(bank_axis), P(), P()),
        out_specs=(out_spec, out_spec) if want_dist else out_spec)(
        stored, row_valid, col_valid, queries)


# --------------------------------------------------------------------------
# cam_topk: streaming best-match top-k (CAM-retrieval attention hot loop)
# --------------------------------------------------------------------------
def cam_topk(keys: jax.Array, query: jax.Array, *, k: int, chunk: int = 512,
             distance: str = "dot", valid_len: Optional[int] = None,
             interpret: Optional[bool] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """keys (S, D) or (..., S, D); query (D,) or (..., D).

    Returns (scores, indices) of shape (..., k); scores are -distance,
    descending.  Rows at index >= valid_len are excluded.
    """
    itp = _interpret() if interpret is None else interpret
    S, D = keys.shape[-2:]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    k = min(k, S)

    limit = S if valid_len is None else valid_len

    def one(kv: jax.Array, q: jax.Array):
        x = kv
        if pad:
            x = jnp.pad(x, ((0, pad), (0, 0)))
        vals, idx = cam_topk_pallas(x, q, k=k, chunk=chunk,
                                    distance=distance, valid_len=limit,
                                    interpret=itp)
        bad = idx >= limit
        vals = jnp.where(bad, -jnp.inf, vals)
        idx = jnp.where(bad, -1, idx)
        return vals, idx

    if keys.ndim == 2:
        return one(keys, query)
    bk = keys.reshape(-1, S, D)
    bq = query.reshape(-1, D)
    vals, idx = jax.vmap(one)(bk, bq)
    lead = keys.shape[:-2]
    # explicit (*lead, k): reshape(-1) would mis-fold the batch axes back
    # into the top-k axis for keys.ndim > 2
    return vals.reshape(*lead, k), idx.reshape(*lead, k)


# --------------------------------------------------------------------------
# hamming_packed: bit-packed TCAM search
# --------------------------------------------------------------------------
def pack_bits(bits: jax.Array,
              care: Optional[jax.Array] = None) -> jax.Array:
    """Pack 0/1 (optionally ternary via ``care`` mask) into uint32 words.

    Don't-care columns are zeroed in the packed word (mask both operands
    with the same ``care`` mask so XOR contributes nothing there).
    """
    x = bits
    if care is not None:
        x = x * care
    return ref.pack_bits_ref(x)


def hamming_packed(stored_packed: jax.Array, query_packed: jax.Array, *,
                   n_valid_bits: int, tile_r: int = 256,
                   q_tile: Optional[int] = None,
                   interpret: Optional[bool] = None) -> jax.Array:
    """stored (R, W) uint32, query (W,) or (Q, W) uint32 -> dist (R,) or
    (Q, R).  Batched queries share each resident stored tile; the default
    Q-tile comes from the same VMEM working-set helper as the float
    kernels (``cam_search.default_q_tile``)."""
    itp = _interpret() if interpret is None else interpret
    R, W = stored_packed.shape
    tr = tile_r
    while R % tr and tr > 1:
        tr //= 2
    if query_packed.ndim == 2:
        return hamming_packed_batched_pallas(
            stored_packed, query_packed, tile_r=tr, q_tile=q_tile,
            interpret=itp)
    return hamming_packed_pallas(stored_packed, query_packed, tile_r=tr,
                                 interpret=itp)
