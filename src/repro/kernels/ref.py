"""Pure-jnp oracles for the Pallas kernels (ground truth for tests)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def cam_search_ref(stored: jax.Array, query: jax.Array, distance: str,
                   col_valid: Optional[jax.Array] = None) -> jax.Array:
    """Reference subarray-grid distance computation.

    stored: (nv, nh, R, C); query: (nh, C); col_valid: (nh, C) or None.
    Returns distances (nv, nh, R).
    """
    q = query[None, :, None, :]                       # (1, nh, 1, C)
    v = 1.0 if col_valid is None else col_valid[None, :, None, :]
    if distance == "hamming":
        d = (stored != q).astype(jnp.float32) * v
    elif distance == "l1":
        d = jnp.abs(stored - q) * v
    elif distance == "l2":
        d = jnp.square(stored - q) * v
    elif distance == "dot":
        d = -(stored * q) * v
    else:
        raise ValueError(distance)
    return jnp.sum(d, axis=-1)


def cam_search_batched_ref(stored: jax.Array, queries: jax.Array,
                           distance: str,
                           col_valid: Optional[jax.Array] = None
                           ) -> jax.Array:
    """Batched oracle: queries (Q, nh, C) -> distances (Q, nv, nh, R)."""
    return jax.vmap(lambda q: cam_search_ref(stored, q, distance, col_valid)
                    )(queries)


def cam_topk_ref(keys: jax.Array, query: jax.Array, k: int,
                 distance: str = "dot"
                 ) -> Tuple[jax.Array, jax.Array]:
    """Reference streaming best-match top-k.

    keys: (S, D); query: (D,). Returns (scores (k,), indices (k,)) where
    score = -distance (larger is better), sorted descending.
    """
    if distance == "dot":
        score = keys @ query                      # larger = better
    elif distance == "l2":
        score = -jnp.sum(jnp.square(keys - query[None, :]), axis=-1)
    elif distance == "l1":
        score = -jnp.sum(jnp.abs(keys - query[None, :]), axis=-1)
    else:
        raise ValueError(distance)
    return jax.lax.top_k(score, k)


def pack_bits_ref(bits: jax.Array) -> jax.Array:
    """Pack a (..., C) 0/1 float/int array into (..., ceil(C/32)) uint32."""
    C = bits.shape[-1]
    W = (C + 31) // 32
    pad = W * 32 - C
    x = jnp.pad(bits.astype(jnp.uint32), [(0, 0)] * (bits.ndim - 1)
                + [(0, pad)])
    x = x.reshape(*bits.shape[:-1], W, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(x * weights, axis=-1, dtype=jnp.uint32)


def hamming_packed_ref(stored_packed: jax.Array, query_packed: jax.Array,
                       n_valid_bits: int) -> jax.Array:
    """Reference bit-packed hamming distance.

    stored_packed: (R, W) uint32; query_packed: (W,) uint32.
    Padding bits are zero in both, so XOR of padding contributes 0.
    """
    x = jnp.bitwise_xor(stored_packed, query_packed[None, :])
    pc = jax.lax.population_count(x)
    return jnp.sum(pc, axis=-1).astype(jnp.int32)
