"""Pallas TPU kernels for CAMASim's compute hot-spots.

  cam_search    — tiled subarray distance search (the CAM array analogue):
                  single-query, query-batched (stored grid streamed from HBM
                  once per batch), and batched+fused-sense variants
  cam_topk      — streaming best-match top-k (winner-take-all SA analogue;
                  hot loop of CAM-retrieval attention)
  hamming_pack  — bit-packed XOR+popcount TCAM search (single + batched)

Each kernel ships with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py); tests sweep shapes/dtypes and assert_allclose against the oracle.
Kernels execute via interpret=True off-TPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
