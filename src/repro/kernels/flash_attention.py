"""Pallas TPU kernel: fused causal flash attention (forward).

The §Perf roofline shows every train/prefill cell memory-bound on the
pure-JAX attention's S^2 score-tile HBM spill (EXPERIMENTS.md §Roofline).
This kernel keeps the running softmax state (m, l, acc) in VMEM scratch
while streaming K/V tiles, so HBM traffic is O(q + k + v + out) + the K/V
restreaming — no S^2 tensor ever leaves VMEM.

Grid: (B, KVH, G, nq, nk) — nk innermost/sequential on TPU, so scratch
carries across k tiles of one q tile.  Causal tiles entirely above the
diagonal are skipped (@pl.when), recovering the ~2x the masked-naive path
wastes.

GQA layout: q (B, KVH, G, S, D); k/v (B, KVH, S, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            qt: int, kt: int, scale: float, causal: bool):
    i = pl.program_id(3)                       # q tile
    j = pl.program_id(4)                       # k tile
    nk = pl.num_programs(4)

    @pl.when(j == 0)
    def _init():
        m_sc[...] = jnp.full((qt, 1), NEG_INF, jnp.float32)
        l_sc[...] = jnp.zeros((qt, 1), jnp.float32)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = i * qt
    k_start = j * kt
    # causal skip: tile strictly above the diagonal contributes nothing
    live = (not causal) or (k_start <= q_start + qt - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0, 0]                     # (qt, D)
        k = k_ref[0, 0]                        # (kt, D)
        v = v_ref[0, 0]                        # (kt, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (qt, kt)
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (qt, kt), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (qt, kt), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_sc[...]                     # (qt, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                 # (qt, kt)
        corr = jnp.exp(m_prev - m_new)         # (qt, 1)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)           # (qt, D)
        acc_sc[...] = acc_sc[...] * corr + pv
        m_sc[...] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        out = acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_tile", "kv_tile", "causal",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           q_tile: int = 512, kv_tile: int = 512,
                           causal: bool = True,
                           interpret: bool = False) -> jax.Array:
    """q (B,S,H,D), k/v (B,S,KVH,D) -> (B,S,H,D), fused causal attention."""
    B, S, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    qt, kt = min(q_tile, S), min(kv_tile, S)
    assert S % qt == 0 and S % kt == 0, (S, qt, kt)
    nq, nk = S // qt, S // kt
    scale = D ** -0.5

    qr = q.reshape(B, S, KVH, G, D).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)               # (B, KVH, S, D)
    vr = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_kernel, qt=qt, kt=kt, scale=scale,
                          causal=causal),
        grid=(B, KVH, G, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, qt, D),
                         lambda b, h, g, i, j: (b, h, g, i, 0)),
            pl.BlockSpec((1, 1, kt, D),
                         lambda b, h, g, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, kt, D),
                         lambda b, h, g, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, qt, D),
                               lambda b, h, g, i, j: (b, h, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, nq * qt, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qt, 1), jnp.float32),
            pltpu.VMEM((qt, 1), jnp.float32),
            pltpu.VMEM((qt, D), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)
