"""Pallas TPU kernel: streaming best-match top-k (the CAM winner-take-all).

This is the sense-amplifier analogue for best-match CAM (DESIGN.md §2) and
the hot loop of CAM-retrieval attention: stream the stored keys through VMEM
chunk by chunk, score each chunk against the query, and maintain a running
top-k (score, index) set in VMEM scratch — never materializing the full
(S,)-sized score vector in HBM.

Grid: (S // chunk,) — sequential on TPU, so scratch carries across steps.
Per step:
    keys   (chunk, D)  VMEM  <- HBM chunk c
    query  (1, D)      VMEM  (resident)
    scratch top_vals (1, k) / top_idx (1, k)  VMEM
On the last step the merged top-k is written out.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _score_chunk(keys, q, distance: str):
    if distance == "dot":
        return keys @ q                         # (chunk,)
    if distance == "l2":
        return -jnp.sum(jnp.square(keys - q[None, :]), axis=-1)
    if distance == "l1":
        return -jnp.sum(jnp.abs(keys - q[None, :]), axis=-1)
    raise ValueError(distance)


def _kernel(keys_ref, query_ref, out_vals_ref, out_idx_ref,
            top_vals, top_idx, *, k: int, chunk: int, distance: str,
            valid_len: int):
    c = pl.program_id(0)
    n_chunks = pl.num_programs(0)

    @pl.when(c == 0)
    def _init():
        top_vals[0, :] = jnp.full((k,), -jnp.inf, jnp.float32)
        top_idx[0, :] = jnp.full((k,), -1, jnp.int32)

    keys = keys_ref[...]                        # (chunk, D)
    q = query_ref[0]                            # (D,)
    scores = _score_chunk(keys, q, distance)    # (chunk,)
    idx = c * chunk + jax.lax.iota(jnp.int32, chunk)
    # padding rows (idx >= valid_len) must never win the top-k
    scores = jnp.where(idx < valid_len, scores, -jnp.inf)

    # merge running top-k with this chunk then re-select top-k
    all_vals = jnp.concatenate([top_vals[0, :], scores])
    all_idx = jnp.concatenate([top_idx[0, :], idx])
    new_vals, sel = jax.lax.top_k(all_vals, k)
    top_vals[0, :] = new_vals
    top_idx[0, :] = jnp.take(all_idx, sel)

    @pl.when(c == n_chunks - 1)
    def _emit():
        out_vals_ref[0, :] = top_vals[0, :]
        out_idx_ref[0, :] = top_idx[0, :]


@functools.partial(jax.jit,
                   static_argnames=("k", "chunk", "distance", "interpret",
                                    "valid_len"))
def cam_topk_pallas(keys: jax.Array, query: jax.Array, *, k: int,
                    chunk: int = 512, distance: str = "dot",
                    valid_len: int = -1, interpret: bool = False):
    """keys (S, D), query (D,) -> (scores (k,), indices (k,)).

    S must be a multiple of ``chunk``; rows at index >= valid_len are
    excluded inside the kernel (-inf score) so zero-padding can never win.
    Scores are -distance (larger = better), descending.
    """
    S, D = keys.shape
    assert S % chunk == 0, f"S={S} not a multiple of chunk={chunk}"
    n_chunks = S // chunk
    assert k <= chunk, (k, chunk)
    if valid_len < 0:
        valid_len = S
    vals, idx = pl.pallas_call(
        functools.partial(_kernel, k=k, chunk=chunk, distance=distance,
                          valid_len=valid_len),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec((chunk, D), lambda c: (c, 0)),
            pl.BlockSpec((1, D), lambda c: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda c: (0, 0)),
            pl.BlockSpec((1, k), lambda c: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        scratch_shapes=[
            # VMEM scratch carrying the running top-k across grid steps
            pltpu.VMEM((1, k), jnp.float32),
            pltpu.VMEM((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(keys.astype(jnp.float32), query.astype(jnp.float32)[None, :])
    return vals[0], idx[0]
