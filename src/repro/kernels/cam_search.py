"""Pallas TPU kernels: tiled CAM subarray search (single-query and batched).

TPU adaptation of the CAM array (DESIGN.md §2): each grid step loads one
(R, C) subarray tile from HBM into VMEM — the analogue of the data resident
in a physical CAM array — evaluates the match-line reduction against the
query segment(s), and reduces along the column axis.  The grid iterates the
(nv, nh) subarray mesh, exactly the partition produced by the mapping
submodule.

Two kernels:

``cam_search_pallas`` — the original single-query kernel.  Per grid step
(i, j) it broadcasts one (C,) query segment across the rows on the VPU:

    stored    (1, 1, R, C)  VMEM   <- HBM tile (i, j)
    query     (1, C)        VMEM   <- segment j (revisited across i)
    col_valid (1, C)        VMEM
    out       (1, 1, R)     VMEM   -> dist tile (i, j)

``cam_search_batched_pallas`` — the query-batched kernel (store once,
search many; paper Fig. 1b).  The grid becomes (nv, nh, Q/Qt) with the
Q-tile axis innermost, so a stored tile's BlockSpec index (i, j) is constant
across consecutive steps: Pallas keeps the (R, C) tile resident in VMEM and
each stored tile is streamed from HBM **once per full query batch** instead
of once per query (the vmap-of-single-query path re-streams the whole grid
Q times).  Per grid step (i, j, k):

    stored    (1, 1, R, C)  VMEM   <- HBM tile (i, j); resident across k
    queries   (Qt, 1, C)    VMEM   <- Q-tile k, segment j
    col_valid (1, C)        VMEM
    out       (Qt, 1, 1, R) VMEM   -> dist tile (k, i, j)

VMEM working set per step: 4·(R·C + Qt·C + C + Qt·R) bytes (f32).  For the
default Qt = 32 and a 64×64 subarray that is ~32 KiB — far below the ~16 MiB
VMEM budget, so Qt can be raised until either the (Qt, C) query tile or the
(Qt, R) output tile approaches the (R, C) stored tile in size; past that the
kernel stops being stored-stream-bound and larger tiles buy nothing.

Distance formulation: for ``l2``/``dot`` the batched kernel is shaped for
the MXU — the cross term is a (Qt, C) × (C, R) matmul and the masked column
weights are folded into the row/query norms (‖s‖² − 2·S·Qᵀ + ‖q‖², all
norms computed over valid columns only).  ``l1``/``hamming`` have no matmul
form and keep the VPU broadcast-compare-reduce path, materializing a
(Qt, R, C) block in registers.

``cam_search_fused_pallas`` — batched search + fused sense-and-reduce
epilogue.  The sense-amplifier model of ``core.subarray.sense`` (exact /
best / threshold) and the intra-subarray winner-take-all reduction
(min over the R match lines) run inside the kernel while the distance block
is still in VMEM.  With ``want_dist=False`` only the digital match lines are
written back, so the (Q, nv, nh, R) float distance tensor never hits HBM —
this is the common exact/threshold AND-merge path, where the merge consumes
match lines only.

``cam_range_fused_pallas`` — the ACAM variant of the fused batched kernel
(paper §III-C, Table III: analog cells store a [lo, hi] range per cell; the
memristor / complementary-FeFET ACAMs are the hardware targets).  The
"distance" is the range-violation count of ``core.distance.range_violations``
— #cells whose stored interval excludes the query value — and the same
exact/best/threshold sense epilogue runs on it in-kernel.  The 5-D
(nv, nh, R, C, 2) range grid is NOT blocked as a 5-D ref: the caller splits
the trailing [lo, hi] dim before ``pallas_call`` and the kernel takes two
dense (R, C) planes per tile, so the lane (last) dimension of every block
stays the dense C axis the VPU wants.  Per grid step (i, j, k):

    lo, hi    (1, 1, R, C)  VMEM  <- HBM tiles (i, j); resident across k
    queries   (Qt, 1, C)    VMEM  <- Q-tile k, segment j
    out       (Qt, 1, 1, R) VMEM  -> violation-count / match tile (k, i, j)

The violation compare-and-count has no matmul form (like l1/hamming) and
materializes a (Qt, R, C) block in registers on the VPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")

# Conservative per-step VMEM budget for the default Q-tile derivation: well
# under the ~16 MiB physical budget so double-buffered pipelines and the
# (Qt, R, C) register blocks of the VPU distances still fit.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024

# Interpret-mode grids pay per-step dispatch overhead; below this batch size
# the identical jnp tile math wins (BENCH: kernel_acam_range_q1 at 0.18x).
SMALL_Q_CROSSOVER = 4


def default_q_tile(rows: int, cols: int, planes: int = 1, *,
                   budget_bytes: int = VMEM_BUDGET_BYTES) -> int:
    """Default fused-kernel Q-tile from the VMEM working-set formula.

    The module docstring's per-step working set is
    4·(planes·R·C + Qt·C + C + Qt·R) bytes (f32), and past the point where
    the (Qt, C) query tile / (Qt, R) output tile approach the stored tile
    in size the kernel stops being stored-stream-bound — so the tile is
    sized to the stored planes (``stream``), clamped to what the budget
    allows (``cap``), floored at 8 (sublane granularity) and capped at 256,
    then rounded down to a power of two for friendly grid divisions.
    ``planes`` is 1 for point-code grids, 2 for ACAM [lo, hi] grids.
    """
    words = budget_bytes // 4
    stream = (planes * rows * cols) // (rows + cols)
    cap = (words - planes * rows * cols - cols) // (rows + cols)
    qt = min(max(stream, 8), max(cap, 1), 256)
    return max(1, 1 << (int(qt).bit_length() - 1))


def _dist_block(stored, q, valid, distance: str):
    if distance == "hamming":
        d = (stored != q).astype(jnp.float32)
    elif distance == "l1":
        d = jnp.abs(stored - q)
    elif distance == "l2":
        d = jnp.square(stored - q)
    elif distance == "dot":
        d = -(stored * q)
    else:
        raise ValueError(distance)
    return jnp.sum(d * valid, axis=-1)


def _kernel(stored_ref, query_ref, valid_ref, out_ref, *, distance: str):
    stored = stored_ref[0, 0]          # (R, C)
    q = query_ref[0]                   # (C,)
    valid = valid_ref[0]               # (C,)
    out_ref[0, 0] = _dist_block(stored, q[None, :], valid[None, :], distance)


@functools.partial(jax.jit,
                   static_argnames=("distance", "interpret"))
def cam_search_pallas(stored: jax.Array, query: jax.Array,
                      col_valid: jax.Array, *, distance: str = "l2",
                      interpret: bool = False) -> jax.Array:
    """stored (nv, nh, R, C), query (nh, C), col_valid (nh, C)
    -> dist (nv, nh, R)."""
    nv, nh, R, C = stored.shape
    assert query.shape == (nh, C), (query.shape, (nh, C))
    grid = (nv, nh)
    return pl.pallas_call(
        functools.partial(_kernel, distance=distance),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, R, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, C), lambda i, j: (j, 0)),
            pl.BlockSpec((1, C), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nv, nh, R), jnp.float32),
        interpret=interpret,
    )(stored.astype(jnp.float32), query.astype(jnp.float32),
      col_valid.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Query-batched kernel
# ---------------------------------------------------------------------------
def _dist_block_batched(stored, q, valid, distance: str) -> jax.Array:
    """stored (R, C), q (Qt, C), valid (C,) -> dist (Qt, R)."""
    if distance in ("l2", "dot"):
        # MXU formulation: fold the column mask into one operand so the
        # cross term is a plain (Qt, C) x (C, R) matmul.
        qv = q * valid[None, :]
        cross = jax.lax.dot_general(
            qv, stored, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (Qt, R)
        if distance == "dot":
            return -cross
        sn = jnp.sum(stored * stored * valid[None, :], axis=-1)   # (R,)
        qn = jnp.sum(q * qv, axis=-1)                             # (Qt,)
        return sn[None, :] - 2.0 * cross + qn[:, None]
    # VPU broadcast path: (Qt, R, C) block in registers.
    s = stored[None, :, :]
    qq = q[:, None, :]
    if distance == "hamming":
        d = (s != qq).astype(jnp.float32)
    elif distance == "l1":
        d = jnp.abs(s - qq)
    else:
        raise ValueError(distance)
    return jnp.sum(d * valid[None, None, :], axis=-1)


def _batched_kernel(stored_ref, query_ref, valid_ref, out_ref, *,
                    distance: str):
    stored = stored_ref[0, 0]            # (R, C)
    q = query_ref[:, 0, :]               # (Qt, C)
    valid = valid_ref[0]                 # (C,)
    out_ref[:, 0, 0, :] = _dist_block_batched(stored, q, valid, distance)


@functools.partial(jax.jit,
                   static_argnames=("distance", "q_tile", "interpret"))
def cam_search_batched_pallas(stored: jax.Array, queries: jax.Array,
                              col_valid: jax.Array, *,
                              distance: str = "l2",
                              q_tile: Optional[int] = None,
                              interpret: bool = False) -> jax.Array:
    """stored (nv, nh, R, C), queries (Q, nh, C), col_valid (nh, C)
    -> dist (Q, nv, nh, R).

    The stored grid is streamed from HBM once for the whole query batch
    (Q-tile axis innermost; see module docstring for the block layout).
    ``q_tile=None`` derives the tile from ``default_q_tile(R, C)``.
    """
    nv, nh, R, C = stored.shape
    Q = queries.shape[0]
    assert queries.shape == (Q, nh, C), (queries.shape, (Q, nh, C))
    if q_tile is None:
        q_tile = default_q_tile(R, C)
    qt = max(1, min(q_tile, Q))
    pad = (-Q) % qt
    if pad:
        queries = jnp.pad(queries, ((0, pad), (0, 0), (0, 0)))
    nq = (Q + pad) // qt
    out = pl.pallas_call(
        functools.partial(_batched_kernel, distance=distance),
        grid=(nv, nh, nq),
        in_specs=[
            pl.BlockSpec((1, 1, R, C), lambda i, j, k: (i, j, 0, 0)),
            pl.BlockSpec((qt, 1, C), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((1, C), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((qt, 1, 1, R), lambda i, j, k: (k, i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((Q + pad, nv, nh, R), jnp.float32),
        interpret=interpret,
    )(stored.astype(jnp.float32), queries.astype(jnp.float32),
      col_valid.astype(jnp.float32))
    return out[:Q]


# ---------------------------------------------------------------------------
# Batched search with fused sense-and-reduce epilogue
# ---------------------------------------------------------------------------
def _sense_block(d: jax.Array, rv: jax.Array, sensing: str,
                 sensing_limit: float, threshold: float) -> jax.Array:
    """d (Qt, R) distances (inf on invalid rows), rv (R,) -> match (Qt, R)."""
    if sensing == "exact":
        m = d <= sensing_limit
    elif sensing == "best":
        # intra-subarray winner-take-all: min over the R match lines while
        # the distance block is still in VMEM
        m = d <= (jnp.min(d, axis=-1, keepdims=True) + sensing_limit)
    elif sensing == "threshold":
        m = d <= (threshold + sensing_limit)
    else:
        raise ValueError(sensing)
    return m.astype(jnp.float32) * rv[None, :]


def _fused_epilogue(d, rv, out_refs, *, sensing: str, sensing_limit: float,
                    threshold: float, want_dist: bool):
    """Shared kernel epilogue: padding-row inf mask, sense, write-back."""
    d = jnp.where(rv[None, :] > 0, d, _INF)   # padding rows never win
    m = _sense_block(d, rv, sensing, sensing_limit, threshold)
    if want_dist:
        out_refs[0][:, 0, 0, :] = d
        out_refs[1][:, 0, 0, :] = m
    else:
        out_refs[0][:, 0, 0, :] = m


def _fused_kernel(stored_ref, query_ref, valid_ref, rowv_ref, *out_refs,
                  distance: str, sensing: str, sensing_limit: float,
                  threshold: float, want_dist: bool):
    d = _dist_block_batched(stored_ref[0, 0], query_ref[:, 0, :],
                            valid_ref[0], distance)
    _fused_epilogue(d, rowv_ref[0], out_refs, sensing=sensing,
                    sensing_limit=sensing_limit, threshold=threshold,
                    want_dist=want_dist)


def _fused_driver(kernel_body, stored_planes, queries: jax.Array,
                  col_valid: jax.Array, row_valid: jax.Array, *,
                  q_tile: Optional[int], want_dist: bool, interpret: bool):
    """Shared scaffolding for the fused batched kernels: Q-tile clamp/pad,
    the (nv, nh, Q/Qt) grid with the Q-tile axis innermost, BlockSpecs
    (one (1, 1, R, C) resident spec per stored plane), pallas_call, and
    the [:Q] unpad.  ``stored_planes`` is (stored,) for point-code grids
    and (lo, hi) for ACAM range grids.  ``q_tile=None`` derives the tile
    from the VMEM working-set formula (``default_q_tile``)."""
    nv, nh, R, C = stored_planes[0].shape
    Q = queries.shape[0]
    assert queries.shape == (Q, nh, C), (queries.shape, (Q, nh, C))
    assert row_valid.shape == (nv, R), (row_valid.shape, (nv, R))
    if q_tile is None:
        q_tile = default_q_tile(R, C, len(stored_planes))
    qt = max(1, min(q_tile, Q))
    pad = (-Q) % qt
    if pad:
        queries = jnp.pad(queries, ((0, pad), (0, 0), (0, 0)))
    nq = (Q + pad) // qt
    shape = jax.ShapeDtypeStruct((Q + pad, nv, nh, R), jnp.float32)
    spec = pl.BlockSpec((qt, 1, 1, R), lambda i, j, k: (k, i, j, 0))
    stored_spec = pl.BlockSpec((1, 1, R, C), lambda i, j, k: (i, j, 0, 0))
    out = pl.pallas_call(
        kernel_body,
        grid=(nv, nh, nq),
        in_specs=[stored_spec] * len(stored_planes) + [
            pl.BlockSpec((qt, 1, C), lambda i, j, k: (k, j, 0)),
            pl.BlockSpec((1, C), lambda i, j, k: (j, 0)),
            pl.BlockSpec((1, R), lambda i, j, k: (i, 0)),
        ],
        out_specs=(spec, spec) if want_dist else spec,
        out_shape=(shape, shape) if want_dist else shape,
        interpret=interpret,
    )(*(p.astype(jnp.float32) for p in stored_planes),
      queries.astype(jnp.float32), col_valid.astype(jnp.float32),
      row_valid.astype(jnp.float32))
    if want_dist:
        return out[0][:Q], out[1][:Q]
    return out[:Q]


@functools.partial(jax.jit,
                   static_argnames=("distance", "sensing", "sensing_limit",
                                    "threshold", "q_tile", "want_dist",
                                    "interpret"))
def cam_search_fused_pallas(stored: jax.Array, queries: jax.Array,
                            col_valid: jax.Array, row_valid: jax.Array, *,
                            distance: str = "l2", sensing: str = "best",
                            sensing_limit: float = 0.0,
                            threshold: float = 0.0,
                            q_tile: Optional[int] = None,
                            want_dist: bool = True,
                            interpret: bool = False):
    """Batched search + in-kernel sense amplifier.

    stored (nv, nh, R, C), queries (Q, nh, C), col_valid (nh, C),
    row_valid (nv, R).

    Returns ``(dist, match)`` each (Q, nv, nh, R) — or ``match`` alone when
    ``want_dist=False``, in which case the float distance tensor is never
    written to HBM (exact/threshold AND-merge path).  Distances on padding
    rows are +inf, matching ``core.subarray.subarray_query``.
    """
    body = functools.partial(
        _fused_kernel, distance=distance, sensing=sensing,
        sensing_limit=float(sensing_limit), threshold=float(threshold),
        want_dist=want_dist)
    return _fused_driver(body, (stored,), queries, col_valid, row_valid,
                         q_tile=q_tile, want_dist=want_dist,
                         interpret=interpret)


# ---------------------------------------------------------------------------
# ACAM range match with fused sense-and-reduce epilogue
# ---------------------------------------------------------------------------
def _range_block_batched(lo, hi, q, valid) -> jax.Array:
    """lo/hi (R, C), q (Qt, C), valid (C,) -> violation counts (Qt, R).

    A cell votes a violation when the query value falls outside its stored
    closed interval [lo, hi]; padded columns are masked out.  Counts are
    small integers in f32, so the sum is exact in any reduction order."""
    qq = q[:, None, :]                                   # (Qt, 1, C)
    viol = ((qq < lo[None, :, :]) | (qq > hi[None, :, :])
            ).astype(jnp.float32)
    return jnp.sum(viol * valid[None, None, :], axis=-1)


def _range_fused_kernel(lo_ref, hi_ref, query_ref, valid_ref, rowv_ref,
                        *out_refs, sensing: str, sensing_limit: float,
                        threshold: float, want_dist: bool):
    d = _range_block_batched(lo_ref[0, 0], hi_ref[0, 0], query_ref[:, 0, :],
                             valid_ref[0])
    _fused_epilogue(d, rowv_ref[0], out_refs, sensing=sensing,
                    sensing_limit=sensing_limit, threshold=threshold,
                    want_dist=want_dist)


@functools.partial(jax.jit,
                   static_argnames=("sensing", "sensing_limit", "threshold",
                                    "q_tile", "want_dist", "interpret"))
def cam_range_fused_pallas(stored_lo: jax.Array, stored_hi: jax.Array,
                           queries: jax.Array, col_valid: jax.Array,
                           row_valid: jax.Array, *, sensing: str = "exact",
                           sensing_limit: float = 0.0,
                           threshold: float = 0.0,
                           q_tile: Optional[int] = None,
                           want_dist: bool = True,
                           interpret: bool = False):
    """Batched ACAM range search + in-kernel sense amplifier.

    stored_lo / stored_hi (nv, nh, R, C) — the two planes of a 5-D
    (nv, nh, R, C, 2) range grid, split by the caller so every BlockSpec
    keeps a dense lane dim; queries (Q, nh, C); col_valid (nh, C);
    row_valid (nv, R).

    Same contract as ``cam_search_fused_pallas``: returns ``(dist, match)``
    each (Q, nv, nh, R) — dist is the range-violation count, +inf on
    padding rows — or ``match`` alone when ``want_dist=False`` (the count
    tensor then never hits HBM; the ACAM exact-match AND-merge path).
    The grid is (nv, nh, Q/Qt) with the Q-tile innermost, so both stored
    planes are streamed from HBM once per query batch.
    """
    assert stored_hi.shape == stored_lo.shape, (stored_hi.shape,
                                                stored_lo.shape)
    body = functools.partial(
        _range_fused_kernel, sensing=sensing,
        sensing_limit=float(sensing_limit), threshold=float(threshold),
        want_dist=want_dist)
    return _fused_driver(body, (stored_lo, stored_hi), queries, col_valid,
                         row_valid, q_tile=q_tile, want_dist=want_dist,
                         interpret=interpret)


# ---------------------------------------------------------------------------
# jnp twin of the fused kernels (small-batch interpret-mode dispatch target)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("distance", "sensing", "sensing_limit",
                                    "threshold", "want_dist"))
def cam_fused_reference(stored_planes, queries: jax.Array,
                        col_valid: jax.Array, row_valid: jax.Array, *,
                        distance: str, sensing: str,
                        sensing_limit: float = 0.0, threshold: float = 0.0,
                        want_dist: bool = True):
    """Pure-jnp twin of ``cam_search_fused_pallas`` / ``cam_range_fused_
    pallas``, built from the SAME per-tile functions the kernel bodies call
    (``_dist_block_batched`` / ``_range_block_batched`` / ``_sense_block``)
    vmapped over the (nv, nh) grid — so its results are the kernels', by
    construction.  ``ops._fused_call`` dispatches here for interpret-mode
    batches below ``SMALL_Q_CROSSOVER``, where per-grid-step emulation
    overhead dominates (BENCH: kernel_acam_range_q1 ran at 0.18x of jnp).

    ``stored_planes``: (stored,) point grids or (lo, hi) for
    ``distance='range'``, each (nv, nh, R, C); same outputs as the kernels.
    """
    planes = tuple(p.astype(jnp.float32) for p in stored_planes)
    n_planes = len(planes)
    q = queries.astype(jnp.float32)
    cv = col_valid.astype(jnp.float32)
    rv = row_valid.astype(jnp.float32)

    def tile(tile_planes, qseg, valid, rowv):
        if distance == "range":
            d = _range_block_batched(tile_planes[0], tile_planes[1], qseg,
                                     valid)
        else:
            d = _dist_block_batched(tile_planes[0], qseg, valid, distance)
        d = jnp.where(rowv[None, :] > 0, d, _INF)
        m = _sense_block(d, rowv, sensing, float(sensing_limit),
                         float(threshold))
        return d, m

    per_seg = jax.vmap(tile, in_axes=((0,) * n_planes, 1, 0, None),
                       out_axes=(1, 1))                  # over nh
    per_bank = jax.vmap(lambda tp, rowv: per_seg(tp, q, cv, rowv),
                        in_axes=((0,) * n_planes, 0),
                        out_axes=(1, 1))                 # over nv
    d, m = per_bank(planes, rv)                          # (Q, nv, nh, R)
    return (d, m) if want_dist else m
