"""Pallas TPU kernel: tiled CAM subarray search.

TPU adaptation of the CAM array (DESIGN.md §2): each grid step loads one
(R, C) subarray tile from HBM into VMEM — the analogue of the data resident
in a physical CAM array — broadcasts the query segment across the rows on
the VPU, and reduces along the match-line (column) axis.  The grid iterates
the (nv, nh) subarray mesh, exactly the partition produced by the mapping
submodule.

Block layout (per grid step (i, j)):
    stored    (1, 1, R, C)  VMEM   <- HBM tile (i, j)
    query     (1, C)        VMEM   <- segment j (revisited across i: stays hot)
    col_valid (1, C)        VMEM
    out       (1, 1, R)     VMEM   -> dist tile (i, j)

For MXU alignment choose C as a multiple of 128 and R a multiple of 8 where
possible; unaligned sizes still lower but waste lanes (the circuit-level
analogue: a partially used subarray).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dist_block(stored, q, valid, distance: str):
    if distance == "hamming":
        d = (stored != q).astype(jnp.float32)
    elif distance == "l1":
        d = jnp.abs(stored - q)
    elif distance == "l2":
        d = jnp.square(stored - q)
    elif distance == "dot":
        d = -(stored * q)
    else:
        raise ValueError(distance)
    return jnp.sum(d * valid, axis=-1)


def _kernel(stored_ref, query_ref, valid_ref, out_ref, *, distance: str):
    stored = stored_ref[0, 0]          # (R, C)
    q = query_ref[0]                   # (C,)
    valid = valid_ref[0]               # (C,)
    out_ref[0, 0] = _dist_block(stored, q[None, :], valid[None, :], distance)


@functools.partial(jax.jit,
                   static_argnames=("distance", "interpret"))
def cam_search_pallas(stored: jax.Array, query: jax.Array,
                      col_valid: jax.Array, *, distance: str = "l2",
                      interpret: bool = False) -> jax.Array:
    """stored (nv, nh, R, C), query (nh, C), col_valid (nh, C)
    -> dist (nv, nh, R)."""
    nv, nh, R, C = stored.shape
    assert query.shape == (nh, C), (query.shape, (nh, C))
    grid = (nv, nh)
    return pl.pallas_call(
        functools.partial(_kernel, distance=distance),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, R, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, C), lambda i, j: (j, 0)),
            pl.BlockSpec((1, C), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nv, nh, R), jnp.float32),
        interpret=interpret,
    )(stored.astype(jnp.float32), query.astype(jnp.float32),
      col_valid.astype(jnp.float32))
