"""Pallas TPU kernels: tiled CAM subarray search (single-query and batched).

TPU adaptation of the CAM array (DESIGN.md §2): each grid step loads one
(R, C) subarray tile from HBM into VMEM — the analogue of the data resident
in a physical CAM array — evaluates the match-line reduction against the
query segment(s), and reduces along the column axis.  The grid iterates the
(nv, nh) subarray mesh, exactly the partition produced by the mapping
submodule.

Two kernels:

``cam_search_pallas`` — the original single-query kernel.  Per grid step
(i, j) it broadcasts one (C,) query segment across the rows on the VPU:

    stored    (1, 1, R, C)  VMEM   <- HBM tile (i, j)
    query     (1, C)        VMEM   <- segment j (revisited across i)
    col_valid (1, C)        VMEM
    out       (1, 1, R)     VMEM   -> dist tile (i, j)

``cam_search_batched_pallas`` — the query-batched kernel (store once,
search many; paper Fig. 1b).  The grid becomes (nv, nh, Q/Qt) with the
Q-tile axis innermost, so a stored tile's BlockSpec index (i, j) is constant
across consecutive steps: Pallas keeps the (R, C) tile resident in VMEM and
each stored tile is streamed from HBM **once per full query batch** instead
of once per query (the vmap-of-single-query path re-streams the whole grid
Q times).  Per grid step (i, j, k):

    stored    (1, 1, R, C)  VMEM   <- HBM tile (i, j); resident across k
    queries   (Qt, 1, C)    VMEM   <- Q-tile k, segment j
    col_valid (1, C)        VMEM
    out       (Qt, 1, 1, R) VMEM   -> dist tile (k, i, j)

VMEM working set per step: 4·(R·C + Qt·C + C + Qt·R) bytes (f32).  For the
default Qt = 32 and a 64×64 subarray that is ~32 KiB — far below the ~16 MiB
VMEM budget, so Qt can be raised until either the (Qt, C) query tile or the
(Qt, R) output tile approaches the (R, C) stored tile in size; past that the
kernel stops being stored-stream-bound and larger tiles buy nothing.

Distance formulation: for ``l2``/``dot`` the batched kernel is shaped for
the MXU — the cross term is a (Qt, C) × (C, R) matmul and the masked column
weights are folded into the row/query norms (‖s‖² − 2·S·Qᵀ + ‖q‖², all
norms computed over valid columns only).  ``l1``/``hamming`` have no matmul
form and keep the VPU broadcast-compare-reduce path, materializing a
(Qt, R, C) block in registers.

``cam_search_fused_pallas`` — batched search + fused sense-and-reduce
epilogue.  The sense-amplifier model of ``core.subarray.sense`` (exact /
best / threshold) and the intra-subarray winner-take-all reduction
(min over the R match lines) run inside the kernel while the distance block
is still in VMEM.  With ``want_dist=False`` only the digital match lines are
written back, so the (Q, nv, nh, R) float distance tensor never hits HBM —
this is the common exact/threshold AND-merge path, where the merge consumes
match lines only.

``cam_range_fused_pallas`` — the ACAM variant of the fused batched kernel
(paper §III-C, Table III: analog cells store a [lo, hi] range per cell; the
memristor / complementary-FeFET ACAMs are the hardware targets).  The
"distance" is the range-violation count of ``core.distance.range_violations``
— #cells whose stored interval excludes the query value — and the same
exact/best/threshold sense epilogue runs on it in-kernel.  The 5-D
(nv, nh, R, C, 2) range grid is NOT blocked as a 5-D ref: the caller splits
the trailing [lo, hi] dim before ``pallas_call`` and the kernel takes two
dense (R, C) planes per tile, so the lane (last) dimension of every block
stays the dense C axis the VPU wants.  Per grid step (i, j, k):

    lo, hi    (1, 1, R, C)  VMEM  <- HBM tiles (i, j); resident across k
    queries   (Qt, 1, C)    VMEM  <- Q-tile k, segment j
    out       (Qt, 1, 1, R) VMEM  -> violation-count / match tile (k, i, j)

The violation compare-and-count has no matmul form (like l1/hamming) and
materializes a (Qt, R, C) block in registers on the VPU.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INF = float("inf")

# Conservative per-step VMEM budget for the default Q-tile derivation: well
# under the ~16 MiB physical budget so double-buffered pipelines and the
# (Qt, R, C) register blocks of the VPU distances still fit.
VMEM_BUDGET_BYTES = 4 * 1024 * 1024

# Pipelined-driver budget: the bank-blocked grid keeps whole stored bank
# blocks resident across Q-tiles (half the budget) plus the in-flight
# query/output tiles (the other half), closer to the ~16 MiB physical VMEM
# than the per-tile formula's conservative 4 MiB.
RESIDENT_BUDGET_BYTES = 12 * 1024 * 1024

# Per-grid-step dispatch overhead (seconds) for the measured-model Q-tile
# choice.  Interpret mode pays this in host dispatch per step; compiled
# Mosaic pays a (much smaller) scalar-core cost — either way the model only
# RANKS ladder rungs, and kernel_bench.py validates the ranking against
# wall clock.  Overridable at import via CAMASIM_STEP_OVERHEAD_S (or at
# runtime via set_kernel_model / sim.step_overhead_s; see
# benchmarks/calibrate_kernel_model.py for a fitting script).
STEP_OVERHEAD_S = float(os.environ.get("CAMASIM_STEP_OVERHEAD_S", 2e-4))

# Nominal HBM bandwidth for the traffic term of the Q-tile model; the same
# constant plan.autotune.simulated_qps uses (bytes/s).
HBM_BYTES_PER_S = 819e9

# Ceiling on the per-step VPU broadcast block (qt, vb·segs·R, C) that the
# no-matmul distances (l1 / unpacked hamming / ACAM range) materialize while
# comparing every query lane against every cell.  The MXU distances
# (l2 / dot) and the bit-packed hamming path never build this block, so the
# cap binds only where the block is real — measured on the ACAM Q-sweep
# geometry (8 banks x 512 x 128): rungs past this cliff run ~4x slower and
# non-monotonically (kernel_bench.py qps_monotone contract).  Overridable
# at import via CAMASIM_BCAST_BUDGET_BYTES (or at runtime via
# set_kernel_model / sim.bcast_budget_bytes).
BCAST_BUDGET_BYTES = int(float(
    os.environ.get("CAMASIM_BCAST_BUDGET_BYTES", 24 * 1024 * 1024)))

# Interpret-mode grids pay per-step dispatch overhead; below this batch size
# the identical jnp tile math wins (BENCH: kernel_acam_range_q1 at 0.18x).
SMALL_Q_CROSSOVER = 4

# The power-of-two Q-tile ladder (what SimConfig.q_tile validates against).
Q_TILES = (1, 2, 4, 8, 16, 32, 64, 128, 256)


def set_kernel_model(step_overhead_s: Optional[float] = None,
                     bcast_budget_bytes: Optional[int] = None) -> None:
    """Override the measured-model constants at runtime.

    ``None`` leaves a constant untouched.  The constants only RANK
    ladder rungs; re-fit them on new hardware with
    ``benchmarks/calibrate_kernel_model.py`` and pin the results via the
    ``CAMASIM_STEP_OVERHEAD_S`` / ``CAMASIM_BCAST_BUDGET_BYTES``
    environment variables or the ``sim.step_overhead_s`` /
    ``sim.bcast_budget_bytes`` config fields (which call this).
    """
    global STEP_OVERHEAD_S, BCAST_BUDGET_BYTES
    if step_overhead_s is not None:
        if step_overhead_s <= 0:
            raise ValueError("step_overhead_s must be > 0")
        STEP_OVERHEAD_S = float(step_overhead_s)
    if bcast_budget_bytes is not None:
        if bcast_budget_bytes <= 0:
            raise ValueError("bcast_budget_bytes must be > 0")
        BCAST_BUDGET_BYTES = int(bcast_budget_bytes)


def kernel_model() -> dict:
    """The active measured-model constants (after env/config overrides)."""
    return {"step_overhead_s": STEP_OVERHEAD_S,
            "bcast_budget_bytes": BCAST_BUDGET_BYTES,
            "hbm_bytes_per_s": HBM_BYTES_PER_S}


def default_q_tile(rows: int, cols: int, planes: int = 1, *,
                   budget_bytes: int = VMEM_BUDGET_BYTES) -> int:
    """Default fused-kernel Q-tile from the VMEM working-set formula.

    The module docstring's per-step working set is
    4·(planes·R·C + Qt·C + C + Qt·R) bytes (f32), and past the point where
    the (Qt, C) query tile / (Qt, R) output tile approach the stored tile
    in size the kernel stops being stored-stream-bound — so the tile is
    sized to the stored planes (``stream``), clamped to what the budget
    allows (``cap``), floored at 8 (sublane granularity) and capped at 256,
    then rounded down to a power of two for friendly grid divisions.
    ``planes`` is 1 for point-code grids, 2 for ACAM [lo, hi] grids.

    This is the UNPIPELINED drivers' formula (the ``pipeline=False``
    off-switch keeps it so that path stays bit- and schedule-identical to
    the historical kernels); the pipelined drivers use the measured-model
    ``choose_q_tile`` hook instead.
    """
    words = budget_bytes // 4
    stream = (planes * rows * cols) // (rows + cols)
    cap = (words - planes * rows * cols - cols) // (rows + cols)
    qt = min(max(stream, 8), max(cap, 1), 256)
    return max(1, 1 << (int(qt).bit_length() - 1))


def resident_banks(banks: int, segs: int, rows: int, cols: int,
                   planes: int = 1, *, itemsize: int = 4,
                   budget_bytes: int = RESIDENT_BUDGET_BYTES) -> int:
    """Bank-block size for the pipelined driver's VMEM-resident fast path.

    Returns the largest divisor ``vb`` of ``banks`` whose
    (vb, segs, rows, cols) stored planes fit the resident half of the
    budget (the other half holds the double-buffered query/output tiles).
    ``vb == banks`` means the WHOLE store stays on-chip and is streamed
    from HBM once total — no re-stream per Q-tile; smaller ``vb`` still
    streams the store exactly once per batch (block axis outermost) while
    Pallas prefetches the next bank block during the current block's
    distance math.  0 = not even one bank fits; the caller falls back to
    the per-(R, C)-tile grid.
    """
    half = budget_bytes // 2
    per_bank = planes * segs * rows * cols * itemsize
    if per_bank <= 0 or per_bank > half or banks < 1:
        return 0
    return max(v for v in range(1, banks + 1)
               if banks % v == 0 and v * per_bank <= half)


def choose_q_tile(rows: int, cols: int, planes: int = 1, *, banks: int = 1,
                  segs: int = 1, want_dist: bool = True, itemsize: int = 4,
                  bcast_cols: int = 0,
                  budget_bytes: int = RESIDENT_BUDGET_BYTES,
                  hbm_bytes_per_s: float = HBM_BYTES_PER_S,
                  step_overhead_s: Optional[float] = None) -> int:
    """Measured-model Q-tile autotune hook for the pipelined drivers.

    Walks the power-of-two ladder and scores every rung with the same
    HBM-traffic proxy ``plan.autotune.simulated_qps`` bills (stored-plane
    stream + query stream + output write-back over a nominal bandwidth)
    PLUS a per-grid-step dispatch term — the cost interpret mode actually
    pays and the fixed formula ignored; ``benchmarks/kernel_bench.py``
    validates the ranking against wall clock.  Rungs whose working set
    (resident bank block + query tile + output tile) blows the budget are
    infeasible.  The choice is per GEOMETRY, not per batch: the runtime
    clamp ``qt = min(qt, Q)`` then makes per-call fixed overhead amortize
    monotonically in Q (larger batches reuse the same block schedule over
    more queries, which is the monotone-qps contract the Q-sweep rows
    assert).

    ``bcast_cols`` declares the lane width of the per-step VPU broadcast
    block for no-matmul distances (0 = no block: l2/dot run on the MXU and
    packed hamming reduces (Qt, R, W) with W = C/32 words).  When nonzero,
    rungs whose (qt, bank-block rows, bcast_cols) compare block blows
    ``BCAST_BUDGET_BYTES`` are infeasible — the block dwarfs every streamed
    operand and growing it past the cache cliff is what made large-Q
    batches SLOWER per query (the throughput collapse this driver fixes).
    """
    if step_overhead_s is None:     # resolve at call time, not def time,
        step_overhead_s = STEP_OVERHEAD_S   # so set_kernel_model applies
    vb = resident_banks(banks, segs, rows, cols, planes, itemsize=itemsize,
                        budget_bytes=budget_bytes)
    out_planes = 2 if want_dist else 1
    stored = float(planes * banks * segs * rows * cols * itemsize)
    Q = 256.0          # reference batch: the ladder's top rung
    best, best_t = 1, None
    for qt in Q_TILES:
        nq = -(-int(Q) // qt)
        if vb:
            blocks = banks // vb
            block_bytes = (planes * vb * segs * rows * cols * itemsize
                           + qt * segs * cols * itemsize
                           + qt * vb * segs * rows * 4 * out_planes)
            bcast_bytes = 4 * qt * vb * segs * rows * bcast_cols
            steps = blocks * nq
            stream = stored                       # store on-chip once
            q_bytes = itemsize * Q * segs * cols * blocks
        else:
            block_bytes = (planes * rows * cols * itemsize
                           + qt * cols * itemsize + qt * rows * 4 * out_planes)
            bcast_bytes = 4 * qt * rows * bcast_cols
            steps = banks * segs * nq
            stream = stored * nq                  # re-streamed per Q-tile
            q_bytes = itemsize * Q * segs * cols * banks
        if block_bytes > budget_bytes or bcast_bytes > BCAST_BUDGET_BYTES:
            continue
        out_bytes = 4.0 * Q * banks * segs * rows * out_planes
        t = ((stream + q_bytes + out_bytes) / hbm_bytes_per_s
             + steps * step_overhead_s)
        if best_t is None or t < best_t:
            best, best_t = qt, t
    return best


def _dist_block(stored, q, valid, distance: str):
    if distance == "hamming":
        d = (stored != q).astype(jnp.float32)
    elif distance == "l1":
        d = jnp.abs(stored - q)
    elif distance == "l2":
        d = jnp.square(stored - q)
    elif distance == "dot":
        d = -(stored * q)
    else:
        raise ValueError(distance)
    return jnp.sum(d * valid, axis=-1)


def _kernel(stored_ref, query_ref, valid_ref, out_ref, *, distance: str):
    stored = stored_ref[0, 0]          # (R, C)
    q = query_ref[0]                   # (C,)
    valid = valid_ref[0]               # (C,)
    out_ref[0, 0] = _dist_block(stored, q[None, :], valid[None, :], distance)


@functools.partial(jax.jit,
                   static_argnames=("distance", "interpret"))
def cam_search_pallas(stored: jax.Array, query: jax.Array,
                      col_valid: jax.Array, *, distance: str = "l2",
                      interpret: bool = False) -> jax.Array:
    """stored (nv, nh, R, C), query (nh, C), col_valid (nh, C)
    -> dist (nv, nh, R)."""
    nv, nh, R, C = stored.shape
    assert query.shape == (nh, C), (query.shape, (nh, C))
    grid = (nv, nh)
    return pl.pallas_call(
        functools.partial(_kernel, distance=distance),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, R, C), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, C), lambda i, j: (j, 0)),
            pl.BlockSpec((1, C), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((nv, nh, R), jnp.float32),
        interpret=interpret,
    )(stored.astype(jnp.float32), query.astype(jnp.float32),
      col_valid.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Query-batched kernel
# ---------------------------------------------------------------------------
def packed_hamming_block(stored, q) -> jax.Array:
    """stored (R, W) uint32, q (Qt, W) uint32 -> XOR+popcount (Qt, R) int32.

    The bit-packed TCAM match line (``kernels.hamming_pack``) as a tile
    function: don't-care/padded columns are zeroed in BOTH operands at pack
    time (``ops.pack_bits``), so XOR contributes nothing there and the
    count equals the col_valid-masked unpacked hamming distance exactly.
    """
    x = jnp.bitwise_xor(stored[None, :, :], q[:, None, :])
    return jnp.sum(jax.lax.population_count(x), axis=-1, dtype=jnp.int32)


def _dist_block_batched(stored, q, valid, distance: str) -> jax.Array:
    """stored (R, C), q (Qt, C), valid (C,) -> dist (Qt, R).

    Integer dtypes select the exact quantized-code fast paths (only safe —
    and only requested by ``ops._fused_call`` — when the grid holds
    noise-free integral codes): uint32 operands are bit-packed 1-bit codes
    (XOR + popcount, ``valid`` already folded in at pack time), int8/int16
    operands run the distances on narrow integers — on TPU the l2/dot
    cross term becomes an int8 MXU matmul at a quarter of the f32 HBM
    bandwidth.  Every int path produces the same f32 values as the float
    path: all products/sums are exact small integers.
    """
    if stored.dtype == jnp.uint32 and distance == "hamming":
        return packed_hamming_block(stored, q).astype(jnp.float32)
    integer = jnp.issubdtype(stored.dtype, jnp.integer)
    if distance in ("l2", "dot"):
        # MXU formulation: fold the column mask into one operand so the
        # cross term is a plain (Qt, C) x (C, R) matmul.
        qv = q * (valid.astype(q.dtype)[None, :] if integer
                  else valid[None, :])
        cross = jax.lax.dot_general(
            qv, stored, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)         # (Qt, R)
        if distance == "dot":
            return -cross
        if integer:
            sf = stored.astype(jnp.float32)
            qf = q.astype(jnp.float32)
            sn = jnp.sum(sf * sf * valid[None, :], axis=-1)
            qn = jnp.sum(qf * qf * valid[None, :], axis=-1)
        else:
            sn = jnp.sum(stored * stored * valid[None, :], axis=-1)  # (R,)
            qn = jnp.sum(q * qv, axis=-1)                            # (Qt,)
        return sn[None, :] - 2.0 * cross + qn[:, None]
    # VPU broadcast path: (Qt, R, C) block in registers.
    s = stored[None, :, :]
    qq = q[:, None, :]
    if distance == "hamming":
        d = (s != qq).astype(jnp.float32)
    elif distance == "l1":
        d = jnp.abs(s - qq)
    else:
        raise ValueError(distance)
    return jnp.sum(d * valid[None, None, :], axis=-1)


def _batched_kernel(stored_ref, query_ref, valid_ref, out_ref, *,
                    distance: str):
    stored = stored_ref[0, 0]            # (R, C)
    q = query_ref[:, 0, :]               # (Qt, C)
    valid = valid_ref[0]                 # (C,)
    out_ref[:, 0, 0, :] = _dist_block_batched(stored, q, valid, distance)


def _block_batched_kernel(stored_ref, query_ref, valid_ref, out_ref, *,
                          distance: str):
    """Bank-blocked variant of ``_batched_kernel``: stored (vb, nh, R, C)
    resident across the inner Q-tile axis, q (qt, nh, C), valid (nh, C),
    out (qt, vb, nh, R).  Same tile function vmapped over (nh, vb)."""
    stored = stored_ref[...]
    q = query_ref[...]
    valid = valid_ref[...]
    per_seg = jax.vmap(
        lambda s, qseg, v: _dist_block_batched(s, qseg, v, distance),
        in_axes=(0, 1, 0), out_axes=1)                    # over nh
    per_bank = jax.vmap(lambda s: per_seg(s, q, valid),
                        in_axes=0, out_axes=1)            # over vb
    out_ref[...] = per_bank(stored)


@functools.partial(jax.jit,
                   static_argnames=("distance", "q_tile", "interpret",
                                    "pipeline"))
def cam_search_batched_pallas(stored: jax.Array, queries: jax.Array,
                              col_valid: jax.Array, *,
                              distance: str = "l2",
                              q_tile: Optional[int] = None,
                              interpret: bool = False,
                              pipeline: bool = True) -> jax.Array:
    """stored (nv, nh, R, C), queries (Q, nh, C), col_valid (nh, C)
    -> dist (Q, nv, nh, R).

    The stored grid is streamed from HBM once for the whole query batch
    (Q-tile axis innermost; see module docstring for the block layout).
    ``pipeline=True`` upgrades that to the bank-blocked double-buffered
    schedule when ``resident_banks`` finds a block size: grid
    (nv/vb, Q/Qt), each stored byte crosses HBM once per batch instead of
    once per Q-tile, and ``q_tile=None`` is chosen per geometry by
    ``choose_q_tile``.  ``pipeline=False`` keeps the historical per-tile
    grid with ``default_q_tile`` (bit- and schedule-identical off-switch).
    """
    nv, nh, R, C = stored.shape
    Q = queries.shape[0]
    assert queries.shape == (Q, nh, C), (queries.shape, (Q, nh, C))
    cdt = _content_dtype((stored,))
    vb = (resident_banks(nv, nh, R, C, 1, itemsize=cdt.itemsize)
          if pipeline else 0)
    if q_tile is None:
        if pipeline:
            bcast = 0 if distance in ("l2", "dot") else C
            q_tile = choose_q_tile(R, C, 1, banks=nv, segs=nh,
                                   want_dist=False, itemsize=cdt.itemsize,
                                   bcast_cols=bcast)
        else:
            q_tile = default_q_tile(R, C)
    qt = max(1, min(q_tile, Q))
    pad = (-Q) % qt
    if pad:
        queries = jnp.pad(queries, ((0, pad), (0, 0), (0, 0)))
    nq = (Q + pad) // qt
    operands = (stored.astype(cdt), queries.astype(cdt),
                col_valid.astype(jnp.float32))
    out_shape = jax.ShapeDtypeStruct((Q + pad, nv, nh, R), jnp.float32)
    if vb:
        out = pl.pallas_call(
            functools.partial(_block_batched_kernel, distance=distance),
            grid=(nv // vb, nq),
            in_specs=[
                pl.BlockSpec((vb, nh, R, C), lambda b, k: (b, 0, 0, 0)),
                pl.BlockSpec((qt, nh, C), lambda b, k: (k, 0, 0)),
                pl.BlockSpec((nh, C), lambda b, k: (0, 0)),
            ],
            out_specs=pl.BlockSpec((qt, vb, nh, R),
                                   lambda b, k: (k, b, 0, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(*operands)
    else:
        out = pl.pallas_call(
            functools.partial(_batched_kernel, distance=distance),
            grid=(nv, nh, nq),
            in_specs=[
                pl.BlockSpec((1, 1, R, C), lambda i, j, k: (i, j, 0, 0)),
                pl.BlockSpec((qt, 1, C), lambda i, j, k: (k, j, 0)),
                pl.BlockSpec((1, C), lambda i, j, k: (j, 0)),
            ],
            out_specs=pl.BlockSpec((qt, 1, 1, R),
                                   lambda i, j, k: (k, i, j, 0)),
            out_shape=out_shape,
            interpret=interpret,
        )(*operands)
    return out[:Q]


# ---------------------------------------------------------------------------
# Batched search with fused sense-and-reduce epilogue
# ---------------------------------------------------------------------------
def _sense_block(d: jax.Array, rv: jax.Array, sensing: str,
                 sensing_limit: float, threshold: float) -> jax.Array:
    """d (Qt, R) distances (inf on invalid rows), rv (R,) -> match (Qt, R)."""
    if sensing == "exact":
        m = d <= sensing_limit
    elif sensing == "best":
        # intra-subarray winner-take-all: min over the R match lines while
        # the distance block is still in VMEM
        m = d <= (jnp.min(d, axis=-1, keepdims=True) + sensing_limit)
    elif sensing == "threshold":
        m = d <= (threshold + sensing_limit)
    else:
        raise ValueError(sensing)
    return m.astype(jnp.float32) * rv[None, :]


def _fused_epilogue(d, rv, out_refs, *, sensing: str, sensing_limit: float,
                    threshold: float, want_dist: bool):
    """Shared kernel epilogue: padding-row inf mask, sense, write-back."""
    d = jnp.where(rv[None, :] > 0, d, _INF)   # padding rows never win
    m = _sense_block(d, rv, sensing, sensing_limit, threshold)
    if want_dist:
        out_refs[0][:, 0, 0, :] = d
        out_refs[1][:, 0, 0, :] = m
    else:
        out_refs[0][:, 0, 0, :] = m


def _fused_kernel(stored_ref, query_ref, valid_ref, rowv_ref, *out_refs,
                  distance: str, sensing: str, sensing_limit: float,
                  threshold: float, want_dist: bool):
    d = _dist_block_batched(stored_ref[0, 0], query_ref[:, 0, :],
                            valid_ref[0], distance)
    _fused_epilogue(d, rowv_ref[0], out_refs, sensing=sensing,
                    sensing_limit=sensing_limit, threshold=threshold,
                    want_dist=want_dist)


def _tile_fused(tile_planes, qseg, valid, rowv, *, distance: str,
                sensing: str, sensing_limit: float, threshold: float):
    """One (R, C) tile end-to-end: distance, padding-row inf mask, sense.
    Shared verbatim by the bank-blocked kernel body and the jnp reference
    twin — the bit-identity of the pipelined path is by construction."""
    if distance == "range":
        d = _range_block_batched(tile_planes[0], tile_planes[1], qseg, valid)
    else:
        d = _dist_block_batched(tile_planes[0], qseg, valid, distance)
    d = jnp.where(rowv[None, :] > 0, d, _INF)
    m = _sense_block(d, rowv, sensing, sensing_limit, threshold)
    return d, m


def _block_fused_kernel(*refs, n_planes: int, distance: str, sensing: str,
                        sensing_limit: float, threshold: float,
                        want_dist: bool):
    """Bank-blocked pipelined kernel body.

    Per grid step (b, k) the refs hold a whole (vb, nh, R, C) bank block
    per stored plane (resident across the inner Q-tile axis; Pallas
    double-buffers the NEXT block's HBM fetch while this one computes), a
    (qt, nh, C) query tile, (nh, C) col_valid, (vb, R) row_valid, and
    (qt, vb, nh, R) out tiles.  The body vmaps the same per-tile function
    as ``cam_fused_reference`` over (nh, vb)."""
    plane_refs = refs[:n_planes]
    query_ref, valid_ref, rowv_ref = refs[n_planes:n_planes + 3]
    out_refs = refs[n_planes + 3:]
    planes = tuple(r[...] for r in plane_refs)            # (vb, nh, R, C)
    q = query_ref[...]                                    # (qt, nh, C)
    cv = valid_ref[...]                                   # (nh, C)
    rv = rowv_ref[...]                                    # (vb, R)
    tile = functools.partial(_tile_fused, distance=distance, sensing=sensing,
                             sensing_limit=sensing_limit, threshold=threshold)
    per_seg = jax.vmap(tile, in_axes=((0,) * n_planes, 1, 0, None),
                       out_axes=(1, 1))                   # over nh
    per_bank = jax.vmap(lambda tp, rowv: per_seg(tp, q, cv, rowv),
                        in_axes=((0,) * n_planes, 0),
                        out_axes=(1, 1))                  # over vb
    d, m = per_bank(planes, rv)                           # (qt, vb, nh, R)
    if want_dist:
        out_refs[0][...] = d
        out_refs[1][...] = m
    else:
        out_refs[0][...] = m


def _content_dtype(stored_planes):
    """Kernel compute dtype: integer planes (the quantized-code / packed
    fast paths) keep their dtype; everything else runs the historical f32."""
    cdt = stored_planes[0].dtype
    if not jnp.issubdtype(cdt, jnp.integer):
        cdt = jnp.dtype(jnp.float32)
    return jnp.dtype(cdt)


def _fused_driver(stored_planes, queries: jax.Array,
                  col_valid: jax.Array, row_valid: jax.Array, *,
                  distance: str, sensing: str, sensing_limit: float,
                  threshold: float, q_tile: Optional[int], want_dist: bool,
                  interpret: bool, pipeline: bool):
    """Shared scaffolding for the fused batched kernels (point-code grids
    pass ``stored_planes=(stored,)`` with a real distance; ACAM range grids
    pass ``(lo, hi)`` with ``distance='range'``).

    ``pipeline=True`` (the default) runs the double-buffered bank-blocked
    schedule when ``resident_banks`` finds a block size: grid
    (nv/vb, Q/Qt) with the Q-tile axis innermost and a (vb, nh, R, C)
    stored BlockSpec indexed by the block axis alone — each stored byte
    crosses HBM once per BATCH (not once per Q-tile), Pallas prefetches
    block b+1 while block b computes, and ``vb == nv`` is the VMEM-resident
    fast path (whole store on-chip, grid (1, Q/Qt)).  ``q_tile=None`` is
    chosen per geometry by the measured-model ``choose_q_tile``.

    ``pipeline=False`` is the bit- and schedule-identical off-switch: the
    historical (nv, nh, Q/Qt) per-tile grid with ``default_q_tile``.
    Both paths compute identical tile math — the block body vmaps the same
    tile functions the per-tile bodies call."""
    nv, nh, R, C = stored_planes[0].shape
    Q = queries.shape[0]
    n_planes = len(stored_planes)
    assert queries.shape == (Q, nh, C), (queries.shape, (Q, nh, C))
    assert row_valid.shape == (nv, R), (row_valid.shape, (nv, R))
    cdt = _content_dtype(stored_planes)
    vb = (resident_banks(nv, nh, R, C, n_planes, itemsize=cdt.itemsize)
          if pipeline else 0)
    if q_tile is None:
        if pipeline:
            # l2/dot take the MXU matmul form; everything else broadcasts a
            # (Qt, rows, C) compare block on the VPU (for packed hamming C
            # is already the packed word width, so the cap never binds)
            bcast = 0 if distance in ("l2", "dot") else C
            q_tile = choose_q_tile(R, C, n_planes, banks=nv, segs=nh,
                                   want_dist=want_dist,
                                   itemsize=cdt.itemsize, bcast_cols=bcast)
        else:
            q_tile = default_q_tile(R, C, n_planes)
    qt = max(1, min(q_tile, Q))
    pad = (-Q) % qt
    if pad:
        queries = jnp.pad(queries, ((0, pad), (0, 0), (0, 0)))
    nq = (Q + pad) // qt
    shape = jax.ShapeDtypeStruct((Q + pad, nv, nh, R), jnp.float32)
    planes = tuple(p.astype(cdt) for p in stored_planes)
    qs = queries.astype(cdt)
    cv = col_valid.astype(jnp.float32)
    rv = row_valid.astype(jnp.float32)
    if vb:
        body = functools.partial(
            _block_fused_kernel, n_planes=n_planes, distance=distance,
            sensing=sensing, sensing_limit=sensing_limit,
            threshold=threshold, want_dist=want_dist)
        spec = pl.BlockSpec((qt, vb, nh, R), lambda b, k: (k, b, 0, 0))
        stored_spec = pl.BlockSpec((vb, nh, R, C), lambda b, k: (b, 0, 0, 0))
        out = pl.pallas_call(
            body,
            grid=(nv // vb, nq),
            in_specs=[stored_spec] * n_planes + [
                pl.BlockSpec((qt, nh, C), lambda b, k: (k, 0, 0)),
                pl.BlockSpec((nh, C), lambda b, k: (0, 0)),
                pl.BlockSpec((vb, R), lambda b, k: (b, 0)),
            ],
            out_specs=(spec, spec) if want_dist else spec,
            out_shape=(shape, shape) if want_dist else shape,
            interpret=interpret,
        )(*planes, qs, cv, rv)
    else:
        if distance == "range":
            body = functools.partial(
                _range_fused_kernel, sensing=sensing,
                sensing_limit=sensing_limit, threshold=threshold,
                want_dist=want_dist)
        else:
            body = functools.partial(
                _fused_kernel, distance=distance, sensing=sensing,
                sensing_limit=sensing_limit, threshold=threshold,
                want_dist=want_dist)
        spec = pl.BlockSpec((qt, 1, 1, R), lambda i, j, k: (k, i, j, 0))
        stored_spec = pl.BlockSpec((1, 1, R, C),
                                   lambda i, j, k: (i, j, 0, 0))
        out = pl.pallas_call(
            body,
            grid=(nv, nh, nq),
            in_specs=[stored_spec] * n_planes + [
                pl.BlockSpec((qt, 1, C), lambda i, j, k: (k, j, 0)),
                pl.BlockSpec((1, C), lambda i, j, k: (j, 0)),
                pl.BlockSpec((1, R), lambda i, j, k: (i, 0)),
            ],
            out_specs=(spec, spec) if want_dist else spec,
            out_shape=(shape, shape) if want_dist else shape,
            interpret=interpret,
        )(*planes, qs, cv, rv)
    if want_dist:
        return out[0][:Q], out[1][:Q]
    return out[:Q]


@functools.partial(jax.jit,
                   static_argnames=("distance", "sensing", "sensing_limit",
                                    "threshold", "q_tile", "want_dist",
                                    "interpret", "pipeline"))
def cam_search_fused_pallas(stored: jax.Array, queries: jax.Array,
                            col_valid: jax.Array, row_valid: jax.Array, *,
                            distance: str = "l2", sensing: str = "best",
                            sensing_limit: float = 0.0,
                            threshold: float = 0.0,
                            q_tile: Optional[int] = None,
                            want_dist: bool = True,
                            interpret: bool = False,
                            pipeline: bool = True):
    """Batched search + in-kernel sense amplifier.

    stored (nv, nh, R, C), queries (Q, nh, C), col_valid (nh, C),
    row_valid (nv, R).

    Returns ``(dist, match)`` each (Q, nv, nh, R) — or ``match`` alone when
    ``want_dist=False``, in which case the float distance tensor is never
    written to HBM (exact/threshold AND-merge path).  Distances on padding
    rows are +inf, matching ``core.subarray.subarray_query``.

    ``pipeline=True`` selects the bank-blocked double-buffered schedule
    (see ``_fused_driver``); ``pipeline=False`` is the bit- and
    schedule-identical historical per-tile grid.
    """
    return _fused_driver((stored,), queries, col_valid, row_valid,
                         distance=distance, sensing=sensing,
                         sensing_limit=float(sensing_limit),
                         threshold=float(threshold),
                         q_tile=q_tile, want_dist=want_dist,
                         interpret=interpret, pipeline=pipeline)


# ---------------------------------------------------------------------------
# ACAM range match with fused sense-and-reduce epilogue
# ---------------------------------------------------------------------------
def _range_block_batched(lo, hi, q, valid) -> jax.Array:
    """lo/hi (R, C), q (Qt, C), valid (C,) -> violation counts (Qt, R).

    A cell votes a violation when the query value falls outside its stored
    closed interval [lo, hi]; padded columns are masked out.  Counts are
    small integers in f32, so the sum is exact in any reduction order."""
    qq = q[:, None, :]                                   # (Qt, 1, C)
    viol = ((qq < lo[None, :, :]) | (qq > hi[None, :, :])
            ).astype(jnp.float32)
    return jnp.sum(viol * valid[None, None, :], axis=-1)


def _range_fused_kernel(lo_ref, hi_ref, query_ref, valid_ref, rowv_ref,
                        *out_refs, sensing: str, sensing_limit: float,
                        threshold: float, want_dist: bool):
    d = _range_block_batched(lo_ref[0, 0], hi_ref[0, 0], query_ref[:, 0, :],
                             valid_ref[0])
    _fused_epilogue(d, rowv_ref[0], out_refs, sensing=sensing,
                    sensing_limit=sensing_limit, threshold=threshold,
                    want_dist=want_dist)


@functools.partial(jax.jit,
                   static_argnames=("sensing", "sensing_limit", "threshold",
                                    "q_tile", "want_dist", "interpret",
                                    "pipeline"))
def cam_range_fused_pallas(stored_lo: jax.Array, stored_hi: jax.Array,
                           queries: jax.Array, col_valid: jax.Array,
                           row_valid: jax.Array, *, sensing: str = "exact",
                           sensing_limit: float = 0.0,
                           threshold: float = 0.0,
                           q_tile: Optional[int] = None,
                           want_dist: bool = True,
                           interpret: bool = False,
                           pipeline: bool = True):
    """Batched ACAM range search + in-kernel sense amplifier.

    stored_lo / stored_hi (nv, nh, R, C) — the two planes of a 5-D
    (nv, nh, R, C, 2) range grid, split by the caller so every BlockSpec
    keeps a dense lane dim; queries (Q, nh, C); col_valid (nh, C);
    row_valid (nv, R).

    Same contract as ``cam_search_fused_pallas``: returns ``(dist, match)``
    each (Q, nv, nh, R) — dist is the range-violation count, +inf on
    padding rows — or ``match`` alone when ``want_dist=False`` (the count
    tensor then never hits HBM; the ACAM exact-match AND-merge path).
    The grid is (nv, nh, Q/Qt) with the Q-tile innermost, so both stored
    planes are streamed from HBM once per query batch.
    """
    assert stored_hi.shape == stored_lo.shape, (stored_hi.shape,
                                                stored_lo.shape)
    return _fused_driver((stored_lo, stored_hi), queries, col_valid,
                         row_valid, distance="range", sensing=sensing,
                         sensing_limit=float(sensing_limit),
                         threshold=float(threshold),
                         q_tile=q_tile, want_dist=want_dist,
                         interpret=interpret, pipeline=pipeline)


# ---------------------------------------------------------------------------
# jnp twin of the fused kernels (small-batch interpret-mode dispatch target)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit,
                   static_argnames=("distance", "sensing", "sensing_limit",
                                    "threshold", "want_dist"))
def cam_fused_reference(stored_planes, queries: jax.Array,
                        col_valid: jax.Array, row_valid: jax.Array, *,
                        distance: str, sensing: str,
                        sensing_limit: float = 0.0, threshold: float = 0.0,
                        want_dist: bool = True):
    """Pure-jnp twin of ``cam_search_fused_pallas`` / ``cam_range_fused_
    pallas``, built from the SAME per-tile functions the kernel bodies call
    (``_dist_block_batched`` / ``_range_block_batched`` / ``_sense_block``)
    vmapped over the (nv, nh) grid — so its results are the kernels', by
    construction.  ``ops._fused_call`` dispatches here for interpret-mode
    batches below ``SMALL_Q_CROSSOVER``, where per-grid-step emulation
    overhead dominates (BENCH: kernel_acam_range_q1 ran at 0.18x of jnp).

    ``stored_planes``: (stored,) point grids or (lo, hi) for
    ``distance='range'``, each (nv, nh, R, C); same outputs as the kernels.
    """
    cdt = _content_dtype(stored_planes)
    planes = tuple(p.astype(cdt) for p in stored_planes)
    n_planes = len(planes)
    q = queries.astype(cdt)
    cv = col_valid.astype(jnp.float32)
    rv = row_valid.astype(jnp.float32)
    tile = functools.partial(_tile_fused, distance=distance, sensing=sensing,
                             sensing_limit=float(sensing_limit),
                             threshold=float(threshold))
    per_seg = jax.vmap(tile, in_axes=((0,) * n_planes, 1, 0, None),
                       out_axes=(1, 1))                  # over nh
    per_bank = jax.vmap(lambda tp, rowv: per_seg(tp, q, cv, rowv),
                        in_axes=((0,) * n_planes, 0),
                        out_axes=(1, 1))                 # over nv
    d, m = per_bank(planes, rv)                          # (Q, nv, nh, R)
    return (d, m) if want_dist else m
