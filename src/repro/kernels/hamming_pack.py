"""Pallas TPU kernel: bit-packed hamming CAM search.

TPU adaptation of the TCAM match-line wired-XNOR (DESIGN.md §2): 32 ternary
cells pack into one uint32 word; per-cell XNOR + wired-AND becomes
XOR + population-count on the VPU.  A 64-column TCAM row collapses to two
machine words, so a (R=64, C=64) subarray search is a (64, 2) uint32 tile —
a ~32x density win over the unpacked float path and the reason this kernel
exists.

Don't-care (ternary) columns are handled by masking them to zero in *both*
stored and query words at pack time (ops.pack_bits), so XOR yields 0 there.

Grid: row tiles of size ``tile_r``.
    stored (tile_r, W) uint32 VMEM
    query  (1, W)      uint32 VMEM (resident across steps)
    out    (tile_r,)   int32

``hamming_packed_batched_pallas`` batches queries the same way the float
cam_search kernel does: grid (R/tile_r, Q/q_tile) with the Q-tile axis
innermost, so each stored (tile_r, W) tile is streamed from HBM once per
query batch; the (q_tile, tile_r, W) XOR + popcount runs on the VPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cam_search import default_q_tile, packed_hamming_block


def _kernel(stored_ref, query_ref, out_ref):
    s = stored_ref[...]                       # (tile_r, W) uint32
    q = query_ref[...]                        # (1, W)
    out_ref[...] = packed_hamming_block(s, q)[0]


@functools.partial(jax.jit, static_argnames=("tile_r", "interpret"))
def hamming_packed_pallas(stored_packed: jax.Array,
                          query_packed: jax.Array, *, tile_r: int = 256,
                          interpret: bool = False) -> jax.Array:
    """stored_packed (R, W) uint32, query_packed (W,) -> dist (R,) int32."""
    R, W = stored_packed.shape
    tile_r = min(tile_r, R)
    assert R % tile_r == 0, (R, tile_r)
    return pl.pallas_call(
        _kernel,
        grid=(R // tile_r,),
        in_specs=[
            pl.BlockSpec((tile_r, W), lambda r: (r, 0)),
            pl.BlockSpec((1, W), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_r,), lambda r: (r,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.int32),
        interpret=interpret,
    )(stored_packed, query_packed[None, :])


def _batched_kernel(stored_ref, query_ref, out_ref):
    # (tile_r, W) x (q_tile, W) -> (q_tile, tile_r); the same XOR+popcount
    # tile the fused kernels' packed-hamming fast path dispatches to
    out_ref[...] = packed_hamming_block(stored_ref[...], query_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_r", "q_tile", "interpret"))
def hamming_packed_batched_pallas(stored_packed: jax.Array,
                                  queries_packed: jax.Array, *,
                                  tile_r: int = 256,
                                  q_tile: Optional[int] = None,
                                  interpret: bool = False) -> jax.Array:
    """stored (R, W) uint32, queries (Q, W) uint32 -> dist (Q, R) int32.

    ``q_tile=None`` derives the tile from the same VMEM working-set helper
    the float kernels use (``cam_search.default_q_tile`` on the row tile;
    the historical hardcoded 8 was inconsistent with the float default)."""
    R, W = stored_packed.shape
    Q = queries_packed.shape[0]
    assert queries_packed.shape == (Q, W), (queries_packed.shape, (Q, W))
    tile_r = min(tile_r, R)
    assert R % tile_r == 0, (R, tile_r)
    if q_tile is None:
        q_tile = default_q_tile(tile_r, W)
    qt = max(1, min(q_tile, Q))
    pad = (-Q) % qt
    if pad:
        queries_packed = jnp.pad(queries_packed, ((0, pad), (0, 0)))
    nq = (Q + pad) // qt
    out = pl.pallas_call(
        _batched_kernel,
        grid=(R // tile_r, nq),
        in_specs=[
            pl.BlockSpec((tile_r, W), lambda r, k: (r, 0)),
            pl.BlockSpec((qt, W), lambda r, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((qt, tile_r), lambda r, k: (k, r)),
        out_shape=jax.ShapeDtypeStruct((Q + pad, R), jnp.int32),
        interpret=interpret,
    )(stored_packed, queries_packed)
    return out[:Q]
