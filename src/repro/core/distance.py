"""Distance functions for CAM search (paper Table I/III: Hamming, L1, L2).

All distances operate on the *code domain* (possibly noisy, possibly masked
by padding) and are written to broadcast a batch of queries against a batch
of stored rows:

    stored : (..., R, C)
    query  : (..., C)      -> dist (..., R)

``valid`` masks padded columns so partitioning never changes results.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _masked(x: jax.Array, valid: jax.Array | None) -> jax.Array:
    if valid is None:
        return x
    return x * valid


def hamming(stored: jax.Array, query: jax.Array,
            valid: jax.Array | None = None) -> jax.Array:
    """#cells whose codes differ (exact cell mismatch count)."""
    diff = (stored != query[..., None, :]).astype(jnp.float32)
    return jnp.sum(_masked(diff, valid), axis=-1)


def l1(stored: jax.Array, query: jax.Array,
       valid: jax.Array | None = None) -> jax.Array:
    diff = jnp.abs(stored - query[..., None, :])
    return jnp.sum(_masked(diff, valid), axis=-1)


def l2(stored: jax.Array, query: jax.Array,
       valid: jax.Array | None = None) -> jax.Array:
    """Squared L2 (monotone in L2; what the analog ML discharge integrates)."""
    diff = jnp.square(stored - query[..., None, :])
    return jnp.sum(_masked(diff, valid), axis=-1)


def dot(stored: jax.Array, query: jax.Array,
        valid: jax.Array | None = None) -> jax.Array:
    """Negative inner product, so that smaller == more similar (beyond-paper;
    used by CAM-retrieval attention)."""
    prod = stored * query[..., None, :]
    return -jnp.sum(_masked(prod, valid), axis=-1)


def range_violations(stored: jax.Array, query: jax.Array,
                     valid: jax.Array | None = None) -> jax.Array:
    """ACAM range match: stored (..., R, C, 2) holds [lo, hi] per cell;
    distance = number of cells whose range excludes the query value
    (0 == full row match, as in X-TIME-style decision-tree inference)."""
    lo = stored[..., 0]
    hi = stored[..., 1]
    q = query[..., None, :]
    viol = ((q < lo) | (q > hi)).astype(jnp.float32)
    return jnp.sum(_masked(viol, valid), axis=-1)


DISTANCE_FNS = {
    "hamming": hamming,
    "l1": l1,
    "l2": l2,
    "dot": dot,
    "range": range_violations,
}


def get_distance(name: str):
    try:
        return DISTANCE_FNS[name]
    except KeyError:
        raise ValueError(f"unknown distance {name!r}; have {list(DISTANCE_FNS)}")
