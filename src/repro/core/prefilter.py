"""Stage-1 bank prefilter for the two-stage search cascade.

Every stored row gets a bit-packed *signature* derived from its clean code
(before D2D programming noise) by thresholding a strided subset of its
dimensions; signatures for a bank's R rows pack into an (R, W) uint32 block.
At query time the same thresholding produces a (Q, W) query signature and a
batched XOR+popcount (``ops.hamming_packed``) scores every bank as the
minimum row Hamming distance; only the ``top_p_banks`` best-scoring banks
see the exact fused kernel.

Scores are *margin-normalized* per query (each query's best bank is shifted
to margin 0) before the per-batch min-reduction so that one easy query
cannot drown out another query's only good bank.  Selected bank ids are
returned sorted ascending; with ``p = nv`` the selection is therefore
``arange(nv)`` exactly, which is what makes the p=nv cascade bit-identical
to the full scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops

from .mapping import GridSpec

# Sentinel score for invalid (padding) rows: larger than any real Hamming
# distance (signatures are at most a few thousand bits wide) while leaving
# int32 headroom for the margin subtraction.
_INVALID_SCORE = 1 << 24


def signature_positions(N: int, signature_bits: int) -> jax.Array:
    """Static column subset sampled into the signature.

    ``signature_bits=0`` (or >= N) uses every dimension — one signature bit
    per stored dim; otherwise a strided subset keeps the packed width at
    ``ceil(signature_bits / 32)`` words.
    """
    if signature_bits <= 0 or signature_bits >= N:
        return jnp.arange(N)
    return jnp.arange(signature_bits) * N // signature_bits


def signature_values(codes: jax.Array) -> jax.Array:
    """(K, N) point codes pass through; (K, N, 2) ACAM [lo, hi] ranges
    collapse to their midpoints."""
    if codes.ndim == 3:
        return (codes[..., 0] + codes[..., 1]) * 0.5
    return codes


def signature_threshold(values: jax.Array, cell_type: str,
                        data_bits: int) -> jax.Array:
    """Scalar binarization threshold in the quantized code domain.

    Binary cells store 0/1 so 0.5 splits them; MCAM codes live in
    [0, 2^bits - 1] so the level midpoint splits them; ACAM passes raw
    values through quantization, so fall back to the data mean.
    """
    if cell_type in ("bcam", "tcam"):
        return jnp.float32(0.5)
    if cell_type == "mcam":
        return jnp.float32(((1 << data_bits) - 1) / 2.0)
    return jnp.mean(values.astype(jnp.float32))


def _binarize_pack(values: jax.Array, thr: jax.Array,
                   positions: jax.Array) -> jax.Array:
    """(..., N) values -> (..., W) uint32 packed sign bits at ``positions``."""
    sel = jnp.take(values, positions, axis=-1)
    bits = (sel > thr).astype(jnp.int32)
    return kops.pack_bits(bits)


def row_signatures(values: jax.Array, thr: jax.Array, spec: GridSpec,
                   signature_bits: int) -> jax.Array:
    """(K, N) placed code values -> (nv, R, W) uint32 bank signatures.

    Padding rows pack to all-zero words; they are excluded from scoring via
    ``row_valid`` in ``bank_scores`` rather than by their signature.
    """
    pos = signature_positions(spec.N, signature_bits)
    packed = _binarize_pack(values, thr, pos)           # (K, W)
    W = packed.shape[-1]
    packed = jnp.pad(packed, ((0, spec.padded_K - spec.K), (0, 0)))
    return packed.reshape(spec.nv, spec.R, W)


def query_signatures(qcodes: jax.Array, thr: jax.Array, spec: GridSpec,
                     signature_bits: int) -> jax.Array:
    """(Q, N) quantized query codes -> (Q, W) uint32 query signatures."""
    pos = signature_positions(spec.N, signature_bits)
    return _binarize_pack(qcodes, thr, pos)


def bank_scores(sigs: jax.Array, qsig: jax.Array, row_valid: jax.Array, *,
                use_kernel: bool = True) -> jax.Array:
    """(nv, R, W) signatures x (Q, W) queries -> (Q, nv) int32 bank scores.

    A bank's score is the minimum signature Hamming distance over its valid
    rows — the bank-level lower bound the router prunes on.  Banks with no
    valid rows score ``_INVALID_SCORE``.
    """
    nv, R, W = sigs.shape
    flat = sigs.reshape(nv * R, W)
    if use_kernel:
        d = kops.hamming_packed(flat, qsig, n_valid_bits=32 * W)
    else:
        x = jnp.bitwise_xor(flat[None, :, :], qsig[:, None, :])
        d = jnp.sum(jax.lax.population_count(x), axis=-1, dtype=jnp.int32)
    d = d.reshape(-1, nv, R)
    d = jnp.where(row_valid[None] > 0, d, _INVALID_SCORE)
    return jnp.min(d, axis=-1)


def select_banks(scores: jax.Array, p: int,
                 valid: jax.Array | None = None) -> jax.Array:
    """(Q, nv) batch scores -> (p,) sorted ascending bank ids.

    Per-query margin normalization (subtract each query's best bank score)
    then a min-reduction across the batch: a bank survives if it is within
    the batch's tightest margin anywhere.  Every query's argmin bank has
    margin 0, so each query's best bank is always selected (up to ties
    beyond ``p``).  Sorted ascending so ``p = nv`` yields ``arange(nv)``.

    ``valid`` (Q,) masks batch rows out of the min-reduction entirely: a
    serve batch zero-padded to a fixed width must not let its pad queries'
    best banks claim top-p slots from real queries (a pad's margin-0 bank
    is as strong a claim as any real query's).  With every row valid the
    selection is bit-identical to ``valid=None``.
    """
    margin = scores - jnp.min(scores, axis=-1, keepdims=True)
    if valid is not None:
        margin = jnp.where(valid[:, None], margin, _INVALID_SCORE)
    batch = jnp.min(margin, axis=0)                     # (nv,)
    _, ids = jax.lax.top_k(-batch, p)
    return jnp.sort(ids).astype(jnp.int32)


def update_row_signatures(sigs: jax.Array, values: jax.Array,
                          thr: jax.Array, spec: GridSpec,
                          signature_bits: int, slots: jax.Array) -> jax.Array:
    """Incremental counterpart of ``row_signatures``: re-pack the (M, N)
    code values landing in global row ``slots`` (M,) and scatter them into
    the resident (nv, R, W) signature block.  Bit-identical to the slots'
    rows of a fresh ``row_signatures`` pass with the same threshold."""
    pos = signature_positions(spec.N, signature_bits)
    packed = _binarize_pack(values, thr, pos)           # (M, W)
    v, r = slots // spec.R, slots % spec.R
    return sigs.at[v, r].set(packed)
