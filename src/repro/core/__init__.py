"""CAMASim core — the paper's contribution, as a composable JAX library.

Functional simulator (accuracy) + performance evaluator (latency/energy/area)
for CAM-based in-memory search accelerators, configurable across the
application / architecture / circuit / device levels (paper Table III).
"""
from .camasim import CAMASim
from .config import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                     DeviceConfig)
from .functional import CAMState, FunctionalSimulator
from .perf import (MeshLink, MeshSpec, PerfResult, estimate_arch,
                   predict_search, predict_search_sharded, predict_write)
from .sharded import ShardedCAMSimulator

__all__ = [
    "CAMASim", "CAMConfig", "AppConfig", "ArchConfig", "CircuitConfig",
    "DeviceConfig", "CAMState", "FunctionalSimulator", "PerfResult",
    "MeshLink", "MeshSpec", "ShardedCAMSimulator", "estimate_arch",
    "predict_search", "predict_search_sharded", "predict_write",
]
