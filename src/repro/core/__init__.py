"""CAMASim core — the paper's contribution, as a composable JAX library.

Functional simulator (accuracy) + performance evaluator (latency/energy/area)
for CAM-based in-memory search accelerators, configurable across the
application / architecture / circuit / device levels (paper Table III).
"""
from .backend import Backend, make_backend
from .camasim import CAMASim
from .config import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                     DeviceConfig, ReliabilityConfig, SimConfig)
from .functional import CAMState, FunctionalSimulator
from .perf import (MeshLink, MeshSpec, PerfReport, PerfResult, estimate_arch,
                   predict_schedule, predict_search, predict_search_sharded,
                   predict_write)
from .reliability import ReliabilityState
from .results import SearchResult
from .sharded import ShardedCAMSimulator
from . import plan

__all__ = [
    "Backend", "CAMASim", "CAMConfig", "AppConfig", "ArchConfig",
    "CircuitConfig", "DeviceConfig", "ReliabilityConfig",
    "ReliabilityState", "SimConfig", "CAMState",
    "FunctionalSimulator", "PerfReport", "PerfResult", "SearchResult",
    "MeshLink", "MeshSpec", "ShardedCAMSimulator", "estimate_arch",
    "make_backend", "plan", "predict_schedule", "predict_search",
    "predict_search_sharded", "predict_write",
]
