"""Typed search results.

``SearchResult`` replaces the bare ``(indices, mask)`` tuples the
simulators used to return.  It still *unpacks* like that tuple
(``idx, mask = sim.query(...)``) so every existing call site keeps
working, but carries names, an optional distance tensor, and ``topk``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import jax


@dataclass
class SearchResult:
    """Result of one CAM search batch.

    indices: (Q, k) matched row indices, -1 padded (or (k,) for a single
        query).
    mask: (Q, padded_K) application-level match lines.
    dist: optional (Q, padded_K) merged distances, when the merge path
        produced them (None on match-line-only merges).
    """
    indices: jax.Array
    mask: jax.Array
    dist: Optional[jax.Array] = None

    # ------------------------------------------------- tuple compatibility
    def __iter__(self) -> Iterator[jax.Array]:
        return iter((self.indices, self.mask))

    def __len__(self) -> int:
        return 2

    def __getitem__(self, i):
        return (self.indices, self.mask)[i]

    # ------------------------------------------------------------ helpers
    def topk(self, k: int) -> jax.Array:
        """First k matched indices per query (-1 padded)."""
        if k < 0:
            raise ValueError("k must be >= 0")
        return self.indices[..., :k]

    @property
    def n_queries(self) -> int:
        return self.indices.shape[0] if self.indices.ndim > 1 else 1


# A pytree so jax.block_until_ready / device transfers / jit boundaries
# treat a result like the tuple it replaces.
jax.tree_util.register_pytree_node(
    SearchResult,
    lambda r: ((r.indices, r.mask, r.dist), None),
    lambda _, leaves: SearchResult(*leaves),
)
