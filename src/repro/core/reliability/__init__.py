"""Device reliability subsystem: fault injection + self-healing store.

``faults``     — deterministic, shard-invariant fault maps (stuck cells,
                 dead rows/columns), conductance drift, and the
                 ``ReliabilityState`` pytree the store carries.
``mitigation`` — write-verify programming, wear-aware spare selection,
                 and the scrub policy that picks the most-drifted rows.

Everything is gated on ``config.reliability.enabled``: with the section
absent or disabled, no code in this package runs and the store behaves
bit-identically to the pre-reliability simulator.
"""
from .faults import (ReliabilityState, code_ceiling, effective_grid,
                     has_cell_faults, init_state)
from .mitigation import pick_scrub_slots, plan_spares, program_rows_verified

__all__ = [
    "ReliabilityState", "code_ceiling", "effective_grid", "has_cell_faults",
    "init_state", "pick_scrub_slots", "plan_spares",
    "program_rows_verified",
]
