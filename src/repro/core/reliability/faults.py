"""Fault models: stuck cells, dead rows/columns, conductance drift.

Every fault map is a *deterministic function* of ``reliability.fault_seed``
and global cell coordinates, keyed per row SLOT with the same
``fold_in(key, slot)`` pattern the mutable store's ``d2d_fold='row'``
noise uses (``variation._row_noise``).  Because draws depend only on
global indices — never on how the nv (bank) axis happens to be split —
the functional and sharded backends derive bit-identical fault maps, and
a sharded state's padding banks simply draw extra (harmless, row-invalid)
values.

Faults live on the READ path: the stored grid always holds what
programming achieved, and ``effective_grid`` overlays what a search
actually senses — drift decay first (a function of the logical store
age), then stuck-at levels, then dead columns.  Write-verify
(``mitigation``) uses the same overlay as its readback, so a cell that
cannot hold its target is detected at program time.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..config import CAMConfig, ReliabilityConfig
from ..variation import sort_ranges

# RNG lane tags: distinct fold_in salts so each fault mechanism (and the
# verify re-draws in ``mitigation``) consumes an independent stream.
STUCK_LANE = 0x73747563      # 'stuc'
DEAD_ROW_LANE = 0x64726F77   # 'drow'
DEAD_COL_LANE = 0x64636F6C   # 'dcol'
VERIFY_LANE = 0x76726679     # 'vrfy'


@dataclass
class ReliabilityState:
    """Per-store reliability bookkeeping (a pytree; rides on CAMState).

    All (nv, R) fields are shaped like ``row_valid`` so the sharded
    backend pads and places them with the same bank sharding.
    """
    age: jax.Array       # () int32 — logical store age (serve steps)
    prog_age: jax.Array  # (nv, R) int32 — age at last programming
    writes: jax.Array    # (nv, R) int32 — cumulative programming pulses
    retired: jax.Array   # (nv, R) bool — slots taken out of service
    failed: jax.Array    # (nv, R) bool — live rows that failed verify


jax.tree_util.register_pytree_node(
    ReliabilityState,
    lambda s: ((s.age, s.prog_age, s.writes, s.retired, s.failed), None),
    lambda _, leaves: ReliabilityState(*leaves),
)


def init_state(nv: int, R: int) -> ReliabilityState:
    return ReliabilityState(
        age=jnp.zeros((), jnp.int32),
        prog_age=jnp.zeros((nv, R), jnp.int32),
        writes=jnp.zeros((nv, R), jnp.int32),
        retired=jnp.zeros((nv, R), bool),
        failed=jnp.zeros((nv, R), bool))


def has_cell_faults(rel: ReliabilityConfig) -> bool:
    return rel.stuck_frac > 0 or rel.dead_row_frac > 0


def code_ceiling(config: CAMConfig) -> float:
    """Top of the code domain — stuck-at levels land uniformly in
    [0, ceiling].  Analog cells (bits == 0) span [0, 1]."""
    bits = config.app.data_bits
    return float(2 ** bits - 1) if bits else 1.0


def fault_base_key(rel: ReliabilityConfig) -> jax.Array:
    return jax.random.PRNGKey(rel.fault_seed)


def slot_fault_maps(rel: ReliabilityConfig, slots: jax.Array,
                    seg_shape: tuple, dtype, code_hi: float):
    """Stuck/dead-row overlays for row slots ``slots`` (M,).

    Returns ``(mask, vals)`` each (M, *seg_shape): cells where ``mask``
    holds read ``vals`` regardless of what was programmed.  A dead row
    is modeled as every cell stuck at 0 (its match line never fires for
    real data).  For ACAM range grids ``seg_shape`` carries the trailing
    [lo, hi] plane axis — the two devices of a cell fail independently.
    """
    key = fault_base_key(rel)
    ks = jax.random.fold_in(key, STUCK_LANE)
    kd = jax.random.fold_in(key, DEAD_ROW_LANE)
    zero = jnp.zeros((), dtype)

    def one(s):
        km, kv = jax.random.split(jax.random.fold_in(ks, s))
        m = jax.random.uniform(km, seg_shape) < rel.stuck_frac
        v = (jax.random.uniform(kv, seg_shape) * code_hi).astype(dtype)
        dead = jax.random.uniform(jax.random.fold_in(kd, s), ()) \
            < rel.dead_row_frac
        return m | dead, jnp.where(dead, zero, v)

    return jax.vmap(one)(slots.astype(jnp.int32))


def dead_row_flags(rel: ReliabilityConfig, slots: jax.Array) -> jax.Array:
    """(M,) bool — which of the given global row slots are dead."""
    kd = jax.random.fold_in(fault_base_key(rel), DEAD_ROW_LANE)
    return jax.vmap(
        lambda s: jax.random.uniform(jax.random.fold_in(kd, s), ())
        < rel.dead_row_frac)(slots.astype(jnp.int32))


def col_fault_banks(rel: ReliabilityConfig, banks: jax.Array,
                    nh: int, C: int) -> jax.Array:
    """Dead-column masks for the given bank ids: (M, nh, C) bool.

    Folded per global (bank, horizontal-subarray) pair so any bank-axis
    split draws the same columns dead.
    """
    kc = jax.random.fold_in(fault_base_key(rel), DEAD_COL_LANE)

    def one(v):
        return jax.vmap(
            lambda h: jax.random.uniform(
                jax.random.fold_in(kc, v * nh + h), (C,))
            < rel.dead_col_frac)(jnp.arange(nh, dtype=jnp.int32))

    return jax.vmap(one)(banks.astype(jnp.int32))


def apply_read_faults(x: jax.Array, stuck_mask, stuck_vals,
                      col_dead) -> jax.Array:
    """Overlay read faults on row segments ``x`` (..., nh, C[, 2]).

    ``stuck_mask``/``stuck_vals`` broadcast against ``x`` (or None);
    ``col_dead`` is (..., nh, C) (or None) — dead columns read 0.
    """
    if stuck_mask is not None:
        x = jnp.where(stuck_mask, stuck_vals, x)
    if col_dead is not None:
        if x.ndim == col_dead.ndim + 1:      # ACAM [lo, hi] planes
            col_dead = col_dead[..., None]
        x = jnp.where(col_dead, jnp.zeros((), x.dtype), x)
    return x


def effective_grid(grid: jax.Array, rel_state: ReliabilityState,
                   config: CAMConfig) -> jax.Array:
    """What a search senses: drift decay, then stuck cells, then dead
    columns, over the full (nv, nh, R, C[, 2]) stored grid.

    Purely elementwise in global coordinates, so it commutes with any
    bank-axis sharding.  C2C sensing noise (if configured) applies on
    top of this grid downstream — stuck cells still see cycle noise, a
    deliberate simplification (the sense path, not the cell, is noisy).
    """
    rel = config.reliability
    nv, nh, R, C = grid.shape[:4]
    extra = grid.shape[4:]
    g = grid
    if rel.drift_rate > 0:
        dt = jnp.maximum(rel_state.age - rel_state.prog_age, 0)  # (nv, R)
        decay = jnp.exp(-rel.drift_rate * dt.astype(g.dtype))
        g = g * decay.reshape(nv, 1, R, *([1] * (g.ndim - 3)))
    if has_cell_faults(rel):
        slots = jnp.arange(nv * R, dtype=jnp.int32)
        m, v = slot_fault_maps(rel, slots, (nh, C, *extra), g.dtype,
                               code_ceiling(config))
        m = jnp.moveaxis(m.reshape(nv, R, nh, C, *extra), 1, 2)
        v = jnp.moveaxis(v.reshape(nv, R, nh, C, *extra), 1, 2)
        g = jnp.where(m, v, g)
    if rel.dead_col_frac > 0:
        cm = col_fault_banks(rel, jnp.arange(nv), nh, C)   # (nv, nh, C)
        cm = cm.reshape(nv, nh, 1, C, *([1] * len(extra)))
        g = jnp.where(cm, jnp.zeros((), g.dtype), g)
    if g is not grid and g.ndim == 5:
        # faults can invert a [lo, hi] pair; an inverted range matches
        # nothing, while physically the two conductances still bound an
        # interval — same rationale as variation.sort_ranges
        g = sort_ranges(g)
    return g
