"""Mitigation stack: write-verify, spare-row healing, scrub selection.

``program_rows_verified`` is the jit-side programming core shared by
fresh writes, inserts/updates, spare-row re-programming, and scrub: it
draws the legacy per-slot D2D noise as attempt 0 (so with verify off the
programmed cells are bit-identical to ``variation.apply_d2d_slots``),
reads each attempt back through the fault overlay, and re-programs only
the out-of-tolerance cells up to ``verify_retries`` times.  The attempt
counts it returns are the extra row programs the estimator bills.

``plan_spares`` / ``pick_scrub_slots`` are the host-side policies: both
operate on numpy copies of the (replicated-scalar and row-mask) state,
so the functional and sharded backends make identical decisions.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import variation
from ..config import DeviceConfig, ReliabilityConfig
from . import faults


def program_rows_verified(
        clean_segs: jax.Array, old_segs: jax.Array, slots: jax.Array, *,
        dev: DeviceConfig, rel: ReliabilityConfig, bits: int,
        key: jax.Array, col_valid: jax.Array, code_hi: float, R: int,
        live: Optional[jax.Array] = None,
        worn: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Write-verify programming of (M, nh, C[, 2]) row segments.

    ``old_segs`` holds the slots' current grid content: cells of a
    ``worn`` slot (past their write endurance) are frozen there — pulses
    still land (and are billed) but the stored value never moves.
    ``live`` masks which rows verify actually checks (free/padding rows
    are programmed exactly like the legacy path but never retried).

    Returns ``(programmed, attempts, ok)``: the final cell values to
    scatter into the grid, per-row pulse counts (attempt 0 included),
    and whether every live checked cell ended within ``verify_tol`` of
    its target.
    """
    M = clean_segs.shape[0]
    is_range = clean_segs.ndim == 4
    seg_shape = clean_segs.shape[1:]
    noisy_write = dev.variation in ("d2d", "both")
    nh, C = col_valid.shape

    if faults.has_cell_faults(rel):
        sm, sv = faults.slot_fault_maps(rel, slots, seg_shape,
                                        clean_segs.dtype, code_hi)
    else:
        sm = jnp.zeros((M, *seg_shape), bool)
        sv = jnp.zeros((M, *seg_shape), clean_segs.dtype)
    if rel.dead_col_frac > 0:
        cd = faults.col_fault_banks(rel, slots // R, nh, C)
    else:
        cd = jnp.zeros((M, nh, C), bool)
    cv = col_valid > 0
    if is_range:
        cv = cv[..., None]
    if live is None:
        live = jnp.ones((M,), bool)
    if worn is None:
        worn = jnp.zeros((M,), bool)

    def one(s, seg, old, sm_i, sv_i, cd_i, live_i, worn_i):
        def attempt(k):
            cand = (variation._row_noise(seg, dev, bits, k, s)
                    if noisy_write else seg)
            return jnp.where(worn_i, old, cand)

        # verify compares interval endpoints for ranges (sorted on both
        # sides), through the same read-fault overlay a search sees
        tgt = jnp.sort(seg, -1) if is_range else seg

        def bad_of(x):
            rb = faults.apply_read_faults(
                jnp.sort(x, -1) if is_range else x, sm_i, sv_i, cd_i)
            return (jnp.abs(rb - tgt) > rel.verify_tol) & cv & live_i

        cur = attempt(key)          # attempt 0 == the legacy slot draw
        bad = bad_of(cur)
        attempts = jnp.ones((), jnp.int32)
        for a in range(1, rel.verify_retries + 1):
            retried = bad.any()
            redraw = attempt(jax.random.fold_in(key,
                                                faults.VERIFY_LANE + a))
            cur = jnp.where(bad, redraw, cur)
            attempts = attempts + retried.astype(jnp.int32)
            bad = bad_of(cur)
        return cur, attempts, ~bad.any()

    prog, attempts, ok = jax.vmap(one)(
        slots.astype(jnp.int32), clean_segs, old_segs, sm, sv, cd,
        live, worn)
    prog = variation._maybe_sort_ranges(prog, is_range and noisy_write)
    return prog, attempts, ok


def plan_spares(rv: np.ndarray, failed: np.ndarray, retired: np.ndarray,
                writes: np.ndarray, R: int, spares_per_bank: int
                ) -> Tuple[list, list]:
    """Spare-row remap plan: for each live failed slot, pick a free
    non-retired slot in the SAME bank (hardware spare wordlines are
    bank-local, and staying in-bank preserves IVF cluster placement),
    least-worn first.  A bank stops donating once ``spares_per_bank``
    of its slots are retired.

    All inputs are flat (padded_K,) numpy views; returns ``(src, dst)``
    slot lists (possibly empty).  Deterministic: iteration is in
    ascending failed-slot order with stable least-worn tie-breaks.
    """
    rv = rv.copy()
    retired = retired.copy()
    src, dst = [], []
    for j in np.where((rv > 0) & failed)[0]:
        v = int(j) // R
        bank = np.arange(v * R, min((v + 1) * R, rv.size))
        if int(retired[bank].sum()) >= spares_per_bank:
            continue
        cand = bank[(rv[bank] == 0) & ~retired[bank]]
        if cand.size == 0:
            continue
        pick = int(cand[np.argsort(writes[cand], kind="stable")][0])
        src.append(int(j))
        dst.append(pick)
        retired[j] = True
        rv[j] = 0.0
        rv[pick] = 1.0
    return src, dst


def pick_scrub_slots(rv: np.ndarray, prog_age: np.ndarray, age: int,
                     scrub_rows: int) -> np.ndarray:
    """Scrub policy: the ``scrub_rows`` live slots with the largest
    drift age (``age - prog_age``), most-drifted first, skipping rows
    with nothing to gain (dt <= 0).  Returns ascending slot ids (the
    programming order; deterministic under stable ties)."""
    dt = np.where(rv > 0, age - prog_age, -1)
    order = np.argsort(-dt, kind="stable")[:max(scrub_rows, 0)]
    order = order[dt[order] > 0]
    return np.sort(order).astype(np.int64)
