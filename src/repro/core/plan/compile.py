"""Query-program lowering: IR -> ``Schedule`` of CAM primitive calls.

A program (``core.plan.ir``) lowers to a ``Schedule``: one or more write
placements (each a stored-row array for one ``CAMASim.write``), the query
passes that search them, and a host-side combine that folds the per-pass
match masks back into the program's semantics (bool for predicates, labels
for trees/ensembles).

Lowering shape
--------------
Predicates normalize to DNF (``ir.to_dnf``): each conjunction intersects
into one [lo, hi] box = ONE stored ACAM row; the OR across conjunctions is
the CAM's native match-line disjunction — no host work beyond "any row
matched".  Trees map leaf-per-row exactly like the hand lowering in
``examples/acam_decision_tree.py`` (that example is now a thin client of
this module, proven bit-identical to its historical hand-rolled version).
Ensembles place one row GROUP per tree; ``mapping.plan_group_offsets``
chooses the row placement, bank-aligning groups (co-fired predicates land
in the same banks, filler rows are unmatchable lo > hi boxes).  On a
point CAM (``app.distance != 'range'``) only OR-of-``Point`` programs
lower: the rows are the point values themselves.

``max_rows_per_pass`` packs groups first-fit into multiple passes when a
deployment caps resident rows; the combine then merges masks across
passes, and ``perf.predict_schedule`` bills the passes' latency/energy in
series before any write.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import mapping
from ..config import CAMConfig
from . import ir

__all__ = ["QueryPass", "Schedule", "CompiledProgram", "lower"]


@dataclass(frozen=True)
class QueryPass:
    """One write placement + query pass.

    ``stored``: the rows handed to ``CAMASim.write`` — (K, N, 2) [lo, hi]
    boxes on a range CAM, (K, N) values on a point CAM.  ``labels`` and
    ``groups`` are per-row combine metadata: the leaf label (0 for
    predicates) and the co-fired group id (tree index; -1 marks filler
    rows, which can never match and never vote).
    """
    stored: np.ndarray
    labels: np.ndarray
    groups: np.ndarray

    @property
    def rows(self) -> int:
        return self.stored.shape[0]


@dataclass(frozen=True)
class Schedule:
    """The compiled program: write placements + query passes + combine
    mode (``kind``: 'match' = boolean predicate, 'tree' = first-match
    label, 'ensemble' = per-group first-match labels, majority vote)."""
    kind: str
    passes: Tuple[QueryPass, ...]
    n_features: int
    n_groups: int
    range_mode: bool

    @property
    def total_rows(self) -> int:
        return sum(p.rows for p in self.passes)

    def pass_shapes(self) -> List[Tuple[int, int]]:
        """Per-pass (entries, dims) — the shapes ``predict_schedule``
        bills."""
        return [(p.rows, self.n_features) for p in self.passes]

    # ---------------------------------------------------------- combine
    def combine(self, masks: Sequence[np.ndarray]) -> np.ndarray:
        """Host-side combine: per-pass match masks -> program output.

        ``masks[i]`` is pass i's (Q, padded_K_i) row-match mask (the
        ``SearchResult.mask`` of that pass; padding columns past the
        pass's stored rows are ignored).  Returns bool (Q,) for 'match'
        programs, labels (Q,) otherwise.
        """
        if len(masks) != len(self.passes):
            raise ValueError(f"{len(self.passes)} passes but "
                             f"{len(masks)} masks")
        mask = np.concatenate(
            [np.asarray(m)[:, : p.rows] > 0
             for m, p in zip(masks, self.passes)], axis=1)
        labels = np.concatenate([p.labels for p in self.passes])
        groups = np.concatenate([p.groups for p in self.passes])
        real = mask & (groups >= 0)[None, :]
        if self.kind == "match":
            return real.any(axis=1)
        if self.kind == "tree":
            # first matching row, like the hand lowering's
            # labels[max(idx[:, 0], 0)]: argmax of an all-False row is 0,
            # reproducing the historical row-0 fallback
            return labels[np.argmax(real, axis=1)]
        # ensemble: each tree votes its first-matching leaf's label
        votes = np.empty((mask.shape[0], self.n_groups), np.int64)
        for g in range(self.n_groups):
            cols = np.where(groups == g)[0]
            sub = real[:, cols]
            votes[:, g] = labels[cols][np.argmax(sub, axis=1)]
        n_labels = int(labels.max()) + 1
        counts = np.zeros((mask.shape[0], n_labels), np.int64)
        for g in range(self.n_groups):
            np.add.at(counts, (np.arange(mask.shape[0]), votes[:, g]), 1)
        return counts.argmax(axis=1)   # ties -> smallest label (ir._majority)


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------
def _group_boxes(program: ir.Program, config: CAMConfig, n: int):
    """IR -> per-group row arrays + labels (range mode) or point rows."""
    range_mode = config.app.distance == "range"
    if isinstance(program, (ir.Tree, ir.Ensemble)):
        if not range_mode:
            raise ValueError(
                "tree programs need a range CAM: app.distance='range', "
                "circuit.cell_type='acam' (got "
                f"distance={config.app.distance!r})")
        trees = (program.trees if isinstance(program, ir.Ensemble)
                 else (program,))
        kind = "ensemble" if isinstance(program, ir.Ensemble) else "tree"
        groups = []
        for t in trees:
            lo = np.asarray([l.lo for l in t.leaves], np.float32)
            hi = np.asarray([l.hi for l in t.leaves], np.float32)
            labels = np.asarray([l.label for l in t.leaves], np.int64)
            groups.append((np.stack([lo, hi], axis=-1), labels))
        return kind, groups, True

    dnf = ir.to_dnf(program)
    if range_mode:
        los, his = zip(*[ir.conjunction_box(c, n) for c in dnf])
        rows = np.stack([np.asarray(los, np.float32),
                         np.asarray(his, np.float32)], axis=-1)
        return "match", [(rows, np.zeros(len(dnf), np.int64))], True
    # point CAM: every conjunction must be exactly one full-width Point
    pts = []
    for conj in dnf:
        if len(conj) != 1 or not isinstance(conj[0], ir.Point):
            raise ValueError(
                "a point CAM (app.distance != 'range') lowers only "
                "OR-of-Point programs; range/band predicates need "
                "distance='range' with cell_type='acam'")
        if len(conj[0].values) != n:
            raise ValueError(
                f"point of {len(conj[0].values)} dims in {n}-dim program")
        pts.append(conj[0].values)
    rows = np.asarray(pts, np.float32)
    return "match", [(rows, np.zeros(len(pts), np.int64))], False


def lower(program: ir.Program, config: CAMConfig, *,
          n_features: Optional[int] = None,
          max_rows_per_pass: Optional[int] = None,
          align_banks: Optional[bool] = None) -> Schedule:
    """Lower an IR program onto the configured CAM.

    ``align_banks`` (default: auto — on for multi-group range programs)
    starts every group at a subarray-row boundary via
    ``mapping.plan_group_offsets``, so each co-fired group owns whole
    banks; gaps are filler rows with lo > hi, which can never satisfy an
    exact range match.  ``max_rows_per_pass`` packs groups first-fit into
    multiple sequential passes (a resident-row capacity budget); a single
    group larger than the budget still gets one (oversized) pass.
    """
    if config.app.match_type != "exact":
        raise ValueError(
            "query programs are boolean: they compile onto exact match "
            f"(got app.match_type={config.app.match_type!r})")
    n = n_features if n_features is not None else ir.program_dims(program)
    if n < ir.program_dims(program):
        raise ValueError(f"n_features={n} < program's "
                         f"{ir.program_dims(program)} features")
    kind, groups, range_mode = _group_boxes(program, config, n)
    if range_mode and config.circuit.cell_type != "acam":
        raise ValueError("range lowering needs circuit.cell_type='acam' "
                         f"(got {config.circuit.cell_type!r})")

    align = (align_banks if align_banks is not None
             else (range_mode and len(groups) > 1))
    if align and not range_mode:
        raise ValueError("bank alignment needs a range CAM (point rows "
                         "have no unmatchable filler encoding)")

    # first-fit pack the groups into passes under the row budget
    R = config.circuit.rows
    batches: List[List[Tuple[np.ndarray, np.ndarray]]] = [[]]
    used = 0
    for g in groups:
        need = g[0].shape[0]
        if align:
            need += (-used) % R
        if batches[-1] and max_rows_per_pass is not None \
                and used + need > max_rows_per_pass:
            batches.append([])
            used = 0
            need = g[0].shape[0]
        batches[-1].append(g)
        used += need

    passes = []
    g_base = 0
    for batch in batches:
        sizes = [g[0].shape[0] for g in batch]
        offsets, total = mapping.plan_group_offsets(sizes, R, align)
        if range_mode:
            stored = np.empty((total, n, 2), np.float32)
            stored[..., 0] = np.inf     # filler: lo > hi never matches
            stored[..., 1] = -np.inf
        else:
            stored = np.zeros((total, n), np.float32)
        labels = np.full(total, -1, np.int64)
        gids = np.full(total, -1, np.int64)
        for i, (rows, labs) in enumerate(batch):
            o = int(offsets[i])
            stored[o:o + rows.shape[0]] = rows
            labels[o:o + rows.shape[0]] = labs
            gids[o:o + rows.shape[0]] = g_base + i
        passes.append(QueryPass(stored=stored, labels=labels, groups=gids))
        g_base += len(batch)

    return Schedule(kind=kind, passes=tuple(passes), n_features=n,
                    n_groups=g_base, range_mode=range_mode)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------
class CompiledProgram:
    """A lowered program bound to a ``CAMASim`` facade.

    ``write()`` programs every pass's placement into the backend (one
    ``CAMASim.write`` each); ``run(X)`` queries all passes and combines on
    the host; ``estimate()`` bills the whole schedule on the estimator —
    latency/energy/area BEFORE any write (``perf.predict_schedule``).
    """

    def __init__(self, sim, schedule: Schedule):
        self.sim = sim
        self.schedule = schedule
        self.states: Optional[list] = None

    # ------------------------------------------------------------ write
    def write(self, key=None) -> "CompiledProgram":
        """Program the passes' placements.  ``key=None`` gives every pass
        the backend's default write key — a single-pass schedule is then
        bit-identical to a plain ``sim.write(stored)``."""
        import jax
        import jax.numpy as jnp
        keys = ([None] * len(self.schedule.passes) if key is None
                else list(jax.random.split(key,
                                           len(self.schedule.passes))))
        self.states = [self.sim.write(jnp.asarray(p.stored), k)
                       for p, k in zip(self.schedule.passes, keys)]
        return self

    # ------------------------------------------------------------ query
    def query_raw(self, queries, key=None) -> list:
        """Per-pass ``SearchResult``s (writes first if needed)."""
        import jax
        if self.states is None:
            self.write()
        keys = ([None] * len(self.states) if key is None
                else list(jax.random.split(key, len(self.states))))
        return [self.sim.query(s, queries, k)
                for s, k in zip(self.states, keys)]

    def run(self, queries, key=None) -> np.ndarray:
        """Execute the program: bool (Q,) for predicates, labels (Q,)
        for trees/ensembles."""
        results = self.query_raw(queries, key)
        return self.schedule.combine([np.asarray(r.mask) for r in results])

    __call__ = run

    # ------------------------------------------------------------- perf
    def estimate(self, *, mesh=None, link: str = "on_package",
                 queries_per_batch: int = 1, n_queries: int = 1,
                 include_write: bool = False, ops_per_query: int = 1,
                 clock_hz: Optional[float] = None):
        """Whole-schedule billing (``perf.predict_schedule``), defaulting
        the mesh to the backend's own topology like ``eval_perf`` does."""
        from ..perf import MeshSpec, predict_schedule
        if mesh is None:
            nb = getattr(self.sim.backend, "n_banks", None)
            if nb:
                mesh = MeshSpec(int(nb), link)
        return predict_schedule(
            self.sim.config, self.schedule.pass_shapes(), mesh=mesh,
            queries_per_batch=queries_per_batch, n_queries=n_queries,
            include_write=include_write, ops_per_query=ops_per_query,
            clock_hz=clock_hz)
