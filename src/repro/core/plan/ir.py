"""Query IR: a tiny program representation for CAM search programs.

C4CAM-style (PAPERS.md, arXiv:2309.06418): applications describe WHAT to
search — point matches, per-feature range predicates, AND/OR combinations,
and decision-tree ensembles (the ``acam_decision_tree`` workload
generalized) — and the compiler (``core.plan.compile``) lowers the program
onto CAM primitives (write placements + query passes + a host-side
combine).

Nodes
-----
``Point(values)``            exact match of a full N-dim vector
``Band(feature, lo, hi)``    lo <= x[feature] <= hi (half-open at +/-inf)
``And(children)``            conjunction of predicates
``Or(children)``             disjunction of predicates
``Leaf(lo, hi, label)``      one root-to-leaf path: a box + its class
``Tree(leaves)``             a decision tree (leaves tile the space)
``Ensemble(trees)``          majority vote over trees

Predicates (`Point`/`Band`/`And`/`Or`) evaluate to booleans; `Tree` and
`Ensemble` evaluate to labels.  ``evaluate`` is the pure-numpy reference
semantics every lowering is tested against; ``to_dnf`` normalizes a
predicate into OR-of-ANDs — the CAM's native shape: each conjunction is
one stored row (per-feature range intersection), the OR across rows is
the match-line disjunction the CAM performs for free.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Sequence, Tuple, Union

import numpy as np

NEG_INF = -math.inf
POS_INF = math.inf


# ---------------------------------------------------------------------------
# nodes
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class Point:
    """Exact point match: x == values (element-wise, post-quantization)."""
    values: Tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(float(v)
                                                 for v in self.values))


@dataclass(frozen=True)
class Band:
    """One-feature range predicate: lo <= x[feature] <= hi."""
    feature: int
    lo: float = NEG_INF
    hi: float = POS_INF

    def __post_init__(self):
        if self.feature < 0:
            raise ValueError("feature must be >= 0")


@dataclass(frozen=True)
class And:
    children: Tuple["Predicate", ...]

    def __init__(self, *children):
        if len(children) == 1 and isinstance(children[0], (tuple, list)):
            children = tuple(children[0])
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Or:
    children: Tuple["Predicate", ...]

    def __init__(self, *children):
        if len(children) == 1 and isinstance(children[0], (tuple, list)):
            children = tuple(children[0])
        object.__setattr__(self, "children", tuple(children))


@dataclass(frozen=True)
class Leaf:
    """One root-to-leaf path: the feature-space box that reaches it."""
    lo: Tuple[float, ...]
    hi: Tuple[float, ...]
    label: int

    def __post_init__(self):
        object.__setattr__(self, "lo", tuple(float(v) for v in self.lo))
        object.__setattr__(self, "hi", tuple(float(v) for v in self.hi))
        if len(self.lo) != len(self.hi):
            raise ValueError("lo/hi length mismatch")


@dataclass(frozen=True)
class Tree:
    leaves: Tuple[Leaf, ...]

    def __init__(self, leaves):
        leaves = tuple(leaves)
        if not leaves:
            raise ValueError("Tree needs at least one leaf")
        n = len(leaves[0].lo)
        if any(len(l.lo) != n for l in leaves):
            raise ValueError("all leaves must span the same features")
        object.__setattr__(self, "leaves", leaves)

    @property
    def n_features(self) -> int:
        return len(self.leaves[0].lo)


@dataclass(frozen=True)
class Ensemble:
    """Tree ensemble; classification is a majority vote over the trees
    (ties break toward the smallest label)."""
    trees: Tuple[Tree, ...]

    def __init__(self, trees):
        trees = tuple(trees)
        if not trees:
            raise ValueError("Ensemble needs at least one tree")
        n = trees[0].n_features
        if any(t.n_features != n for t in trees):
            raise ValueError("all trees must span the same features")
        object.__setattr__(self, "trees", trees)

    @property
    def n_features(self) -> int:
        return self.trees[0].n_features


Predicate = Union[Point, Band, And, Or]
Program = Union[Predicate, Tree, Ensemble]


def tree_from_paths(paths: Sequence[Tuple]) -> Tree:
    """Build a ``Tree`` from ``(lo_vec, hi_vec, label)`` triples — the
    exact shape ``examples/acam_decision_tree.tree_paths`` emits."""
    return Tree([Leaf(tuple(lo), tuple(hi), int(label))
                 for lo, hi, label in paths])


def program_dims(program: Program) -> int:
    """Feature count the program spans (max feature index + 1 for bare
    band predicates)."""
    if isinstance(program, (Tree, Ensemble)):
        return program.n_features
    if isinstance(program, Point):
        return len(program.values)
    if isinstance(program, Band):
        return program.feature + 1
    if isinstance(program, (And, Or)):
        return max(program_dims(c) for c in program.children)
    raise TypeError(f"not an IR node: {program!r}")


# ---------------------------------------------------------------------------
# reference semantics (pure numpy — the oracle every lowering must match)
# ---------------------------------------------------------------------------
def evaluate(program: Program, x) -> np.ndarray:
    """Reference evaluation on a batch ``x`` (Q, N).

    Predicates return bool (Q,); ``Tree``/``Ensemble`` return labels (Q,).
    """
    x = np.atleast_2d(np.asarray(x, np.float64))
    if isinstance(program, Point):
        v = np.asarray(program.values, np.float64)
        return (x[:, : v.size] == v).all(axis=1)
    if isinstance(program, Band):
        c = x[:, program.feature]
        return (c >= program.lo) & (c <= program.hi)
    if isinstance(program, And):
        out = np.ones(x.shape[0], bool)
        for ch in program.children:
            out &= evaluate(ch, x)
        return out
    if isinstance(program, Or):
        out = np.zeros(x.shape[0], bool)
        for ch in program.children:
            out |= evaluate(ch, x)
        return out
    if isinstance(program, Tree):
        return _tree_labels(program, x)
    if isinstance(program, Ensemble):
        votes = np.stack([_tree_labels(t, x) for t in program.trees])
        return _majority(votes)
    raise TypeError(f"not an IR node: {program!r}")


def _tree_labels(tree: Tree, x: np.ndarray) -> np.ndarray:
    lo = np.asarray([l.lo for l in tree.leaves])      # (L, N)
    hi = np.asarray([l.hi for l in tree.leaves])
    labels = np.asarray([l.label for l in tree.leaves])
    inside = ((x[:, None, :] >= lo) & (x[:, None, :] <= hi)).all(-1)
    # leaves tile the space: take the FIRST matching leaf (same row-order
    # tie-break as the CAM's gather merge)
    first = np.argmax(inside, axis=1)
    return labels[first]


def _majority(votes: np.ndarray) -> np.ndarray:
    """(T, Q) per-tree labels -> (Q,) majority vote, ties to the smallest
    label."""
    n_labels = int(votes.max()) + 1
    counts = np.zeros((votes.shape[1], n_labels), np.int64)
    for t in range(votes.shape[0]):
        np.add.at(counts, (np.arange(votes.shape[1]), votes[t]), 1)
    return counts.argmax(axis=1)


# ---------------------------------------------------------------------------
# DNF normalization (predicates only)
# ---------------------------------------------------------------------------
def to_dnf(pred: Predicate) -> Tuple[Tuple[Union[Point, Band], ...], ...]:
    """OR-of-ANDs normal form: a tuple of conjunctions, each a tuple of
    ``Point``/``Band`` literals.  The CAM-native shape — each conjunction
    becomes one stored row, the OR is the CAM's match-line disjunction."""
    if isinstance(pred, (Point, Band)):
        return ((pred,),)
    if isinstance(pred, Or):
        out = []
        for ch in pred.children:
            out.extend(to_dnf(ch))
        return tuple(out)
    if isinstance(pred, And):
        prod = ((),)
        for ch in pred.children:
            terms = to_dnf(ch)
            prod = tuple(p + t for p in prod for t in terms)
        return prod
    raise TypeError(f"not a predicate: {pred!r}")


def conjunction_box(conj: Sequence[Union[Point, Band]], n: int
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Intersect a conjunction's literals into one [lo, hi] box (N,).

    A ``Point`` literal pins its features to degenerate [v, v] bands; an
    infeasible intersection yields lo > hi on some feature — which the
    ACAM lowering stores verbatim (a lo > hi cell can never satisfy
    lo <= q <= hi, so the row simply never matches — same as the
    reference semantics of an empty conjunction)."""
    lo = np.full(n, NEG_INF)
    hi = np.full(n, POS_INF)
    for lit in conj:
        if isinstance(lit, Band):
            if lit.feature >= n:
                raise ValueError(f"feature {lit.feature} out of range "
                                 f"for {n} dims")
            lo[lit.feature] = max(lo[lit.feature], lit.lo)
            hi[lit.feature] = min(hi[lit.feature], lit.hi)
        elif isinstance(lit, Point):
            v = np.asarray(lit.values, np.float64)
            if v.size > n:
                raise ValueError(f"point of {v.size} dims in {n}-dim "
                                 "program")
            lo[: v.size] = np.maximum(lo[: v.size], v)
            hi[: v.size] = np.minimum(hi[: v.size], v)
        else:
            raise TypeError(f"not a literal: {lit!r}")
    return lo, hi
