"""Estimator-driven deployment autotuner: pick the ``sim`` config before
any write.

Sweeps the DEPLOYMENT space — knobs that change how the experiment
executes, not what it computes: the fused-kernel query tile
(``sim.q_tile``), the C2C noise tile (``sim.c2c_query_tile``), the mesh
split (``sim.devices`` x ``sim.query_shards`` + link preset), and the
search-cascade budget (``sim.top_p_banks`` / ``sim.signature_bits``) —
scoring every candidate purely on the performance estimator
(``perf.perf_report`` over ``plan(entries, dims)`` shapes).  No backend is
constructed and no ``write`` ever happens: the sweep is deterministic
arithmetic, so ``CAMASim.autotune`` can rank thousands of deployments in
milliseconds and the winner is directly loadable from JSON.

Two metric families coexist honestly:

* hardware-model metrics (``latency_ns`` / ``energy_pj`` / ``area_um2`` /
  ``edp``) come from the paper-calibrated estimator — ``q_tile`` and
  ``c2c_query_tile`` do NOT move these (the modeled CAM fires whole
  subarrays regardless of how the simulator tiles its batches);
* ``sim_qps`` is a SIMULATOR-throughput proxy — the HBM bytes the fused
  kernels stream per batch (stored planes x passes + queries + match
  write-back) over a nominal HBM bandwidth — which is what ``q_tile``
  does move.  ``benchmarks/autotune_bench.py`` reports how well this
  proxy's ranking agrees with measured qps (rank agreement as an honest
  BENCH field).
"""
from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from ..config import CAMConfig
from ..perf import MeshSpec, PerfReport, estimate_arch, perf_report
from ..perf.interconnect import MESH_LINKS

__all__ = ["Candidate", "AutotuneResult", "autotune", "default_space",
           "simulated_qps", "OBJECTIVES", "Q_TILE_LADDER"]

# the power-of-two ladder SimConfig.q_tile validates against
Q_TILE_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256)

# nominal accelerator HBM bandwidth for the simulator-throughput proxy
# (bytes/s); the proxy only RANKS candidates, absolute qps is calibrated
# against measurement by benchmarks/autotune_bench.py
HBM_BYTES_PER_S = 819e9

# objective -> (metric key, sign); candidates minimize sign * value
OBJECTIVES = {
    "latency": ("latency_ns", 1.0),
    "energy": ("energy_pj", 1.0),
    "area": ("area_um2", 1.0),
    "edp": ("edp_pj_ns", 1.0),
    "qps": ("sim_qps", -1.0),
}

# sweep-knob iteration order (fixed, so the argmin tie-break — first
# minimum wins — is reproducible and testable against a hand-rolled loop)
_KNOBS = ("q_tile", "c2c_query_tile", "devices", "query_shards", "link",
          "top_p_banks", "signature_bits")


@dataclass(frozen=True)
class Candidate:
    """One scored deployment: the full config (loadable as-is), the knob
    assignment that produced it, and its metrics."""
    config: CAMConfig
    knobs: Dict[str, object]
    metrics: Dict[str, float]
    objective: float
    report: PerfReport = field(repr=False, default=None)


@dataclass
class AutotuneResult:
    """Ranked sweep output.  ``best``/``config`` are the argmin;
    ``candidates`` is the full ranked table (ascending objective);
    ``skipped`` counts knob combinations rejected by config validation."""
    objective: str
    entries: int
    dims: int
    queries_per_batch: int
    candidates: List[Candidate]
    skipped: int = 0

    @property
    def best(self) -> Candidate:
        return self.candidates[0]

    @property
    def config(self) -> CAMConfig:
        return self.best.config

    def table(self, top: Optional[int] = None) -> str:
        """Human-readable ranked candidate table."""
        rows = self.candidates[:top]
        hdr = (f"{'#':>3} {'q_tile':>6} {'c2c':>4} {'dev':>4} {'qsh':>4} "
               f"{'link':>10} {'top_p':>6} {'sig':>4} {'lat_ns':>10} "
               f"{'en_pJ':>10} {'edp':>12} {'qps':>12}")
        out = [hdr]
        for i, c in enumerate(rows):
            k, m = c.knobs, c.metrics
            out.append(
                f"{i:3d} {str(k['q_tile']):>6} {k['c2c_query_tile']:4d} "
                f"{k['devices']:4d} {k['query_shards']:4d} "
                f"{str(k['link']):>10} {str(k['top_p_banks']):>6} "
                f"{k['signature_bits']:4d} {m['latency_ns']:10.2f} "
                f"{m['energy_pj']:10.2f} {m['edp_pj_ns']:12.2f} "
                f"{m['sim_qps']:12.0f}")
        return "\n".join(out)


def default_space(config: CAMConfig, entries: int, dims: int
                  ) -> Dict[str, Sequence]:
    """A small default sweep adapted to the planned store shape: the
    q_tile ladder's upper rungs, 1/2/4-device meshes over two link
    presets, and — when the grid has enough banks to route — a top-p/4
    cascade budget."""
    spec = estimate_arch(config, entries, dims).spec
    space: Dict[str, Sequence] = {
        "q_tile": [None, 16, 64, 256],
        "c2c_query_tile": [config.sim.c2c_query_tile],
        "devices": [1, 2, 4],
        "query_shards": [1],
        "link": ["on_package", "pcb"],
        "top_p_banks": [None],
        "signature_bits": [0],
    }
    if spec.nv >= 4:
        space["top_p_banks"] = [None, max(1, spec.nv // 4)]
    return space


def simulated_qps(config: CAMConfig, entries: int, dims: int, *,
                  queries_per_batch: int = 1,
                  q_tile: Optional[int] = None,
                  devices: int = 1, query_shards: int = 1,
                  top_p_banks: Optional[int] = None,
                  want_dist: bool = True,
                  pipeline: Optional[bool] = None) -> float:
    """Simulator-throughput proxy: fused-kernel HBM traffic per batch.

    Unpipelined (``pipeline=False``), the fused kernels stream the
    resident stored planes from HBM once per Q-tile
    (``ceil(Q_local / q_tile)`` passes) and move the query block down and
    the (Q, nv, nh, R) match/count block back.  With the bank-blocked
    pipeline (``sim.pipeline``, the default) and a store that fits the
    residency budget (``kernels.cam_search.resident_banks``), the stored
    planes cross HBM ONCE per batch and the query block is re-streamed per
    bank block instead — the same model ``choose_q_tile`` ranks rungs
    with, per-grid-step dispatch term included.  The slowest device bounds
    the batch; bank sharding divides the streamed banks, query sharding
    divides the local batch (and multiplies throughput), and the cascade's
    top-p routing shrinks the searched banks.  Returned as queries/second
    over ``HBM_BYTES_PER_S`` — a RANKING proxy, validated against
    measurement by ``benchmarks/autotune_bench.py`` and
    ``benchmarks/kernel_bench.py``.
    """
    # module (not value) import: set_kernel_model / env overrides mutate
    # cam_search.STEP_OVERHEAD_S and the estimator must see the same
    # constant the kernel drivers rank with
    from repro.kernels import cam_search
    choose_q_tile = cam_search.choose_q_tile
    default_q_tile = cam_search.default_q_tile
    resident_banks = cam_search.resident_banks

    spec = estimate_arch(config, entries, dims).spec
    planes = 2 if config.app.distance == "range" else 1
    if pipeline is None:
        pipeline = config.sim.pipeline
    Q = max(1, queries_per_batch)
    q_loc = math.ceil(Q / max(1, query_shards))
    nv_loc = math.ceil(spec.nv / max(1, devices))
    p_loc = (nv_loc if top_p_banks is None
             else min(nv_loc, math.ceil(min(top_p_banks, spec.nv)
                                        / max(1, devices))))
    vb = (resident_banks(p_loc, spec.nh, spec.R, spec.C, planes)
          if pipeline else 0)
    if q_tile:
        qt = q_tile
    elif pipeline:
        # same MXU-vs-broadcast split the kernel drivers apply: l2/dot
        # have a matmul form, the rest pay the (Qt, rows, C) VPU block
        bcast = 0 if config.app.distance in ("l2", "dot") else spec.C
        qt = choose_q_tile(spec.R, spec.C, planes, banks=p_loc,
                           segs=spec.nh, want_dist=want_dist,
                           bcast_cols=bcast)
    else:
        qt = default_q_tile(spec.R, spec.C, planes)
    qt = max(1, min(qt, q_loc))
    passes = math.ceil(q_loc / qt)
    if vb:
        # bank-blocked pipeline: store streamed once per batch, query tile
        # re-streamed per bank block, one grid step per (block, Q-tile)
        blocks = p_loc // vb
        stream = 4.0 * planes * p_loc * spec.nh * spec.R * spec.C
        q_bytes = 4.0 * q_loc * spec.nh * spec.C * blocks
        steps = blocks * passes
    else:
        stream = 4.0 * planes * p_loc * spec.nh * spec.R * spec.C * passes
        q_bytes = 4.0 * q_loc * spec.nh * spec.C
        steps = p_loc * spec.nh * passes
    out_bytes = (4.0 * q_loc * p_loc * spec.nh * spec.R
                 * (2 if want_dist else 1))
    # all shard groups run in parallel, so the whole Q-batch lands in one
    # local-group time; the dispatch term matters off-TPU (interpret mode)
    # and only sharpens the ranking on hardware
    t_s = ((stream + q_bytes + out_bytes) / HBM_BYTES_PER_S
           + steps * cam_search.STEP_OVERHEAD_S)
    return Q / t_s


def _candidate_config(config: CAMConfig, knobs: dict) -> CAMConfig:
    """Assemble one candidate's full config from a knob assignment."""
    sim = dict(
        q_tile=knobs["q_tile"],
        c2c_query_tile=knobs["c2c_query_tile"],
        devices=knobs["devices"] if knobs["devices"] > 1 else 0,
        query_shards=knobs["query_shards"],
        backend="sharded" if (knobs["devices"] > 1
                              or knobs["query_shards"] > 1)
        else "functional",
        top_p_banks=knobs["top_p_banks"],
        signature_bits=knobs["signature_bits"],
    )
    if knobs["top_p_banks"] is not None:
        if config.sim.prefilter == "off":
            sim["prefilter"] = "signature"
        # routed searches with C2C noise need the per-bank RNG fold
        if config.device.variation in ("c2c", "both"):
            sim["c2c_fold"] = "bank"
    cand = config.replace(sim=sim)
    cand.validate()
    return cand


def autotune(config: CAMConfig, entries: int, dims: int, *,
             space: Optional[Dict[str, Sequence]] = None,
             objective: str = "edp",
             queries_per_batch: int = 32) -> AutotuneResult:
    """Exhaustive estimator sweep over the deployment space.

    ``space`` overrides any subset of the ``default_space`` axes (lists of
    values per knob name).  Every candidate is billed with
    ``perf_report`` over the planned ``(entries, dims)`` shape — zero
    writes, zero backends — and ranked by ``objective`` (see
    ``OBJECTIVES``; ties break toward the earlier knob combination, in
    ``_KNOBS`` iteration order).  Invalid combinations (config
    cross-validation) are skipped and counted.
    """
    if objective not in OBJECTIVES:
        raise ValueError(f"objective {objective!r} not in "
                         f"{sorted(OBJECTIVES)}")
    metric, sign = OBJECTIVES[objective]
    sp = dict(default_space(config, entries, dims))
    if space:
        unknown = set(space) - set(sp)
        if unknown:
            raise ValueError(f"unknown sweep knobs {sorted(unknown)}; "
                             f"knobs: {sorted(sp)}")
        sp.update(space)
    for l in sp["link"]:
        if l not in MESH_LINKS:
            raise ValueError(f"unknown link preset {l!r}; presets: "
                             f"{sorted(MESH_LINKS)}")

    candidates: List[Tuple[float, int, Candidate]] = []
    skipped = 0
    order = 0
    for combo in itertools.product(*(sp[k] for k in _KNOBS)):
        knobs = dict(zip(_KNOBS, combo))
        if knobs["devices"] <= 1 and knobs["query_shards"] <= 1 \
                and knobs["link"] != sp["link"][0]:
            continue    # single chip: the link never fires; dedupe
        try:
            cand_cfg = _candidate_config(config, knobs)
        except ValueError:
            skipped += 1
            continue
        arch = estimate_arch(cand_cfg, entries, dims)
        d = knobs["devices"]
        mesh = MeshSpec(d, knobs["link"]) if d > 1 else None
        q_loc = math.ceil(queries_per_batch
                          / max(1, knobs["query_shards"]))
        report = perf_report(cand_cfg, arch, mesh=mesh,
                             queries_per_batch=q_loc)
        qps = simulated_qps(
            cand_cfg, entries, dims, queries_per_batch=queries_per_batch,
            q_tile=knobs["q_tile"], devices=d,
            query_shards=knobs["query_shards"],
            top_p_banks=knobs["top_p_banks"])
        metrics = {
            "latency_ns": report["latency_ns"],
            "energy_pj": report["energy_pj"],
            # query sharding replicates the store across shard groups
            "area_um2": report["area_um2"] * max(1, knobs["query_shards"]),
            "edp_pj_ns": report["edp_pj_ns"],
            "sim_qps": qps,
        }
        obj = sign * metrics[metric]
        candidates.append(
            (obj, order,
             Candidate(config=cand_cfg, knobs=knobs, metrics=metrics,
                       objective=obj, report=report)))
        order += 1
    if not candidates:
        raise ValueError("every knob combination was invalid for this "
                         "config — nothing to rank")
    candidates.sort(key=lambda t: (t[0], t[1]))
    return AutotuneResult(objective=objective, entries=entries, dims=dims,
                          queries_per_batch=queries_per_batch,
                          candidates=[c for _, _, c in candidates],
                          skipped=skipped)
