"""Query compiler + deployment autotuner (C4CAM-style planning layer).

``ir`` is the tiny program representation (points, range predicates,
AND/OR, trees, ensembles); ``lower`` compiles a program into a
``Schedule`` of CAM primitive calls; ``autotune`` sweeps the deployment
space purely on the estimator.  ``CAMASim.compile`` / ``CAMASim.autotune``
are the facade entry points.
"""
from . import ir
from .autotune import (OBJECTIVES, Q_TILE_LADDER, AutotuneResult, Candidate,
                       autotune, default_space, simulated_qps)
from .compile import CompiledProgram, QueryPass, Schedule, lower
from .ir import (And, Band, Ensemble, Leaf, Or, Point, Tree, evaluate,
                 program_dims, to_dnf, tree_from_paths)

__all__ = [
    "ir", "Point", "Band", "And", "Or", "Leaf", "Tree", "Ensemble",
    "evaluate", "to_dnf", "tree_from_paths", "program_dims",
    "QueryPass", "Schedule", "CompiledProgram", "lower",
    "autotune", "default_space", "simulated_qps", "AutotuneResult",
    "Candidate", "OBJECTIVES", "Q_TILE_LADDER",
]
