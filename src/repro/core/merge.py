"""Merging submodule (paper §III-B/III-C, Fig. 3): the partition-and-merge
problem.

Horizontal merge (N > C): combine results across the ``nh`` axis — each
subarray only saw a segment of the query vector.
    exact  -> AND of per-segment exact matches (exact, lossless)
    best   -> voting: each subarray votes for its best rows; the row with the
              most votes is the approximate global best (Kazemi et al. [7])
    adder  -> (beyond-paper extension) sum per-segment distances: lossless
              best/threshold merge at the cost of an adder tree per row
threshold -> no existing efficient scheme (paper Fig. 3b); only 'adder'.

Vertical merge (K > R): combine results across the ``nv`` axis — different
subarrays hold different entries.
    exact/threshold -> gather: concatenate match lines (lossless)
    best            -> comparator tree over subarray winners

Inputs use the shapes produced by ``subarray.subarray_query``:
    dist  (..., nv, nh, R)
    match (..., nv, nh, R)
Outputs are global, fixed-shape results over padded_K = nv*R rows.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Horizontal merge: (..., nv, nh, R) -> per-row scores (..., nv, R)
# --------------------------------------------------------------------------
def h_merge_and(match: jax.Array) -> jax.Array:
    """Exact-match AND across segments: 1.0 iff every segment matched."""
    return jnp.prod(match, axis=-2)


def h_merge_voting(match: jax.Array) -> jax.Array:
    """Voting: count segments in which this row was sensed as a match.
    Higher vote count == better approximate match."""
    return jnp.sum(match, axis=-2)


def h_merge_adder(dist: jax.Array) -> jax.Array:
    """Adder: exact full-vector distance = sum of segment distances.
    (Lossless for L1/L2^2/Hamming, all of which are coordinate-separable.)"""
    return jnp.sum(dist, axis=-2)


# --------------------------------------------------------------------------
# Vertical merge: per-row scores (..., nv, R) -> global results (..., nv*R)
# --------------------------------------------------------------------------
def v_merge_gather(row_scores: jax.Array) -> jax.Array:
    """Gather: flatten the (nv, R) grid into global match lines."""
    return row_scores.reshape(*row_scores.shape[:-2], -1)


def pad_topk(vals: jax.Array, idx: jax.Array, k: int, *, largest: bool
             ) -> Tuple[jax.Array, jax.Array]:
    """Pad comparator outputs (..., k') out to width ``k`` with never-valid
    sentinels: 0 votes when ``largest``, +inf distance otherwise, index -1.

    ``finalize_topk`` maps both sentinels to -1 / unmatched, so a clamped
    top-k (fewer rows than requested matches) keeps the caller-visible
    (..., match_param) shape instead of crashing ``jax.lax.top_k``."""
    short = k - vals.shape[-1]
    if short <= 0:
        return vals, idx
    pad = [(0, 0)] * (vals.ndim - 1) + [(0, short)]
    sentinel = 0.0 if largest else float("inf")
    return (jnp.pad(vals, pad, constant_values=sentinel),
            jnp.pad(idx, pad, constant_values=-1))


def v_merge_comparator_topk(values: jax.Array, k: int, largest: bool
                            ) -> Tuple[jax.Array, jax.Array]:
    """Comparator tree: global top-k over all nv*R rows.

    values: (..., nv, R) per-row scores (votes if ``largest`` else distances).
    Returns (topk_values, topk_global_indices), always of width ``k``:
    ``k`` is clamped to the row count for the ``jax.lax.top_k`` call (a
    match_param larger than the padded store must degrade to -1 padding,
    not crash — the sharded comparator path already clamps) and the result
    is padded back out with never-valid sentinels.
    """
    flat = values.reshape(*values.shape[:-2], -1)
    sign = 1.0 if largest else -1.0
    v, idx = jax.lax.top_k(sign * flat, min(k, flat.shape[-1]))
    return pad_topk(sign * v, idx, k, largest=largest)


# --------------------------------------------------------------------------
# Horizontal reductions shared with the cross-device (sharded) combiner.
#
# These operate on the per-subarray (..., nv, nh, R) tensors and collapse
# only the nh axis, producing per-row quantities that are LOCAL to each nv
# block — so a device holding an nv-shard of the grid computes exactly the
# slice of the full reduction its rows contribute, and the vertical merge
# across devices reduces to a gather (exact/threshold) or a candidate
# re-rank (best).  ``core.sharded`` is the other caller.
# --------------------------------------------------------------------------
def h_reduce_match(dist: jax.Array, match: jax.Array, *, match_type: str,
                   h_merge: str, sensing_limit: float = 0.0,
                   threshold: float = 0.0) -> jax.Array:
    """Exact/threshold horizontal merge -> (..., nv, R) 0/1 row mask."""
    nh = match.shape[-2]
    if h_merge == "and":
        if match_type == "threshold" and nh > 1:
            # Paper Fig. 3b: no existing efficient horizontal merge for
            # threshold match.  Use 'adder' (our beyond-paper extension).
            raise ValueError(
                "threshold match with horizontal partitioning (nh>1) has "
                "no AND/voting merge (paper Fig. 3b); use h_merge='adder'")
        return h_merge_and(match)                          # (..., nv, R)
    if h_merge == "adder":
        total = h_merge_adder(dist)                        # exact distance
        total = jnp.where(jnp.isfinite(total), total, 3.4e38)
        thr = sensing_limit if match_type == "exact" else (
            threshold + sensing_limit)
        return (total <= thr).astype(jnp.float32)
    if h_merge == "voting":
        raise ValueError(f"{match_type} match has no voting h-merge "
                         "(paper Fig. 3b)")
    raise ValueError(f"unknown h_merge {h_merge!r}")


def voting_dmax(dist: jax.Array) -> jax.Array:
    """Per-query max finite summed distance (..., 1, 1) over this nv block.

    The voting tie-break normalizer must be computed over ALL rows of the
    query's grid; a sharded grid takes ``lax.pmax`` of this local value
    across the bank axis before calling ``h_reduce_best``."""
    total = h_merge_adder(dist)
    return jnp.max(jnp.where(jnp.isfinite(total), total, 0.0),
                   axis=(-2, -1), keepdims=True)


def h_reduce_best(dist: jax.Array, match: jax.Array, *, h_merge: str,
                  dmax: jax.Array | None = None
                  ) -> Tuple[jax.Array, bool]:
    """Best-match horizontal merge -> ((..., nv, R) row scores, largest).

    ``largest`` tells the comparator stage which direction wins (votes are
    maximized, distances minimized).  ``dmax``: pre-computed tie-break
    normalizer for the voting merge (``voting_dmax`` + pmax on sharded
    grids); defaults to the local per-query max.
    """
    nh = match.shape[-2]
    if h_merge == "voting":
        votes = h_merge_voting(match)                      # (..., nv, R)
        # lexicographic (votes desc, distance asc): normalize the
        # distance into [0, 1) so it can never flip a vote difference
        # (votes are small ints — exactly representable in f32).
        total = h_merge_adder(dist)
        finite = jnp.isfinite(total)
        # per-query max (last two axes): with a batched (Q, nv, R) total
        # a global max would couple the queries' tie-break scales
        if dmax is None:
            dmax = voting_dmax(dist)
        dmax = dmax + 1.0
        norm = jnp.clip(jnp.where(finite, total, dmax) / dmax,
                        0.0, 0.999)
        return votes - norm, True
    if h_merge == "adder":
        return h_merge_adder(dist), False
    if h_merge == "and" and nh == 1:
        # no horizontal partitioning: distances are already global
        return dist[..., 0, :], False
    raise ValueError(f"best match h_merge {h_merge!r} unsupported")


# --------------------------------------------------------------------------
# Vertical finalization shared with the cross-device combiner
# --------------------------------------------------------------------------
def first_k_indices(mask: jax.Array, k: int) -> jax.Array:
    """First-k matched indices (fixed shape) of a 0/1 row mask, -1 padded.

    Appending always-zero rows to ``mask`` never changes the result, so a
    bank-padded sharded grid yields the same indices as the unpadded one.
    ``k`` beyond the row count pads with -1 (same clamp-and-pad contract as
    the comparator merge)."""
    score = mask * 2.0 - jnp.arange(mask.shape[-1]) / mask.shape[-1]
    _, idx = jax.lax.top_k(score, min(k, mask.shape[-1]))
    got = jnp.take_along_axis(mask, idx, axis=-1) > 0
    idx = jnp.where(got, idx, -1)
    short = k - idx.shape[-1]
    if short > 0:
        idx = jnp.pad(idx, [(0, 0)] * (idx.ndim - 1) + [(0, short)],
                      constant_values=-1)
    return idx


def finalize_topk(vals: jax.Array, idx: jax.Array, *, largest: bool,
                  K: int) -> Tuple[jax.Array, jax.Array]:
    """Winner validity + -1 padding + scatter mask over ``K`` global rows.

    vals/idx (..., k): comparator outputs with their GLOBAL row indices
    (already offset on sharded grids).  Invalid winners — zero/negative
    votes when ``largest``, non-finite distances otherwise — become -1.
    """
    valid = (vals > 0) if largest else jnp.isfinite(vals)
    idx = jnp.where(valid, idx, -1)
    mask = jnp.zeros((*idx.shape[:-1], K))
    return idx, put_topk_mask(mask, idx)


def local_topk_candidates(values: jax.Array, k: int, *, largest: bool,
                          row_offset=0) -> Tuple[jax.Array, jax.Array]:
    """Per-shard comparator stage: top-k candidate (values, global indices).

    values (..., nv_local, R) row scores of this shard; ``row_offset`` is
    the shard's first global row (bank_index * nv_local * R).  ``k`` is
    clamped to the shard's row count.  ``jax.lax.top_k`` is stable (ties
    keep the lowest index), so concatenating shards' candidate lists in
    bank order and re-ranking with another stable top-k reproduces the
    single-device comparator bit-for-bit: any row the global comparator
    selects from a shard is necessarily in that shard's local top-k.
    """
    flat = values.reshape(*values.shape[:-2], -1)
    kl = max(1, min(k, flat.shape[-1]))
    sign = 1.0 if largest else -1.0
    v, idx = jax.lax.top_k(sign * flat, kl)
    return sign * v, idx + row_offset


def rerank_candidates(vals: jax.Array, idx: jax.Array, k: int, *,
                      largest: bool) -> Tuple[jax.Array, jax.Array]:
    """Re-rank gathered candidates (..., n_shards*k_local) -> global top-k.

    The candidate axis must be ordered (bank asc, local rank asc): stable
    top-k then breaks value ties toward the lowest global row index,
    exactly as the unsharded ``v_merge_comparator_topk`` does.  Output is
    padded out to width ``k`` (sentinels via ``pad_topk``) when fewer
    candidates exist — matching the single-device clamp-and-pad, so both
    paths return (..., match_param) even for k > padded_K."""
    sign = 1.0 if largest else -1.0
    v, p = jax.lax.top_k(sign * vals, min(k, vals.shape[-1]))
    return pad_topk(sign * v, jnp.take_along_axis(idx, p, axis=-1), k,
                    largest=largest)


# --------------------------------------------------------------------------
# Cross-device merge payload accounting (perf model contract)
# --------------------------------------------------------------------------
def match_k(match_type: str, match_param: int, padded_K: int) -> int:
    """Result width k of the merge for a ``padded_K``-row store.

    Single source of truth for ``FunctionalSimulator.match_k`` and the
    perf model (``perf.estimator.predict_search_sharded``), so the
    modeled candidate widths can never drift from the executed ones."""
    if match_type == "best":
        return match_param
    return max(1, min(padded_K, 16))


def shard_merge_payload(match_type: str, h_merge: str, *, Q: int,
                        nv_local: int, R: int, k: int) -> dict:
    """Per-device array shapes the cross-device vertical merge moves.

    Mirrors ``core.sharded.ShardedCAMSimulator._combine`` exactly — the
    perf model derives its chip-to-chip byte counts from these shapes and
    a multidevice test asserts them against the arrays the simulator
    actually hands to ``lax.all_gather`` / ``lax.pmax``:

      exact/threshold  ``all_gather`` of the h-reduced 0/1 match-line
                       block -> ``{'match_rows': (Q, nv_local, R)}``
      best             stable local top-k candidates, k clamped to the
                       shard's row count (``local_topk_candidates``) ->
                       ``{'cand_vals': (Q, kl), 'cand_idx': (Q, kl)}``;
                       the voting h-merge additionally all-reduces the
                       per-query tie-break normalizer ->
                       ``{'dmax': (Q, 1, 1)}``.
    """
    if match_type in ("exact", "threshold"):
        return {"match_rows": (Q, nv_local, R)}
    if match_type != "best":
        raise ValueError(f"unknown match_type {match_type!r}")
    kl = max(1, min(k, nv_local * R))
    payload = {"cand_vals": (Q, kl), "cand_idx": (Q, kl)}
    if h_merge == "voting":
        payload["dmax"] = (Q, 1, 1)
    return payload


# --------------------------------------------------------------------------
# Full merge dispatch
# --------------------------------------------------------------------------
def merge(dist: jax.Array, match: jax.Array, *, match_type: str,
          h_merge: str, v_merge: str, match_param: int,
          sensing_limit: float = 0.0, threshold: float = 0.0
          ) -> Tuple[jax.Array, jax.Array]:
    """Merge per-subarray results into application-level search results.

    Returns ``(indices, mask)``:
      * ``indices`` (..., match_param): top-k matched entry indices for best
        match (or first-k matches for exact/threshold), padded with -1.
      * ``mask``    (..., padded_K): 1.0 for every matched entry
        (exact/threshold) or for the top-k set (best).

    ``dist`` may be None on the exact/threshold AND-merge path, which
    consumes match lines only (the fused kernel then never materializes the
    distance tensor in HBM).
    """
    k = max(1, match_param)

    if match_type in ("exact", "threshold"):
        if v_merge != "gather":
            raise ValueError(f"{match_type} match uses gather v-merge")
        row = h_reduce_match(dist, match, match_type=match_type,
                             h_merge=h_merge, sensing_limit=sensing_limit,
                             threshold=threshold)
        mask = v_merge_gather(row)                          # (..., K)
        return first_k_indices(mask, k), mask

    if match_type == "best":
        if v_merge != "comparator":
            raise ValueError("best match requires comparator v-merge")
        values, largest = h_reduce_best(dist, match, h_merge=h_merge)
        vals, idx = v_merge_comparator_topk(values, k, largest=largest)
        K = match.shape[-3] * match.shape[-1]
        return finalize_topk(vals, idx, largest=largest, K=K)

    raise ValueError(f"unknown match_type {match_type!r}")


# --------------------------------------------------------------------------
# Selected-bank merge (search-cascade stage 2): the fused kernel ran only on
# a gathered (p, nh, R, C) sub-grid; these helpers merge that subset back
# against the ORIGINAL bank ids so results keep the full-store coordinate
# frame.  With ``bank_ids = arange(nv)`` (i.e. p = nv, sorted ascending)
# every helper degenerates bit-for-bit to its full-scan counterpart: the
# gather is the identity, the scatter writes every position exactly once,
# and the top-k sees the same flat tensor in the same order.
# --------------------------------------------------------------------------
def scatter_match_rows(row: jax.Array, bank_ids: jax.Array,
                       nv_total: int) -> jax.Array:
    """(..., p, R) selected-bank 0/1 rows -> (..., nv_total*R) global mask.

    Unselected banks read as unmatched — exactly what the cascade asserts
    (their stage-1 bound exceeded every selected bank's)."""
    p, R = row.shape[-2:]
    cols = (bank_ids[:, None] * R + jnp.arange(R)).reshape(-1)
    flat = row.reshape(*row.shape[:-2], p * R)
    out = jnp.zeros((*row.shape[:-2], nv_total * R), row.dtype)
    return out.at[..., cols].set(flat)


def selected_topk(values: jax.Array, k: int, *, largest: bool,
                  bank_ids: jax.Array, bank_offset=0
                  ) -> Tuple[jax.Array, jax.Array]:
    """``local_topk_candidates`` over a gathered (..., p, R) bank subset.

    Returned indices are GLOBAL rows: the flat position maps back through
    ``bank_ids`` (plus ``bank_offset`` banks on sharded grids, where the
    ids are shard-local).  ``bank_ids`` must be sorted ascending so stable
    top-k tie-breaking matches the full-scan comparator."""
    p, R = values.shape[-2:]
    flat = values.reshape(*values.shape[:-2], -1)
    kl = max(1, min(k, flat.shape[-1]))
    sign = 1.0 if largest else -1.0
    v, idx = jax.lax.top_k(sign * flat, kl)
    bank = jnp.take(bank_ids, idx // R) + bank_offset
    return sign * v, bank * R + idx % R


def merge_selected(dist: jax.Array, match: jax.Array, bank_ids: jax.Array, *,
                   nv_total: int, match_type: str, h_merge: str,
                   v_merge: str, match_param: int, sensing_limit: float = 0.0,
                   threshold: float = 0.0) -> Tuple[jax.Array, jax.Array]:
    """``merge`` for selected-bank results (..., p, nh, R) against a
    ``nv_total``-bank store.  Same (indices, mask) contract: indices are
    global rows of the FULL store; the mask spans all nv_total*R rows."""
    k = max(1, match_param)

    if match_type in ("exact", "threshold"):
        if v_merge != "gather":
            raise ValueError(f"{match_type} match uses gather v-merge")
        row = h_reduce_match(dist, match, match_type=match_type,
                             h_merge=h_merge, sensing_limit=sensing_limit,
                             threshold=threshold)
        mask = scatter_match_rows(row, bank_ids, nv_total)
        return first_k_indices(mask, k), mask

    if match_type == "best":
        if v_merge != "comparator":
            raise ValueError("best match requires comparator v-merge")
        values, largest = h_reduce_best(dist, match, h_merge=h_merge)
        vals, idx = selected_topk(values, k, largest=largest,
                                  bank_ids=bank_ids)
        vals, idx = pad_topk(vals, idx, k, largest=largest)
        K = nv_total * match.shape[-1]
        return finalize_topk(vals, idx, largest=largest, K=K)

    raise ValueError(f"unknown match_type {match_type!r}")


def put_topk_mask(mask: jax.Array, idx: jax.Array) -> jax.Array:
    """Scatter 1.0 at top-k indices (ignoring -1 padding)."""
    safe = jnp.maximum(idx, 0)
    upd = (idx >= 0).astype(mask.dtype)
    # one-hot scatter-add, batched over leading dims
    oh = jax.nn.one_hot(safe, mask.shape[-1], dtype=mask.dtype) * upd[..., None]
    return jnp.clip(mask + oh.sum(axis=-2), 0.0, 1.0)
