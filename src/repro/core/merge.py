"""Merging submodule (paper §III-B/III-C, Fig. 3): the partition-and-merge
problem.

Horizontal merge (N > C): combine results across the ``nh`` axis — each
subarray only saw a segment of the query vector.
    exact  -> AND of per-segment exact matches (exact, lossless)
    best   -> voting: each subarray votes for its best rows; the row with the
              most votes is the approximate global best (Kazemi et al. [7])
    adder  -> (beyond-paper extension) sum per-segment distances: lossless
              best/threshold merge at the cost of an adder tree per row
threshold -> no existing efficient scheme (paper Fig. 3b); only 'adder'.

Vertical merge (K > R): combine results across the ``nv`` axis — different
subarrays hold different entries.
    exact/threshold -> gather: concatenate match lines (lossless)
    best            -> comparator tree over subarray winners

Inputs use the shapes produced by ``subarray.subarray_query``:
    dist  (..., nv, nh, R)
    match (..., nv, nh, R)
Outputs are global, fixed-shape results over padded_K = nv*R rows.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# Horizontal merge: (..., nv, nh, R) -> per-row scores (..., nv, R)
# --------------------------------------------------------------------------
def h_merge_and(match: jax.Array) -> jax.Array:
    """Exact-match AND across segments: 1.0 iff every segment matched."""
    return jnp.prod(match, axis=-2)


def h_merge_voting(match: jax.Array) -> jax.Array:
    """Voting: count segments in which this row was sensed as a match.
    Higher vote count == better approximate match."""
    return jnp.sum(match, axis=-2)


def h_merge_adder(dist: jax.Array) -> jax.Array:
    """Adder: exact full-vector distance = sum of segment distances.
    (Lossless for L1/L2^2/Hamming, all of which are coordinate-separable.)"""
    return jnp.sum(dist, axis=-2)


# --------------------------------------------------------------------------
# Vertical merge: per-row scores (..., nv, R) -> global results (..., nv*R)
# --------------------------------------------------------------------------
def v_merge_gather(row_scores: jax.Array) -> jax.Array:
    """Gather: flatten the (nv, R) grid into global match lines."""
    return row_scores.reshape(*row_scores.shape[:-2], -1)


def v_merge_comparator_topk(values: jax.Array, k: int, largest: bool
                            ) -> Tuple[jax.Array, jax.Array]:
    """Comparator tree: global top-k over all nv*R rows.

    values: (..., nv, R) per-row scores (votes if ``largest`` else distances).
    Returns (topk_values, topk_global_indices).
    """
    flat = values.reshape(*values.shape[:-2], -1)
    sign = 1.0 if largest else -1.0
    v, idx = jax.lax.top_k(sign * flat, k)
    return sign * v, idx


# --------------------------------------------------------------------------
# Full merge dispatch
# --------------------------------------------------------------------------
def merge(dist: jax.Array, match: jax.Array, *, match_type: str,
          h_merge: str, v_merge: str, match_param: int,
          sensing_limit: float = 0.0, threshold: float = 0.0
          ) -> Tuple[jax.Array, jax.Array]:
    """Merge per-subarray results into application-level search results.

    Returns ``(indices, mask)``:
      * ``indices`` (..., match_param): top-k matched entry indices for best
        match (or first-k matches for exact/threshold), padded with -1.
      * ``mask``    (..., padded_K): 1.0 for every matched entry
        (exact/threshold) or for the top-k set (best).

    ``dist`` may be None on the exact/threshold AND-merge path, which
    consumes match lines only (the fused kernel then never materializes the
    distance tensor in HBM).
    """
    nh = match.shape[-2]
    k = max(1, match_param)

    if match_type in ("exact", "threshold"):
        if h_merge == "and":
            if match_type == "threshold" and nh > 1:
                # Paper Fig. 3b: no existing efficient horizontal merge for
                # threshold match.  Use 'adder' (our beyond-paper extension).
                raise ValueError(
                    "threshold match with horizontal partitioning (nh>1) has "
                    "no AND/voting merge (paper Fig. 3b); use h_merge='adder'")
            row = h_merge_and(match)                       # (..., nv, R)
        elif h_merge == "adder":
            total = h_merge_adder(dist)                    # exact distance
            total = jnp.where(jnp.isfinite(total), total, 3.4e38)
            thr = sensing_limit if match_type == "exact" else (
                threshold + sensing_limit)
            row = (total <= thr).astype(jnp.float32)
        elif h_merge == "voting":
            raise ValueError(f"{match_type} match has no voting h-merge "
                             "(paper Fig. 3b)")
        else:
            raise ValueError(f"unknown h_merge {h_merge!r}")
        if v_merge != "gather":
            raise ValueError(f"{match_type} match uses gather v-merge")
        mask = v_merge_gather(row)                          # (..., K)
        # first-k matched indices (fixed shape), -1 padded
        score = mask * 2.0 - jnp.arange(mask.shape[-1]) / mask.shape[-1]
        _, idx = jax.lax.top_k(score, k)
        got = jnp.take_along_axis(mask, idx, axis=-1) > 0
        idx = jnp.where(got, idx, -1)
        return idx, mask

    if match_type == "best":
        if v_merge != "comparator":
            raise ValueError("best match requires comparator v-merge")
        if h_merge == "voting":
            votes = h_merge_voting(match)                   # (..., nv, R)
            # lexicographic (votes desc, distance asc): normalize the
            # distance into [0, 1) so it can never flip a vote difference
            # (votes are small ints — exactly representable in f32).
            total = h_merge_adder(dist)
            finite = jnp.isfinite(total)
            # per-query max (last two axes): with a batched (Q, nv, R) total
            # a global max would couple the queries' tie-break scales
            dmax = jnp.max(jnp.where(finite, total, 0.0),
                           axis=(-2, -1), keepdims=True) + 1.0
            norm = jnp.clip(jnp.where(finite, total, dmax) / dmax,
                            0.0, 0.999)
            score = votes - norm
            sv, idx = v_merge_comparator_topk(score, k, largest=True)
            valid = sv > 0
        elif h_merge == "adder":
            total = h_merge_adder(dist)
            dv, idx = v_merge_comparator_topk(total, k, largest=False)
            valid = jnp.isfinite(dv)
        elif h_merge == "and" and nh == 1:
            # no horizontal partitioning: distances are already global
            total = dist[..., 0, :]                         # (..., nv, R)
            dv, idx = v_merge_comparator_topk(total, k, largest=False)
            valid = jnp.isfinite(dv)
        else:
            raise ValueError(f"best match h_merge {h_merge!r} unsupported")
        idx = jnp.where(valid, idx, -1)
        K = dist.shape[-3] * dist.shape[-1]
        mask = jnp.zeros((*idx.shape[:-1], K))
        mask = put_topk_mask(mask, idx)
        return idx, mask

    raise ValueError(f"unknown match_type {match_type!r}")


def put_topk_mask(mask: jax.Array, idx: jax.Array) -> jax.Array:
    """Scatter 1.0 at top-k indices (ignoring -1 padding)."""
    safe = jnp.maximum(idx, 0)
    upd = (idx >= 0).astype(mask.dtype)
    # one-hot scatter-add, batched over leading dims
    oh = jax.nn.one_hot(safe, mask.shape[-1], dtype=mask.dtype) * upd[..., None]
    return jnp.clip(mask + oh.sum(axis=-2), 0.0, 1.0)
