"""The backend contract behind the CAMASim facade.

C4CAM-style argument: compilers and DSE loops need ONE stable CAM
execution interface regardless of topology.  ``Backend`` is that
contract — ``FunctionalSimulator`` (single chip) and
``ShardedCAMSimulator`` (device mesh) both implement it, and
``make_backend`` turns ``config.sim.backend`` into an instance, so
swapping single-chip ⟷ mesh is a one-line config change with
bit-identical results.
"""
from __future__ import annotations

from typing import Optional, Protocol, Tuple, runtime_checkable

import jax

from .config import CAMConfig
from .functional import CAMState
from .perf import ArchSpecifics, PerfReport
from .results import SearchResult


@runtime_checkable
class Backend(Protocol):
    """Store-once / search-many CAM simulation, any topology.

    ``write`` and ``query`` are the user-facing pipeline;
    ``segment_queries`` / ``search_shard`` are the shard-local pieces a
    distributed driver may call inside a shard_map body; ``plan`` /
    ``arch_specifics`` / ``eval_perf`` are the hardware-prediction side
    (``plan`` makes ``eval_perf`` usable before any data is written).
    """
    config: CAMConfig

    def write(self, stored: jax.Array,
              key: Optional[jax.Array] = None) -> CAMState: ...

    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None,
              valid_count: Optional[int] = None) -> SearchResult: ...

    # mutable-store contract: online edits of the resident state (the
    # serve engine's insert/delete/update requests route here), plus an
    # explicit compaction that is bit-identical to a fresh write of the
    # live rows
    def insert(self, state: CAMState, rows: jax.Array,
               key: Optional[jax.Array] = None
               ) -> Tuple[CAMState, jax.Array]: ...

    def delete(self, state: CAMState, ids) -> CAMState: ...

    def update(self, state: CAMState, ids, rows: jax.Array,
               key: Optional[jax.Array] = None) -> CAMState: ...

    def compact(self, state: CAMState,
                key: Optional[jax.Array] = None) -> CAMState: ...

    # reliability contract (no-ops / errors unless config.reliability is
    # enabled): the serve engine ages the store once per step and scrubs
    # the most-drifted rows on its schedule
    def age_tick(self, state: CAMState, steps: int = 1) -> CAMState: ...

    def scrub(self, state: CAMState,
              key: Optional[jax.Array] = None) -> CAMState: ...

    def segment_queries(self, state: CAMState,
                        queries: jax.Array) -> jax.Array: ...

    def search_shard(self, grid: jax.Array, qseg: jax.Array, **kw
                     ) -> Tuple[Optional[jax.Array], jax.Array]: ...

    def plan(self, entries: int, dims: int) -> ArchSpecifics: ...

    def arch_specifics(self) -> ArchSpecifics: ...

    def eval_perf(self, **kw) -> PerfReport: ...


def make_backend(config: CAMConfig) -> Backend:
    """Instantiate the backend ``config.sim.backend`` names.

    Everything the backend needs (kernels, mesh size, query split, C2C
    fold) is read from the config's ``sim`` section.
    """
    from .functional import FunctionalSimulator
    from .sharded import ShardedCAMSimulator
    if config.sim.backend == "functional":
        return FunctionalSimulator(config)
    if config.sim.backend == "sharded":
        return ShardedCAMSimulator(config)
    raise ValueError(f"unknown sim.backend {config.sim.backend!r}")
