"""Functional simulator (paper Fig. 1b): write simulation + query simulation.

Write:  stored data --quantize--> codes --map--> subarray grid --D2D-->
        CAM data (what the physical cells actually hold).
Query:  query data --quantize(shared scale)--> segments; per query cycle the
        CAM data sees fresh C2C noise; each subarray searches in parallel;
        merge produces application-level match indices.

Everything is jit-able; queries are processed as a batch (vmapped over the
query axis) which is exactly the CAM usage model: store once, search many.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import mapping, merge, quantize, subarray, variation
from .config import CAMConfig


@dataclass
class CAMState:
    """State produced by write simulation (a pytree)."""
    grid: jax.Array          # (nv, nh, R, C) noisy stored codes
    lo: jax.Array            # quantization range (shared with queries)
    hi: jax.Array
    spec: mapping.GridSpec   # static partition spec
    col_valid: jax.Array     # (nh, C)
    row_valid: jax.Array     # (nv, R)


jax.tree_util.register_pytree_node(
    CAMState,
    lambda s: ((s.grid, s.lo, s.hi, s.col_valid, s.row_valid), s.spec),
    lambda spec, leaves: CAMState(leaves[0], leaves[1], leaves[2], spec,
                                  leaves[3], leaves[4]),
)


class FunctionalSimulator:
    """Automated in-memory search simulation (accuracy path of CAMASim)."""

    def __init__(self, config: CAMConfig, use_kernel: bool = False):
        config.validate()
        self.config = config
        self.use_kernel = use_kernel

    # ------------------------------------------------------------- write
    def write(self, stored: jax.Array, key: Optional[jax.Array] = None
              ) -> CAMState:
        """Write simulation: quantize + map + D2D variation.

        ACAM accepts ``stored`` of shape (K, N, 2) holding per-cell
        [lo, hi] ranges (X-TIME-style); other cells take (K, N) values."""
        cfg = self.config
        if stored.ndim == 3:
            assert cfg.circuit.cell_type == "acam",                 "range stores need cell_type='acam'"
        K, N = stored.shape[:2]
        spec = mapping.grid_spec(K, N, cfg.circuit.rows, cfg.circuit.cols)
        return self._write_jit(stored, spec,
                               key if key is not None
                               else jax.random.PRNGKey(0))

    @partial(jax.jit, static_argnums=(0, 2))
    def _write_jit(self, stored, spec, key):
        cfg = self.config
        if stored.ndim == 3:        # ACAM ranges: no quantization
            codes, lo, hi = stored, jnp.zeros(()), jnp.ones(())
        else:
            codes, lo, hi = quantize.quantize_for_cell(
                stored, cfg.circuit.cell_type, cfg.app.data_bits)
        grid = mapping.partition_stored(codes, spec)
        grid = variation.apply_d2d(grid, cfg.device, cfg.app.data_bits, key)
        return CAMState(grid=grid, lo=lo, hi=hi, spec=spec,
                        col_valid=mapping.col_valid_mask(spec),
                        row_valid=mapping.row_valid_mask(spec))

    # ------------------------------------------------------------- query
    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
        """Query simulation.

        queries: (Q, N) application-domain query batch.
        Returns (indices (Q, k), mask (Q, padded_K)); indices padded with -1.
        """
        if queries.ndim == 1:
            idx, mask = self.query(state, queries[None],
                                   key)
            return idx[0], mask[0]
        return self._query_jit(state, queries,
                               key if key is not None
                               else jax.random.PRNGKey(1))

    @partial(jax.jit, static_argnums=(0,))
    def _query_jit(self, state: CAMState, queries, key):
        cfg = self.config
        bits = cfg.app.data_bits
        qcodes, _, _ = quantize.quantize_for_cell(
            queries, cfg.circuit.cell_type, bits, state.lo, state.hi)
        qseg = mapping.partition_query(qcodes, state.spec)   # (Q, nh, C)

        c2c = cfg.device.variation in ("c2c", "both")
        if c2c:
            keys = variation.split_for_queries(key, queries.shape[0])

            def one(q, k):
                g = variation.apply_c2c(state.grid, cfg.device, bits, k)
                return self._search_one(g, q, state)
            return jax.vmap(one)(qseg, keys)
        # no per-query noise: broadcast the query batch through the grid
        return jax.vmap(lambda q: self._search_one(state.grid, q, state)
                        )(qseg)

    def _search_one(self, grid, qseg, state: CAMState):
        cfg = self.config
        dist, match = subarray.subarray_query(
            grid, qseg,
            distance=cfg.app.distance,
            sensing=cfg.circuit.sensing,
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0,
            col_valid=state.col_valid,
            row_valid=state.row_valid,
            use_kernel=self.use_kernel)
        k = cfg.app.match_param if cfg.app.match_type == "best" else max(
            1, min(state.spec.padded_K, 16))
        return merge.merge(
            dist, match,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge,
            v_merge=cfg.arch.v_merge,
            match_param=k,
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0)
