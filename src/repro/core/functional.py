"""Functional simulator (paper Fig. 1b): write simulation + query simulation.

Write:  stored data --quantize--> codes --map--> subarray grid --D2D-->
        CAM data (what the physical cells actually hold).
Query:  query data --quantize(shared scale)--> segments; per query cycle the
        CAM data sees fresh C2C noise; each subarray searches in parallel;
        merge produces application-level match indices.

Everything is jit-able.  Queries follow the CAM usage model — store once,
search many — as ONE fused batched search: the whole (Q, nh, C) segment
block is evaluated against the resident grid in a single
``subarray_query_batched`` call (on the kernel path that is one Pallas pass
that streams each stored tile from HBM once for the entire batch, with the
sense amplifier fused in), then one batched merge.  The per-query vmap of
the old pipeline — which re-streamed the full (nv, nh, R, C) grid once per
query and re-traced the sense/merge stages Q times — is gone.

C2C variation is the one place a per-cycle axis survives: each search cycle
must see fresh array noise, so the batch is processed as a vmap over
Q-tiles of ``c2c_query_tile`` cycles, drawing one noise instance per
tile (a tile models the queries issued within one search cycle).  The
default tile of 1 reproduces the historical per-query noise draw
bit-exactly; larger tiles trade noise granularity for amortizing the noisy
grid construction and search across the tile.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import mapping, merge, prefilter, quantize, reliability, subarray, \
    variation
from .config import CAMConfig
from .reliability import ReliabilityState
from .results import SearchResult


def resolve_sim_overrides(config: CAMConfig, **overrides) -> CAMConfig:
    """Fold deprecated constructor kwargs into ``config.sim``.

    ``None`` means "not given" (take the config value); anything else is a
    legacy override — honored for one release with a DeprecationWarning,
    validated by ``SimConfig`` itself.
    """
    given = {k: v for k, v in overrides.items() if v is not None}
    if not given:
        return config
    warnings.warn(
        f"constructor kwargs {sorted(given)} are deprecated; set them in "
        "the config's sim section (SimConfig) instead",
        DeprecationWarning, stacklevel=3)
    return config.replace(sim=given)


@dataclass
class CAMState:
    """State produced by write simulation (a pytree).

    The last three fields exist only when the search cascade is configured
    (``sim.prefilter != 'off'``): bit-packed per-row signatures for the
    stage-1 bank prefilter, their binarization threshold, and — for the
    'ivf' prefilter — the clustered placement permutation
    (``placed[i] = orig[perm[i]]``) that the query path inverts so returned
    indices always refer to the caller's original row order.
    """
    grid: jax.Array          # (nv, nh, R, C) noisy stored codes
    lo: jax.Array            # quantization range (shared with queries)
    hi: jax.Array
    spec: mapping.GridSpec   # static partition spec
    col_valid: jax.Array     # (nh, C)
    row_valid: jax.Array     # (nv, R)
    sigs: Optional[jax.Array] = None      # (nv, R, W) uint32 signatures
    sig_thr: Optional[jax.Array] = None   # scalar binarization threshold
    perm: Optional[jax.Array] = None      # (padded_K,) placement perm
    codes: Optional[jax.Array] = None     # (nv, nh, R, C[, 2]) CLEAN placed
                                          # codes (pre-D2D) — the mutable
                                          # store's source of truth, so
                                          # ``compact`` can re-place live
                                          # rows bit-identically to a
                                          # fresh write
    rel: Optional[ReliabilityState] = None  # reliability bookkeeping (age,
                                            # wear, retired/failed flags);
                                            # only when config.reliability
                                            # is enabled


jax.tree_util.register_pytree_node(
    CAMState,
    lambda s: ((s.grid, s.lo, s.hi, s.col_valid, s.row_valid, s.sigs,
                s.sig_thr, s.perm, s.codes, s.rel), s.spec),
    lambda spec, leaves: CAMState(leaves[0], leaves[1], leaves[2], spec,
                                  leaves[3], leaves[4], leaves[5],
                                  leaves[6], leaves[7], leaves[8],
                                  leaves[9]),
)


def _replace_state(state: CAMState, **kw) -> CAMState:
    """CAMState copy with the given fields replaced."""
    fields = dict(grid=state.grid, lo=state.lo, hi=state.hi,
                  spec=state.spec, col_valid=state.col_valid,
                  row_valid=state.row_valid, sigs=state.sigs,
                  sig_thr=state.sig_thr, perm=state.perm,
                  codes=state.codes, rel=state.rel)
    fields.update(kw)
    return CAMState(**fields)


class FunctionalSimulator:
    """Automated in-memory search simulation (accuracy path of CAMASim).

    Execution knobs come from ``config.sim`` (use_kernel, c2c_query_tile,
    c2c_fold); the constructor kwargs of the same names are deprecated
    overrides kept for one release.
    """

    def __init__(self, config: CAMConfig,
                 use_kernel: Optional[bool] = None,
                 c2c_query_tile: Optional[int] = None,
                 c2c_fold: Optional[str] = None):
        config = resolve_sim_overrides(config, use_kernel=use_kernel,
                                       c2c_query_tile=c2c_query_tile,
                                       c2c_fold=c2c_fold)
        config.validate()
        self.config = config
        self.use_kernel = config.sim.use_kernel
        self.c2c_query_tile = config.sim.c2c_query_tile
        self.q_tile = config.sim.q_tile
        self.pipeline = config.sim.pipeline
        # Narrow-int / bit-packed kernel fast paths need the stored grid to
        # hold exact small integers: quantized point codes (data_bits wide)
        # with no device variation folded in.  ACAM range grids and analog
        # noise keep the float path.  0 disables; else the code width in
        # bits (threaded to kernels.ops as ``int_codes``).
        app, dev, circ = config.app, config.device, config.circuit
        # (reliability faults/drift turn the sensed grid into floats, so
        # the exact-integer fast path is also gated on reliability off)
        self.int_codes = (
            app.data_bits
            if (self.pipeline and app.data_bits and app.data_bits <= 8
                and app.distance in ("hamming", "l1", "l2", "dot")
                and dev.variation == "none" and circ.cell_type != "acam"
                and not config.reliability.enabled)
            else 0)
        # 'grid': one normal draw over the whole (nv, nh, R, C) grid per
        # cycle (the historical single-device draw).  'bank': one draw per
        # nv bank from fold_in(cycle_key, bank index) — bit-identical no
        # matter how the nv axis is split across devices, so the sharded
        # simulator (core.sharded) always runs its reference in this mode.
        self.c2c_fold = config.sim.c2c_fold
        # measured-model overrides: fitted constants from
        # benchmarks/calibrate_kernel_model.py, pinned in the config
        if (config.sim.step_overhead_s is not None
                or config.sim.bcast_budget_bytes is not None):
            from repro.kernels.cam_search import set_kernel_model
            set_kernel_model(
                step_overhead_s=config.sim.step_overhead_s,
                bcast_budget_bytes=config.sim.bcast_budget_bytes)
        self._arch = None          # perf.ArchSpecifics, set by write()/plan()

    # ------------------------------------------------------------- perf
    def plan(self, entries: int, dims: int):
        """Estimator-only planning: derive ``ArchSpecifics`` from shapes
        alone so ``eval_perf`` works *before* (or without) ``write``."""
        from .perf import estimate_arch
        self._arch = estimate_arch(self.config, entries, dims)
        return self._arch

    def arch_specifics(self):
        if self._arch is None:
            raise RuntimeError(
                "call write() or plan() before querying arch specifics")
        return self._arch

    def eval_perf(self, n_queries: int = 1, include_write: bool = False,
                  ops_per_query: int = 1,
                  clock_hz: Optional[float] = None,
                  mesh=None, queries_per_batch: int = 1,
                  searched_fraction: Optional[float] = None,
                  prefilter_bits: Optional[int] = None):
        """Hardware performance prediction for the written (or planned)
        store; see ``perf.perf_report`` for the report shape.  The cascade
        knobs default to what ``config.sim`` implies (``cascade_billing``);
        pass them explicitly to sweep the routing budget pre-write."""
        from .perf import perf_report
        return perf_report(self.config, self.arch_specifics(), mesh=mesh,
                           n_queries=n_queries, include_write=include_write,
                           ops_per_query=ops_per_query, clock_hz=clock_hz,
                           queries_per_batch=queries_per_batch,
                           searched_fraction=searched_fraction,
                           prefilter_bits=prefilter_bits)

    # ------------------------------------------------------------- write
    def write(self, stored: jax.Array, key: Optional[jax.Array] = None
              ) -> CAMState:
        """Write simulation: quantize + map + D2D variation.

        ACAM accepts ``stored`` of shape (K, N, 2) holding per-cell
        [lo, hi] ranges (X-TIME-style); other cells take (K, N) values."""
        cfg = self.config
        if stored.ndim == 3:
            assert cfg.circuit.cell_type == "acam",                 "range stores need cell_type='acam'"
            if cfg.app.distance != "range":
                # fail loudly at write time: the jnp path used to compute
                # range violations silently mislabeled as the configured
                # distance, while the kernel path rejected the combination
                # deep in dispatch
                raise ValueError(
                    "ACAM [lo, hi] range stores require distance='range' "
                    f"(got {cfg.app.distance!r})")
        elif cfg.app.distance == "range":
            raise ValueError(
                "distance='range' requires a (K, N, 2) range store "
                f"(got shape {tuple(stored.shape)})")
        K, N = stored.shape[:2]
        self.plan(K, N)            # record arch specifics for eval_perf
        spec = mapping.grid_spec(K, N, cfg.circuit.rows, cfg.circuit.cols,
                                 cfg.sim.capacity)
        key = key if key is not None else jax.random.PRNGKey(0)
        return self._heal_failed(self._write_jit(stored, spec, key), key)

    @partial(jax.jit, static_argnums=(0, 2))
    def _write_jit(self, stored, spec, key):
        cfg = self.config
        if stored.ndim == 3:        # ACAM ranges: no quantization
            codes, lo, hi = stored, jnp.zeros(()), jnp.ones(())
        else:
            codes, lo, hi = quantize.quantize_for_cell(
                stored, cfg.circuit.cell_type, cfg.app.data_bits)
        return self._place_codes(codes, lo, hi, spec, key)

    def _place_codes(self, codes, lo, hi, spec, key):
        """Place already-quantized code rows: prefilter signatures /
        clustered permutation, partition, D2D programming noise.  Shared
        by ``write`` (fresh data) and ``compact`` (the live rows' resident
        clean codes with the store's frozen scale)."""
        cfg = self.config
        sigs = sig_thr = perm = None
        if cfg.sim.prefilter != "off":
            cvals = prefilter.signature_values(codes)
            if cfg.sim.prefilter == "ivf":
                # clustered placement: reorder rows so similar entries
                # colocate in the same nv bank; the query path maps
                # indices back through perm so callers never see it
                perm = mapping.placement_perm(cvals, spec)
                codes = jnp.take(codes, perm[:spec.K], axis=0)
                cvals = jnp.take(cvals, perm[:spec.K], axis=0)
            # signatures come from the clean placed codes, BEFORE the D2D
            # programming noise below: stage 1 models a separate 1-bit
            # TCAM slab programmed from the same source data
            sig_thr = prefilter.signature_threshold(
                cvals, cfg.circuit.cell_type, cfg.app.data_bits)
            sigs = prefilter.row_signatures(cvals, sig_thr, spec,
                                            cfg.sim.signature_bits)
        clean = mapping.partition_stored(codes, spec)
        relcfg = cfg.reliability
        rel = None
        if relcfg.enabled:
            # verified programming over every slot: attempt 0 draws the
            # legacy per-slot noise, so with verify/faults all zero the
            # grid is bit-identical to apply_d2d_rowfold
            nv, nh, R, C = clean.shape[:4]
            extra = clean.shape[4:]
            rows = jnp.moveaxis(clean, 2, 1).reshape(nv * R, nh, C, *extra)
            slots = jnp.arange(nv * R, dtype=jnp.int32)
            live = slots < spec.K
            prog, attempts, ok = reliability.program_rows_verified(
                rows, jnp.zeros_like(rows), slots, dev=cfg.device,
                rel=relcfg, bits=cfg.app.data_bits, key=key,
                col_valid=mapping.col_valid_mask(spec),
                code_hi=reliability.code_ceiling(cfg), R=R, live=live)
            grid = jnp.moveaxis(prog.reshape(nv, R, nh, C, *extra), 1, 2)
            rel = ReliabilityState(
                age=jnp.zeros((), jnp.int32),
                prog_age=jnp.zeros((nv, R), jnp.int32),
                writes=jnp.where(live, attempts, 0).reshape(nv, R),
                retired=jnp.zeros((nv, R), bool),
                failed=(~ok & live).reshape(nv, R))
        elif cfg.sim.d2d_fold == "row":
            grid = variation.apply_d2d_rowfold(clean, cfg.device,
                                               cfg.app.data_bits, key)
        else:
            grid = variation.apply_d2d(clean, cfg.device, cfg.app.data_bits,
                                       key)
        return CAMState(grid=grid, lo=lo, hi=hi, spec=spec,
                        col_valid=mapping.col_valid_mask(spec),
                        row_valid=mapping.row_valid_mask(spec),
                        sigs=sigs, sig_thr=sig_thr, perm=perm, codes=clean,
                        rel=rel)

    # --------------------------------------------------------- mutations
    # Online edits of the resident store (free-list allocation over the
    # existing row_valid masks): deletes flip validity bits, inserts claim
    # free row slots, updates re-program live slots in place.  Grid shape,
    # signatures block, and placement permutation never change — only the
    # touched rows' cells/signatures are re-derived — so a sharded store
    # mutates without a re-shard.
    def _check_mutable(self):
        cfg = self.config
        if (cfg.device.variation in ("d2d", "both")
                and cfg.sim.d2d_fold != "row"):
            # the grid-level D2D draw cannot be reproduced for a single
            # row, so incremental writes could never match a fresh write
            raise ValueError(
                "online insert/update with D2D variation requires "
                "sim.d2d_fold='row' (per-row-slot RNG fold)")

    def _check_rows(self, state: CAMState, rows: jax.Array):
        cfg = self.config
        want_range = cfg.app.distance == "range"
        if want_range and (rows.ndim != 3 or rows.shape[-1] != 2):
            raise ValueError(
                "range stores take (M, N, 2) [lo, hi] rows "
                f"(got shape {tuple(rows.shape)})")
        if not want_range and rows.ndim != 2:
            raise ValueError(
                f"expected (M, N) rows (got shape {tuple(rows.shape)})")
        if rows.shape[1] != state.spec.N:
            raise ValueError(
                f"row width {rows.shape[1]} != stored dims {state.spec.N}")

    def free_slots(self, state: CAMState) -> np.ndarray:
        """Global row slots currently free.  Only slots below
        ``spec.padded_K`` count — a sharded state's all-invalid padding
        banks are not allocatable capacity.  Without reliability the
        order is ascending; with it the allocator is wear-aware: retired
        slots never come back, and the least-worn (fewest programming
        pulses) free slot is claimed first (ascending slot id breaks
        ties, so an unworn store allocates exactly like the legacy
        free list)."""
        padded_K = state.spec.padded_K
        rv = np.asarray(state.row_valid).reshape(-1)[:padded_K]
        free = np.where(rv == 0)[0]
        if state.rel is not None and self.config.reliability.enabled:
            retired = np.asarray(state.rel.retired).reshape(-1)[:padded_K]
            free = free[~retired[free]]
            writes = np.asarray(state.rel.writes).reshape(-1)[:padded_K]
            free = free[np.argsort(writes[free], kind="stable")]
        return free

    def _slots_of(self, state: CAMState, ids) -> jax.Array:
        """Map caller-order row ids to global row slots (inverse of the
        placement permutation); every id must name a live row."""
        ids = np.asarray(ids).reshape(-1)
        padded_K = state.spec.padded_K
        if ids.size and (ids.min() < 0 or ids.max() >= padded_K):
            raise ValueError(f"row ids must be in [0, {padded_K})")
        if state.perm is not None:
            inv = np.empty(padded_K, np.int64)
            inv[np.asarray(state.perm)] = np.arange(padded_K)
            slots = inv[ids]
        else:
            slots = ids
        rv = np.asarray(state.row_valid).reshape(-1)
        dead = ids[rv[slots] == 0]
        if dead.size:
            raise ValueError(f"row ids {dead.tolist()} are not live rows")
        return jnp.asarray(slots, jnp.int32)

    def insert(self, state: CAMState, rows: jax.Array,
               key: Optional[jax.Array] = None
               ) -> Tuple[CAMState, jax.Array]:
        """Claim free row slots for ``rows`` (M, N[, 2]) and program them.

        Returns ``(new_state, ids)`` where ``ids`` (M,) are the caller-order
        row indices the inserted rows will report in search results.  With
        ``sim.d2d_fold='row'`` the programmed cells (noise included) are
        bit-identical to the slots' rows under a fresh ``write`` with the
        same key.  Raises when the store lacks free slots — size head-room
        with ``sim.capacity`` (``perf_report``'s inserts/sec figure prices
        it)."""
        rows = jnp.asarray(rows)
        self._check_mutable()
        self._check_rows(state, rows)
        free = self.free_slots(state)
        if rows.shape[0] > free.size:
            raise ValueError(
                f"store full: {rows.shape[0]} inserts but only {free.size} "
                "free slots — delete rows, compact(), or re-write with a "
                "larger sim.capacity")
        slots = jnp.asarray(free[:rows.shape[0]], jnp.int32)
        key = key if key is not None else jax.random.PRNGKey(0)
        new_state = self._heal_failed(
            self._write_rows(state, rows, slots, key, True), key)
        # ids come from the pre-heal perm: healing swaps a failed slot's
        # perm entry along with its data, so the returned NAME stays
        # valid wherever the row physically lands
        ids = (jnp.take(state.perm, slots) if state.perm is not None
               else slots)
        return new_state, ids

    def delete(self, state: CAMState, ids) -> CAMState:
        """Flip the validity bits of live rows ``ids`` (caller order).
        Deleted rows never match again (search and the bank prefilter both
        mask on ``row_valid``) and their slots return to the free list."""
        slots = self._slots_of(state, ids)
        v, r = slots // state.spec.R, slots % state.spec.R
        return _replace_state(state,
                              row_valid=state.row_valid.at[v, r].set(0.0))

    def update(self, state: CAMState, ids, rows: jax.Array,
               key: Optional[jax.Array] = None) -> CAMState:
        """Re-program live rows ``ids`` in place with new ``rows`` data
        (fresh programming noise from ``key``'s per-slot fold)."""
        rows = jnp.asarray(rows)
        self._check_mutable()
        self._check_rows(state, rows)
        slots = self._slots_of(state, ids)
        if slots.shape[0] != rows.shape[0]:
            raise ValueError(
                f"{slots.shape[0]} ids but {rows.shape[0]} rows")
        key = key if key is not None else jax.random.PRNGKey(0)
        return self._heal_failed(
            self._write_rows(state, rows, slots, key, False), key)

    @partial(jax.jit, static_argnums=(0, 5, 6))
    def _write_rows(self, state: CAMState, rows, slots, key, set_valid,
                    is_codes=False):
        """Program ``rows`` (M, N[, 2]) into global slots ``slots`` (M,):
        quantize with the store's frozen scale, scatter clean codes +
        per-slot-folded D2D noise, refresh only the touched rows'
        signatures.  ``is_codes`` skips quantization for rows already in
        the code domain (scrub and spare-heal re-program resident clean
        codes).  With reliability enabled, programming runs write-verify
        (``reliability.program_rows_verified``) and updates the wear
        counters / failed flags."""
        cfg = self.config
        bits = cfg.app.data_bits
        spec = state.spec
        if is_codes or rows.ndim == 3:   # ACAM ranges: no quantization
            codes = rows
        else:
            codes, _, _ = quantize.quantize_for_cell(
                rows, cfg.circuit.cell_type, bits, state.lo, state.hi)
        segs = mapping.partition_rows(codes, spec)       # (M, nh, C[, 2])
        v, r = slots // spec.R, slots % spec.R
        rel = state.rel
        relcfg = cfg.reliability
        if relcfg.enabled and rel is not None:
            old = state.grid[v, :, r]                    # (M, nh, C[, 2])
            worn = (rel.writes[v, r] >= relcfg.endurance_writes
                    if relcfg.endurance_writes > 0
                    else jnp.zeros(slots.shape, bool))
            noisy, attempts, ok = reliability.program_rows_verified(
                segs, old, slots, dev=cfg.device, rel=relcfg, bits=bits,
                key=key, col_valid=state.col_valid,
                code_hi=reliability.code_ceiling(cfg), R=spec.R,
                worn=worn)
            rel = ReliabilityState(
                age=rel.age,
                # worn cells never actually re-program, so their drift
                # clock keeps running from the last real program
                prog_age=rel.prog_age.at[v, r].set(
                    jnp.where(worn, rel.prog_age[v, r], rel.age)),
                writes=rel.writes.at[v, r].add(attempts),
                retired=rel.retired,
                failed=rel.failed.at[v, r].set(~ok))
        else:
            noisy = variation.apply_d2d_slots(segs, cfg.device, bits, key,
                                              slots)
        grid = state.grid.at[v, :, r].set(noisy)
        clean = (state.codes.at[v, :, r].set(segs)
                 if state.codes is not None else None)
        row_valid = (state.row_valid.at[v, r].set(1.0) if set_valid
                     else state.row_valid)
        sigs = state.sigs
        if sigs is not None:
            cvals = prefilter.signature_values(codes)
            sigs = prefilter.update_row_signatures(
                sigs, cvals, state.sig_thr, spec, cfg.sim.signature_bits,
                slots)
        return CAMState(grid=grid, lo=state.lo, hi=state.hi, spec=spec,
                        col_valid=state.col_valid, row_valid=row_valid,
                        sigs=sigs, sig_thr=state.sig_thr, perm=state.perm,
                        codes=clean, rel=rel)

    def compact(self, state: CAMState,
                key: Optional[jax.Array] = None) -> CAMState:
        """Re-place the live rows as a fresh store: gather their clean
        codes in caller order and re-run the full placement pipeline
        (signature threshold, IVF clustering, partition, D2D noise) with
        the store's frozen quantization scale.  Bit-identical to a fresh
        ``write`` of the live rows whenever that write derives the same
        scale (and the same ``key`` is used); the grid shrinks back to
        ``grid_spec(K_live, ..., sim.capacity)``.

        After compaction row ids are renumbered 0..K_live-1 in the old
        caller order (the usual consequence of compacting a free list)."""
        if state.codes is None:
            raise ValueError("state has no resident clean codes "
                             "(written by an older version?) — re-write "
                             "the store to enable compact()")
        cfg = self.config
        spec = state.spec
        rv = np.asarray(state.row_valid).reshape(-1)[:spec.padded_K]
        live = np.where(rv > 0)[0]
        if live.size == 0:
            raise ValueError("cannot compact an empty store")
        ids = (np.asarray(state.perm)[live] if state.perm is not None
               else live)
        slots = jnp.asarray(live[np.argsort(ids, kind="stable")], jnp.int32)
        rows = self._gather_code_rows(state, slots)
        new_spec = mapping.grid_spec(int(live.size), spec.N, spec.R, spec.C,
                                     cfg.sim.capacity)
        self.plan(int(live.size), spec.N)
        key = key if key is not None else jax.random.PRNGKey(0)
        # reliability note: compaction models a re-deployment onto a
        # fresh slab, so wear/age counters reset with the placement
        return self._heal_failed(
            self._place_jit(rows, state.lo, state.hi, new_spec, key), key)

    @partial(jax.jit, static_argnums=(0,))
    def _gather_code_rows(self, state: CAMState, slots) -> jax.Array:
        """Un-partition the clean codes of the given slots: (M, N[, 2])."""
        spec = state.spec
        c = state.codes
        extra = c.shape[4:]
        rows = jnp.moveaxis(c, 2, 1).reshape(
            c.shape[0] * spec.R, spec.nh * spec.C, *extra)
        return jnp.take(rows, slots, axis=0)[:, :spec.N]

    @partial(jax.jit, static_argnums=(0, 4))
    def _place_jit(self, codes, lo, hi, spec, key):
        return self._place_codes(codes, lo, hi, spec, key)

    # ------------------------------------------------------- reliability
    def _heal_failed(self, state: CAMState, key) -> CAMState:
        """Spare-row healing: remap live rows that failed write-verify
        (dead/stuck/worn slots) onto same-bank spare slots, re-programming
        their resident clean codes there.  The placement permutation
        swaps along with the data, so callers' row ids never change.
        Rounds repeat while verify still fails and spares remain (a spare
        can itself be dead — the next round retires it and tries the
        next-least-worn one); a row whose bank runs out of spare budget
        stays flagged ``failed`` in place (degraded, honestly reported)."""
        relcfg = self.config.reliability
        if (state.rel is None or not relcfg.enabled
                or relcfg.spares_per_bank < 1 or state.codes is None):
            return state
        # each round retires at least one slot, so this terminates; the
        # explicit bound is a backstop against pathological fault maps
        for _ in range(8):
            healed = self._heal_round(state, key)
            if healed is None:
                break
            state = healed
        return state

    def _heal_round(self, state: CAMState, key):
        relcfg = self.config.reliability
        spec = state.spec
        padded_K = spec.padded_K
        rv = np.asarray(state.row_valid).reshape(-1)[:padded_K]
        rel = state.rel
        src, dst = reliability.plan_spares(
            rv,
            np.asarray(rel.failed).reshape(-1)[:padded_K],
            np.asarray(rel.retired).reshape(-1)[:padded_K],
            np.asarray(rel.writes).reshape(-1)[:padded_K],
            spec.R, relcfg.spares_per_bank)
        if not src:
            return None
        src_j = jnp.asarray(src, jnp.int32)
        dst_j = jnp.asarray(dst, jnp.int32)
        rows = self._gather_code_rows(state, src_j)
        # the spare slots draw the same per-slot noise a direct write
        # with this key would, keeping insert/fresh-write parity intact
        state = self._write_rows(state, rows, dst_j, key, True, True)
        vs, rs = src_j // spec.R, src_j % spec.R
        rel = state.rel
        rel = ReliabilityState(
            age=rel.age, prog_age=rel.prog_age, writes=rel.writes,
            retired=rel.retired.at[vs, rs].set(True),
            failed=rel.failed.at[vs, rs].set(False))
        perm = (np.asarray(state.perm).copy() if state.perm is not None
                else np.arange(padded_K))
        perm[np.asarray(dst)], perm[np.asarray(src)] = \
            perm[np.asarray(src)], perm[np.asarray(dst)].copy()
        return _replace_state(
            state,
            row_valid=state.row_valid.at[vs, rs].set(0.0),
            perm=jnp.asarray(perm, jnp.int32), rel=rel)

    def age_tick(self, state: CAMState, steps: int = 1) -> CAMState:
        """Advance the logical store age (drift clock) by ``steps``.
        The serve engine calls this once per ``CAMSearchServer.step()``."""
        if state.rel is None:
            return state
        rel = state.rel
        return _replace_state(state, rel=ReliabilityState(
            age=(rel.age + jnp.int32(steps)).astype(jnp.int32),
            prog_age=rel.prog_age, writes=rel.writes,
            retired=rel.retired, failed=rel.failed))

    def scrub(self, state: CAMState,
              key: Optional[jax.Array] = None) -> CAMState:
        """Background scrub: re-program the ``scrub_rows`` most-drifted
        live rows from their resident clean codes (write-verify applies;
        a row that can no longer hold its data is spare-healed).  A
        no-op when nothing has drifted."""
        relcfg = self.config.reliability
        if not relcfg.enabled or state.rel is None:
            raise ValueError("scrub() requires config.reliability.enabled "
                             "and a reliability-tracked state")
        if state.codes is None:
            raise ValueError("state has no resident clean codes — re-write "
                             "the store to enable scrub()")
        self._check_mutable()
        spec = state.spec
        padded_K = spec.padded_K
        slots = reliability.pick_scrub_slots(
            np.asarray(state.row_valid).reshape(-1)[:padded_K],
            np.asarray(state.rel.prog_age).reshape(-1)[:padded_K],
            int(np.asarray(state.rel.age)), relcfg.scrub_rows)
        if slots.size == 0:
            return state
        key = key if key is not None else jax.random.PRNGKey(0)
        slots_j = jnp.asarray(slots, jnp.int32)
        rows = self._gather_code_rows(state, slots_j)
        return self._heal_failed(
            self._write_rows(state, rows, slots_j, key, False, True), key)

    # ------------------------------------------------------------- query
    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None,
              valid_count: Optional[int] = None) -> SearchResult:
        """Query simulation.

        queries: (Q, N) application-domain query batch.
        Returns a ``SearchResult`` (indices (Q, k) padded with -1, mask
        (Q, padded_K)); it unpacks as the historical ``(idx, mask)`` tuple.

        ``valid_count`` marks only the first ``valid_count`` batch rows as
        real queries: the serve loop pads short batches to a fixed width,
        and the pad rows must not influence the cascade's shared bank
        routing (``select_banks``).  Passed as a traced scalar so varying
        counts at one batch width share a single compilation.  ``None``
        (every row real) is bit-identical to ``valid_count=Q``; non-cascade
        searches evaluate each row independently, so the knob only affects
        routed searches.
        """
        if queries.ndim == 1:
            idx, mask = self.query(state, queries[None],
                                   key)
            return SearchResult(idx[0], mask[0])
        idx, mask = self._query_jit(state, queries,
                                    key if key is not None
                                    else jax.random.PRNGKey(1),
                                    None if valid_count is None
                                    else jnp.asarray(valid_count, jnp.int32))
        return SearchResult(idx, mask)

    @partial(jax.jit, static_argnums=(0,))
    def _query_jit(self, state: CAMState, queries, key, valid_count=None):
        idx, mask = self._query_inner(state, queries, key, valid_count)
        return self._to_original(state, idx, mask)

    def _effective_state(self, state: CAMState) -> CAMState:
        """Read path: what a search senses.  Overlays drift decay and the
        deterministic fault maps on the stored grid (a no-op unless
        reliability is enabled — the off path touches nothing)."""
        cfg = self.config
        if not cfg.reliability.enabled or state.rel is None:
            return state
        return _replace_state(
            state, grid=reliability.effective_grid(state.grid, state.rel,
                                                   cfg))

    def _query_inner(self, state: CAMState, queries, key, valid_count=None):
        cfg = self.config
        state = self._effective_state(state)
        bits = cfg.app.data_bits
        qcodes = self.query_codes(state, queries)            # (Q, N)
        qseg = mapping.partition_query(qcodes, state.spec)   # (Q, nh, C)

        if cfg.sim.cascade_enabled() and state.sigs is not None:
            valid = (None if valid_count is None
                     else jnp.arange(queries.shape[0]) < valid_count)
            return self._query_cascade(state, qcodes, qseg, key, valid)

        if cfg.device.variation not in ("c2c", "both"):
            # store once, search many: one fused batched pass
            return self._search_batch(state.grid, qseg, state)

        if self.c2c_fold == "bank":
            # per-bank RNG fold (the shard-invariant draw): search the
            # whole batch through the shard-local entry with v_offset=0,
            # then one batched merge — the single-device reference for
            # the sharded simulator's parity guarantee.
            dist, match = self.search_shard(
                state.grid, qseg, col_valid=state.col_valid,
                row_valid=state.row_valid, key=key)
            return self.merge_rows(dist, match, state.spec.padded_K)

        # C2C: fresh array noise per search cycle; one Q-tile per cycle.
        # All cycle noises are drawn in one batched primitive and the cycles
        # run as a vmap (parallel, like the old per-query pipeline) — the
        # memory high-water mark (n_tiles noisy grids) matches the old path
        # at the default tile of 1 and shrinks as the tile grows.
        Q = qseg.shape[0]
        tile = min(self.c2c_query_tile, Q)
        pad = (-Q) % tile
        qt = jnp.pad(qseg, ((0, pad), (0, 0), (0, 0)))
        n_tiles = qt.shape[0] // tile
        qt = qt.reshape(n_tiles, tile, *qseg.shape[1:])
        keys = variation.split_for_queries(key, n_tiles)
        noisy = variation.apply_c2c_batched(state.grid, cfg.device, bits,
                                            keys)

        idx, mask = jax.vmap(
            lambda g, q: self._search_batch(g, q, state))(noisy, qt)
        idx = idx.reshape(n_tiles * tile, *idx.shape[2:])[:Q]
        mask = mask.reshape(n_tiles * tile, *mask.shape[2:])[:Q]
        return idx, mask

    # ------------------------------------------------- shard-local pieces
    # The sharded simulator (core.sharded) drives these from inside a
    # shard_map body: each device runs the same quantize/search pipeline on
    # its local nv (bank) shard of the grid, and only the vertical merge
    # crosses devices.
    def need_dist(self) -> bool:
        """The AND merge consumes match lines only; the fused kernel then
        skips the (Q, nv, nh, R) distance write-back entirely."""
        cfg = self.config
        return not (cfg.app.match_type in ("exact", "threshold")
                    and cfg.arch.h_merge == "and")

    def match_k(self, padded_K: int) -> int:
        """Result width k of the merge for a padded_K-row store."""
        cfg = self.config
        return merge.match_k(cfg.app.match_type, cfg.app.match_param,
                             padded_K)

    def query_codes(self, state: CAMState, queries: jax.Array) -> jax.Array:
        """Quantize with the store's shared scale: (Q, N) code-domain."""
        cfg = self.config
        qcodes, _, _ = quantize.quantize_for_cell(
            queries, cfg.circuit.cell_type, cfg.app.data_bits,
            state.lo, state.hi)
        return qcodes

    def segment_queries(self, state: CAMState, queries: jax.Array
                        ) -> jax.Array:
        """Quantize (shared scale) + partition: (Q, N) -> (Q, nh, C)."""
        return mapping.partition_query(self.query_codes(state, queries),
                                       state.spec)

    # --------------------------------------------------- cascade (stage 1)
    def route_banks(self, state: CAMState, qcodes: jax.Array,
                    p: Optional[int] = None,
                    valid: Optional[jax.Array] = None) -> jax.Array:
        """Stage-1 routing: (Q, N) query codes -> (p,) sorted bank ids.
        ``valid`` (Q,) bool excludes pad rows from the shared selection."""
        cfg = self.config
        qsig = prefilter.query_signatures(qcodes, state.sig_thr, state.spec,
                                          cfg.sim.signature_bits)
        scores = prefilter.bank_scores(state.sigs, qsig, state.row_valid,
                                       use_kernel=self.use_kernel)
        if p is None:
            p = min(cfg.sim.top_p_banks, state.spec.nv)
        return prefilter.select_banks(scores, p, valid)

    def _query_cascade(self, state: CAMState, qcodes, qseg, key,
                       valid: Optional[jax.Array] = None):
        """Two-stage search: route to top-p banks, exact-search only the
        gathered (p, nh, R, C) sub-grid, merge against original bank ids.

        With ``top_p_banks >= nv`` the selection is ``arange(nv)``, the
        gather is the identity, and the result is bit-identical to the
        full scan (a parity test asserts this per cell/merge combo)."""
        cfg = self.config
        spec = state.spec
        bank_ids = self.route_banks(state, qcodes, valid=valid)
        sub_grid = jnp.take(state.grid, bank_ids, axis=0)
        sub_rv = jnp.take(state.row_valid, bank_ids, axis=0)
        # C2C noise (if any) folds per ORIGINAL bank id, so the surviving
        # banks see exactly the noise they would in a full scan
        dist, match = self.search_shard(
            sub_grid, qseg, col_valid=state.col_valid, row_valid=sub_rv,
            key=key, bank_ids=bank_ids)
        return merge.merge_selected(
            dist, match, bank_ids, nv_total=spec.nv,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge,
            v_merge=cfg.arch.v_merge,
            match_param=self.match_k(spec.padded_K),
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0)

    def _to_original(self, state: CAMState, idx, mask):
        """Map placed-order results back to the caller's row order.

        ``placed[i] = orig[perm[i]]``, so a placed index maps through a
        gather and the placed mask scatters onto original positions."""
        if state.perm is None:
            return idx, mask
        safe = jnp.take(state.perm, jnp.maximum(idx, 0))
        idx = jnp.where(idx >= 0, safe, -1)
        mask = jnp.zeros_like(mask).at[..., state.perm].set(mask)
        return idx, mask

    def search_shard(self, grid: jax.Array, qseg: jax.Array, *,
                     col_valid: jax.Array, row_valid: jax.Array,
                     key: Optional[jax.Array] = None, v_offset=0,
                     cycle_keys: Optional[jax.Array] = None,
                     bank_ids: Optional[jax.Array] = None
                     ) -> Tuple[Optional[jax.Array], jax.Array]:
        """Shard-local search over a pre-split grid.

        ``grid`` may be an nv-shard of the full stored grid whose first
        bank has global index ``v_offset`` (``row_valid`` is the matching
        (nv_local, R) shard; ``col_valid`` is replicated).  C2C noise uses
        the per-bank RNG fold (``variation.apply_c2c_banked``), so any
        split of the nv axis draws bit-identical noise.  ``cycle_keys``
        overrides the per-cycle key derivation for query-sharded batches
        (the caller splits the global key and slices this shard's cycles).
        ``bank_ids`` names the global bank each grid slot holds when the
        shard is a *gathered* subset (the cascade's top-p banks) rather
        than a contiguous slice — C2C noise then folds by those ids.

        Returns ``(dist, match)``, each (Q, nv_local, nh, R); ``dist`` is
        None when the merge consumes match lines only.
        """
        cfg = self.config
        bits = cfg.app.data_bits

        def run(g, q):
            return subarray.subarray_query_batched(
                g, q,
                distance=cfg.app.distance,
                sensing=cfg.circuit.sensing,
                sensing_limit=cfg.circuit.sensing_limit,
                threshold=float(cfg.app.match_param)
                if cfg.app.match_type == "threshold" else 0.0,
                col_valid=col_valid,
                row_valid=row_valid,
                use_kernel=self.use_kernel,
                want_dist=self.need_dist(),
                q_tile=self.q_tile,
                pipeline=self.pipeline,
                int_codes=self.int_codes)

        if cfg.device.variation not in ("c2c", "both"):
            return run(grid, qseg)

        Q = qseg.shape[0]
        tile = min(self.c2c_query_tile, Q)
        pad = (-Q) % tile
        qt = jnp.pad(qseg, ((0, pad), (0, 0), (0, 0)))
        n_tiles = qt.shape[0] // tile
        qt = qt.reshape(n_tiles, tile, *qseg.shape[1:])
        if cycle_keys is None:
            cycle_keys = variation.split_for_queries(key, n_tiles)
        noisy = variation.apply_c2c_banked(grid, cfg.device, bits,
                                           cycle_keys, v_offset,
                                           bank_ids=bank_ids)
        dist, match = jax.vmap(run)(noisy, qt)
        match = match.reshape(n_tiles * tile, *match.shape[2:])[:Q]
        if dist is not None:
            dist = dist.reshape(n_tiles * tile, *dist.shape[2:])[:Q]
        return dist, match

    def merge_rows(self, dist, match, padded_K: int):
        """Single-device merge of (Q, nv, nh, R) subarray outputs."""
        cfg = self.config
        return merge.merge(
            dist, match,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge,
            v_merge=cfg.arch.v_merge,
            match_param=self.match_k(padded_K),
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0)

    def _search_batch(self, grid, qseg, state: CAMState):
        """One fused batched search + merge over a (Q, nh, C) block."""
        cfg = self.config
        dist, match = subarray.subarray_query_batched(
            grid, qseg,
            distance=cfg.app.distance,
            sensing=cfg.circuit.sensing,
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0,
            col_valid=state.col_valid,
            row_valid=state.row_valid,
            use_kernel=self.use_kernel,
            want_dist=self.need_dist(),
            q_tile=self.q_tile,
            pipeline=self.pipeline,
            int_codes=self.int_codes)
        return self.merge_rows(dist, match, state.spec.padded_K)
