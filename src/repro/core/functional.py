"""Functional simulator (paper Fig. 1b): write simulation + query simulation.

Write:  stored data --quantize--> codes --map--> subarray grid --D2D-->
        CAM data (what the physical cells actually hold).
Query:  query data --quantize(shared scale)--> segments; per query cycle the
        CAM data sees fresh C2C noise; each subarray searches in parallel;
        merge produces application-level match indices.

Everything is jit-able.  Queries follow the CAM usage model — store once,
search many — as ONE fused batched search: the whole (Q, nh, C) segment
block is evaluated against the resident grid in a single
``subarray_query_batched`` call (on the kernel path that is one Pallas pass
that streams each stored tile from HBM once for the entire batch, with the
sense amplifier fused in), then one batched merge.  The per-query vmap of
the old pipeline — which re-streamed the full (nv, nh, R, C) grid once per
query and re-traced the sense/merge stages Q times — is gone.

C2C variation is the one place a per-cycle axis survives: each search cycle
must see fresh array noise, so the batch is processed as a vmap over
Q-tiles of ``c2c_query_tile`` cycles, drawing one noise instance per
tile (a tile models the queries issued within one search cycle).  The
default tile of 1 reproduces the historical per-query noise draw
bit-exactly; larger tiles trade noise granularity for amortizing the noisy
grid construction and search across the tile.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import mapping, merge, quantize, subarray, variation
from .config import CAMConfig
from .results import SearchResult


def resolve_sim_overrides(config: CAMConfig, **overrides) -> CAMConfig:
    """Fold deprecated constructor kwargs into ``config.sim``.

    ``None`` means "not given" (take the config value); anything else is a
    legacy override — honored for one release with a DeprecationWarning,
    validated by ``SimConfig`` itself.
    """
    given = {k: v for k, v in overrides.items() if v is not None}
    if not given:
        return config
    warnings.warn(
        f"constructor kwargs {sorted(given)} are deprecated; set them in "
        "the config's sim section (SimConfig) instead",
        DeprecationWarning, stacklevel=3)
    return config.replace(sim=given)


@dataclass
class CAMState:
    """State produced by write simulation (a pytree)."""
    grid: jax.Array          # (nv, nh, R, C) noisy stored codes
    lo: jax.Array            # quantization range (shared with queries)
    hi: jax.Array
    spec: mapping.GridSpec   # static partition spec
    col_valid: jax.Array     # (nh, C)
    row_valid: jax.Array     # (nv, R)


jax.tree_util.register_pytree_node(
    CAMState,
    lambda s: ((s.grid, s.lo, s.hi, s.col_valid, s.row_valid), s.spec),
    lambda spec, leaves: CAMState(leaves[0], leaves[1], leaves[2], spec,
                                  leaves[3], leaves[4]),
)


class FunctionalSimulator:
    """Automated in-memory search simulation (accuracy path of CAMASim).

    Execution knobs come from ``config.sim`` (use_kernel, c2c_query_tile,
    c2c_fold); the constructor kwargs of the same names are deprecated
    overrides kept for one release.
    """

    def __init__(self, config: CAMConfig,
                 use_kernel: Optional[bool] = None,
                 c2c_query_tile: Optional[int] = None,
                 c2c_fold: Optional[str] = None):
        config = resolve_sim_overrides(config, use_kernel=use_kernel,
                                       c2c_query_tile=c2c_query_tile,
                                       c2c_fold=c2c_fold)
        config.validate()
        self.config = config
        self.use_kernel = config.sim.use_kernel
        self.c2c_query_tile = config.sim.c2c_query_tile
        # 'grid': one normal draw over the whole (nv, nh, R, C) grid per
        # cycle (the historical single-device draw).  'bank': one draw per
        # nv bank from fold_in(cycle_key, bank index) — bit-identical no
        # matter how the nv axis is split across devices, so the sharded
        # simulator (core.sharded) always runs its reference in this mode.
        self.c2c_fold = config.sim.c2c_fold
        self._arch = None          # perf.ArchSpecifics, set by write()/plan()

    # ------------------------------------------------------------- perf
    def plan(self, entries: int, dims: int):
        """Estimator-only planning: derive ``ArchSpecifics`` from shapes
        alone so ``eval_perf`` works *before* (or without) ``write``."""
        from .perf import estimate_arch
        self._arch = estimate_arch(self.config, entries, dims)
        return self._arch

    def arch_specifics(self):
        if self._arch is None:
            raise RuntimeError(
                "call write() or plan() before querying arch specifics")
        return self._arch

    def eval_perf(self, n_queries: int = 1, include_write: bool = False,
                  ops_per_query: int = 1,
                  clock_hz: Optional[float] = None,
                  mesh=None, queries_per_batch: int = 1):
        """Hardware performance prediction for the written (or planned)
        store; see ``perf.perf_report`` for the report shape."""
        from .perf import perf_report
        return perf_report(self.config, self.arch_specifics(), mesh=mesh,
                           n_queries=n_queries, include_write=include_write,
                           ops_per_query=ops_per_query, clock_hz=clock_hz,
                           queries_per_batch=queries_per_batch)

    # ------------------------------------------------------------- write
    def write(self, stored: jax.Array, key: Optional[jax.Array] = None
              ) -> CAMState:
        """Write simulation: quantize + map + D2D variation.

        ACAM accepts ``stored`` of shape (K, N, 2) holding per-cell
        [lo, hi] ranges (X-TIME-style); other cells take (K, N) values."""
        cfg = self.config
        if stored.ndim == 3:
            assert cfg.circuit.cell_type == "acam",                 "range stores need cell_type='acam'"
            if cfg.app.distance != "range":
                # fail loudly at write time: the jnp path used to compute
                # range violations silently mislabeled as the configured
                # distance, while the kernel path rejected the combination
                # deep in dispatch
                raise ValueError(
                    "ACAM [lo, hi] range stores require distance='range' "
                    f"(got {cfg.app.distance!r})")
        elif cfg.app.distance == "range":
            raise ValueError(
                "distance='range' requires a (K, N, 2) range store "
                f"(got shape {tuple(stored.shape)})")
        K, N = stored.shape[:2]
        self.plan(K, N)            # record arch specifics for eval_perf
        spec = mapping.grid_spec(K, N, cfg.circuit.rows, cfg.circuit.cols)
        return self._write_jit(stored, spec,
                               key if key is not None
                               else jax.random.PRNGKey(0))

    @partial(jax.jit, static_argnums=(0, 2))
    def _write_jit(self, stored, spec, key):
        cfg = self.config
        if stored.ndim == 3:        # ACAM ranges: no quantization
            codes, lo, hi = stored, jnp.zeros(()), jnp.ones(())
        else:
            codes, lo, hi = quantize.quantize_for_cell(
                stored, cfg.circuit.cell_type, cfg.app.data_bits)
        grid = mapping.partition_stored(codes, spec)
        grid = variation.apply_d2d(grid, cfg.device, cfg.app.data_bits, key)
        return CAMState(grid=grid, lo=lo, hi=hi, spec=spec,
                        col_valid=mapping.col_valid_mask(spec),
                        row_valid=mapping.row_valid_mask(spec))

    # ------------------------------------------------------------- query
    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None) -> SearchResult:
        """Query simulation.

        queries: (Q, N) application-domain query batch.
        Returns a ``SearchResult`` (indices (Q, k) padded with -1, mask
        (Q, padded_K)); it unpacks as the historical ``(idx, mask)`` tuple.
        """
        if queries.ndim == 1:
            idx, mask = self.query(state, queries[None],
                                   key)
            return SearchResult(idx[0], mask[0])
        idx, mask = self._query_jit(state, queries,
                                    key if key is not None
                                    else jax.random.PRNGKey(1))
        return SearchResult(idx, mask)

    @partial(jax.jit, static_argnums=(0,))
    def _query_jit(self, state: CAMState, queries, key):
        cfg = self.config
        bits = cfg.app.data_bits
        qseg = self.segment_queries(state, queries)          # (Q, nh, C)

        if cfg.device.variation not in ("c2c", "both"):
            # store once, search many: one fused batched pass
            return self._search_batch(state.grid, qseg, state)

        if self.c2c_fold == "bank":
            # per-bank RNG fold (the shard-invariant draw): search the
            # whole batch through the shard-local entry with v_offset=0,
            # then one batched merge — the single-device reference for
            # the sharded simulator's parity guarantee.
            dist, match = self.search_shard(
                state.grid, qseg, col_valid=state.col_valid,
                row_valid=state.row_valid, key=key)
            return self.merge_rows(dist, match, state.spec.padded_K)

        # C2C: fresh array noise per search cycle; one Q-tile per cycle.
        # All cycle noises are drawn in one batched primitive and the cycles
        # run as a vmap (parallel, like the old per-query pipeline) — the
        # memory high-water mark (n_tiles noisy grids) matches the old path
        # at the default tile of 1 and shrinks as the tile grows.
        Q = qseg.shape[0]
        tile = min(self.c2c_query_tile, Q)
        pad = (-Q) % tile
        qt = jnp.pad(qseg, ((0, pad), (0, 0), (0, 0)))
        n_tiles = qt.shape[0] // tile
        qt = qt.reshape(n_tiles, tile, *qseg.shape[1:])
        keys = variation.split_for_queries(key, n_tiles)
        noisy = variation.apply_c2c_batched(state.grid, cfg.device, bits,
                                            keys)

        idx, mask = jax.vmap(
            lambda g, q: self._search_batch(g, q, state))(noisy, qt)
        idx = idx.reshape(n_tiles * tile, *idx.shape[2:])[:Q]
        mask = mask.reshape(n_tiles * tile, *mask.shape[2:])[:Q]
        return idx, mask

    # ------------------------------------------------- shard-local pieces
    # The sharded simulator (core.sharded) drives these from inside a
    # shard_map body: each device runs the same quantize/search pipeline on
    # its local nv (bank) shard of the grid, and only the vertical merge
    # crosses devices.
    def need_dist(self) -> bool:
        """The AND merge consumes match lines only; the fused kernel then
        skips the (Q, nv, nh, R) distance write-back entirely."""
        cfg = self.config
        return not (cfg.app.match_type in ("exact", "threshold")
                    and cfg.arch.h_merge == "and")

    def match_k(self, padded_K: int) -> int:
        """Result width k of the merge for a padded_K-row store."""
        cfg = self.config
        return merge.match_k(cfg.app.match_type, cfg.app.match_param,
                             padded_K)

    def segment_queries(self, state: CAMState, queries: jax.Array
                        ) -> jax.Array:
        """Quantize (shared scale) + partition: (Q, N) -> (Q, nh, C)."""
        cfg = self.config
        qcodes, _, _ = quantize.quantize_for_cell(
            queries, cfg.circuit.cell_type, cfg.app.data_bits,
            state.lo, state.hi)
        return mapping.partition_query(qcodes, state.spec)

    def search_shard(self, grid: jax.Array, qseg: jax.Array, *,
                     col_valid: jax.Array, row_valid: jax.Array,
                     key: Optional[jax.Array] = None, v_offset=0,
                     cycle_keys: Optional[jax.Array] = None
                     ) -> Tuple[Optional[jax.Array], jax.Array]:
        """Shard-local search over a pre-split grid.

        ``grid`` may be an nv-shard of the full stored grid whose first
        bank has global index ``v_offset`` (``row_valid`` is the matching
        (nv_local, R) shard; ``col_valid`` is replicated).  C2C noise uses
        the per-bank RNG fold (``variation.apply_c2c_banked``), so any
        split of the nv axis draws bit-identical noise.  ``cycle_keys``
        overrides the per-cycle key derivation for query-sharded batches
        (the caller splits the global key and slices this shard's cycles).

        Returns ``(dist, match)``, each (Q, nv_local, nh, R); ``dist`` is
        None when the merge consumes match lines only.
        """
        cfg = self.config
        bits = cfg.app.data_bits

        def run(g, q):
            return subarray.subarray_query_batched(
                g, q,
                distance=cfg.app.distance,
                sensing=cfg.circuit.sensing,
                sensing_limit=cfg.circuit.sensing_limit,
                threshold=float(cfg.app.match_param)
                if cfg.app.match_type == "threshold" else 0.0,
                col_valid=col_valid,
                row_valid=row_valid,
                use_kernel=self.use_kernel,
                want_dist=self.need_dist())

        if cfg.device.variation not in ("c2c", "both"):
            return run(grid, qseg)

        Q = qseg.shape[0]
        tile = min(self.c2c_query_tile, Q)
        pad = (-Q) % tile
        qt = jnp.pad(qseg, ((0, pad), (0, 0), (0, 0)))
        n_tiles = qt.shape[0] // tile
        qt = qt.reshape(n_tiles, tile, *qseg.shape[1:])
        if cycle_keys is None:
            cycle_keys = variation.split_for_queries(key, n_tiles)
        noisy = variation.apply_c2c_banked(grid, cfg.device, bits,
                                           cycle_keys, v_offset)
        dist, match = jax.vmap(run)(noisy, qt)
        match = match.reshape(n_tiles * tile, *match.shape[2:])[:Q]
        if dist is not None:
            dist = dist.reshape(n_tiles * tile, *dist.shape[2:])[:Q]
        return dist, match

    def merge_rows(self, dist, match, padded_K: int):
        """Single-device merge of (Q, nv, nh, R) subarray outputs."""
        cfg = self.config
        return merge.merge(
            dist, match,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge,
            v_merge=cfg.arch.v_merge,
            match_param=self.match_k(padded_K),
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0)

    def _search_batch(self, grid, qseg, state: CAMState):
        """One fused batched search + merge over a (Q, nh, C) block."""
        cfg = self.config
        dist, match = subarray.subarray_query_batched(
            grid, qseg,
            distance=cfg.app.distance,
            sensing=cfg.circuit.sensing,
            sensing_limit=cfg.circuit.sensing_limit,
            threshold=float(cfg.app.match_param)
            if cfg.app.match_type == "threshold" else 0.0,
            col_valid=state.col_valid,
            row_valid=state.row_valid,
            use_kernel=self.use_kernel,
            want_dist=self.need_dist())
        return self.merge_rows(dist, match, state.spec.padded_K)
