"""Subarray query simulation (paper §III-C).

Simulates the in-array search: every subarray computes, in parallel, the
distance between its stored rows and the corresponding query segment, and the
sensing circuit converts the analog signal into digital match outputs.

Sensing limit (SL): the smallest voltage/current difference the sense
amplifier can detect.  Entries whose signal is within SL of the detected
signal are indistinguishable and are all reported as matches — e.g. for best
match, the 2nd-closest entry within SL of the closest is also flagged.

Shapes:
    stored : (nv, nh, R, C)  code-domain subarray grid
    query  : (..., nh, C)    query segments
    out    : dist  (..., nv, nh, R)   per-subarray distances
             match (..., nv, nh, R)   sensing-circuit digital outputs
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .distance import get_distance


def subarray_distances(stored: jax.Array, query: jax.Array,
                       distance: str,
                       col_valid: jax.Array | None = None,
                       use_kernel: bool = False) -> jax.Array:
    """Per-subarray distances.

    ``col_valid``: (nh, C) mask of real (non-padded) columns.
    ``use_kernel``: route through the Pallas cam_search kernel (TPU path).
    """
    if stored.ndim == 5:                            # ACAM [lo, hi] ranges
        from .distance import range_violations
        q = query[..., None, :, :]
        valid = None if col_valid is None else col_valid[..., None, :]
        return range_violations(stored, q, valid)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.cam_search(stored, query, distance=distance,
                               col_valid=col_valid)
    fn = get_distance(distance)
    # broadcast query (..., nh, C) against stored (nv, nh, R, C):
    # -> (..., nv, nh, R)
    q = query[..., None, :, :]                      # (..., 1, nh, C)
    valid = None if col_valid is None else col_valid[..., None, :]
    return fn(stored, q, valid)


def sense(dist: jax.Array, sensing: str, sensing_limit: float,
          threshold: float = 0.0,
          row_valid: jax.Array | None = None) -> jax.Array:
    """Sense-amplifier model: distances -> digital match lines.

    exact     : match iff dist <= SL              (ideal SA: dist == 0)
    best      : match iff dist <= min(dist) + SL  (winner-take-all SA)
    threshold : match iff dist <= threshold + SL
    ``row_valid``: (nv, R) mask, padding rows never match.
    """
    if sensing == "exact":
        m = dist <= sensing_limit
    elif sensing == "best":
        # min over rows of this subarray (last axis)
        big = jnp.where(_rv(dist, row_valid) > 0, dist, jnp.inf)
        m = dist <= (jnp.min(big, axis=-1, keepdims=True) + sensing_limit)
    elif sensing == "threshold":
        m = dist <= (threshold + sensing_limit)
    else:
        raise ValueError(f"unknown sensing {sensing!r}")
    m = m.astype(jnp.float32)
    if row_valid is not None:
        m = m * _rv(m, row_valid)
    return m


def _rv(x: jax.Array, row_valid: jax.Array | None) -> jax.Array:
    """Broadcast (nv, R) row mask against (..., nv, nh, R)."""
    if row_valid is None:
        return jnp.ones_like(x)
    return jnp.broadcast_to(row_valid[:, None, :], x.shape[-3:]).astype(x.dtype)


def subarray_query(stored: jax.Array, query: jax.Array, *, distance: str,
                   sensing: str, sensing_limit: float, threshold: float = 0.0,
                   col_valid: jax.Array | None = None,
                   row_valid: jax.Array | None = None,
                   use_kernel: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Full subarray search: distances + sensed matches."""
    dist = subarray_distances(stored, query, distance, col_valid, use_kernel)
    if row_valid is not None:
        # padding rows get +inf distance so they never win a best-match
        rv = jnp.broadcast_to(row_valid[:, None, :], dist.shape[-3:])
        dist = jnp.where(rv > 0, dist, jnp.inf)
    match = sense(dist, sensing, sensing_limit, threshold, row_valid)
    return dist, match


def subarray_query_batched(stored: jax.Array, queries: jax.Array, *,
                           distance: str, sensing: str, sensing_limit: float,
                           threshold: float = 0.0,
                           col_valid: jax.Array | None = None,
                           row_valid: jax.Array | None = None,
                           use_kernel: bool = False,
                           want_dist: bool = True,
                           q_tile: int | None = None,
                           pipeline: bool = True,
                           int_codes: int = 0
                           ) -> Tuple[jax.Array | None, jax.Array]:
    """Batched subarray search over a (Q, nh, C) query block.

    The store-once / search-many entry point: one call evaluates the whole
    query batch against the resident grid.  On the kernel path this runs the
    query-batched Pallas kernel with the sense epilogue fused (distances and
    match lines produced in a single pass over the stored grid); ACAM range
    grids (5-dim [lo, hi] stored) dispatch to the fused range kernel.  The
    jnp path broadcasts the batch through the same ops as ``subarray_query``.

    ``want_dist=False`` skips the distance write-back on the kernel path and
    returns ``(None, match)`` on both paths — one contract for merges that
    consume match lines only.

    ``q_tile`` overrides the fused kernels' VMEM-formula query tile
    (``sim.q_tile`` threads through here); the jnp path evaluates the whole
    batch at once regardless, so the knob never changes results.

    ``pipeline`` / ``int_codes`` (``sim.pipeline``; the functional
    simulator's noise-free integral-code detection) select the kernels'
    bank-blocked double-buffered schedule and the narrow-int / bit-packed
    distance fast paths — schedule/dtype rewrites only, results unchanged.
    """
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.cam_search_fused(
            stored, queries, distance=distance, sensing=sensing,
            sensing_limit=sensing_limit, threshold=threshold,
            col_valid=col_valid, row_valid=row_valid, want_dist=want_dist,
            q_tile=q_tile, pipeline=pipeline, int_codes=int_codes)
        return out if want_dist else (None, out)
    dist, match = subarray_query(stored, queries, distance=distance,
                                 sensing=sensing,
                                 sensing_limit=sensing_limit,
                                 threshold=threshold, col_valid=col_valid,
                                 row_valid=row_valid, use_kernel=False)
    return (dist, match) if want_dist else (None, match)
