"""Quantization submodule (paper §III-C).

Transforms application-level data into the representations storable by the
underlying CAM cells: binary for BCAM/TCAM, 2/3-bit (or n-bit) integer codes
for MCAM, analog ranges for ACAM.  The paper uses linear quantization; other
techniques can be plugged in via ``QUANTIZERS``.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def linear_quantize(x: jax.Array, bits: int,
                    lo: float | jax.Array | None = None,
                    hi: float | jax.Array | None = None
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Linear quantization to ``bits``-bit integer codes.

    Returns ``(codes, lo, hi)`` where codes are float-typed integers in
    ``[0, 2**bits - 1]`` (kept float so variation noise can be added in the
    code domain, as the paper does for conductance-domain noise).

    ``bits == 0`` means full precision (identity, used for ACAM / fp cells).
    """
    if bits == 0:
        z = jnp.zeros((), x.dtype)
        return x, z, z + 1.0
    if lo is None:
        lo = jnp.min(x)
    if hi is None:
        hi = jnp.max(x)
    lo = jnp.asarray(lo, x.dtype)
    hi = jnp.asarray(hi, x.dtype)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, jnp.ones((), x.dtype))
    q = jnp.round((x - lo) / scale)
    q = jnp.clip(q, 0, levels)
    return q.astype(jnp.float32), lo, hi


def dequantize(codes: jax.Array, bits: int, lo: jax.Array,
               hi: jax.Array) -> jax.Array:
    if bits == 0:
        return codes
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    return codes * scale + lo


def binarize(x: jax.Array,
             threshold: float | jax.Array | None = None) -> jax.Array:
    """1-bit quantization for BCAM/TCAM (sign/threshold binarization)."""
    thr = jnp.mean(x) if threshold is None else threshold
    return (x > thr).astype(jnp.float32)


def acam_ranges(x: jax.Array, margin: float = 0.0
                ) -> Tuple[jax.Array, jax.Array]:
    """ACAM cells store analog [lo, hi] ranges; a point value maps to a
    degenerate range widened by ``margin``."""
    return x - margin, x + margin


def quantize_for_cell(x: jax.Array, cell_type: str, bits: int,
                      lo=None, hi=None):
    """Dispatch on CAM cell type (paper: BCAM/TCAM 1b, MCAM nb, ACAM analog).

    Returns ``(codes, lo, hi)``; ``lo``/``hi`` are the quantization state
    shared between write and query time.  For binary cells the state is the
    binarization threshold itself (carried in ``lo``): queries must be
    thresholded at the STORE's write-time threshold, not at their own batch
    mean — otherwise a query's code drifts with the composition of the
    batch it happens to arrive in (the "shared scale" contract of
    ``functional.segment_queries``).
    """
    if cell_type in ("bcam", "tcam"):
        thr = jnp.mean(x) if lo is None else jnp.asarray(lo)
        return binarize(x, thr), thr, thr + 1.0
    if cell_type == "mcam":
        return linear_quantize(x, bits, lo, hi)
    if cell_type == "acam":
        return linear_quantize(x, 0, lo, hi)  # identity
    raise ValueError(f"unknown cell type {cell_type!r}")


QUANTIZERS = {
    "linear": linear_quantize,
    "binary": binarize,
}
