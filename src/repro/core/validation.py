"""Paper Table IV validation setups (DRL / MANN / HDC).

Each entry reproduces the application/architecture/circuit/device setup the
paper adopted from the respective publication, plus the published (pub.)
and CAMASim-reported (sim.) reference numbers we validate against.

DRL's logical operation is a CAM-based stochastic sampling routine that
issues ~142 sequential search cycles at the 150 MHz system clock (the paper
notes the "randomness inherent in the implemented sampling operation");
MANN/HDC are single-search queries.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from .config import (AppConfig, ArchConfig, CAMConfig, CircuitConfig,
                     DeviceConfig)


@dataclass(frozen=True)
class ValidationTarget:
    name: str
    config: CAMConfig
    K: int                      # stored entries
    N: int                      # dims
    n_subarrays: int            # paper Table IV column
    ops_per_query: int = 1
    clock_hz: Optional[float] = None
    pub_latency_ns: float = 0.0
    sim_latency_ns: float = 0.0   # CAMASim paper's own reported value
    pub_energy_pj: float = 0.0
    sim_energy_pj: float = 0.0


MANN = ValidationTarget(
    name="MANN [8]",
    config=CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=1,
                      data_bits=3),
        arch=ArchConfig(subarrays_per_array=4, arrays_per_mat=4,
                        mats_per_bank=4, h_merge="voting",
                        v_merge="comparator"),
        circuit=CircuitConfig(rows=32, cols=64, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet")),
    K=32, N=512, n_subarrays=8,
    pub_latency_ns=6.5, sim_latency_ns=6.4,
    pub_energy_pj=16.6, sim_energy_pj=17.7)

HDC = ValidationTarget(
    name="HDC [7]",
    config=CAMConfig(
        app=AppConfig(distance="l2", match_type="best", match_param=1,
                      data_bits=2),
        arch=ArchConfig(subarrays_per_array=4, arrays_per_mat=4,
                        mats_per_bank=4, h_merge="voting",
                        v_merge="comparator"),
        circuit=CircuitConfig(rows=32, cols=128, cell_type="mcam",
                              sensing="best"),
        device=DeviceConfig(device="fefet")),
    K=26, N=2048, n_subarrays=16,
    pub_latency_ns=12.2, sim_latency_ns=12.8,
    pub_energy_pj=269.0, sim_energy_pj=252.0)

DRL = ValidationTarget(
    name="DRL [4]",
    config=CAMConfig(
        app=AppConfig(distance="hamming", match_type="exact",
                      match_param=1, data_bits=1),
        arch=ArchConfig(subarrays_per_array=4, arrays_per_mat=4,
                        mats_per_bank=4, h_merge="and", v_merge="gather"),
        circuit=CircuitConfig(rows=64, cols=64, cell_type="tcam",
                              sensing="exact"),
        device=DeviceConfig(device="cmos")),
    K=4096, N=64, n_subarrays=64,
    ops_per_query=142, clock_hz=150e6,
    pub_latency_ns=1000.0, sim_latency_ns=950.0,
    pub_energy_pj=None or 46.0e6, sim_energy_pj=46.0e6)

TARGETS = (DRL, MANN, HDC)


def mesh_anchor(target: ValidationTarget, devices: int = 1,
                link: str = "on_package"):
    """Single-chip vs mesh-level prediction pair for a Table IV target.

    The d=1 mesh prediction is the calibration anchor: it must reproduce
    the single-chip rollup (the numbers validated against Table IV)
    bit-for-bit, so the mesh extension can never drift the calibrated
    baseline.  Returns ``(single, sharded)`` PerfResults at the target's
    ``ops_per_query``.
    """
    from .perf import (MeshSpec, estimate_arch, predict_search,
                       predict_search_sharded)
    arch = estimate_arch(target.config, target.K, target.N)
    single = predict_search(target.config, arch,
                            ops_per_query=target.ops_per_query)
    sharded = predict_search_sharded(
        target.config, arch, MeshSpec(devices, link),
        ops_per_query=target.ops_per_query)
    return single, sharded
