"""CAMASim configuration (paper Table III + execution).

The design space of a CAM-based accelerator is described by four nested
configs — application, architecture, circuit, device — mirroring Table III of
the paper, plus a fifth ``sim`` section describing how the experiment is
*executed* (backend, kernels, mesh split, serving batch) so a single JSON
file specifies the entire experiment and ``CAMASim.from_json(path)`` can
reconstruct it.  Configs are plain frozen dataclasses so they can be used
as jit static arguments, hashed, and serialized to/from JSON.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Enumerated choices (kept as strings for JSON friendliness; validated below)
# ---------------------------------------------------------------------------
DISTANCES = ("hamming", "l1", "l2", "dot", "range")
MATCH_TYPES = ("exact", "best", "threshold")
CELL_TYPES = ("bcam", "tcam", "mcam", "acam")
H_MERGE = ("and", "voting", "adder")  # 'adder' = beyond-paper exact-sum merge
V_MERGE = ("gather", "comparator")
DEVICES = ("cmos", "fefet", "reram", "skyrmion")
VARIATION_TYPES = ("none", "d2d", "c2c", "both")
VARIATION_SPECS = ("stat", "exper")
BACKENDS = ("functional", "sharded")
C2C_FOLDS = ("grid", "bank")
D2D_FOLDS = ("grid", "row")
PREFILTERS = ("off", "signature", "ivf")


def _check(value, allowed, name):
    if value not in allowed:
        raise ValueError(f"{name}={value!r} not in {allowed}")


@dataclass(frozen=True)
class AppConfig:
    """Application-level choices (Table III, app. config.)."""
    distance: str = "l2"           # Hamm./L1/L2 (+ dot, beyond-paper)
    match_type: str = "best"       # exact / best / threshold
    match_param: int = 1           # #neighbours (best) or threshold (thr/exact)
    data_bits: int = 3             # data type: number of bits per cell (0 = fp)

    def __post_init__(self):
        _check(self.distance, DISTANCES, "distance")
        _check(self.match_type, MATCH_TYPES, "match_type")
        if self.match_param < 0:
            raise ValueError("match_param must be >= 0")
        if not (0 <= self.data_bits <= 8):
            raise ValueError("data_bits must be in [0, 8] (0 = full precision)")


@dataclass(frozen=True)
class ArchConfig:
    """Architecture-level choices (Table III, arch. config.)."""
    subarrays_per_array: int = 4
    arrays_per_mat: int = 4
    mats_per_bank: int = 4
    h_merge: str = "voting"        # horizontal merge: and / voting / adder
    v_merge: str = "comparator"    # vertical merge: gather / comparator

    def __post_init__(self):
        _check(self.h_merge, H_MERGE, "h_merge")
        _check(self.v_merge, V_MERGE, "v_merge")
        for f_ in ("subarrays_per_array", "arrays_per_mat", "mats_per_bank"):
            if getattr(self, f_) < 1:
                raise ValueError(f"{f_} must be >= 1")


@dataclass(frozen=True)
class CircuitConfig:
    """Circuit-level choices (Table III, circ. config.)."""
    rows: int = 64                 # R: rows per subarray
    cols: int = 64                 # C: cols per subarray
    cell_type: str = "mcam"        # bcam / tcam / mcam / acam
    sensing: str = "best"          # sensing circuit type: exact/best/threshold
    sensing_limit: float = 0.0     # SL: min detectable signal difference
                                   # (in quantized-LSB distance units)

    def __post_init__(self):
        _check(self.cell_type, CELL_TYPES, "cell_type")
        _check(self.sensing, MATCH_TYPES, "sensing")
        if self.rows < 1 or self.cols < 1:
            raise ValueError("rows/cols must be >= 1")
        if self.sensing_limit < 0:
            raise ValueError("sensing_limit must be >= 0")


@dataclass(frozen=True)
class DeviceConfig:
    """Device-level choices (Table III, dev. config.)."""
    device: str = "fefet"          # cmos / fefet / reram / skyrmion
    variation: str = "none"        # none / d2d / c2c / both
    variation_spec: str = "stat"   # stat (Gaussian) / exper (empirical table)
    variation_std: float = 0.0     # Gaussian STD in LSBs (stat spec)
    # experimental spec: per-level empirical stds (e.g. measured from chips);
    # length must be 2**data_bits when used.
    exper_table: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        _check(self.device, DEVICES, "device")
        _check(self.variation, VARIATION_TYPES, "variation")
        _check(self.variation_spec, VARIATION_SPECS, "variation_spec")
        if self.variation_std < 0:
            raise ValueError("variation_std must be >= 0")


@dataclass(frozen=True)
class SimConfig:
    """Execution-level choices: how the experiment runs, not what it is.

    ``backend`` picks the simulator ``CAMASim`` dispatches to; the other
    fields are the knobs that used to be scattered constructor kwargs on
    ``CAMASim`` / ``FunctionalSimulator`` / ``ShardedCAMSimulator`` /
    ``CAMSearchServer``, so one JSON file specifies the full experiment.
    """
    backend: str = "functional"    # functional (single chip) / sharded (mesh)
    use_kernel: bool = False       # fused Pallas search kernels
    devices: int = 0               # sharded: bank-axis size (0 = all local)
    query_shards: int = 1          # sharded: optional query-axis split
    c2c_query_tile: int = 1        # queries per C2C noise draw (search cycle)
    c2c_fold: str = "grid"         # C2C RNG fold: grid / bank (shard-invariant)
    d2d_fold: str = "grid"         # D2D RNG fold: grid / row (insert-invariant)
    capacity: int = 0              # row head-room: grid sized for
                                   # max(K, capacity) rows so inserts have
                                   # free slots (0 = exactly K)
    serve_batch: int = 32          # CAMSearchServer micro-batch ceiling
    serve_queue: int = 0           # CAMSearchServer admission bound
                                   # (submits beyond it raise QueueFull;
                                   # 0 = unbounded)
    # Two-stage search cascade (sublinear search): 'signature' scores each
    # nv-bank with a bit-packed Hamming prefilter before the exact kernel;
    # 'ivf' additionally reorders rows at write time so similar entries
    # colocate in the same bank (returned indices are unchanged — the
    # placement permutation is tracked in the state).
    prefilter: str = "off"         # off / signature / ivf
    top_p_banks: Optional[int] = None  # banks searched per batch (None = all)
    signature_bits: int = 0        # stage-1 signature width (0 = one per dim)
    # Fused-kernel query tile: queries per stored-grid pass.  None keeps the
    # kernels' VMEM working-set formula (kernels.cam_search.default_q_tile);
    # an explicit value must sit on the same power-of-two ladder the formula
    # rounds to, so the autotuner's pick is directly settable from JSON.
    q_tile: Optional[int] = None
    # Bank-blocked double-buffered kernel schedule (VMEM-resident stores,
    # per-geometry measured q_tile, narrow-int/bit-packed distance paths for
    # noise-free integral codes).  False is the bit- and schedule-identical
    # off-switch: the historical per-tile grid with the VMEM formula tile.
    pipeline: bool = True
    # Measured-model constant overrides (kernels.cam_search): per-grid-step
    # dispatch seconds and the VPU broadcast-block byte cap the Q-tile
    # autotune ranks rungs with.  None keeps the module defaults (which
    # the CAMASIM_STEP_OVERHEAD_S / CAMASIM_BCAST_BUDGET_BYTES env vars
    # override at import); fit fresh values on new hardware with
    # benchmarks/calibrate_kernel_model.py.
    step_overhead_s: Optional[float] = None
    bcast_budget_bytes: Optional[int] = None

    def __post_init__(self):
        _check(self.backend, BACKENDS, "backend")
        if self.c2c_fold not in C2C_FOLDS:
            raise ValueError("c2c_fold must be 'grid' or 'bank'")
        if self.d2d_fold not in D2D_FOLDS:
            raise ValueError("d2d_fold must be 'grid' or 'row'")
        if self.capacity < 0:
            raise ValueError("capacity must be >= 0 (0 = no head-room)")
        if self.serve_queue < 0:
            raise ValueError("serve_queue must be >= 0 (0 = unbounded)")
        if self.c2c_query_tile < 1:
            raise ValueError("c2c_query_tile must be >= 1")
        if self.devices < 0:
            raise ValueError("devices must be >= 0 (0 = all local devices)")
        if self.query_shards < 1:
            raise ValueError("query_shards must be >= 1")
        if self.serve_batch < 1:
            raise ValueError("serve_batch must be >= 1")
        _check(self.prefilter, PREFILTERS, "prefilter")
        if self.top_p_banks is not None and self.top_p_banks < 1:
            raise ValueError("top_p_banks must be >= 1 (or None = all banks)")
        if self.signature_bits < 0:
            raise ValueError("signature_bits must be >= 0 (0 = one per dim)")
        if self.q_tile is not None:
            q = self.q_tile
            if not (1 <= q <= 256) or (q & (q - 1)):
                raise ValueError(
                    "q_tile must be a power of two in [1, 256] "
                    "(or None = the kernels' VMEM formula)")
        if self.step_overhead_s is not None and self.step_overhead_s <= 0:
            raise ValueError(
                "step_overhead_s must be > 0 (or None = module default)")
        if self.bcast_budget_bytes is not None and self.bcast_budget_bytes <= 0:
            raise ValueError(
                "bcast_budget_bytes must be > 0 (or None = module default)")

    def cascade_enabled(self) -> bool:
        """Both stages configured: a prefilter is selected AND a bank
        budget is set (``top_p_banks=None`` disables the cascade even when
        signatures/placement are derived at write time)."""
        return self.prefilter != "off" and self.top_p_banks is not None


@dataclass(frozen=True)
class ReliabilityConfig:
    """Device reliability model: fault injection + self-healing knobs.

    ``enabled=False`` (the default) is the hard off-switch — every
    consumer gates on it, so a config without this section (or with it
    disabled) behaves bit-identically to the pre-reliability code.

    Fault maps are deterministic functions of ``fault_seed`` keyed per
    global row SLOT (``fold_in`` — the same fold the mutable store's
    ``d2d_fold='row'`` noise uses), so the functional and sharded
    backends derive bit-identical faults regardless of how the bank axis
    is split.
    """
    enabled: bool = False
    stuck_frac: float = 0.0       # fraction of cells stuck at a random level
    dead_row_frac: float = 0.0    # fraction of row slots entirely dead
    dead_col_frac: float = 0.0    # fraction of subarray columns dead
    endurance_writes: int = 0     # programs per slot before cells freeze
                                  # (0 = unlimited endurance)
    drift_rate: float = 0.0       # conductance decay per unit age:
                                  # g_eff = g * exp(-rate * (age - prog_age))
    verify_retries: int = 0       # write-verify re-program attempts
    verify_tol: float = 0.0       # max |readback - target| accepted by
                                  # verify (code-domain LSBs)
    spares_per_bank: int = 0      # free slots a bank may donate to remap
                                  # dead/worn rows (0 = no redundancy)
    scrub_every: int = 0          # serve steps between background scrub
                                  # passes (0 = scrubbing off)
    scrub_rows: int = 1           # most-drifted rows re-programmed per pass
    fault_seed: int = 0           # RNG seed the fault maps derive from

    def __post_init__(self):
        for f_ in ("stuck_frac", "dead_row_frac", "dead_col_frac"):
            v = getattr(self, f_)
            if not (0.0 <= v <= 1.0):
                raise ValueError(f"{f_} must be in [0, 1]")
        for f_ in ("endurance_writes", "verify_retries", "spares_per_bank",
                   "scrub_every"):
            if getattr(self, f_) < 0:
                raise ValueError(f"{f_} must be >= 0")
        if self.drift_rate < 0:
            raise ValueError("drift_rate must be >= 0")
        if self.verify_tol < 0:
            raise ValueError("verify_tol must be >= 0")
        if self.scrub_rows < 1:
            raise ValueError("scrub_rows must be >= 1")


_SECTIONS = {
    "app": "AppConfig", "arch": "ArchConfig", "circuit": "CircuitConfig",
    "device": "DeviceConfig", "sim": "SimConfig",
    "reliability": "ReliabilityConfig",
}


@dataclass(frozen=True)
class CAMConfig:
    """Full CAMASim configuration: 4 design levels + execution."""
    app: AppConfig = field(default_factory=AppConfig)
    arch: ArchConfig = field(default_factory=ArchConfig)
    circuit: CircuitConfig = field(default_factory=CircuitConfig)
    device: DeviceConfig = field(default_factory=DeviceConfig)
    sim: SimConfig = field(default_factory=SimConfig)
    reliability: ReliabilityConfig = field(
        default_factory=ReliabilityConfig)

    # ------------------------------------------------------------------ io
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # an all-default reliability section means "subsystem absent":
        # leave it out so pre-reliability configs round-trip verbatim
        if self.reliability == ReliabilityConfig():
            del d["reliability"]
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @classmethod
    def from_dict(cls, d: dict) -> "CAMConfig":
        # unknown keys are dropped in EVERY section (not just circuit), so
        # configs serialized by newer versions still load
        dev = known_fields(DeviceConfig, d.get("device", {}))
        if dev.get("exper_table") is not None:
            dev["exper_table"] = tuple(dev["exper_table"])
        return cls(
            app=AppConfig(**known_fields(AppConfig, d.get("app", {}))),
            arch=ArchConfig(**known_fields(ArchConfig, d.get("arch", {}))),
            circuit=CircuitConfig(
                **known_fields(CircuitConfig, d.get("circuit", {}))),
            device=DeviceConfig(**dev),
            sim=SimConfig(**known_fields(SimConfig, d.get("sim", {}))),
            reliability=ReliabilityConfig(
                **known_fields(ReliabilityConfig,
                               d.get("reliability", {}))),
        )

    @classmethod
    def from_json(cls, s: str) -> "CAMConfig":
        return cls.from_dict(json.loads(s))

    # ------------------------------------------------------------- helpers
    def replace(self, **sections) -> "CAMConfig":
        """Replace whole sections or nested fields.

        ``cfg.replace(circuit=dict(rows=128))`` merges into the existing
        circuit config.
        """
        out = {}
        for name in _SECTIONS:
            cur = getattr(self, name)
            if name in sections:
                val = sections[name]
                if isinstance(val, dict):
                    out[name] = dataclasses.replace(cur, **val)
                else:
                    out[name] = val
            else:
                out[name] = cur
        return CAMConfig(**out)

    def validate(self) -> None:
        """Cross-level validation (paper Fig. 3b constraints)."""
        if self.app.match_type == "threshold" and self.arch.h_merge in ("voting",):
            raise ValueError(
                "threshold match has no voting-based horizontal merge "
                "(paper: no existing efficient scheme)")
        if self.app.match_type == "exact" and self.arch.h_merge == "voting":
            raise ValueError("exact match uses AND horizontal merge, not voting")
        if self.app.match_type == "best" and self.arch.v_merge == "gather":
            raise ValueError("best match requires comparator vertical merge")
        if self.circuit.cell_type == "bcam" and self.app.data_bits > 1:
            raise ValueError("BCAM stores 1 bit per cell")
        if self.circuit.cell_type == "tcam" and self.app.data_bits > 1:
            raise ValueError("TCAM stores 1 bit (+don't-care) per cell")
        if (self.sim.cascade_enabled() and self.sim.backend == "functional"
                and self.device.variation in ("c2c", "both")
                and self.sim.c2c_fold == "grid"):
            # the grid fold draws ONE normal over the whole (nv, nh, R, C)
            # grid per cycle; that draw cannot be restricted to a gathered
            # bank subset, so routed searches need the per-bank fold
            raise ValueError(
                "the search cascade with C2C variation requires "
                "sim.c2c_fold='bank' (per-bank RNG fold)")
        if (self.reliability.enabled
                and self.device.variation in ("d2d", "both")
                and self.sim.d2d_fold != "row"):
            # verified programming (and scrub/heal re-programming) draws
            # noise per row slot; the grid-level D2D draw cannot be
            # reproduced for individual rows
            raise ValueError(
                "reliability with D2D variation requires "
                "sim.d2d_fold='row' (per-row-slot RNG fold)")


def known_fields(section_cls, d: dict) -> dict:
    """Drop keys that are not fields of ``section_cls`` (forward compat:
    configs serialized by newer versions must still load)."""
    keep = {f.name for f in dataclasses.fields(section_cls)}
    return {k: v for k, v in d.items() if k in keep}


def dev_free(d: dict) -> dict:
    """Deprecated alias: circuit-section unknown-key filtering (the
    asymmetric pre-``known_fields`` form, kept for one release)."""
    return known_fields(CircuitConfig, d)
