"""Variation modelling submodule (paper §III-C, dev. config.).

Two variation types:
  * D2D (device-to-device): a one-time perturbation applied when data is
    written into the CAM (each physical cell deviates from its programmed
    level).  Applied once to the stored codes.
  * C2C (cycle-to-cycle): a per-query perturbation (each search cycle sees a
    slightly different effective level).  Applied dynamically per query.

Two specifications:
  * 'stat'  — Gaussian with configurable STD (in code-domain LSBs).
  * 'exper' — empirical per-level STD table measured from fabricated chips
    (level-dependent noise, e.g. higher conductance levels are noisier).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import DeviceConfig


def _sigma_for(codes: jax.Array, cfg: DeviceConfig, bits: int) -> jax.Array:
    """Per-cell noise STD, from either a scalar or a per-level table.

    The exper table is indexed by *integer code level*; analog cells
    (``bits == 0`` — fp MCAM stores and ACAM [lo, hi] ranges) have no
    levels, so casting their values to indices would silently bin
    e.g. every range bound in [0, 1) to level 0.  For analog cells the
    table is a documented no-op: the stat STD is used instead.
    """
    if (cfg.variation_spec == "stat" or cfg.exper_table is None
            or bits == 0):
        return jnp.full_like(codes, cfg.variation_std)
    table = jnp.asarray(cfg.exper_table, jnp.float32)
    levels = table.shape[0]
    idx = jnp.clip(codes.astype(jnp.int32), 0, levels - 1)
    return table[idx]


def sort_ranges(noisy: jax.Array) -> jax.Array:
    """Re-order a noisy ACAM grid's trailing [lo, hi] planes so lo <= hi.

    Independent noise draws on the two bounds can invert a narrow range
    (lo + eps > hi + eps'); an inverted range matches NOTHING, so a cell
    that should *widen* under noise would instead go dark.  Physically the
    two programmed conductances still define an interval — the cell's
    effective range is [min, max] of the noisy bounds.
    """
    return jnp.sort(noisy, axis=-1)


def _maybe_sort_ranges(noisy: jax.Array, is_range: bool) -> jax.Array:
    return sort_ranges(noisy) if is_range else noisy


def apply_d2d(codes: jax.Array, cfg: DeviceConfig, bits: int,
              key: jax.Array) -> jax.Array:
    """Write-time (one-shot) variation on stored codes.

    ``codes`` is the full (nv, nh, R, C[, 2]) grid; a 5-D grid is an ACAM
    range store whose noisy [lo, hi] planes are re-sorted (``sort_ranges``).
    """
    if cfg.variation not in ("d2d", "both"):
        return codes
    sigma = _sigma_for(codes, cfg, bits)
    noisy = codes + sigma * jax.random.normal(key, codes.shape, codes.dtype)
    return _maybe_sort_ranges(noisy, codes.ndim == 5)


def _row_noise(row_seg: jax.Array, cfg: DeviceConfig, bits: int,
               key: jax.Array, slot: jax.Array) -> jax.Array:
    """Noise for ONE global row slot: drawn from ``fold_in(key, slot)``
    over the row's (nh, C[, 2]) segment block, independent of every other
    slot's draw."""
    sigma = _sigma_for(row_seg, cfg, bits)
    noise = jax.random.normal(jax.random.fold_in(key, slot), row_seg.shape,
                              row_seg.dtype)
    return row_seg + sigma * noise


def apply_d2d_rowfold(codes: jax.Array, cfg: DeviceConfig, bits: int,
                      key: jax.Array) -> jax.Array:
    """Write-time variation with a per-row-slot RNG fold (the mutable-store
    draw).

    The noise for global row slot ``s`` (``s = v * R + r``) is drawn from
    ``fold_in(key, s)``, so an incremental ``insert``/``update`` that
    re-programs only slot ``s`` with the same base key reproduces the
    noise a fresh full write would give that slot bit-exactly.  The grid
    fold of ``apply_d2d`` has no such property (one grid-wide draw cannot
    be re-drawn for a single row), which is why mutations require
    ``sim.d2d_fold='row'``.
    """
    if cfg.variation not in ("d2d", "both"):
        return codes
    nv, nh, R = codes.shape[:3]
    extra = codes.shape[4:]
    rows = jnp.moveaxis(codes, 2, 1).reshape(nv * R, nh, codes.shape[3],
                                             *extra)
    slots = jnp.arange(nv * R, dtype=jnp.int32)
    noisy = jax.vmap(lambda s, r: _row_noise(r, cfg, bits, key, s))(slots,
                                                                    rows)
    noisy = jnp.moveaxis(noisy.reshape(nv, R, nh, codes.shape[3], *extra),
                         1, 2)
    return _maybe_sort_ranges(noisy, codes.ndim == 5)


def apply_d2d_slots(row_segs: jax.Array, cfg: DeviceConfig, bits: int,
                    key: jax.Array, slots: jax.Array) -> jax.Array:
    """The incremental counterpart of ``apply_d2d_rowfold``: noise for the
    (M, nh, C[, 2]) row segments landing in global slots ``slots`` (M,),
    drawn from the same per-slot fold — bit-identical to the slots' rows
    in a full ``apply_d2d_rowfold`` pass with the same key."""
    if cfg.variation not in ("d2d", "both"):
        return row_segs
    noisy = jax.vmap(lambda s, r: _row_noise(r, cfg, bits, key, s))(
        slots.astype(jnp.int32), row_segs)
    return _maybe_sort_ranges(noisy, row_segs.ndim == 4)


def apply_c2c(codes: jax.Array, cfg: DeviceConfig, bits: int,
              key: jax.Array) -> jax.Array:
    """Per-query (dynamic) variation; fresh noise every search cycle.

    Same grid contract (and range re-sort) as ``apply_d2d``.
    """
    if cfg.variation not in ("c2c", "both"):
        return codes
    sigma = _sigma_for(codes, cfg, bits)
    noisy = codes + sigma * jax.random.normal(key, codes.shape, codes.dtype)
    return _maybe_sort_ranges(noisy, codes.ndim == 5)


def split_for_queries(key: jax.Array, n_queries: int) -> jax.Array:
    """One independent C2C key per query cycle."""
    return jax.random.split(key, n_queries)


def apply_c2c_batched(codes: jax.Array, cfg: DeviceConfig, bits: int,
                      keys: jax.Array) -> jax.Array:
    """C2C noise for a batch of search cycles in one fused draw.

    keys (T, 2) -> (T, *codes.shape) noisy grids, one per cycle; the noise
    for all T cycles is generated in a single batched primitive instead of
    T per-query closures.  Bit-identical to ``apply_c2c`` called per key.
    """
    if cfg.variation not in ("c2c", "both"):
        return jnp.broadcast_to(codes, (keys.shape[0], *codes.shape))
    return jax.vmap(lambda k: apply_c2c(codes, cfg, bits, k))(keys)


def apply_c2c_banked(codes: jax.Array, cfg: DeviceConfig, bits: int,
                     keys: jax.Array, v_offset: jax.Array | int = 0,
                     bank_ids: Optional[jax.Array] = None) -> jax.Array:
    """C2C noise with a per-bank RNG fold (the multi-device draw).

    The noise for bank ``v`` of cycle ``t`` is drawn from
    ``fold_in(keys[t], v_offset + v)``, so a grid split along its nv (bank)
    axis across devices — each device passing its first global bank index
    as ``v_offset`` — draws bit-identical noise to the unsplit grid with
    ``v_offset=0``.  The full-grid draw of ``apply_c2c`` has no such
    split-invariance (one (nv, nh, R, C) normal draw cannot be sliced into
    per-shard draws), which is why the sharded simulator uses this fold.

    ``bank_ids`` overrides the contiguous ``v_offset + arange(nv)`` fold
    ids for *gathered* (non-contiguous) bank subsets — the search cascade
    passes the selected banks' ORIGINAL ids so each surviving bank draws
    exactly the noise it would in a full scan.

    codes (nv, nh, R, C[, 2]); keys (T, 2) -> (T, *codes.shape).
    """
    if cfg.variation not in ("c2c", "both"):
        return jnp.broadcast_to(codes, (keys.shape[0], *codes.shape))
    nv = codes.shape[0]
    if bank_ids is None:
        bank_ids = jnp.arange(nv) + v_offset

    def one_bank(key: jax.Array, v: jax.Array, bank: jax.Array) -> jax.Array:
        sigma = _sigma_for(bank, cfg, bits)
        noise = jax.random.normal(jax.random.fold_in(key, v), bank.shape,
                                  bank.dtype)
        return bank + sigma * noise

    def one_cycle(key: jax.Array) -> jax.Array:
        return jax.vmap(lambda v, b: one_bank(key, v, b))(bank_ids, codes)

    # the [lo, hi] re-sort is elementwise over the trailing dim, so it
    # commutes with the bank split: sorting after the fold keeps the
    # shard-invariance of the draw
    return _maybe_sort_ranges(jax.vmap(one_cycle)(keys), codes.ndim == 5)
