"""Circuit-level CAM device library (EvaCAM-like lookup models).

The paper retrieves subarray-level numbers from EvaCAM [6] or SPICE; here we
embed an analytical model whose constants are *calibrated to the paper's own
validation data* (Table IV, 22nm, 150 MHz max clock):

    search latency  t_sub = t_base + t_wl*R + t_ml*C + t_sa
    search energy   e_sub = R*C*(e_cell + e_pre) + R*e_sa
    write  latency  t_wr  = rows_written * t_wr_row
    write  energy   e_wr  = cells_written * e_wr_cell
    area            a_sub = R*C*a_cell + R*a_sa + C*a_drv

All times ns, energies pJ (per-cell constants in fJ = 1e-3 pJ), areas um^2.
Constants vary by (device, cell_type, data_bits); see CALIBRATION notes in
benchmarks/table4_validation.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple


@dataclass(frozen=True)
class CellModel:
    # latency (ns)
    t_base: float      # fixed sense path
    t_wl: float        # wordline/driver delay per row
    t_ml: float        # matchline RC per column
    t_sa: float        # sense amplifier resolve
    # energy (fJ)
    e_cell: float      # per-cell search energy (ML discharge share)
    e_pre: float       # per-cell precharge / search-line driver energy
    e_sa: float        # per-row sense amp energy
    # write
    t_wr_row: float    # ns per row written
    e_wr_cell: float   # fJ per cell written
    # area (um^2)
    a_cell: float
    a_sa: float
    a_drv: float
    # leakage (uW per cell, amortized into energy at low clock for CMOS)
    p_leak: float = 0.0

    def search_latency(self, R: int, C: int) -> float:
        return self.t_base + self.t_wl * R + self.t_ml * C + self.t_sa

    def search_energy_pj(self, R: int, C: int) -> float:
        return (R * C * (self.e_cell + self.e_pre) + R * self.e_sa) * 1e-3

    def write_latency(self, rows: int) -> float:
        return rows * self.t_wr_row

    def write_energy_pj(self, rows: int, C: int) -> float:
        return rows * C * self.e_wr_cell * 1e-3

    def area_um2(self, R: int, C: int) -> float:
        return R * C * self.a_cell + R * self.a_sa + C * self.a_drv


# ---------------------------------------------------------------------------
# LUT keyed by (device, cell_type, data_bits). data_bits=0 matches any bits
# (fallback). Calibrated against paper Table IV; see DESIGN.md §2.
# ---------------------------------------------------------------------------
_LUT: Dict[Tuple[str, str, int], CellModel] = {}


def _reg(device: str, cell: str, bits: int, model: CellModel) -> None:
    _LUT[(device, cell, bits)] = model


# --- CMOS 16T TCAM @22nm, 150MHz system clock (DRL validation target) ------
# Full-swing ML precharge + SL drivers dominate energy; large cell area.
_reg("cmos", "tcam", 1, CellModel(
    t_base=0.8, t_wl=0.004, t_ml=0.045, t_sa=0.45,
    e_cell=540.0, e_pre=660.0, e_sa=18.0,
    t_wr_row=2.0, e_wr_cell=45.0,
    a_cell=2.4, a_sa=12.0, a_drv=3.0, p_leak=0.02))
_reg("cmos", "bcam", 1, CellModel(
    t_base=0.7, t_wl=0.004, t_ml=0.040, t_sa=0.45,
    e_cell=380.0, e_pre=470.0, e_sa=18.0,
    t_wr_row=2.0, e_wr_cell=32.0,
    a_cell=1.7, a_sa=12.0, a_drv=3.0, p_leak=0.015))

# --- FeFET MCAM @22nm (MANN / HDC validation targets) -----------------------
# 2-FeFET cell; analog ML discharge encodes L2-like distance; best-match WTA
# sense.  3-bit storage (MANN), 2-bit storage (HDC: larger ML swing per level
# -> higher per-cell search energy, per the published design [7]).
_reg("fefet", "mcam", 3, CellModel(
    t_base=0.35, t_wl=0.002, t_ml=0.072, t_sa=0.28,
    e_cell=0.42, e_pre=0.34, e_sa=5.0,
    t_wr_row=150.0, e_wr_cell=18.0,
    a_cell=0.12, a_sa=9.0, a_drv=1.2))
# 2-bit MCAM: narrower level separation needs a longer ML integration
# window and larger per-level swing than 3-bit (per the HDC design [7])
_reg("fefet", "mcam", 2, CellModel(
    t_base=0.35, t_wl=0.002, t_ml=0.0845, t_sa=0.28,
    e_cell=2.1, e_pre=1.6, e_sa=5.0,
    t_wr_row=150.0, e_wr_cell=14.0,
    a_cell=0.10, a_sa=9.0, a_drv=1.2))
_reg("fefet", "tcam", 1, CellModel(
    t_base=0.30, t_wl=0.002, t_ml=0.050, t_sa=0.25,
    e_cell=0.35, e_pre=0.30, e_sa=4.0,
    t_wr_row=150.0, e_wr_cell=10.0,
    a_cell=0.08, a_sa=8.0, a_drv=1.0))
_reg("fefet", "acam", 0, CellModel(
    t_base=0.40, t_wl=0.002, t_ml=0.080, t_sa=0.30,
    e_cell=0.80, e_pre=0.60, e_sa=6.0,
    t_wr_row=180.0, e_wr_cell=22.0,
    a_cell=0.15, a_sa=10.0, a_drv=1.4))

# --- ReRAM TCAM/MCAM (2T2R) --------------------------------------------------
_reg("reram", "tcam", 1, CellModel(
    t_base=0.45, t_wl=0.003, t_ml=0.060, t_sa=0.30,
    e_cell=0.9, e_pre=0.7, e_sa=5.0,
    t_wr_row=100.0, e_wr_cell=500.0,
    a_cell=0.10, a_sa=9.0, a_drv=1.2))
_reg("reram", "mcam", 0, CellModel(
    t_base=0.50, t_wl=0.003, t_ml=0.075, t_sa=0.32,
    e_cell=1.4, e_pre=1.0, e_sa=5.5,
    t_wr_row=120.0, e_wr_cell=650.0,
    a_cell=0.11, a_sa=9.0, a_drv=1.2))

# --- Skyrmion TCAM (Sky-TCAM [10]) ------------------------------------------
_reg("skyrmion", "tcam", 1, CellModel(
    t_base=1.2, t_wl=0.006, t_ml=0.090, t_sa=0.5,
    e_cell=0.12, e_pre=0.10, e_sa=3.0,
    t_wr_row=400.0, e_wr_cell=30.0,
    a_cell=0.06, a_sa=8.0, a_drv=1.0))


def get_cell_model(device: str, cell_type: str, data_bits: int) -> CellModel:
    """Lookup with bits-specific entry first, then bits-agnostic fallback."""
    for key in ((device, cell_type, data_bits), (device, cell_type, 0)):
        if key in _LUT:
            return _LUT[key]
    # final fallback: any bits registered for this (device, cell)
    cands = {k: v for k, v in _LUT.items() if k[:2] == (device, cell_type)}
    if cands:
        return cands[min(cands)]
    raise KeyError(f"no circuit model for device={device} cell={cell_type}; "
                   f"register one in core/perf/devices.py")


def register_cell_model(device: str, cell_type: str, bits: int,
                        model: CellModel) -> None:
    """User extension point (e.g. to plug in actual SPICE results)."""
    _reg(device, cell_type, bits, model)
