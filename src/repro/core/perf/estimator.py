"""Performance evaluator (paper Fig. 1c).

Two stages:
  1. *Architecture specifics estimation* — from the stored-data size and the
     arch config, determine the number of compute blocks at each hierarchy
     level (bank-mat-array-subarray) and run the peripheral estimator per
     level for the configured merge scheme.
  2. *Performance prediction* — hierarchical rollup bank→mat→array→subarray
     of CAM (device LUT), peripheral (ALADDIN-like), and interconnect
     (NVSim-like RC) latency / energy / area for search and write.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from .. import merge
from ..config import CAMConfig
from ..mapping import GridSpec, grid_spec
from . import interconnect
from .devices import get_cell_model
from .peripherals import PeripheralBill, estimate_merge_peripherals

# Host-side engine-step overhead billed per streaming insert (ns): queue
# admission, free-slot pick, and dispatch of the 1-row partial write
# through the serve loop.  Calibrated against benchmarks/serve_bench.py's
# measured single-insert serve rates (~750/s functional, ~430/s sharded on
# the CI container — ~1.3 ms/step against a ~150 ns device write);
# check_floors guards the estimate/measurement ratio so it cannot silently
# drift absurd again.
HOST_STEP_OVERHEAD_NS = 1.3e6


@dataclass
class LevelSpec:
    name: str                 # 'array' | 'mat' | 'bank' | 'top'
    n_children: int           # blocks merged at this level
    merging_horizontal: bool  # does this level merge across query segments?
    bill: PeripheralBill = field(default_factory=PeripheralBill)


@dataclass
class ArchSpecifics:
    """Output of stage 1: block counts + peripheral bills per level."""
    spec: GridSpec
    n_subarrays: int
    n_arrays: int
    n_mats: int
    n_banks: int
    levels: List[LevelSpec] = field(default_factory=list)

    def describe(self) -> str:
        s = (f"grid {self.spec.nv}x{self.spec.nh} "
             f"({self.n_subarrays} subarrays of "
             f"{self.spec.R}x{self.spec.C}) -> {self.n_arrays} arrays, "
             f"{self.n_mats} mats, {self.n_banks} banks")
        return s


@dataclass
class PerfResult:
    """Output of stage 2 (per search or write operation)."""
    latency_ns: float
    energy_pj: float
    area_um2: float
    breakdown: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def edp(self) -> float:
        """Energy-delay product in pJ*ns (1e-21 J*s = zJ*s)."""
        return self.latency_ns * self.energy_pj

    @property
    def edp_aj_s(self) -> float:
        """EDP in aJ*s (units used by paper Fig. 4)."""
        # pJ*ns = 1e-12 J * 1e-9 s = 1e-21 J*s = 1e-3 aJ*s
        return self.edp * 1e-3


class PerfReport(dict):
    """The ``eval_perf`` report: the historical dict, key-for-key (a dict
    subclass, so every BENCH consumer and ``perf['latency_ns']`` call site
    is untouched), plus typed accessors and ``to_dict()``.
    """

    @property
    def search(self) -> PerfResult:
        return self["search"]

    @property
    def write(self) -> Optional[PerfResult]:
        return self.get("write")

    @property
    def latency_ns(self) -> float:
        return self["latency_ns"]

    @property
    def energy_pj(self) -> float:
        return self["energy_pj"]

    @property
    def area_um2(self) -> float:
        return self["area_um2"]

    @property
    def edp_pj_ns(self) -> float:
        return self["edp_pj_ns"]

    def to_dict(self) -> dict:
        """The plain-dict view (exact same keys and values)."""
        return dict(self)


def estimate_arch(config: CAMConfig, K: int, N: int) -> ArchSpecifics:
    """Stage 1: architecture specifics estimation.

    CAMASim assumes all stored data fits in the CAM (paper §III-D) and
    derives block counts at the array/mat/bank layers from arch config and
    the stored-data size.
    """
    cfg = config
    spec = grid_spec(K, N, cfg.circuit.rows, cfg.circuit.cols,
                     cfg.sim.capacity)
    n_sub = spec.n_subarrays
    spa = cfg.arch.subarrays_per_array
    apm = cfg.arch.arrays_per_mat
    mpb = cfg.arch.mats_per_bank
    n_arrays = math.ceil(n_sub / spa)
    n_mats = math.ceil(n_arrays / apm)
    n_banks = math.ceil(n_mats / mpb)

    # Which levels merge horizontally vs vertically: the mapper lays the
    # (nv, nh) grid row-major onto subarray slots, so the lowest levels that
    # span multiple horizontal segments merge horizontally first (paper
    # Fig. 2 shows the voting peripherals at the array level).
    a = ArchSpecifics(spec=spec, n_subarrays=n_sub, n_arrays=n_arrays,
                      n_mats=n_mats, n_banks=n_banks)
    remaining_h = spec.nh
    for name, n_children in (("array", min(spa, n_sub)),
                             ("mat", min(apm, max(1, n_arrays))),
                             ("bank", min(mpb, max(1, n_mats))),
                             ("top", max(1, n_banks))):
        merging_h = remaining_h > 1
        consumed = min(remaining_h, max(1, n_children))
        if merging_h:
            remaining_h = math.ceil(remaining_h / consumed)
        bill = estimate_merge_peripherals(
            n_children, cfg.circuit.rows,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge, v_merge=cfg.arch.v_merge,
            merging_horizontal=merging_h)
        a.levels.append(LevelSpec(name, n_children, merging_h, bill))
    return a


def predict_prefilter(config: CAMConfig, arch: ArchSpecifics,
                      sig_bits: int) -> PerfResult:
    """Stage-1 prefilter slab prediction (search cascade).

    The bank prefilter is a separate 1-bit TCAM slab holding one
    ``sig_bits``-wide signature per stored row (one R-row subarray column
    group per bank, ``ceil(sig_bits / C)`` segments).  All signature
    subarrays search in parallel; the Hamming bank scores reduce inside
    the slab, so no extra merge hierarchy is billed.
    """
    cfg = config
    try:
        cell = get_cell_model(cfg.device.device, "tcam", 1)
    except KeyError:
        # device without a 1-bit TCAM entry: fall back to the configured
        # cell so the slab is still billed (conservatively)
        cell = get_cell_model(cfg.device.device, cfg.circuit.cell_type,
                              cfg.app.data_bits)
    R, C = cfg.circuit.rows, cfg.circuit.cols
    Cs = max(1, min(C, sig_bits))
    n_sub = arch.spec.nv * math.ceil(sig_bits / Cs)
    t = cell.search_latency(R, Cs)
    e = cell.search_energy_pj(R, Cs) * n_sub
    a = cell.area_um2(R, Cs) * n_sub
    return PerfResult(latency_ns=t, energy_pj=e, area_um2=a,
                      breakdown={"prefilter": {"latency_ns": t,
                                               "energy_pj": e,
                                               "area_um2": a}})


def cascade_billing(config: CAMConfig,
                    arch: ArchSpecifics) -> "tuple[float, int]":
    """(searched_fraction, prefilter_bits) the configured cascade implies.

    ``(1.0, 0)`` when the cascade is off — the values under which
    ``predict_search`` is bitwise identical to the full-scan prediction
    (the Table IV anchor).
    """
    sim = config.sim
    if not sim.cascade_enabled():
        return 1.0, 0
    spec = arch.spec
    frac = min(1.0, sim.top_p_banks / max(1, spec.nv))
    return frac, sim.signature_bits or spec.N


def predict_search(config: CAMConfig, arch: ArchSpecifics,
                   ops_per_query: int = 1, *,
                   searched_fraction: float = 1.0,
                   prefilter_bits: int = 0) -> PerfResult:
    """Stage 2: hierarchical performance prediction for one query.

    ``ops_per_query`` models applications whose logical operation issues
    multiple sequential CAM search cycles (e.g. the DRL sampling routine
    [4] — see benchmarks/table4_validation.py).

    ``searched_fraction`` bills the search cascade: only that fraction of
    the banks fires per query, scaling search ENERGY (latency and area are
    unchanged — the whole store still exists and the critical path is the
    slowest surviving bank).  ``prefilter_bits > 0`` additionally bills
    the stage-1 signature slab (``predict_prefilter``) in series.  The
    defaults (1.0, 0) are bitwise the full-scan prediction.
    """
    cfg = config
    cell = get_cell_model(cfg.device.device, cfg.circuit.cell_type,
                          cfg.app.data_bits)
    R, C = cfg.circuit.rows, cfg.circuit.cols
    breakdown: Dict[str, Dict[str, float]] = {}

    # --- subarray level: all subarrays search in parallel ------------------
    t = cell.search_latency(R, C)
    e = cell.search_energy_pj(R, C) * arch.n_subarrays
    a_sub = cell.area_um2(R, C)
    area = a_sub * arch.n_subarrays
    breakdown["subarray"] = {"latency_ns": t, "energy_pj": e,
                             "area_um2": area}

    # --- merge hierarchy: array -> mat -> bank -> top ----------------------
    child_area = a_sub
    n_blocks_at = {"array": arch.n_arrays, "mat": arch.n_mats,
                   "bank": arch.n_banks, "top": 1}
    for lvl in arch.levels:
        n_here = n_blocks_at[lvl.name]
        t_p = lvl.bill.latency()
        e_p = lvl.bill.energy() * n_here
        a_p = lvl.bill.area() * n_here
        ic = interconnect.level_interconnect(
            lvl.n_children, child_area,
            bits_down=C * max(1, cfg.app.data_bits),
            bits_up=2 * math.ceil(math.log2(max(2, arch.spec.padded_K))))
        t += t_p + ic["latency_ns"]
        e += e_p + ic["energy_pj"] * n_here
        area += a_p + ic["area_um2"] * n_here
        breakdown[lvl.name] = {
            "latency_ns": t_p + ic["latency_ns"],
            "energy_pj": e_p + ic["energy_pj"] * n_here,
            "area_um2": a_p + ic["area_um2"] * n_here}
        child_area = child_area * lvl.n_children + a_p / max(1, n_here)

    if searched_fraction != 1.0:
        f = max(0.0, min(1.0, searched_fraction))
        e *= f
        for lvl_b in breakdown.values():
            lvl_b["energy_pj"] *= f
    if prefilter_bits > 0:
        pre = predict_prefilter(cfg, arch, prefilter_bits)
        t += pre.latency_ns
        e += pre.energy_pj
        area += pre.area_um2
        breakdown["prefilter"] = pre.breakdown["prefilter"]

    return PerfResult(latency_ns=t * ops_per_query,
                      energy_pj=e * ops_per_query,
                      area_um2=area, breakdown=breakdown)


# Bit widths of the cross-device merge payload fields (merge.
# shard_merge_payload): match lines are 1-bit wires; candidate scores and
# the voting tie-break normalizer travel as f32; candidate indices are
# log2(global rows) wide (same convention as the on-chip bits_up).
def _payload_bits(field: str, global_rows: int) -> int:
    if field == "match_rows":
        return 1
    if field == "cand_idx":
        return max(1, math.ceil(math.log2(max(2, global_rows))))
    if field in ("cand_vals", "dmax"):
        return 32
    raise KeyError(f"unknown merge payload field {field!r}")


def sharded_merge_bytes(config: CAMConfig, arch: ArchSpecifics,
                        devices: int, queries_per_batch: int = 1) -> dict:
    """Per-device chip-to-chip payload bytes for one query batch.

    Shapes come from ``merge.shard_merge_payload`` — the same accounting
    ``ShardedCAMSimulator._combine`` executes — converted to bytes with
    the per-field wire widths above.  Returns the per-field byte map plus
    ``total`` and the shard geometry used (``nv_local``, mesh-padded
    global row count ``rows_pad``).
    """
    cfg = config
    spec = arch.spec
    nv_local = math.ceil(spec.nv / max(1, devices))
    rows_pad = nv_local * max(1, devices) * spec.R
    k = merge.match_k(cfg.app.match_type, cfg.app.match_param,
                      spec.padded_K)
    payload = merge.shard_merge_payload(
        cfg.app.match_type, cfg.arch.h_merge, Q=queries_per_batch,
        nv_local=nv_local, R=spec.R, k=k)
    out = {name: math.prod(shape) * _payload_bits(name, rows_pad) / 8.0
           for name, shape in payload.items()}
    out["total"] = sum(out.values())
    out["nv_local"] = nv_local
    out["rows_pad"] = rows_pad
    return out


def predict_search_sharded(config: CAMConfig, arch: ArchSpecifics,
                           mesh: Union[int, "interconnect.MeshSpec"], *,
                           queries_per_batch: int = 1,
                           ops_per_query: int = 1,
                           searched_fraction: float = 1.0,
                           prefilter_bits: int = 0) -> PerfResult:
    """Mesh-level performance prediction: per-device hierarchy rollup plus
    the cross-device merge, exactly as ``ShardedCAMSimulator`` executes it.

    The stored grid's nv (bank) axis is padded to a device multiple and
    split; every device runs the full single-chip ``predict_search``
    rollup over its local shard (all devices search in parallel), and the
    vertical merge crosses the mesh with the arrays
    ``merge.shard_merge_payload`` describes: an all_gather of per-bank
    match lines for exact/threshold, local-top-k candidate scores +
    indices for best match, one pmax scalar per query for voting
    tie-breaks.  Link traffic amortizes over ``queries_per_batch`` (the
    collective moves the whole batch's payload at once).

    At ``mesh`` size 1 this degenerates bit-for-bit to
    ``predict_search(config, arch, ops_per_query)`` — the Table IV
    calibration anchor.
    """
    mesh = interconnect.as_mesh(mesh)
    d = mesh.devices
    cfg = config
    spec = arch.spec
    # d == 1 reuses the caller's arch so the degeneration is bitwise, not
    # merely numerically close
    local_arch = arch if d == 1 else estimate_arch(
        cfg, math.ceil(spec.nv / d) * spec.R, spec.N)
    # the cascade knobs bill per device: each device searches the same
    # FRACTION of its local banks (p_loc/nv_loc == top_p/nv up to the
    # ceil) and holds its own shard of the signature slab
    local = predict_search(cfg, local_arch, ops_per_query=1,
                           searched_fraction=searched_fraction,
                           prefilter_bits=prefilter_bits)

    Q = max(1, queries_per_batch)
    link = mesh.link_model
    traffic = sharded_merge_bytes(cfg, arch, d, Q)
    wire = interconnect.mesh_all_gather(d, traffic["total"], link)
    # mesh-root merge peripherals: d device results reduced once more with
    # the same scheme the on-chip top level uses.  Only the LINK traffic
    # amortizes over the batch (the collective moves all Q queries' payload
    # in one transfer); the root peripherals merge every query's results
    # separately, so they bill fully per query — same convention as the
    # on-chip 'top' level in predict_search (one root instance).
    root = estimate_merge_peripherals(
        d, cfg.circuit.rows, match_type=cfg.app.match_type,
        h_merge=cfg.arch.h_merge, v_merge=cfg.arch.v_merge,
        merging_horizontal=False)
    t_mesh = wire["latency_ns"] / Q + root.latency()
    e_mesh = wire["energy_pj"] / Q + root.energy()
    a_mesh = root.area() + link.phy_area_um2 * d if d > 1 else 0.0

    t = (local.latency_ns + t_mesh) * ops_per_query
    e = (local.energy_pj * d + e_mesh) * ops_per_query
    breakdown = dict(local.breakdown)
    breakdown["mesh"] = {
        "latency_ns": t_mesh * ops_per_query,
        "energy_pj": e_mesh * ops_per_query,
        "area_um2": a_mesh,
        "devices": float(d),
        "bytes_per_device_batch": traffic["total"],
        "bytes_on_wire_batch": wire["bytes_on_wire"],
    }
    return PerfResult(latency_ns=t, energy_pj=e,
                      area_um2=local.area_um2 * d + a_mesh,
                      breakdown=breakdown)


def perf_report(config: CAMConfig, arch: ArchSpecifics, *,
                mesh: Optional[Union[int, "interconnect.MeshSpec"]] = None,
                n_queries: int = 1, include_write: bool = False,
                ops_per_query: int = 1, clock_hz: Optional[float] = None,
                queries_per_batch: int = 1,
                searched_fraction: Optional[float] = None,
                prefilter_bits: Optional[int] = None) -> "PerfReport":
    """The ``eval_perf`` report shared by ``CAMASim`` (mesh=None: single
    chip) and ``ShardedCAMSimulator`` (mesh = its bank-axis size) — a
    ``PerfReport`` (dict subclass; historical keys preserved verbatim).

    ``clock_hz``: system clock — each search cycle is quantized to
    max(combinational search latency, one clock period).

    ``searched_fraction`` / ``prefilter_bits`` default to whatever the
    config's search cascade implies (``cascade_billing``) — i.e. (1.0, 0),
    the exact full-scan prediction, when the cascade is off; pass them
    explicitly to sweep recall/latency trade-offs before any write."""
    if searched_fraction is None or prefilter_bits is None:
        f, b = cascade_billing(config, arch)
        if searched_fraction is None:
            searched_fraction = f
        if prefilter_bits is None:
            prefilter_bits = b
    if mesh is None:
        search = predict_search(config, arch, ops_per_query=1,
                                searched_fraction=searched_fraction,
                                prefilter_bits=prefilter_bits)
    else:
        search = predict_search_sharded(
            config, arch, mesh, queries_per_batch=queries_per_batch,
            searched_fraction=searched_fraction,
            prefilter_bits=prefilter_bits)
    cycle = search.latency_ns
    if clock_hz is not None:
        cycle = max(cycle, 1e9 / clock_hz)
    search = PerfResult(latency_ns=cycle * ops_per_query,
                        energy_pj=search.energy_pj * ops_per_query,
                        area_um2=search.area_um2,
                        breakdown=search.breakdown)
    out = {
        "arch": arch.describe(),
        "search": search,
        "latency_ns": search.latency_ns,
        "energy_pj": search.energy_pj * n_queries,
        "area_um2": search.area_um2,
        "edp_pj_ns": search.edp,
    }
    if mesh is not None:
        # the per-level breakdown stays per-op (as every on-chip level
        # does), but this top-level entry sits next to the ops-scaled
        # latency_ns/energy_pj and must scale with them
        m = dict(search.breakdown["mesh"])
        m["latency_ns"] *= ops_per_query
        m["energy_pj"] *= ops_per_query
        out["mesh"] = m
    if include_write:
        w = predict_write(config, arch)
        out["write"] = w
        out["energy_pj"] += w.energy_pj
    # mutation billing: a streaming insert is a 1-row partial write.
    # ``device_inserts_per_s`` is the pure hardware rate (one
    # row-programming latency per insert — what the CAM macro admits);
    # ``inserts_per_s`` is the honest SERVING proxy: each insert also pays
    # one engine step of host-side work (queue admission, slot pick,
    # dispatch), which dominates off-accelerator — the device-only figure
    # overstated the measured serve rate by ~8800x (BENCH
    # serve_inserts_*: est 6666667 vs measured 751/432).  Additive keys —
    # existing report consumers and the golden Table IV snapshot are
    # unaffected.
    w1 = predict_write(config, arch, rows=1).latency_ns
    out["device_inserts_per_s"] = 1e9 / w1
    out["inserts_per_s"] = 1e9 / (w1 + HOST_STEP_OVERHEAD_NS)
    # reliability billing: additive keys, present ONLY when the
    # reliability subsystem is on, so the off-report (and the golden
    # Table IV snapshot) stays key-for-key identical
    if config.reliability.enabled:
        rel = config.reliability
        out["expected_row_programs"] = expected_row_programs(
            config, arch.spec.nh * config.circuit.cols)
        scrub = predict_scrub(config, arch)
        out["scrub"] = scrub
        # scrub duty cycle: one scrub pass amortized over its period of
        # serve-engine steps (0 when scrubbing is off)
        out["scrub_energy_pj_per_step"] = (
            scrub.energy_pj / rel.scrub_every if rel.scrub_every > 0
            else 0.0)
    return PerfReport(out)


def predict_schedule(config: CAMConfig, pass_shapes, *,
                     mesh: Optional[Union[int,
                                          "interconnect.MeshSpec"]] = None,
                     n_queries: int = 1, include_write: bool = False,
                     ops_per_query: int = 1,
                     clock_hz: Optional[float] = None,
                     queries_per_batch: int = 1,
                     searched_fraction: Optional[float] = None,
                     prefilter_bits: Optional[int] = None) -> PerfReport:
    """Whole-schedule billing: a multi-pass query program (the
    ``core.plan`` compiler's output) costed through the existing
    single-pass predictors BEFORE any write.

    ``pass_shapes`` is a sequence of per-pass ``(entries, dims)`` store
    shapes (``Schedule.pass_shapes()``).  Every pass is billed exactly as
    ``perf_report`` bills a single store of that shape (same mesh /
    cascade / clock semantics, so a one-pass schedule is key-for-key the
    plain report), and the passes execute in series on their own resident
    slabs: ``latency_ns`` / ``energy_pj`` / ``area_um2`` are the SUMS of
    the per-pass predictions (a property test pins this), ``edp_pj_ns``
    is recomputed from the summed latency and energy.  ``include_write``
    bills each pass's placement as a ``predict_write(rows=K_pass)``
    partial write into its slab.  The per-pass reports ride along under
    ``"passes"``.
    """
    shapes = [(int(k), int(n)) for k, n in pass_shapes]
    if not shapes:
        raise ValueError("a schedule needs at least one pass")
    reports = []
    writes = []
    for K, N in shapes:
        arch = estimate_arch(config, K, N)
        reports.append(perf_report(
            config, arch, mesh=mesh, n_queries=n_queries,
            include_write=False, ops_per_query=ops_per_query,
            clock_hz=clock_hz, queries_per_batch=queries_per_batch,
            searched_fraction=searched_fraction,
            prefilter_bits=prefilter_bits))
        if include_write:
            writes.append(predict_write(config, arch, rows=K))
    lat = sum(r["latency_ns"] for r in reports)
    en = sum(r["energy_pj"] for r in reports)
    area = sum(r["area_um2"] for r in reports)
    out = {
        "arch": " + ".join(r["arch"] for r in reports),
        "search": PerfResult(
            latency_ns=lat, energy_pj=en, area_um2=area,
            breakdown={f"pass{i}": {"latency_ns": r["latency_ns"],
                                    "energy_pj": r["energy_pj"],
                                    "area_um2": r["area_um2"]}
                       for i, r in enumerate(reports)}),
        "latency_ns": lat,
        "energy_pj": en,
        "area_um2": area,
        "edp_pj_ns": lat * en / max(1, n_queries),
        "passes": reports,
        "inserts_per_s": reports[0]["inserts_per_s"],
        "device_inserts_per_s": reports[0]["device_inserts_per_s"],
    }
    if include_write:
        w = PerfResult(
            latency_ns=sum(x.latency_ns for x in writes),
            energy_pj=sum(x.energy_pj for x in writes),
            area_um2=area,
            breakdown={f"pass{i}": x.breakdown["write"]
                       for i, x in enumerate(writes)})
        out["write"] = w
        out["energy_pj"] += w.energy_pj
    return PerfReport(out)


def predict_write(config: CAMConfig, arch: ArchSpecifics,
                  rows: Optional[int] = None) -> PerfResult:
    """Write-path prediction: program all rows (row-parallel across
    subarrays, row-serial within a subarray).

    ``rows`` bills a PARTIAL write of that many rows (an online
    insert/update batch) instead of the full store: latency is row-serial
    in min(R, rows) (free slots cluster in the same subarray row range in
    the worst case), and energy scales the full-store programming energy
    by the touched-row fraction across the nh horizontal segments each
    row spans.  ``rows=None`` keeps the historical full-store billing
    exactly."""
    cfg = config
    cell = get_cell_model(cfg.device.device, cfg.circuit.cell_type,
                          cfg.app.data_bits)
    R, C = cfg.circuit.rows, cfg.circuit.cols
    if rows is None:
        rows_eff = min(R, arch.spec.K)  # rows written per subarray (serial)
        t = cell.write_latency(rows_eff)
        e = cell.write_energy_pj(R, C) * arch.n_subarrays
    else:
        if rows < 0:
            raise ValueError("rows must be >= 0")
        t = cell.write_latency(min(R, rows))
        e = (cell.write_energy_pj(R, C) * arch.spec.nh
             * min(rows, arch.spec.padded_K) / R)
    a = cell.area_um2(R, C) * arch.n_subarrays
    E = expected_row_programs(cfg, arch.spec.nh * C)
    if E != 1.0:
        # write-verify billing: every programmed row costs E expected row
        # programs (initial attempt + re-programs of out-of-tolerance rows)
        t, e = t * E, e * E
    return PerfResult(latency_ns=t, energy_pj=e, area_um2=a,
                      breakdown={"write": {"latency_ns": t, "energy_pj": e,
                                           "area_um2": a}})


# ---------------------------------------------------------------------------
# reliability billing (core.reliability): write-verify retries + scrubbing
# ---------------------------------------------------------------------------
def expected_row_programs(config: CAMConfig, ncells: int) -> float:
    """Expected row-program count per written row under write-verify.

    Analytic model of ``reliability.program_rows_verified``: each of the
    row's ``ncells`` cells independently lands outside ``verify_tol``
    with the Gaussian tail probability erfc(tol / (sigma*sqrt(2))) of the
    D2D programming noise; the row is re-programmed while any live cell
    is out of tolerance, up to ``verify_retries`` times.  Rows holding a
    hard fault (stuck cell / dead row) can never verify and burn every
    retry.  Exactly 1.0 when reliability is off or ``verify_retries`` is
    0, so legacy write billing is untouched.
    """
    rel = config.reliability
    r = rel.verify_retries
    if not rel.enabled or r < 1:
        return 1.0
    dev = config.device
    sigma = 0.0
    if dev.variation in ("d2d", "both"):
        if (dev.variation_spec == "exper" and dev.exper_table
                and config.app.data_bits > 0):
            sigma = sum(dev.exper_table) / len(dev.exper_table)
        else:
            sigma = dev.variation_std
    if sigma > 0:
        p_cell = math.erfc(rel.verify_tol / (sigma * math.sqrt(2.0)))
    else:
        p_cell = 0.0
    p_cell = min(1.0, max(0.0, p_cell))
    # soft (re-programmable) row failure per attempt
    p_soft = 1.0 - (1.0 - p_cell) ** ncells
    # hard faults: a dead row, or any stuck cell in the row
    p_stuck = 1.0 - (1.0 - rel.stuck_frac) ** ncells
    p_hard = rel.dead_row_frac + (1.0 - rel.dead_row_frac) * p_stuck
    e_soft = 1.0 + sum(p_soft ** a for a in range(1, r + 1))
    return p_hard * (1.0 + r) + (1.0 - p_hard) * e_soft


def predict_scrub(config: CAMConfig, arch: ArchSpecifics) -> PerfResult:
    """One background scrub pass: re-program the ``scrub_rows``
    most-drifted rows from their clean codes (a partial write, including
    the expected write-verify retries ``predict_write`` already bills)."""
    return predict_write(config, arch,
                         rows=max(1, config.reliability.scrub_rows))
