"""Performance evaluator (paper Fig. 1c).

Two stages:
  1. *Architecture specifics estimation* — from the stored-data size and the
     arch config, determine the number of compute blocks at each hierarchy
     level (bank-mat-array-subarray) and run the peripheral estimator per
     level for the configured merge scheme.
  2. *Performance prediction* — hierarchical rollup bank→mat→array→subarray
     of CAM (device LUT), peripheral (ALADDIN-like), and interconnect
     (NVSim-like RC) latency / energy / area for search and write.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List

from ..config import CAMConfig
from ..mapping import GridSpec, grid_spec
from . import interconnect
from .devices import get_cell_model
from .peripherals import PeripheralBill, estimate_merge_peripherals


@dataclass
class LevelSpec:
    name: str                 # 'array' | 'mat' | 'bank' | 'top'
    n_children: int           # blocks merged at this level
    merging_horizontal: bool  # does this level merge across query segments?
    bill: PeripheralBill = field(default_factory=PeripheralBill)


@dataclass
class ArchSpecifics:
    """Output of stage 1: block counts + peripheral bills per level."""
    spec: GridSpec
    n_subarrays: int
    n_arrays: int
    n_mats: int
    n_banks: int
    levels: List[LevelSpec] = field(default_factory=list)

    def describe(self) -> str:
        s = (f"grid {self.spec.nv}x{self.spec.nh} "
             f"({self.n_subarrays} subarrays of "
             f"{self.spec.R}x{self.spec.C}) -> {self.n_arrays} arrays, "
             f"{self.n_mats} mats, {self.n_banks} banks")
        return s


@dataclass
class PerfResult:
    """Output of stage 2 (per search or write operation)."""
    latency_ns: float
    energy_pj: float
    area_um2: float
    breakdown: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def edp(self) -> float:
        """Energy-delay product in pJ*ns (1e-21 J*s = zJ*s)."""
        return self.latency_ns * self.energy_pj

    @property
    def edp_aj_s(self) -> float:
        """EDP in aJ*s (units used by paper Fig. 4)."""
        return self.edp * 1e-3 * 1e-9  # pJ->aJ is *1e6; ns->s is *1e-9
        # (kept explicit: pJ*ns = 1e-12 J * 1e-9 s = 1e-21 J*s = 1e-3 aJ*s)


def estimate_arch(config: CAMConfig, K: int, N: int) -> ArchSpecifics:
    """Stage 1: architecture specifics estimation.

    CAMASim assumes all stored data fits in the CAM (paper §III-D) and
    derives block counts at the array/mat/bank layers from arch config and
    the stored-data size.
    """
    cfg = config
    spec = grid_spec(K, N, cfg.circuit.rows, cfg.circuit.cols)
    n_sub = spec.n_subarrays
    spa = cfg.arch.subarrays_per_array
    apm = cfg.arch.arrays_per_mat
    mpb = cfg.arch.mats_per_bank
    n_arrays = math.ceil(n_sub / spa)
    n_mats = math.ceil(n_arrays / apm)
    n_banks = math.ceil(n_mats / mpb)

    # Which levels merge horizontally vs vertically: the mapper lays the
    # (nv, nh) grid row-major onto subarray slots, so the lowest levels that
    # span multiple horizontal segments merge horizontally first (paper
    # Fig. 2 shows the voting peripherals at the array level).
    a = ArchSpecifics(spec=spec, n_subarrays=n_sub, n_arrays=n_arrays,
                      n_mats=n_mats, n_banks=n_banks)
    remaining_h = spec.nh
    for name, n_children in (("array", min(spa, n_sub)),
                             ("mat", min(apm, max(1, n_arrays))),
                             ("bank", min(mpb, max(1, n_mats))),
                             ("top", max(1, n_banks))):
        merging_h = remaining_h > 1
        consumed = min(remaining_h, max(1, n_children))
        if merging_h:
            remaining_h = math.ceil(remaining_h / consumed)
        bill = estimate_merge_peripherals(
            n_children, cfg.circuit.rows,
            match_type=cfg.app.match_type,
            h_merge=cfg.arch.h_merge, v_merge=cfg.arch.v_merge,
            merging_horizontal=merging_h)
        a.levels.append(LevelSpec(name, n_children, merging_h, bill))
    return a


def predict_search(config: CAMConfig, arch: ArchSpecifics,
                   ops_per_query: int = 1) -> PerfResult:
    """Stage 2: hierarchical performance prediction for one query.

    ``ops_per_query`` models applications whose logical operation issues
    multiple sequential CAM search cycles (e.g. the DRL sampling routine
    [4] — see benchmarks/table4_validation.py).
    """
    cfg = config
    cell = get_cell_model(cfg.device.device, cfg.circuit.cell_type,
                          cfg.app.data_bits)
    R, C = cfg.circuit.rows, cfg.circuit.cols
    breakdown: Dict[str, Dict[str, float]] = {}

    # --- subarray level: all subarrays search in parallel ------------------
    t = cell.search_latency(R, C)
    e = cell.search_energy_pj(R, C) * arch.n_subarrays
    a_sub = cell.area_um2(R, C)
    area = a_sub * arch.n_subarrays
    breakdown["subarray"] = {"latency_ns": t, "energy_pj": e,
                             "area_um2": area}

    # --- merge hierarchy: array -> mat -> bank -> top ----------------------
    child_area = a_sub
    n_blocks_at = {"array": arch.n_arrays, "mat": arch.n_mats,
                   "bank": arch.n_banks, "top": 1}
    for lvl in arch.levels:
        n_here = n_blocks_at[lvl.name]
        t_p = lvl.bill.latency()
        e_p = lvl.bill.energy() * n_here
        a_p = lvl.bill.area() * n_here
        ic = interconnect.level_interconnect(
            lvl.n_children, child_area,
            bits_down=C * max(1, cfg.app.data_bits),
            bits_up=2 * math.ceil(math.log2(max(2, arch.spec.padded_K))))
        t += t_p + ic["latency_ns"]
        e += e_p + ic["energy_pj"] * n_here
        area += a_p + ic["area_um2"] * n_here
        breakdown[lvl.name] = {
            "latency_ns": t_p + ic["latency_ns"],
            "energy_pj": e_p + ic["energy_pj"] * n_here,
            "area_um2": a_p + ic["area_um2"] * n_here}
        child_area = child_area * lvl.n_children + a_p / max(1, n_here)

    return PerfResult(latency_ns=t * ops_per_query,
                      energy_pj=e * ops_per_query,
                      area_um2=area, breakdown=breakdown)


def predict_write(config: CAMConfig, arch: ArchSpecifics) -> PerfResult:
    """Write-path prediction: program all rows (row-parallel across
    subarrays, row-serial within a subarray)."""
    cfg = config
    cell = get_cell_model(cfg.device.device, cfg.circuit.cell_type,
                          cfg.app.data_bits)
    R, C = cfg.circuit.rows, cfg.circuit.cols
    rows_eff = min(R, arch.spec.K)  # rows written per subarray (serial)
    t = cell.write_latency(rows_eff)
    e = cell.write_energy_pj(R, C) * arch.n_subarrays
    a = cell.area_um2(R, C) * arch.n_subarrays
    return PerfResult(latency_ns=t, energy_pj=e, area_um2=a,
                      breakdown={"write": {"latency_ns": t, "energy_pj": e,
                                           "area_um2": a}})
