from .devices import CellModel, get_cell_model, register_cell_model
from .estimator import (ArchSpecifics, PerfReport, PerfResult,
                        cascade_billing, estimate_arch, perf_report,
                        predict_prefilter, predict_schedule, predict_search,
                        predict_search_sharded, predict_write,
                        sharded_merge_bytes)
from .interconnect import (MESH_LINKS, MeshLink, MeshSpec, get_mesh_link,
                           mesh_all_gather)
from .peripherals import PeripheralBill, estimate_merge_peripherals

__all__ = [
    "CellModel", "get_cell_model", "register_cell_model",
    "ArchSpecifics", "PerfReport", "PerfResult", "estimate_arch",
    "cascade_billing", "predict_prefilter", "predict_schedule",
    "predict_search", "predict_search_sharded", "predict_write",
    "perf_report",
    "sharded_merge_bytes", "MeshLink", "MeshSpec", "MESH_LINKS",
    "get_mesh_link", "mesh_all_gather",
    "PeripheralBill", "estimate_merge_peripherals",
]
