from .devices import CellModel, get_cell_model, register_cell_model
from .estimator import (ArchSpecifics, PerfResult, estimate_arch,
                        predict_search, predict_write)
from .peripherals import PeripheralBill, estimate_merge_peripherals

__all__ = [
    "CellModel", "get_cell_model", "register_cell_model",
    "ArchSpecifics", "PerfResult", "estimate_arch", "predict_search",
    "predict_write", "PeripheralBill", "estimate_merge_peripherals",
]
