"""Peripheral circuit catalog (ALADDIN-like pre-RTL models, paper §III-D).

Commonly-used peripherals for merge schemes: comparators, adders, registers,
voting counters, and result buffers.  Latency in ns, energy in pJ, area um^2
— 22nm, consistent with the device LUT calibration.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class PeripheralModel:
    t_op: float      # ns per stage/operation
    e_op: float      # pJ per operation
    area: float      # um^2 per instance


# 22nm pre-RTL estimates (ALADDIN-style)
COMPARATOR = PeripheralModel(t_op=0.30, e_op=0.020, area=18.0)   # b-bit cmp
ADDER = PeripheralModel(t_op=0.35, e_op=0.030, area=24.0)
REGISTER = PeripheralModel(t_op=0.05, e_op=0.005, area=6.0)
VOTE_COUNTER = PeripheralModel(t_op=0.25, e_op=0.012, area=14.0)
ENCODER = PeripheralModel(t_op=0.20, e_op=0.010, area=10.0)      # prio encoder
BUFFER_BYTE = PeripheralModel(t_op=0.10, e_op=0.002, area=0.9)   # per byte


def tree_depth(n: int) -> int:
    return max(0, math.ceil(math.log2(max(1, n))))


@dataclass
class PeripheralBill:
    """Peripheral requirements estimated for one hierarchy level."""
    comparators: int = 0
    adders: int = 0
    registers: int = 0
    vote_counters: int = 0
    encoders: int = 0
    buffer_bytes: int = 0
    tree_levels: int = 0       # critical-path depth through this level

    def latency(self) -> float:
        t = self.tree_levels * max(
            COMPARATOR.t_op if self.comparators else 0.0,
            ADDER.t_op if self.adders else 0.0,
            VOTE_COUNTER.t_op if self.vote_counters else 0.0)
        if self.encoders:
            t += ENCODER.t_op
        if self.registers:
            t += REGISTER.t_op
        return t

    def energy(self) -> float:
        return (self.comparators * COMPARATOR.e_op +
                self.adders * ADDER.e_op +
                self.registers * REGISTER.e_op +
                self.vote_counters * VOTE_COUNTER.e_op +
                self.encoders * ENCODER.e_op +
                self.buffer_bytes * BUFFER_BYTE.e_op)

    def area(self) -> float:
        return (self.comparators * COMPARATOR.area +
                self.adders * ADDER.area +
                self.registers * REGISTER.area +
                self.vote_counters * VOTE_COUNTER.area +
                self.encoders * ENCODER.area +
                self.buffer_bytes * BUFFER_BYTE.area)


def estimate_merge_peripherals(n_blocks: int, rows: int, *, match_type: str,
                               h_merge: str, v_merge: str,
                               merging_horizontal: bool) -> PeripheralBill:
    """Peripheral estimator (paper Fig. 1c / Fig. 2).

    Given ``n_blocks`` lower-level blocks merged at this level, estimate the
    peripheral circuits required by the configured merge scheme.  E.g. for
    the voting scheme, one vote counter per row plus a comparator tree to
    pick the max-vote row; for exact match, an AND/gather needs only
    registers and a priority encoder.
    """
    bill = PeripheralBill()
    depth = tree_depth(n_blocks)
    if n_blocks <= 1:
        return bill
    if merging_horizontal:
        if h_merge == "voting":
            bill.vote_counters = rows
            bill.comparators = rows - 1          # max-vote comparator tree
            bill.tree_levels = depth
            bill.buffer_bytes = rows             # vote buffers
        elif h_merge == "adder":
            bill.adders = rows * (n_blocks - 1)  # per-row adder tree
            bill.tree_levels = depth
            bill.buffer_bytes = 4 * rows
        else:  # 'and' — wired-AND across segment match lines
            bill.registers = rows
            bill.tree_levels = 1
    else:
        if match_type == "best" and v_merge == "comparator":
            bill.comparators = n_blocks - 1      # winner comparator tree
            bill.registers = n_blocks            # winner (idx, val) latches
            bill.tree_levels = depth
            bill.buffer_bytes = 8 * n_blocks
        else:  # gather
            bill.registers = n_blocks
            bill.encoders = 1
            bill.tree_levels = 1
            bill.buffer_bytes = max(1, rows * n_blocks // 8)
    return bill
