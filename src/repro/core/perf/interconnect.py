"""Interconnect estimation (NVSim-like RC H-tree, paper §III-D).

Each hierarchy level routes query data down to its children and match
results back up through an H-tree.  We estimate wire length from the
children's footprint (sqrt of aggregate area) and apply distributed-RC
delay + switching energy per the NVSim methodology, with 22nm wire
constants.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# 22nm global-layer wire constants
R_WIRE = 3.0       # ohm/um
C_WIRE = 0.20e-3   # pF/um  (0.2 fF/um)
E_WIRE = 0.02e-3   # pJ/um per bit toggled (CV^2 at ~0.8V, activity 0.5)
T_REPEATER = 2.0e-4  # ns/um repeated-wire delay (~200 ps/mm at 22nm)


@dataclass(frozen=True)
class WireStats:
    length_um: float
    latency_ns: float
    energy_pj_per_bit: float


def htree_level(children: int, child_area_um2: float) -> WireStats:
    """One H-tree level spanning ``children`` blocks of given area."""
    if children <= 1 or child_area_um2 <= 0:
        return WireStats(0.0, 0.0, 0.0)
    side = math.sqrt(children * child_area_um2)
    length = side  # root-to-leaf H-tree ~ half-perimeter ~ side
    # repeated wire: delay linear in length (RC quadratic term buffered out)
    latency = T_REPEATER * length
    energy = E_WIRE * length
    return WireStats(length, latency, energy)


def level_interconnect(children: int, child_area_um2: float,
                       bits_down: int, bits_up: int) -> dict:
    """Latency/energy/area for one level's query-broadcast + result-gather."""
    w = htree_level(children, child_area_um2)
    return {
        "latency_ns": 2 * w.latency_ns,                       # down + up
        "energy_pj": w.energy_pj_per_bit * (bits_down + bits_up),
        "area_um2": 0.15 * w.length_um * max(bits_down, bits_up) ** 0.5,
        "length_um": w.length_um,
    }
