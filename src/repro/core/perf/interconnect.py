"""Interconnect estimation (NVSim-like RC H-tree, paper §III-D) plus the
mesh level above it: chip-to-chip links for sharded CAM topologies.

Each on-chip hierarchy level routes query data down to its children and
match results back up through an H-tree.  We estimate wire length from the
children's footprint (sqrt of aggregate area) and apply distributed-RC
delay + switching energy per the NVSim methodology, with 22nm wire
constants.

Above ``top`` sits the device mesh that ``core.sharded`` actually executes
on: ``MeshLink`` models one chip-to-chip link class (bandwidth, per-hop
latency, energy per bit, PHY area) with presets spanning on-package
bridges, PCB SerDes, and NVLink-class cables; ``mesh_all_gather`` costs the
ring collective the cross-device merge performs.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Union

# 22nm global-layer wire constants
R_WIRE = 3.0       # ohm/um
C_WIRE = 0.20e-3   # pF/um  (0.2 fF/um)
E_WIRE = 0.02e-3   # pJ/um per bit toggled (CV^2 at ~0.8V, activity 0.5)
T_REPEATER = 2.0e-4  # ns/um repeated-wire delay (~200 ps/mm at 22nm)


@dataclass(frozen=True)
class WireStats:
    length_um: float
    latency_ns: float
    energy_pj_per_bit: float


def htree_level(children: int, child_area_um2: float) -> WireStats:
    """One H-tree level spanning ``children`` blocks of given area."""
    if children <= 1 or child_area_um2 <= 0:
        return WireStats(0.0, 0.0, 0.0)
    side = math.sqrt(children * child_area_um2)
    length = side  # root-to-leaf H-tree ~ half-perimeter ~ side
    # repeated wire: delay linear in length (RC quadratic term buffered out)
    latency = T_REPEATER * length
    energy = E_WIRE * length
    return WireStats(length, latency, energy)


def level_interconnect(children: int, child_area_um2: float,
                       bits_down: int, bits_up: int) -> dict:
    """Latency/energy/area for one level's query-broadcast + result-gather."""
    w = htree_level(children, child_area_um2)
    return {
        "latency_ns": 2 * w.latency_ns,                       # down + up
        "energy_pj": w.energy_pj_per_bit * (bits_down + bits_up),
        "area_um2": 0.15 * w.length_um * max(bits_down, bits_up) ** 0.5,
        "length_um": w.length_um,
    }


# ---------------------------------------------------------------------------
# Mesh level: chip-to-chip links above the ``top`` hierarchy level
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class MeshLink:
    """One chip-to-chip link class of the device mesh."""
    name: str
    bandwidth_gbyte_s: float  # per-direction payload bandwidth in
                              # gigaBYTES/s (1 GB/s == 1 byte/ns)
    latency_ns: float         # per-hop link + protocol latency
    energy_pj_per_bit: float  # end-to-end transfer energy per bit
    phy_area_um2: float       # per-chip PHY/SerDes macro footprint


# Link presets (per-direction, per-link ballpark figures for 2.5D bridges,
# board-level SerDes, and NVLink-class cabled fabrics).
MESH_LINKS = {
    "on_package": MeshLink("on_package", bandwidth_gbyte_s=512.0,
                           latency_ns=5.0, energy_pj_per_bit=0.25,
                           phy_area_um2=9_000.0),
    "pcb": MeshLink("pcb", bandwidth_gbyte_s=32.0, latency_ns=30.0,
                    energy_pj_per_bit=4.0, phy_area_um2=25_000.0),
    "nvlink": MeshLink("nvlink", bandwidth_gbyte_s=200.0, latency_ns=12.0,
                       energy_pj_per_bit=1.3, phy_area_um2=40_000.0),
}


def get_mesh_link(link: Union[str, MeshLink]) -> MeshLink:
    if isinstance(link, MeshLink):
        return link
    if link not in MESH_LINKS:
        raise KeyError(f"unknown mesh link {link!r}; presets: "
                       f"{sorted(MESH_LINKS)} (or pass a MeshLink)")
    return MESH_LINKS[link]


@dataclass(frozen=True)
class MeshSpec:
    """Mesh topology above ``top``: device count + link class."""
    devices: int = 1
    link: Union[str, MeshLink] = "on_package"

    def __post_init__(self):
        if self.devices < 1:
            raise ValueError("mesh devices must be >= 1")
        get_mesh_link(self.link)   # validate eagerly

    @property
    def link_model(self) -> MeshLink:
        return get_mesh_link(self.link)


def as_mesh(mesh: Union[int, MeshSpec]) -> MeshSpec:
    """Accept a bare device count where a ``MeshSpec`` is expected."""
    return MeshSpec(devices=mesh) if isinstance(mesh, int) else mesh


def mesh_all_gather(devices: int, bytes_per_device: float,
                    link: Union[str, MeshLink]) -> dict:
    """Ring all-gather of one ``bytes_per_device`` block per chip.

    The standard ring runs ``devices - 1`` serialized steps; in each step
    every chip forwards one block over one link, so every block crosses
    ``devices - 1`` links in total.  A single chip (or an empty payload)
    moves nothing.  ``lax.pmax``-style scalar all-reduces are costed with
    the same ring (their payload is tiny, the hop latency dominates).
    """
    lk = get_mesh_link(link)
    if devices <= 1 or bytes_per_device <= 0:
        return {"latency_ns": 0.0, "energy_pj": 0.0, "bytes_on_wire": 0.0}
    steps = devices - 1
    t_serial = bytes_per_device / lk.bandwidth_gbyte_s     # ns per step
    bytes_on_wire = float(bytes_per_device) * devices * steps
    return {
        "latency_ns": steps * (lk.latency_ns + t_serial),
        "energy_pj": 8.0 * bytes_on_wire * lk.energy_pj_per_bit,
        "bytes_on_wire": bytes_on_wire,
    }
