"""CAMASim facade (paper Fig. 1a): write / query APIs + performance report.

    sim = CAMASim(config)
    state = sim.write(stored)            # (K, N)
    idx, mask = sim.query(state, q)      # (Q, N) -> (Q, k), (Q, K')
    perf = sim.eval_perf(n_queries=Q)    # latency / energy / area / EDP
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from .config import CAMConfig
from .functional import CAMState, FunctionalSimulator
from .perf import (ArchSpecifics, MeshSpec, estimate_arch, perf_report)


class CAMASim:
    def __init__(self, config: CAMConfig, use_kernel: bool = False,
                 c2c_query_tile: int = 1, c2c_fold: str = "grid"):
        config.validate()
        self.config = config
        # c2c_fold plumbs through to the functional simulator so the facade
        # can serve as the bit-exact single-device reference for
        # ShardedCAMSimulator (which always draws C2C noise per bank)
        self.functional = FunctionalSimulator(config, use_kernel=use_kernel,
                                              c2c_query_tile=c2c_query_tile,
                                              c2c_fold=c2c_fold)
        self._arch: Optional[ArchSpecifics] = None
        self._KN: Optional[Tuple[int, int]] = None

    # ------------------------------------------------------------ write
    def write(self, stored: jax.Array,
              key: Optional[jax.Array] = None) -> CAMState:
        self._KN = tuple(stored.shape[:2])   # ACAM ranges carry a 3rd dim
        self._arch = estimate_arch(self.config, *self._KN)
        return self.functional.write(stored, key)

    # ------------------------------------------------------------ query
    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None):
        return self.functional.query(state, queries, key)

    # ----------------------------------------------------------- perf
    def arch_specifics(self) -> ArchSpecifics:
        if self._arch is None:
            raise RuntimeError("call write() before querying arch specifics")
        return self._arch

    def eval_perf(self, n_queries: int = 1, include_write: bool = False,
                  ops_per_query: int = 1,
                  clock_hz: Optional[float] = None,
                  mesh: Optional[Union[int, MeshSpec]] = None,
                  queries_per_batch: int = 1) -> dict:
        """Hardware performance prediction for the written store.

        ``clock_hz``: system clock — each search cycle is quantized to
        max(combinational search latency, one clock period).

        ``mesh``: device count or ``perf.MeshSpec`` — when given, predict
        for the sharded topology ``ShardedCAMSimulator`` executes (per-
        device hierarchy + cross-device merge over chip-to-chip links,
        amortized over ``queries_per_batch``); ``mesh=1`` reproduces the
        single-chip prediction exactly."""
        return perf_report(self.config, self.arch_specifics(), mesh=mesh,
                           n_queries=n_queries, include_write=include_write,
                           ops_per_query=ops_per_query, clock_hz=clock_hz,
                           queries_per_batch=queries_per_batch)

    # ------------------------------------------------------- convenience
    def search(self, stored: jax.Array, queries: jax.Array,
               key: Optional[jax.Array] = None):
        """One-shot write+query (store-once-search-many still preferred)."""
        kw, kq = (jax.random.split(key) if key is not None
                  else (None, None))
        state = self.write(stored, kw)
        return self.query(state, queries, kq)
