"""CAMASim facade (paper Fig. 1a): ONE config-driven entry point that runs
functional simulation and hardware prediction from a single description of
the design space.

    sim = CAMASim(config)                # config.sim picks the backend
    sim = CAMASim.from_json("exp.json")  # the whole experiment from a file
    state = sim.write(stored)            # (K, N)
    res = sim.query(state, q)            # SearchResult; unpacks (idx, mask)
    perf = sim.eval_perf(n_queries=Q)    # PerfReport (latency/energy/area)

The backend (single-chip ``FunctionalSimulator`` vs mesh-sharded
``ShardedCAMSimulator``) is chosen by ``config.sim.backend`` — a one-line
config change with bit-identical search results.  ``plan(entries, dims)``
derives the architecture from shapes alone, so ``eval_perf`` works before
(or without) writing any data — pure-model design-space sweeps never
fabricate stores just to bill area.
"""
from __future__ import annotations

from typing import Optional, Union

import jax

from .backend import Backend, make_backend
from .config import CAMConfig
from .functional import CAMState, FunctionalSimulator, resolve_sim_overrides
from .perf import ArchSpecifics, MeshSpec, PerfReport
from .results import SearchResult


class CAMASim:
    """Config-driven facade over one `Backend`.

    The ``use_kernel`` / ``c2c_query_tile`` / ``c2c_fold`` kwargs are
    deprecated overrides for the ``config.sim`` fields of the same names
    (kept for one release; they emit a DeprecationWarning).
    """

    def __init__(self, config: CAMConfig,
                 use_kernel: Optional[bool] = None,
                 c2c_query_tile: Optional[int] = None,
                 c2c_fold: Optional[str] = None):
        config = resolve_sim_overrides(config, use_kernel=use_kernel,
                                       c2c_query_tile=c2c_query_tile,
                                       c2c_fold=c2c_fold)
        config.validate()
        self.config = config
        self.backend: Backend = make_backend(config)

    # -------------------------------------------------------------- io
    @classmethod
    def from_json(cls, path) -> "CAMASim":
        """Reconstruct the entire experiment from one JSON config file
        (or, like ``CAMConfig.from_json``, from a raw JSON string)."""
        text = str(path)
        if not text.lstrip().startswith("{"):
            with open(path) as f:
                text = f.read()
        return cls(CAMConfig.from_json(text))

    @property
    def functional(self) -> FunctionalSimulator:
        """The underlying single-chip simulator (deprecated attribute,
        kept for one release): the backend itself on the functional
        backend, the sharded backend's shard-local reference otherwise."""
        if isinstance(self.backend, FunctionalSimulator):
            return self.backend
        return self.backend.sim

    # ------------------------------------------------------------ write
    def write(self, stored: jax.Array,
              key: Optional[jax.Array] = None) -> CAMState:
        return self.backend.write(stored, key)

    # -------------------------------------------------------- mutations
    def insert(self, state: CAMState, rows: jax.Array,
               key: Optional[jax.Array] = None):
        """Program ``rows`` into free slots of the resident store; returns
        ``(new_state, ids)`` (see ``FunctionalSimulator.insert``)."""
        return self.backend.insert(state, rows, key)

    def delete(self, state: CAMState, ids) -> CAMState:
        """Invalidate live rows ``ids``; their slots return to the free
        list and they never match again."""
        return self.backend.delete(state, ids)

    def update(self, state: CAMState, ids, rows: jax.Array,
               key: Optional[jax.Array] = None) -> CAMState:
        """Re-program live rows ``ids`` in place with new data."""
        return self.backend.update(state, ids, rows, key)

    def compact(self, state: CAMState,
                key: Optional[jax.Array] = None) -> CAMState:
        """Re-place the live rows as a fresh store (bit-identical to a
        fresh ``write`` of them); row ids renumber 0..K_live-1."""
        return self.backend.compact(state, key)

    # ------------------------------------------------------ reliability
    def age_tick(self, state: CAMState, steps: int = 1) -> CAMState:
        """Advance the store's logical age by ``steps`` (drift clock).
        The serve engine calls this once per ``step()``; a no-op when
        ``config.reliability`` is off."""
        return self.backend.age_tick(state, steps)

    def scrub(self, state: CAMState,
              key: Optional[jax.Array] = None) -> CAMState:
        """Re-program the most-drifted live rows from their clean codes
        (and heal any rows that fail verify onto spares).  The serve
        engine drives this every ``reliability.scrub_every`` steps."""
        return self.backend.scrub(state, key)

    # ------------------------------------------------------------ query
    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None,
              valid_count: Optional[int] = None) -> SearchResult:
        return self.backend.query(state, queries, key,
                                  valid_count=valid_count)

    # ----------------------------------------------------------- perf
    def plan(self, entries: int, dims: int) -> ArchSpecifics:
        """Estimator-only planning: compute ``ArchSpecifics`` from the
        store SHAPE alone, so ``eval_perf`` works before ``write``."""
        return self.backend.plan(entries, dims)

    def arch_specifics(self) -> ArchSpecifics:
        return self.backend.arch_specifics()

    def eval_perf(self, n_queries: int = 1, include_write: bool = False,
                  ops_per_query: int = 1,
                  clock_hz: Optional[float] = None,
                  mesh: Optional[Union[int, MeshSpec]] = None,
                  queries_per_batch: int = 1,
                  searched_fraction: Optional[float] = None,
                  prefilter_bits: Optional[int] = None) -> PerfReport:
        """Hardware performance prediction for the written (or planned)
        store, as a ``PerfReport`` (historical dict keys preserved).

        ``clock_hz``: system clock — each search cycle is quantized to
        max(combinational search latency, one clock period).

        ``mesh``: device count or ``perf.MeshSpec`` — overrides the
        topology to predict for.  Default: the backend's own topology
        (single chip on the functional backend, the bank-axis size on the
        sharded one); ``mesh=1`` reproduces the single-chip prediction
        exactly.

        ``searched_fraction`` / ``prefilter_bits``: search-cascade billing
        overrides; default to what ``config.sim`` implies (full scan —
        1.0 / 0 — when the cascade is off)."""
        return self.backend.eval_perf(
            n_queries=n_queries, include_write=include_write,
            ops_per_query=ops_per_query, clock_hz=clock_hz, mesh=mesh,
            queries_per_batch=queries_per_batch,
            searched_fraction=searched_fraction,
            prefilter_bits=prefilter_bits)

    def sweep_cascade(self, top_p_list, entries: Optional[int] = None,
                      dims: Optional[int] = None, **perf_kw):
        """Estimator-only cascade sweep: predicted perf per ``top_p_banks``
        value, BEFORE any write — the plan()-first recall/latency knob
        exploration the cascade is for.  ``entries``/``dims`` plan the
        architecture when none is planned yet; returns
        ``{top_p: PerfReport}`` (``None`` = full scan, no prefilter)."""
        if entries is not None:
            self.plan(entries, dims)
        arch = self.arch_specifics()
        nv = arch.spec.nv
        sig_bits = self.config.sim.signature_bits or arch.spec.N
        out = {}
        for p in top_p_list:
            if p is None:
                out[p] = self.eval_perf(searched_fraction=1.0,
                                        prefilter_bits=0, **perf_kw)
            else:
                out[p] = self.eval_perf(
                    searched_fraction=min(1.0, p / max(1, nv)),
                    prefilter_bits=sig_bits, **perf_kw)
        return out

    def select_cascade(self, top_p_list, entries: Optional[int] = None,
                      dims: Optional[int] = None, metric: str = "energy_pj",
                      **perf_kw):
        """Pick a cascade budget whose OWN billing beats the full scan.

        Sweeps ``top_p_list`` (plus the ``None`` full-scan baseline) with
        ``sweep_cascade`` and returns ``(best_top_p, reports)`` where
        ``best_top_p`` minimizes ``metric`` — but ONLY among rungs the
        estimator predicts strictly cheaper than the full scan.  A rung
        whose stage-1 signature slab costs more than the banks it skips
        (small grids: the n=2048 geometry bills e_frac=1.186) is never
        selected: when every rung predicts >= the baseline the method
        returns ``None``, i.e. fall back to ``prefilter='off'``.
        """
        reports = self.sweep_cascade(
            [p for p in top_p_list if p is not None] + [None],
            entries, dims, **perf_kw)
        base = reports[None][metric]
        best = None
        for p, rep in reports.items():
            if p is None or rep[metric] >= base:
                continue    # predicts its own loss: never ship it
            if best is None or rep[metric] < reports[best][metric]:
                best = p
        return best, reports

    # ------------------------------------------------ planning / tuning
    def compile(self, program, *, n_features: Optional[int] = None,
                max_rows_per_pass: Optional[int] = None,
                align_banks: Optional[bool] = None):
        """Compile a query program (``core.plan.ir``) onto this CAM.

        Lowers points / range predicates / AND-OR / tree-ensembles into a
        ``Schedule`` of write placements + query passes + a host-side
        combine, and returns a ``CompiledProgram`` bound to this facade:
        ``.run(X)`` executes it on the configured backend, ``.estimate()``
        bills the whole schedule on the estimator before any write."""
        from .plan.compile import CompiledProgram, lower
        schedule = lower(program, self.config, n_features=n_features,
                         max_rows_per_pass=max_rows_per_pass,
                         align_banks=align_banks)
        return CompiledProgram(self, schedule)

    def autotune(self, entries: int, dims: int, *, space=None,
                 objective: str = "edp", queries_per_batch: int = 32):
        """Estimator-only deployment sweep for an ``(entries, dims)``
        store: rank ``sim``-section candidates (q_tile / c2c_query_tile /
        devices / query_shards / link / top_p_banks / signature_bits) and
        return an ``AutotuneResult`` whose ``.config`` is the argmin —
        zero writes, zero backends constructed (``core.plan.autotune``).
        The facade's own config is not mutated; construct
        ``CAMASim(result.config)`` to deploy the winner."""
        from .plan.autotune import autotune as _autotune
        return _autotune(self.config, entries, dims, space=space,
                         objective=objective,
                         queries_per_batch=queries_per_batch)

    # ------------------------------------------------------- convenience
    def search(self, stored: jax.Array, queries: jax.Array,
               key: Optional[jax.Array] = None) -> SearchResult:
        """One-shot write+query (store-once-search-many still preferred)."""
        kw, kq = (jax.random.split(key) if key is not None
                  else (None, None))
        state = self.write(stored, kw)
        return self.query(state, queries, kq)
