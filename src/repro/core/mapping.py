"""Mapping submodule (paper §III-C): partition stored data into subarrays.

Given stored data of K entries × N dims and a subarray of R rows × C cols,
partition into an (nv, nh) grid of (R, C) subarrays:

    nv = ceil(K / R)   vertical   blocks (entries split across subarrays)
    nh = ceil(N / C)   horizontal blocks (dimensions split across subarrays)

Padding cells/rows are tracked with masks so that search results are
identical to the unpartitioned reference (a property test asserts this).
The 2-D grid is then laid onto the bank-mat-array-subarray hierarchy by the
performance estimator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class GridSpec:
    K: int           # entries
    N: int           # dims
    R: int           # rows / subarray
    C: int           # cols / subarray
    nv: int          # vertical blocks
    nh: int          # horizontal blocks

    @property
    def n_subarrays(self) -> int:
        return self.nv * self.nh

    @property
    def padded_K(self) -> int:
        return self.nv * self.R

    @property
    def padded_N(self) -> int:
        return self.nh * self.C


def grid_spec(K: int, N: int, R: int, C: int) -> GridSpec:
    return GridSpec(K=K, N=N, R=R, C=C,
                    nv=math.ceil(K / R), nh=math.ceil(N / C))


def partition_stored(data: jax.Array, spec: GridSpec) -> jax.Array:
    """(K, N[, 2]) -> (nv, nh, R, C[, 2]) with zero padding.

    The optional trailing dim carries ACAM [lo, hi] ranges."""
    K, N = data.shape[:2]
    assert (K, N) == (spec.K, spec.N), (data.shape, spec)
    extra = data.shape[2:]
    pad = ((0, spec.padded_K - K), (0, spec.padded_N - N)) +         ((0, 0),) * len(extra)
    x = jnp.pad(data, pad)
    x = x.reshape(spec.nv, spec.R, spec.nh, spec.C, *extra)
    perm = (0, 2, 1, 3) + tuple(range(4, 4 + len(extra)))
    return x.transpose(*perm)  # (nv, nh, R, C[, 2])


def partition_query(q: jax.Array, spec: GridSpec) -> jax.Array:
    """(..., N) -> (..., nh, C) query segments."""
    pad = [(0, 0)] * (q.ndim - 1) + [(0, spec.padded_N - spec.N)]
    x = jnp.pad(q, pad)
    return x.reshape(*q.shape[:-1], spec.nh, spec.C)


def col_valid_mask(spec: GridSpec) -> jax.Array:
    """(nh, C) 1.0 where the column holds real data, 0.0 where padding."""
    idx = jnp.arange(spec.padded_N).reshape(spec.nh, spec.C)
    return (idx < spec.N).astype(jnp.float32)


def row_valid_mask(spec: GridSpec) -> jax.Array:
    """(nv, R) 1.0 where the row holds a real entry."""
    idx = jnp.arange(spec.padded_K).reshape(spec.nv, spec.R)
    return (idx < spec.K).astype(jnp.float32)


def global_row_index(spec: GridSpec) -> jax.Array:
    """(nv, R) global entry index of each subarray row."""
    return jnp.arange(spec.padded_K).reshape(spec.nv, spec.R)
