"""Mapping submodule (paper §III-C): partition stored data into subarrays.

Given stored data of K entries × N dims and a subarray of R rows × C cols,
partition into an (nv, nh) grid of (R, C) subarrays:

    nv = ceil(K / R)   vertical   blocks (entries split across subarrays)
    nh = ceil(N / C)   horizontal blocks (dimensions split across subarrays)

Padding cells/rows are tracked with masks so that search results are
identical to the unpartitioned reference (a property test asserts this).
The 2-D grid is then laid onto the bank-mat-array-subarray hierarchy by the
performance estimator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GridSpec:
    K: int           # entries
    N: int           # dims
    R: int           # rows / subarray
    C: int           # cols / subarray
    nv: int          # vertical blocks
    nh: int          # horizontal blocks

    @property
    def n_subarrays(self) -> int:
        return self.nv * self.nh

    @property
    def padded_K(self) -> int:
        return self.nv * self.R

    @property
    def padded_N(self) -> int:
        return self.nh * self.C


def grid_spec(K: int, N: int, R: int, C: int, capacity: int = 0) -> GridSpec:
    """``capacity`` reserves row head-room: the grid is sized for
    ``max(K, capacity)`` rows so online inserts find free slots, while
    ``K`` (and therefore ``row_valid_mask``) still describes the rows
    actually written."""
    return GridSpec(K=K, N=N, R=R, C=C,
                    nv=math.ceil(max(K, capacity) / R), nh=math.ceil(N / C))


def partition_stored(data: jax.Array, spec: GridSpec) -> jax.Array:
    """(K, N[, 2]) -> (nv, nh, R, C[, 2]) with zero padding.

    The optional trailing dim carries ACAM [lo, hi] ranges."""
    K, N = data.shape[:2]
    assert (K, N) == (spec.K, spec.N), (data.shape, spec)
    extra = data.shape[2:]
    pad = ((0, spec.padded_K - K), (0, spec.padded_N - N)) +         ((0, 0),) * len(extra)
    x = jnp.pad(data, pad)
    x = x.reshape(spec.nv, spec.R, spec.nh, spec.C, *extra)
    perm = (0, 2, 1, 3) + tuple(range(4, 4 + len(extra)))
    return x.transpose(*perm)  # (nv, nh, R, C[, 2])


def partition_rows(rows: jax.Array, spec: GridSpec) -> jax.Array:
    """(M, N[, 2]) -> (M, nh, C[, 2]) row segments (the per-row view of
    ``partition_stored``, for incremental writes into existing slots)."""
    M, N = rows.shape[:2]
    assert N == spec.N, (rows.shape, spec)
    extra = rows.shape[2:]
    pad = ((0, 0), (0, spec.padded_N - N)) + ((0, 0),) * len(extra)
    x = jnp.pad(rows, pad)
    return x.reshape(M, spec.nh, spec.C, *extra)


def partition_query(q: jax.Array, spec: GridSpec) -> jax.Array:
    """(..., N) -> (..., nh, C) query segments."""
    pad = [(0, 0)] * (q.ndim - 1) + [(0, spec.padded_N - spec.N)]
    x = jnp.pad(q, pad)
    return x.reshape(*q.shape[:-1], spec.nh, spec.C)


def col_valid_mask(spec: GridSpec) -> jax.Array:
    """(nh, C) 1.0 where the column holds real data, 0.0 where padding."""
    idx = jnp.arange(spec.padded_N).reshape(spec.nh, spec.C)
    return (idx < spec.N).astype(jnp.float32)


def row_valid_mask(spec: GridSpec) -> jax.Array:
    """(nv, R) 1.0 where the row holds a real entry."""
    idx = jnp.arange(spec.padded_K).reshape(spec.nv, spec.R)
    return (idx < spec.K).astype(jnp.float32)


def global_row_index(spec: GridSpec) -> jax.Array:
    """(nv, R) global entry index of each subarray row."""
    return jnp.arange(spec.padded_K).reshape(spec.nv, spec.R)


# ---------------------------------------------------------------------------
# grouped row placement (query-compiler write planning)
# ---------------------------------------------------------------------------
def plan_group_offsets(group_sizes, R: int, align: bool = False):
    """Row offsets for placing consecutive row GROUPS (the query compiler's
    co-fired predicate sets — e.g. one tree of an ensemble) into one store.

    ``align=True`` rounds each group's start up to a subarray-row boundary
    (multiples of ``R``), so after ``partition_stored`` every group owns
    whole nv banks and co-fired predicates land in the same banks — no
    bank mixes rows of two groups (the gap rows are filler the compiler
    makes unmatchable).  ``align=False`` packs groups densely.

    Returns ``(offsets, total_rows)`` with ``offsets[i]`` the first row of
    group ``i``.
    """
    if R < 1:
        raise ValueError("R must be >= 1")
    offsets = []
    total = 0
    for s in group_sizes:
        if s < 1:
            raise ValueError("every group needs at least one row")
        if align and total % R:
            total += R - total % R
        offsets.append(total)
        total += int(s)
    return np.asarray(offsets, np.int64), total


# ---------------------------------------------------------------------------
# IVF-style clustered placement (search-cascade stage 1)
# ---------------------------------------------------------------------------
def cluster_permutation(values: jax.Array, nv: int, *, n_clusters: int = 0,
                        iters: int = 4, chunk: int = 65536) -> jax.Array:
    """Clustered row placement: k-means over the code rows, stable-sorted
    by cluster id, so similar entries land in contiguous row ranges — i.e.
    the same nv-bank after ``partition_stored``.  The bank prefilter can
    then prune whole banks without losing a query's near neighbours.

    values (K, D) code-domain rows (ACAM stores pass range midpoints).
    Deterministic (strided centroid init, fixed Lloyd iteration count) and
    jit-friendly; assignment is chunked over ``chunk``-row blocks so the
    (chunk, n_clusters) distance block — not (K, n_clusters) — bounds
    memory at millions of rows.

    Returns ``perm`` (K,) int32 with ``placed[i] = orig[perm[i]]``; the
    stable sort keeps original order within a cluster, so ``nv`` clusters
    of equal size reproduce identity placement on pre-sorted data.
    """
    K, D = values.shape
    nc = max(1, min(n_clusters or min(nv, 128), K))
    x = values.astype(jnp.float32)
    stride = max(1, K // nc)
    cent = x[::stride][:nc]
    nc = cent.shape[0]

    def assign(c):
        cn = jnp.sum(c * c, axis=-1)

        def one(block):
            # argmin ||b - c||^2 = argmin (||c||^2 - 2 b.c) — ||b||^2 is
            # constant per row and cannot change the argmin
            d = cn[None, :] - 2.0 * block @ c.T
            return jnp.argmin(d, axis=-1).astype(jnp.int32)

        if K <= chunk:
            return one(x)
        pad = (-K) % chunk
        xb = jnp.pad(x, ((0, pad), (0, 0))).reshape(-1, chunk, D)
        return jax.lax.map(one, xb).reshape(-1)[:K]

    a = assign(cent)
    for _ in range(iters):
        sums = jnp.zeros((nc, D), jnp.float32).at[a].add(x)
        counts = jnp.zeros((nc, 1), jnp.float32).at[a].add(1.0)
        cent = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), cent)
        a = assign(cent)
    return jnp.argsort(a, stable=True).astype(jnp.int32)


def placement_perm(values: jax.Array, spec: GridSpec) -> jax.Array:
    """(padded_K,) placement permutation: clustered on the real rows,
    identity on the padding rows (which stay at the end, so
    ``row_valid_mask`` is unchanged).  ``placed[i] = orig[perm[i]]``."""
    perm = cluster_permutation(values, spec.nv)
    return jnp.concatenate(
        [perm, jnp.arange(spec.K, spec.padded_K, dtype=jnp.int32)])
