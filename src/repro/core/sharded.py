"""Sharded CAM search: the bank level of the paper's hierarchy as a
physical device-mesh axis.

``ShardedCAMSimulator`` wraps ``FunctionalSimulator`` with a shard_map over
the stored grid's nv (vertical/bank) axis: each device holds an
``(nv_local, nh, R, C)`` shard of the grid and runs the fused batched
search kernel (one HBM pass per query batch) on its local banks, so
dataset capacity scales with the mesh instead of a single HBM.  Only the
*vertical* merge crosses devices — and it reproduces ``merge.merge``
bit-for-bit:

  * exact/threshold (gather v-merge): each device h-reduces its rows to a
    local 0/1 match-line block; ``all_gather`` along the bank axis
    concatenates the blocks into the global match lines (the lossless
    gather of paper Fig. 3).
  * best (comparator v-merge): each device takes a *stable* local top-k of
    its row scores (``merge.local_topk_candidates``), the (n_banks × k)
    candidate scores+global indices are gathered — bytes ~ n_banks·k, not
    the row count — and a stable re-rank picks the global winners
    (``merge.rerank_candidates``).  Stability makes the two-level
    comparator tree exact, ties included.  The voting tie-break normalizer
    is globalized with one ``lax.pmax`` of the per-device max distance.

  Horizontal (nh) reduction and the sense amplifier never cross devices:
  every device holds complete (R, C) subarrays, so ``sensing='best'``'s
  intra-subarray winner-take-all stays inside the local kernel.

C2C variation uses the per-bank RNG fold (``variation.apply_c2c_banked``):
bank v draws its cycle noise from ``fold_in(cycle_key, v)``, which is
invariant to how the nv axis is split — the single-device reference is
``FunctionalSimulator(..., c2c_fold='bank')``.

Grids whose nv does not divide the bank-axis size are padded with
all-invalid banks (row_valid 0): padded rows carry +inf distance / zero
match lines so they can never win, and the returned mask is sliced back to
the true padded_K.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import compat_shard_map, make_cam_mesh
from . import merge, prefilter, variation
from .config import CAMConfig
from .functional import (CAMState, FunctionalSimulator,
                         resolve_sim_overrides)
from .reliability import ReliabilityState
from .perf import ArchSpecifics, MeshLink, MeshSpec, perf_report
from .results import SearchResult


class ShardedCAMSimulator:
    """Multi-device store-once / search-many CAM simulation.

    Drop-in for ``FunctionalSimulator``: ``write`` places the grid across
    the mesh, ``query`` runs the shard_map search + cross-device merge.

    ``mesh``: a mesh with a ``bank_axis`` axis (see
    ``launch.mesh.make_cam_mesh``); when omitted it is derived from
    ``config.sim`` (``devices`` banks x ``query_shards``; 0 devices = all
    local).  ``query_axis``: optional mesh axis that additionally splits
    the query batch (Q must be a multiple of its size; with C2C noise, a
    multiple of ``query_shards * c2c_query_tile`` so cycle tiles align
    with shard boundaries); defaults to 'query' when
    ``config.sim.query_shards > 1``.  The ``use_kernel`` /
    ``c2c_query_tile`` kwargs are deprecated overrides for the
    ``config.sim`` fields of the same names.
    """

    def __init__(self, config: CAMConfig, mesh: Optional[Mesh] = None, *,
                 bank_axis: str = "bank", query_axis: Optional[str] = None,
                 use_kernel: Optional[bool] = None,
                 c2c_query_tile: Optional[int] = None):
        config = resolve_sim_overrides(config, use_kernel=use_kernel,
                                       c2c_query_tile=c2c_query_tile)
        # the inner reference simulator always draws C2C noise per bank
        # (the shard-invariant fold), whatever the config says
        self.sim = FunctionalSimulator(
            config.replace(sim=dict(c2c_fold="bank")))
        self.config = config
        if mesh is None:
            scfg = config.sim
            mesh = make_cam_mesh(scfg.devices or None, scfg.query_shards)
            if query_axis is None and scfg.query_shards > 1:
                query_axis = "query"
        self.mesh = mesh
        sizes = dict(zip(self.mesh.axis_names, self.mesh.axis_sizes))
        if bank_axis not in sizes:
            raise ValueError(f"mesh has no {bank_axis!r} axis: "
                             f"{self.mesh.axis_names}")
        self.bank_axis = bank_axis
        self.n_banks = sizes[bank_axis]
        if query_axis and query_axis not in sizes:
            raise ValueError(f"mesh has no {query_axis!r} axis: "
                             f"{self.mesh.axis_names}")
        self.query_axis = query_axis
        self.n_query = sizes[query_axis] if query_axis else 1

    # ------------------------------------------------------------- write
    def write(self, stored: jax.Array, key: Optional[jax.Array] = None
              ) -> CAMState:
        """Write simulation + mesh placement of the resulting state."""
        return self.shard_state(self.sim.write(stored, key))

    def shard_state(self, state: CAMState) -> CAMState:
        """Pad nv to a bank-axis multiple and place the state's pytree.

        The padding banks are all-invalid (row_valid 0), so searches treat
        them exactly like the in-bank padding rows the mapping submodule
        already produces for K % R != 0.
        """
        from repro.runtime.sharding import cam_state_shardings
        nv = state.grid.shape[0]
        pad = (-nv) % self.n_banks
        grid, row_valid, sigs = state.grid, state.row_valid, state.sigs
        codes, rel = state.codes, state.rel
        if pad:
            grid = jnp.pad(grid,
                           ((0, pad),) + ((0, 0),) * (grid.ndim - 1))
            row_valid = jnp.pad(row_valid, ((0, pad), (0, 0)))
            if sigs is not None:
                sigs = jnp.pad(sigs, ((0, pad), (0, 0), (0, 0)))
            if codes is not None:
                codes = jnp.pad(codes,
                                ((0, pad),) + ((0, 0),) * (codes.ndim - 1))
            if rel is not None:
                # padding banks: never programmed (age 0, no wear) and
                # row-invalid, like the in-bank padding rows
                rel = ReliabilityState(
                    age=rel.age,
                    prog_age=jnp.pad(rel.prog_age, ((0, pad), (0, 0))),
                    writes=jnp.pad(rel.writes, ((0, pad), (0, 0))),
                    retired=jnp.pad(rel.retired, ((0, pad), (0, 0))),
                    failed=jnp.pad(rel.failed, ((0, pad), (0, 0))))
        sh = cam_state_shardings(self.mesh, grid.ndim)
        if rel is not None:
            rel = ReliabilityState(
                age=jax.device_put(rel.age, sh["rel_age"]),
                prog_age=jax.device_put(rel.prog_age, sh["rel_rows"]),
                writes=jax.device_put(rel.writes, sh["rel_rows"]),
                retired=jax.device_put(rel.retired, sh["rel_rows"]),
                failed=jax.device_put(rel.failed, sh["rel_rows"]))
        return CAMState(
            grid=jax.device_put(grid, sh["grid"]),
            lo=jax.device_put(state.lo, sh["lo"]),
            hi=jax.device_put(state.hi, sh["hi"]),
            spec=state.spec,
            col_valid=jax.device_put(state.col_valid, sh["col_valid"]),
            row_valid=jax.device_put(row_valid, sh["row_valid"]),
            sigs=(jax.device_put(sigs, sh["sigs"])
                  if sigs is not None else None),
            sig_thr=(jax.device_put(state.sig_thr, sh["sig_thr"])
                     if state.sig_thr is not None else None),
            perm=(jax.device_put(state.perm, sh["perm"])
                  if state.perm is not None else None),
            codes=(jax.device_put(codes, sh["codes"])
                   if codes is not None else None),
            rel=rel)

    # --------------------------------------------------------- mutations
    # The mutation logic is shape-preserving and bank-local (scatter into
    # the touched rows' slots), so it is delegated to the inner reference
    # simulator on the placed arrays and the result is re-placed without a
    # re-shard (nv is already a bank multiple, so ``shard_state`` only
    # refreshes device placement).  Free slots never include the all-invalid
    # padding banks (``free_slots`` stops at ``spec.padded_K``).
    def insert(self, state: CAMState, rows: jax.Array,
               key: Optional[jax.Array] = None):
        new_state, ids = self.sim.insert(state, rows, key)
        return self.shard_state(new_state), ids

    def delete(self, state: CAMState, ids) -> CAMState:
        return self.shard_state(self.sim.delete(state, ids))

    def update(self, state: CAMState, ids, rows: jax.Array,
               key: Optional[jax.Array] = None) -> CAMState:
        return self.shard_state(self.sim.update(state, ids, rows, key))

    def compact(self, state: CAMState,
                key: Optional[jax.Array] = None) -> CAMState:
        return self.shard_state(self.sim.compact(state, key))

    # ------------------------------------------------------- reliability
    def free_slots(self, state: CAMState):
        return self.sim.free_slots(state)

    def age_tick(self, state: CAMState, steps: int = 1) -> CAMState:
        # only the replicated age scalar changes; the sharded row arrays
        # keep their placement, so no re-shard is needed
        return self.sim.age_tick(state, steps)

    def scrub(self, state: CAMState,
              key: Optional[jax.Array] = None) -> CAMState:
        return self.shard_state(self.sim.scrub(state, key))

    # ------------------------------------------------------------- perf
    def plan(self, entries: int, dims: int) -> ArchSpecifics:
        """Estimator-only planning: derive ``ArchSpecifics`` from shapes
        alone so ``eval_perf`` works before (or without) ``write``."""
        return self.sim.plan(entries, dims)

    def arch_specifics(self) -> ArchSpecifics:
        return self.sim.arch_specifics()

    def eval_perf(self, n_queries: int = 1, include_write: bool = False,
                  ops_per_query: int = 1,
                  clock_hz: Optional[float] = None,
                  link: Union[str, MeshLink] = "on_package",
                  queries_per_batch: int = 1,
                  mesh: Optional[Union[int, MeshSpec]] = None,
                  searched_fraction: Optional[float] = None,
                  prefilter_bits: Optional[int] = None):
        """Mesh-level hardware performance prediction for the written
        store: per-device hierarchy rollup + cross-device merge over
        chip-to-chip ``link``s, for the topology this simulator executes
        (its bank-axis size; pass ``mesh`` to predict a different one).

        ``queries_per_batch`` amortizes the merge collective over a query
        batch (the serving batch size); defaults to 1.  A 1-bank mesh
        reproduces ``CAMASim.eval_perf`` exactly."""
        if mesh is None:
            mesh = MeshSpec(self.n_banks, link)
        return perf_report(
            self.config, self.arch_specifics(),
            mesh=mesh, n_queries=n_queries,
            include_write=include_write, ops_per_query=ops_per_query,
            clock_hz=clock_hz, queries_per_batch=queries_per_batch,
            searched_fraction=searched_fraction,
            prefilter_bits=prefilter_bits)

    # --------------------------------------------------- shard-local pieces
    # Backend-protocol delegation: the same shard-local entry points the
    # functional simulator exposes, on the shared reference simulator.
    def segment_queries(self, state: CAMState, queries: jax.Array
                        ) -> jax.Array:
        return self.sim.segment_queries(state, queries)

    def search_shard(self, grid, qseg, **kw):
        return self.sim.search_shard(grid, qseg, **kw)

    # ------------------------------------------------------------- query
    def query(self, state: CAMState, queries: jax.Array,
              key: Optional[jax.Array] = None,
              valid_count: Optional[int] = None) -> SearchResult:
        """Query simulation across the mesh.

        queries: (Q, N) application-domain batch (or a single (N,) query).
        Returns a ``SearchResult`` (unpacks as ``(indices, mask)``),
        bit-identical to ``FunctionalSimulator(..., c2c_fold='bank')``.

        ``valid_count`` marks only the first ``valid_count`` rows as real
        queries (the serve loop's pad-exclusion knob — see
        ``FunctionalSimulator.query``); it only affects the cascade's
        shared bank routing.
        """
        if queries.ndim == 1:
            idx, mask = self.query(state, queries[None], key)
            return SearchResult(idx[0], mask[0])
        if self.n_banks == 1 and self.n_query == 1:
            # Degenerate 1-device mesh: the shard_map collectives are
            # identities that only add dispatch overhead (BENCH:
            # kernel_*_sharded_d1 losing at 0.97x/0.85x), and the inner
            # simulator IS the documented bit-identical reference
            # (c2c_fold='bank') — delegate outright.
            return self.sim.query(state, queries, key,
                                  valid_count=valid_count)
        Q = queries.shape[0]
        if self.n_query > 1:
            tile = (min(self.sim.c2c_query_tile, Q)
                    if self.config.device.variation in ("c2c", "both")
                    else 1)
            if Q % (self.n_query * tile):
                raise ValueError(
                    f"Q={Q} must be a multiple of query_shards*c2c_tile="
                    f"{self.n_query}*{tile} for query-axis sharding")
        idx, mask = self._query_jit(state, queries,
                                    key if key is not None
                                    else jax.random.PRNGKey(1),
                                    None if valid_count is None
                                    else jnp.asarray(valid_count, jnp.int32))
        return SearchResult(idx, mask)

    @partial(jax.jit, static_argnums=(0,))
    def _query_jit(self, state: CAMState, queries, key, valid_count=None):
        cfg = self.config
        # reliability read path: drift + fault overlay is elementwise in
        # global coordinates, so it applies to the placed grid before the
        # shard_map and partitions along with it (bit-identical to the
        # functional reference's overlay)
        state = self.sim._effective_state(state)
        qcodes = self.sim.query_codes(state, queries)        # (Q, N)
        qseg = self.sim.segment_queries(state, queries)      # (Q, nh, C)
        qsig = qvalid = None
        if cfg.sim.cascade_enabled() and state.sigs is not None:
            # stage-1 query signatures are cheap and replicated-friendly:
            # computed once outside the shard_map, sharded like the batch
            qsig = prefilter.query_signatures(
                qcodes, state.sig_thr, state.spec, cfg.sim.signature_bits)
            # the routing valid mask is materialized (all-true when no
            # count is given) so the shard_map arity stays fixed
            qvalid = (jnp.ones((queries.shape[0],), bool)
                      if valid_count is None
                      else jnp.arange(queries.shape[0]) < valid_count)
        idx, mask = self._sharded_search(state, qseg, qsig, key, qvalid)
        return self.sim._to_original(state, idx,
                                     mask[..., :state.spec.padded_K])

    # -------------------------------------------------------- shard_map
    def _sharded_search(self, state: CAMState, qseg, qsig, key,
                        qvalid=None):
        cfg = self.config
        ba, qa = self.bank_axis, self.query_axis
        nv_pad, R = state.grid.shape[0], state.grid.shape[2]
        assert nv_pad % self.n_banks == 0, \
            "state not placed with shard_state()"
        nv_loc = nv_pad // self.n_banks
        K_pad = nv_pad * R
        k = self.sim.match_k(state.spec.padded_K)
        Q = qseg.shape[0]
        use_c2c = cfg.device.variation in ("c2c", "both")
        tile = min(self.sim.c2c_query_tile, Q) if use_c2c else 1
        n_tiles = -(-Q // tile) if use_c2c else 0

        def cycle_keys_for(key):
            if not use_c2c:
                return None
            # the cycle keys are a function of the GLOBAL tile index:
            # split once for all tiles, slice this query shard's range
            gkeys = variation.split_for_queries(key, n_tiles)
            if self.n_query > 1:
                tiles_loc = n_tiles // self.n_query
                q_idx = jax.lax.axis_index(qa)
                return jax.lax.dynamic_slice_in_dim(
                    gkeys, q_idx * tiles_loc, tiles_loc)
            return gkeys

        q_spec = P(qa) if self.n_query > 1 else P()

        if qsig is not None:
            # per-device routing: each device prunes its OWN nv_loc banks
            # down to p_loc; the global budget splits evenly across the
            # bank axis, so top_p_banks >= nv gives p_loc = nv_loc (full
            # local scan) and the cascade degenerates to the exact path
            p_loc = min(nv_loc,
                        -(-min(cfg.sim.top_p_banks, state.spec.nv)
                          // self.n_banks))

            def body(grid, row_valid, sigs, col_valid, qseg_l, qsig_l,
                     qvalid_l, key):
                b_idx = jax.lax.axis_index(ba)
                scores = prefilter.bank_scores(
                    sigs, qsig_l, row_valid, use_kernel=self.sim.use_kernel)
                local_ids = prefilter.select_banks(scores, p_loc, qvalid_l)
                sub_grid = jnp.take(grid, local_ids, axis=0)
                sub_rv = jnp.take(row_valid, local_ids, axis=0)
                # C2C noise folds by GLOBAL bank id of each selected bank
                dist, match = self.sim.search_shard(
                    sub_grid, qseg_l, col_valid=col_valid, row_valid=sub_rv,
                    key=key, cycle_keys=cycle_keys_for(key),
                    bank_ids=b_idx * nv_loc + local_ids)
                return self._combine_selected(dist, match, local_ids,
                                              b_idx, nv_loc, R, K_pad, k)

            return compat_shard_map(
                body, mesh=self.mesh,
                in_specs=(P(ba), P(ba), P(ba), P(), q_spec, q_spec, q_spec,
                          P()),
                out_specs=(q_spec, q_spec))(
                state.grid, state.row_valid, state.sigs, state.col_valid,
                qseg, qsig, qvalid, key)

        def body(grid, row_valid, col_valid, qseg_l, key):
            b_idx = jax.lax.axis_index(ba)
            dist, match = self.sim.search_shard(
                grid, qseg_l, col_valid=col_valid, row_valid=row_valid,
                key=key, v_offset=b_idx * nv_loc,
                cycle_keys=cycle_keys_for(key))
            return self._combine(dist, match, b_idx, nv_loc, R, K_pad, k)

        return compat_shard_map(
            body, mesh=self.mesh,
            in_specs=(P(ba), P(ba), P(), q_spec, P()),
            out_specs=(q_spec, q_spec))(
            state.grid, state.row_valid, state.col_valid, qseg, key)

    def _combine(self, dist, match, b_idx, nv_loc: int, R: int,
                 K_pad: int, k: int):
        """Cross-device vertical merge of shard-local subarray outputs.

        Mirrors ``merge.merge`` (same h-reduce, same stable comparator
        ordering) with the nv reduction distributed over the bank axis.
        """
        cfg = self.config
        ba = self.bank_axis
        thr = (float(cfg.app.match_param)
               if cfg.app.match_type == "threshold" else 0.0)

        if cfg.app.match_type in ("exact", "threshold"):
            if cfg.arch.v_merge != "gather":
                raise ValueError(
                    f"{cfg.app.match_type} match uses gather v-merge")
            row = merge.h_reduce_match(
                dist, match, match_type=cfg.app.match_type,
                h_merge=cfg.arch.h_merge,
                sensing_limit=cfg.circuit.sensing_limit, threshold=thr)
            # lossless gather: concatenate the per-bank match-line blocks
            rows = jax.lax.all_gather(row, ba, axis=1, tiled=True)
            mask = merge.v_merge_gather(rows)               # (Q, K_pad)
            return merge.first_k_indices(mask, k), mask

        if cfg.app.match_type != "best":
            raise ValueError(f"unknown match_type {cfg.app.match_type!r}")
        if cfg.arch.v_merge != "comparator":
            raise ValueError("best match requires comparator v-merge")
        dmax = None
        if cfg.arch.h_merge == "voting":
            # tie-break normalizer over ALL banks: one scalar-ish pmax
            dmax = jax.lax.pmax(merge.voting_dmax(dist), ba)
        values, largest = merge.h_reduce_best(
            dist, match, h_merge=cfg.arch.h_merge, dmax=dmax)
        vals, gidx = merge.local_topk_candidates(
            values, k, largest=largest, row_offset=b_idx * nv_loc * R)
        return self._comparator_tail(vals, gidx, k, K_pad, largest)

    def _comparator_tail(self, vals, gidx, k: int, K_pad: int,
                         largest: bool):
        """Cross-device comparator tree: gather only the candidate scores
        + global indices, stable re-rank, finalize."""
        ba = self.bank_axis
        av = jax.lax.all_gather(vals, ba)            # (n_banks, Q, k_l)
        ai = jax.lax.all_gather(gidx, ba)
        av = jnp.moveaxis(av, 0, -2).reshape(*vals.shape[:-1], -1)
        ai = jnp.moveaxis(ai, 0, -2).reshape(*gidx.shape[:-1], -1)
        best_v, best_i = merge.rerank_candidates(av, ai, k, largest=largest)
        return merge.finalize_topk(best_v, best_i, largest=largest,
                                   K=K_pad)

    def _combine_selected(self, dist, match, local_ids, b_idx, nv_loc: int,
                          R: int, K_pad: int, k: int):
        """``_combine`` for this device's routed (p_loc, nh, R) bank
        subset: scatter/offset results back into the device's full
        (nv_loc, R) coordinate frame, then the SAME cross-device merge as
        the full scan (the collective payload shapes are unchanged, so
        ``merge.shard_merge_payload`` still models them).  With
        ``p_loc = nv_loc`` and sorted ids this is bit-identical to
        ``_combine``.
        """
        cfg = self.config
        ba = self.bank_axis
        thr = (float(cfg.app.match_param)
               if cfg.app.match_type == "threshold" else 0.0)

        if cfg.app.match_type in ("exact", "threshold"):
            if cfg.arch.v_merge != "gather":
                raise ValueError(
                    f"{cfg.app.match_type} match uses gather v-merge")
            row = merge.h_reduce_match(
                dist, match, match_type=cfg.app.match_type,
                h_merge=cfg.arch.h_merge,
                sensing_limit=cfg.circuit.sensing_limit, threshold=thr)
            # unselected local banks read as unmatched in the gathered rows
            full = merge.scatter_match_rows(row, local_ids, nv_loc)
            rows = full.reshape(*full.shape[:-1], nv_loc, R)
            rows = jax.lax.all_gather(rows, ba, axis=1, tiled=True)
            mask = merge.v_merge_gather(rows)               # (Q, K_pad)
            return merge.first_k_indices(mask, k), mask

        if cfg.app.match_type != "best":
            raise ValueError(f"unknown match_type {cfg.app.match_type!r}")
        if cfg.arch.v_merge != "comparator":
            raise ValueError("best match requires comparator v-merge")
        dmax = None
        if cfg.arch.h_merge == "voting":
            dmax = jax.lax.pmax(merge.voting_dmax(dist), ba)
        values, largest = merge.h_reduce_best(
            dist, match, h_merge=cfg.arch.h_merge, dmax=dmax)
        vals, gidx = merge.selected_topk(
            values, k, largest=largest, bank_ids=local_ids,
            bank_offset=b_idx * nv_loc)
        return self._comparator_tail(vals, gidx, k, K_pad, largest)
