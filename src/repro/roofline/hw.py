"""TPU v5e hardware constants (the TARGET; this container only lowers)."""

PEAK_FLOPS_BF16 = 197e12      # per chip, FLOP/s
HBM_BW = 819e9                # per chip, B/s
ICI_BW = 50e9                 # per link, B/s (~45-50 GB/s on v5e)
HBM_BYTES = 16 * 2 ** 30      # 16 GiB per chip
