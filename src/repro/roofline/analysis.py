"""Roofline derivation from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = wire_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` (the post-SPMD
per-device program).  Collective bytes are NOT in cost_analysis: we parse
the HLO text and sum, per op kind, the *wire* bytes implied by the result
shapes and replica group sizes (ring algorithms assumed):

    all-reduce         2 * B * (n-1)/n      (reduce-scatter + all-gather)
    all-gather         B * (n-1)/n          (B = gathered result bytes)
    reduce-scatter     B_out * (n-1)        (B_out = scattered shard)
    all-to-all         B * (n-1)/n
    collective-permute B
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from . import hw

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota format [groups, group_size]
        return max(1, int(m.group(2)))
    m = _GROUPS_RE.search(line)
    if m:
        members = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(members))
    return default


@dataclass
class CollectiveStats:
    # per-op-kind: (count, result_bytes, wire_bytes) — per device, per step
    ops: Dict[str, Dict[str, float]] = field(default_factory=dict)

    @property
    def wire_bytes(self) -> float:
        return sum(v["wire_bytes"] for v in self.ops.values())

    @property
    def result_bytes(self) -> float:
        return sum(v["result_bytes"] for v in self.ops.values())


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for op in _COLLECTIVES:
            # match '<op>(' or '<op>-start(' as the op of this instruction
            marker = f" {op}("
            marker2 = f" {op}-start("
            if marker not in stripped and marker2 not in stripped:
                continue
            if "=" not in stripped:
                continue
            result_part = stripped.split("=", 1)[1]
            for mk in (marker, marker2):
                if mk in result_part:
                    result_part = result_part.split(mk, 1)[0]
                    break
            B = _shape_bytes(result_part)
            if B == 0:
                continue
            n = _group_size(stripped, n_devices)
            frac = (n - 1) / max(1, n)
            if op == "all-reduce":
                wire = 2.0 * B * frac
            elif op == "all-gather":
                wire = B * frac
            elif op == "reduce-scatter":
                wire = B * (n - 1)
            elif op == "all-to-all":
                wire = B * frac
            else:  # collective-permute
                wire = float(B)
            e = stats.ops.setdefault(
                op, {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0})
            e["count"] += 1
            e["result_bytes"] += B
            e["wire_bytes"] += wire
            break
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    model_flops_global: float
    collectives: Dict[str, Dict[str, float]] = field(default_factory=dict)
    memory_per_device: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_device / hw.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (catches remat/redundancy waste)."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / max(1.0, hlo_global)

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs throughput at the bound, as a fraction of peak
        (an MFU upper bound implied by the dominant roofline term)."""
        t = self.t_bound
        if t <= 0:
            return 0.0
        return (self.model_flops_global
                / (t * self.chips * hw.PEAK_FLOPS_BF16))

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "collectives": self.collectives,
            "memory_per_device": self.memory_per_device,
        }


def model_flops(cfg, kind: str, tokens: int) -> float:
    """6·N·D (train) / 2·N·D (inference) with N = active params."""
    n = cfg.active_params()
    mult = 6.0 if kind == "train" else 2.0
    return mult * n * tokens
