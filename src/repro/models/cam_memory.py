"""MANN-style CAM episodic memory (the paper's validation application [8]).

A key-value memory whose lookup is a CAM best-match search with the full
functional-simulator pipeline (quantization, D2D/C2C variation, partition +
merge, sensing limit).  Used by the few-shot example and the Fig. 4/5
case-study benchmarks; also exposable as an auxiliary LM layer.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import CAMASim, CAMConfig
from repro.core.functional import CAMState


@dataclass
class CAMMemory:
    """Store (key, label) pairs; classify queries by best-match vote."""
    config: CAMConfig
    use_kernel: Optional[bool] = None   # deprecated: set config.sim.use_kernel

    def __post_init__(self):
        if self.use_kernel is not None:
            self.config = self.config.replace(
                sim=dict(use_kernel=self.use_kernel))
        self.sim = CAMASim(self.config)
        self.state: Optional[CAMState] = None
        self.labels: Optional[jax.Array] = None

    # ------------------------------------------------------------------
    def write(self, keys: jax.Array, labels: jax.Array,
              rng: Optional[jax.Array] = None) -> None:
        """keys (K, N) float; labels (K,) int."""
        self.state = self.sim.write(keys, rng)
        self.labels = labels

    def query(self, queries: jax.Array,
              rng: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, jax.Array]:
        """queries (Q, N) -> (predicted labels (Q,), match idx (Q, k)).

        k-NN vote over the CAM's match_param nearest entries (ties ->
        nearest match wins, mirroring a comparator-tree implementation).
        """
        assert self.state is not None, "write() before query()"
        idx, _ = self.sim.query(self.state, queries, rng)
        safe = jnp.maximum(idx, 0)
        got = jnp.take(self.labels, safe, axis=0)         # (Q, k)
        valid = idx >= 0
        n_cls = int(jnp.max(self.labels)) + 1
        votes = jax.nn.one_hot(got, n_cls) * valid[..., None]
        # nearest-match tiebreak: add epsilon weight decaying with rank
        k = idx.shape[-1]
        w = 1.0 + 1e-3 * (k - jnp.arange(k, dtype=jnp.float32))
        votes = (votes * w[None, :, None]).sum(axis=1)
        return jnp.argmax(votes, axis=-1), idx

    def perf(self, n_queries: int = 1) -> dict:
        return self.sim.eval_perf(n_queries=n_queries)


def accuracy(memory: CAMMemory, queries: jax.Array, labels: jax.Array,
             rng: Optional[jax.Array] = None) -> float:
    pred, _ = memory.query(queries, rng)
    return float(jnp.mean((pred == labels).astype(jnp.float32)))
