"""Mamba2 block — SSD (state-space duality) chunked algorithm.

Training uses the chunked SSD form (arXiv:2405.21060 §6): quadratic
attention-like compute inside fixed-size chunks + a linear recurrence over
chunk states (lax.scan), so compute is O(S·Q) instead of O(S^2) and the
recurrent state (H, P, N) is what decode carries — no KV cache at all,
which is why the paper's CAM-retrieval technique is inapplicable here
(DESIGN.md §Arch-applicability).

Decode is the exact recurrence: h <- exp(dt*A) h + dt * B x^T, y = C·h + Dx.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.sharding import shard

from .layers import P, rms_norm_spec


def mamba2_spec(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di = cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * G * N
    return {
        "in_proj": P((d, 2 * di + 2 * G * N + H), ("embed", "ssm_inner")),
        "conv_w": P((cfg.ssm_conv, conv_dim), ("conv", "ssm_inner")),
        "conv_b": P((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": P((H,), ("ssm_heads",), init="small", scale=10.0,
                   dtype=jnp.float32),
        "D": P((H,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": P((H,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "norm": rms_norm_spec(di),
        "out_proj": P((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    x = zxbcdt[..., di:2 * di]
    Bm = zxbcdt[..., 2 * di:2 * di + G * N]
    Cm = zxbcdt[..., 2 * di + G * N:2 * di + 2 * G * N]
    dt = zxbcdt[..., 2 * di + 2 * G * N:]
    return z, x, Bm, Cm, dt


def _gated_norm(params, y: jax.Array, z: jax.Array, eps: float) -> jax.Array:
    """Mamba2 gated RMSNorm: rmsnorm(y * silu(z))."""
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    dt = y.dtype
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * params["norm"]["scale"]).astype(dt)


def _causal_conv_train(x: jax.Array, w: jax.Array, b: jax.Array
                       ) -> jax.Array:
    """Depthwise causal conv: x (B,S,Cd), w (K,Cd)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(K))
    return out + b


def mamba2_train(params, cfg: ModelConfig, x_in: jax.Array,
                 return_state: bool = False):
    """x_in (B,S,d) -> (B,S,d) via chunked SSD.

    ``return_state``: also return the decode cache ({'conv', 'ssm'}) left
    after processing the sequence (prefill path)."""
    Bz, S, _ = x_in.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_headdim
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    zxbcdt = jnp.einsum("bsd,de->bse", x_in, params["in_proj"])
    z, xc, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv_train(
        conv_in, params["conv_w"], params["conv_b"]).astype(jnp.float32)
    ).astype(x_in.dtype)
    xc = conv_out[..., :di]
    Bm = conv_out[..., di:di + G * N]
    Cm = conv_out[..., di + G * N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])                        # (H,), negative
    dA = dt * A                                          # (B,S,H)

    xh = xc.reshape(Bz, nc, Q, H, Pd)
    Bh = Bm.reshape(Bz, nc, Q, G, N)
    Ch = Cm.reshape(Bz, nc, Q, G, N)
    hpg = H // G                                          # heads per group
    dAc = dA.reshape(Bz, nc, Q, H)
    dtc = dt.reshape(Bz, nc, Q, H)
    cs = jnp.cumsum(dAc, axis=2)                          # within-chunk cumsum
    xdt = xh * dtc[..., None]                             # dt-weighted input
    xg = xdt.reshape(Bz, nc, Q, G, hpg, Pd)

    # ---- intra-chunk (quadratic within Q) ------------------------------
    scores = jnp.einsum("bcqgn,bckgn->bcgqk", Ch, Bh,
                        preferred_element_type=jnp.float32)
    csg = cs.reshape(Bz, nc, Q, G, hpg)
    decay = (csg[:, :, :, None] - csg[:, :, None, :, :]
             ).transpose(0, 1, 4, 2, 3, 5)                # (b,c,g,q,k,h)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, None, :, :, None],
                  jnp.exp(jnp.clip(decay, -60.0, 0.0)), 0.0)
    W = scores[..., None] * L                             # (b,c,g,q,k,h)
    y_diag = jnp.einsum("bcgqkh,bckghp->bcqghp", W.astype(xg.dtype),
                        xg.transpose(0, 1, 2, 3, 4, 5),
                        preferred_element_type=jnp.float32)

    # ---- chunk states + inter-chunk recurrence -------------------------
    cs_last = cs[:, :, -1:]                               # (b,c,1,H)
    decay_to_end = jnp.exp(jnp.clip(cs_last - cs, -60.0, 0.0))  # (b,c,Q,H)
    xe = (xdt * decay_to_end[..., None]).reshape(Bz, nc, Q, G, hpg, Pd)
    states = jnp.einsum("bcqgn,bcqghp->bcghpn", Bh.astype(jnp.float32),
                        xe.astype(jnp.float32))           # (b,c,G,hpg,P,N)
    chunk_decay = jnp.exp(jnp.clip(cs_last[:, :, 0], -60.0, 0.0)
                          ).reshape(Bz, nc, G, hpg)       # (b,c,G,hpg)

    def step(h, inp):
        st, dec = inp                                     # (b,G,hpg,P,N)
        h_new = h * dec[..., None, None] + st
        return h_new, h                                   # emit state BEFORE

    h0 = jnp.zeros((Bz, G, hpg, Pd, N), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        step, h0, (states.transpose(1, 0, 2, 3, 4, 5),
                   chunk_decay.transpose(1, 0, 2, 3)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4, 5)         # (b,c,G,hpg,P,N)

    in_decay = jnp.exp(jnp.clip(cs, -60.0, 0.0)
                       ).reshape(Bz, nc, Q, G, hpg)
    y_off = jnp.einsum("bcqgn,bcghpn,bcqgh->bcqghp",
                       Ch.astype(jnp.float32), h_prevs, in_decay)

    y = (y_diag + y_off).reshape(Bz, nc, Q, H, Pd)
    y = y + params["D"][None, None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bz, S, di).astype(x_in.dtype)
    y = shard(y, "batch", "seq", "ssm_inner")
    y = _gated_norm(params, y, z, cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    if return_state:
        cdt = jnp.bfloat16 if cfg.cache_dtype == "bfloat16" else jnp.float32
        K = cfg.ssm_conv
        tail = conv_in[:, S - (K - 1):, :].astype(cdt)    # (B, K-1, conv_dim)
        state = {"conv": tail, "ssm": h_final.reshape(Bz, H, Pd, N)}
        return out, state
    return out


# ---------------------------------------------------------------------------
# Decode (exact recurrence; carries conv + ssm state — no KV cache)
# ---------------------------------------------------------------------------
def mamba2_init_cache(cfg: ModelConfig, batch: int) -> Dict:
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * G * N
    cdt = jnp.bfloat16 if cfg.cache_dtype == "bfloat16" else jnp.float32
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), cdt),
        "ssm": jnp.zeros((batch, H, cfg.ssm_headdim, N), jnp.float32),
    }


def mamba2_decode(params, cfg: ModelConfig, x_in: jax.Array,
                  cache: Dict) -> Tuple[jax.Array, Dict]:
    """x_in (B,d) one token -> (B,d), updated cache."""
    Bz, _ = x_in.shape
    di, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    Pd = cfg.ssm_headdim

    zxbcdt = jnp.einsum("bd,de->be", x_in, params["in_proj"])
    z, xc, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)      # (B, conv_dim)
    window = jnp.concatenate(
        [cache["conv"], conv_in[:, None].astype(cache["conv"].dtype)],
        axis=1)                                            # (B, K, conv_dim)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)
                           ).astype(x_in.dtype)
    xc = conv_out[..., :di]
    Bm = conv_out[..., di:di + G * N]
    Cm = conv_out[..., di + G * N:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                  # (B,H)

    xh = xc.reshape(Bz, H, Pd).astype(jnp.float32)
    Bh = Bm.reshape(Bz, G, N).astype(jnp.float32)
    Ch = Cm.reshape(Bz, G, N).astype(jnp.float32)
    hpg = H // G
    Bx = jnp.einsum("bgn,bghp->bghpn", Bh,
                    (xh * dt[..., None]).reshape(Bz, G, hpg, Pd))
    h = (cache["ssm"].reshape(Bz, G, hpg, Pd, N)
         * dA.reshape(Bz, G, hpg)[..., None, None] + Bx)
    y = jnp.einsum("bgn,bghpn->bghp", Ch, h).reshape(Bz, H, Pd)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(Bz, di).astype(x_in.dtype)
    y = _gated_norm(params, y, z, cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"])
    new_cache = {
        "conv": window[:, 1:],
        "ssm": h.reshape(Bz, H, Pd, N),
    }
    return out, new_cache
