"""Mixture-of-Experts block (deepseek-moe / moonshot style: shared experts +
fine-grained routed experts, top-k).

Grouped GEMMs use ``lax.ragged_dot`` after an argsort dispatch (dropless).
Two distribution modes, both implemented with ``jax.shard_map``:

  * 'tp' (baseline): experts replicated, every expert's hidden dim sharded
    over the model axis — no load imbalance, no token dropping, combine is
    the same psum as a dense TP MLP.
  * 'ep' (§Perf optimization): experts sharded over the model axis; each
    shard compacts the assignments that target its local experts into a
    capacity buffer (capacity factor 1.25, overflow dropped) — compute per
    shard falls by ~n_shards vs 'tp' at small-expert widths where 'tp'
    under-utilizes the MXU.

Routing is either softmax-logits top-k or — the paper's technique — a CAM
best-match search over expert prototype keys (``cam_router``), with MCAM
quantization + D2D variation non-idealities from the functional simulator.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quantize import linear_quantize
from repro.launch.mesh import compat_shard_map
from repro.runtime import sharding as sh

from .layers import P, mlp, mlp_spec


def moe_spec(cfg: ModelConfig) -> Dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    fs = cfg.n_shared_experts * f
    return {
        "router": P((d, E), ("embed", "experts"), dtype=jnp.float32),
        "wi_gate": P((E, d, f), ("experts", "embed", "moe_mlp")),
        "wi_up": P((E, d, f), ("experts", "embed", "moe_mlp")),
        "wo": P((E, f, d), ("experts", "moe_mlp", "embed")),
        "shared": mlp_spec(d, fs),
    }


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------
def route(params, cfg: ModelConfig, x: jax.Array
          ) -> Tuple[jax.Array, jax.Array]:
    """x (T, d) -> (expert_idx (T, k), weights (T, k))."""
    k = cfg.moe_top_k
    if cfg.cam_router:
        # CAM best-match routing: expert prototype keys are the router
        # columns; the search is a quantized dot-distance best match.
        keys = params["router"].T                       # (E, d)
        qx = x.astype(jnp.float32)
        if cfg.cam_router_bits > 0:
            lo = jnp.minimum(jnp.min(keys), jnp.min(qx))
            hi = jnp.maximum(jnp.max(keys), jnp.max(qx))
            qx, _, _ = linear_quantize(qx, cfg.cam_router_bits, lo, hi)
            keys, _, _ = linear_quantize(keys.astype(jnp.float32),
                                         cfg.cam_router_bits, lo, hi)
        scores = qx @ keys.T                            # (T, E), -distance
        scores = scores / jnp.maximum(
            jnp.linalg.norm(keys, axis=-1)[None, :], 1e-6)
    else:
        scores = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(scores, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)
    weights = topv / jnp.maximum(jnp.sum(topv, axis=-1, keepdims=True),
                                 1e-9)
    return topi, weights.astype(x.dtype)


# ---------------------------------------------------------------------------
# Local grouped-GEMM expert compute (shared by both modes)
# ---------------------------------------------------------------------------
def _expert_gemm(xs: jax.Array, gs: jax.Array, wg, wu, wo,
                 balanced: bool = False) -> jax.Array:
    if balanced:
        return _expert_gemm_balanced(xs, wg, wu, wo)
    g = jax.lax.ragged_dot(xs, wg, gs)
    u = jax.lax.ragged_dot(xs, wu, gs)
    h = (jax.nn.silu(g.astype(jnp.float32)) *
         u.astype(jnp.float32)).astype(xs.dtype)
    return jax.lax.ragged_dot(h, wo, gs)


def _expert_gemm_balanced(xs: jax.Array, wg, wu, wo) -> jax.Array:
    """Balanced grouped GEMM (batched einsum), PROBE-ONLY compute model.

    XLA's cost model counts ragged_dot as a dense (m, k) x (g, k, n) — a gx
    FLOP overcount vs the real grouped GEMM a TPU executes.  For dry-run
    cost probes we assume balanced expert loads (what the EP capacity
    buffer enforces in expectation) and compute each expert on an equal
    m/g slice via a batched einsum, which the cost model counts correctly.
    NOT routing-exact for unbalanced loads — never used in training runs
    (cfg.moe_probe_balanced gates it).
    """
    m, d = xs.shape
    g = wg.shape[0]
    cap = max(1, -(-m // g))          # ceil: every row gets a slot
    used = cap * g
    xp = jnp.pad(xs, ((0, used - m), (0, 0))) if used > m else xs[:used]
    xe = xp.reshape(g, cap, d)
    gg = jnp.einsum("ecd,edf->ecf", xe, wg)
    uu = jnp.einsum("ecd,edf->ecf", xe, wu)
    h = (jax.nn.silu(gg.astype(jnp.float32)) *
         uu.astype(jnp.float32)).astype(xs.dtype)
    y = jnp.einsum("ecf,efd->ecd", h, wo).reshape(used, d)
    return y[:m]


def _moe_dispatch_compute(x, topi, weights, wg, wu, wo, n_experts: int,
                          balanced: bool = False):
    """Dropless local MoE: sort assignments by expert, grouped GEMM,
    weighted scatter-add back. x (T,d) -> (T,d)."""
    T, d = x.shape
    k = topi.shape[-1]
    flat_e = topi.reshape(-1)                       # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)           # token of each assignment
    flat_w = weights.reshape(-1)
    order = jnp.argsort(flat_e)                     # stable
    xs = jnp.take(x, flat_t[order], axis=0)         # (T*k, d)
    gs = jnp.bincount(flat_e, length=n_experts)     # group sizes
    ys = _expert_gemm(xs, gs, wg, wu, wo, balanced)  # (T*k, d)
    inv = jnp.argsort(order)
    y = jnp.take(ys, inv, axis=0) * flat_w[:, None]
    return jax.ops.segment_sum(y, flat_t, num_segments=T).astype(x.dtype)


def _moe_ep_compute(x, topi, weights, wg, wu, wo, *, n_experts: int,
                    n_shards: int, shard_idx, capacity: int,
                    balanced: bool = False):
    """Expert-parallel local compute: keep only assignments targeting this
    shard's experts, compact into a capacity buffer, grouped GEMM."""
    T, d = x.shape
    k = topi.shape[-1]
    e_local = n_experts // n_shards
    flat_e = topi.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = weights.reshape(-1)
    mine = (flat_e // e_local) == shard_idx
    # compact: sort not-mine last (stable), take first `capacity`
    order = jnp.argsort(jnp.where(mine, 0, 1), stable=True)
    sel = order[:capacity]
    valid = jnp.take(mine, sel)
    sel_e = jnp.where(valid, jnp.take(flat_e, sel) - shard_idx * e_local, 0)
    sel_t = jnp.take(flat_t, sel)
    sel_w = jnp.where(valid, jnp.take(flat_w, sel), 0.0)
    # sort the buffer by local expert for the grouped GEMM
    order2 = jnp.argsort(jnp.where(valid, sel_e, e_local), stable=True)
    sel_e = jnp.take(sel_e, order2)
    sel_t = jnp.take(sel_t, order2)
    sel_w = jnp.take(sel_w, order2)
    valid = jnp.take(valid, order2)
    xs = jnp.take(x, sel_t, axis=0)
    gs = jnp.bincount(jnp.where(valid, sel_e, e_local),
                      length=e_local + 1)[:e_local]
    ys = _expert_gemm(xs, gs, wg, wu, wo, balanced) * sel_w[:, None]
    return jax.ops.segment_sum(ys, sel_t, num_segments=T).astype(x.dtype)


def _moe_a2a_body(cfg: ModelConfig, n_model: int, capacity: int):
    """Expert-parallel all-to-all MoE (the production pattern; §Perf).

    Tokens are sharded over (data x model); experts over model.  Each shard
    routes locally, packs per-destination capacity buffers, exchanges them
    with one all-to-all, runs its experts' grouped GEMM on what it
    received, and all-to-alls the results back — wire bytes per device are
    O(T_local * topk * d), not O(T * d) all-reduces like 'tp' mode.
    """
    E, k, d = cfg.n_experts, cfg.moe_top_k, cfg.d_model
    e_local = E // n_model

    def body(xl, router, wg, wu, wo, sg, su, so):
        T_l = xl.shape[0]
        topi, w = route({"router": router}, cfg, xl)     # (T_l, k)
        flat_e = topi.reshape(-1)                        # (T_l*k,)
        flat_t = jnp.repeat(jnp.arange(T_l), k)
        flat_w = w.reshape(-1)
        dest = flat_e // e_local                         # target shard

        # ---- pack per-destination capacity buffers ----------------------
        order = jnp.argsort(dest, stable=True)
        dsort = jnp.take(dest, order)
        rank = jnp.arange(T_l * k) - jnp.searchsorted(dsort, dsort,
                                                      side="left")
        ok = rank < capacity
        slot = dsort * capacity + rank                   # (T_l*k,)
        nbuf = n_model * capacity
        safe_slot = jnp.where(ok, slot, nbuf)            # drop -> scratch
        xs = jnp.take(xl, jnp.take(flat_t, order), axis=0)
        send_x = jnp.zeros((nbuf + 1, d), xl.dtype
                           ).at[safe_slot].set(xs)[:nbuf]
        meta_e = jnp.full((nbuf + 1,), e_local, jnp.int32
                          ).at[safe_slot].set(
            jnp.take(flat_e, order) % e_local)[:nbuf]
        # remember where each buffered assignment came from
        src_slot = jnp.full((nbuf + 1,), T_l * k, jnp.int32
                            ).at[safe_slot].set(order)[:nbuf]

        # ---- exchange ----------------------------------------------------
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_model, capacity, d), "model", 0, 0,
            tiled=False).reshape(nbuf, d)
        recv_e = jax.lax.all_to_all(
            meta_e.reshape(n_model, capacity), "model", 0, 0,
            tiled=False).reshape(nbuf)

        # ---- local experts' grouped GEMM ---------------------------------
        order2 = jnp.argsort(recv_e, stable=True)
        xs2 = jnp.take(recv_x, order2, axis=0)
        gs = jnp.bincount(recv_e, length=e_local + 1)[:e_local]
        ys2 = _expert_gemm(xs2, gs, wg, wu, wo,
                           cfg.moe_probe_balanced)
        ys = jnp.zeros_like(recv_x).at[order2].set(
            ys2.astype(recv_x.dtype))

        # ---- return + combine --------------------------------------------
        back = jax.lax.all_to_all(
            ys.reshape(n_model, capacity, d), "model", 0, 0,
            tiled=False).reshape(nbuf, d)
        y_assign = jnp.zeros((T_l * k + 1, d), xl.dtype
                             ).at[src_slot].set(back)[:T_l * k]
        y = y_assign * flat_w[:, None]
        out = jax.ops.segment_sum(y, flat_t, num_segments=T_l)

        # shared experts: tokens differ across model shards here, so the
        # shared weights are REPLICATED and applied fully locally (a psum
        # would sum different tokens)
        shared = mlp({"wi_gate": sg, "wi_up": su, "wo": so}, xl)
        return out.astype(xl.dtype) + shared.astype(xl.dtype)

    return body


# ---------------------------------------------------------------------------
# Public block
# ---------------------------------------------------------------------------
def moe_block(params, cfg: ModelConfig, x: jax.Array,
              mode: str = "tp") -> jax.Array:
    """x (B, S, d) or (B, d) -> same shape."""
    shape = x.shape
    d = shape[-1]
    xf = x.reshape(-1, d)
    ctx = sh._ctx.get()
    if ctx is None or "model" not in ctx.mesh.axis_names:
        topi, w = route(params, cfg, xf)
        y = _moe_dispatch_compute(xf, topi, w, params["wi_gate"],
                                  params["wi_up"], params["wo"],
                                  cfg.n_experts, cfg.moe_probe_balanced)
        y = y + mlp(params["shared"], xf)
        return y.reshape(shape)

    mesh = ctx.mesh
    dp = tuple(a for a in mesh.axis_names if a != "model")
    n_model = mesh.devices.shape[mesh.axis_names.index("model")]
    Psp = jax.sharding.PartitionSpec
    # batch=1 decode can't shard tokens over data: replicate instead
    dp_size = _prod_axis(mesh, dp)
    dp_ok = xf.shape[0] % dp_size == 0 and xf.shape[0] >= dp_size
    x_spec = Psp(dp) if dp_ok else Psp()

    if mode == "a2a" and cfg.n_experts % n_model == 0 \
            and xf.shape[0] % (dp_size * n_model) == 0:
        T_l = xf.shape[0] // (dp_size * n_model)
        capacity = max(1, int(cfg.moe_capacity_factor * T_l
                              * cfg.moe_top_k / n_model) + 1)
        body = _moe_a2a_body(cfg, n_model, capacity)
        Pall = Psp(dp + ("model",))
        yf = compat_shard_map(
            body, mesh=mesh,
            in_specs=(Pall, Psp(), Psp("model"), Psp("model"),
                      Psp("model"), Psp(), Psp(), Psp()),
            out_specs=Pall)(
            xf, params["router"], params["wi_gate"], params["wi_up"],
            params["wo"], params["shared"]["wi_gate"],
            params["shared"]["wi_up"], params["shared"]["wo"])
        return yf.reshape(shape)

    if mode == "ep" and cfg.n_experts % n_model == 0:
        T_local = xf.shape[0] // dp_size if dp_ok else xf.shape[0]
        capacity = max(cfg.moe_top_k, int(
            cfg.moe_capacity_factor * T_local * cfg.moe_top_k
            / n_model + 1))

        def body(xl, router, wg, wu, wo, sg, su, so):
            topi, w = route({"router": router}, cfg, xl)
            sidx = jax.lax.axis_index("model")
            y = _moe_ep_compute(xl, topi, w, wg, wu, wo,
                                n_experts=cfg.n_experts, n_shards=n_model,
                                shard_idx=sidx, capacity=capacity,
                                balanced=cfg.moe_probe_balanced)
            y = y + mlp({"wi_gate": sg, "wi_up": su, "wo": so}, xl)
            return jax.lax.psum(y, "model")

        yf = compat_shard_map(
            body, mesh=mesh,
            in_specs=(x_spec, Psp(), Psp("model"), Psp("model"),
                      Psp("model"), Psp(None, "model"), Psp(None, "model"),
                      Psp("model")),
            out_specs=x_spec)(
            xf, params["router"], params["wi_gate"], params["wi_up"],
            params["wo"], params["shared"]["wi_gate"],
            params["shared"]["wi_up"], params["shared"]["wo"])
        return yf.reshape(shape)

    # 'tp' baseline: expert hidden dim sharded over model
    def body(xl, router, wg, wu, wo, sg, su, so):
        topi, w = route({"router": router}, cfg, xl)
        y = _moe_dispatch_compute(xl, topi, w, wg, wu, wo, cfg.n_experts,
                                  cfg.moe_probe_balanced)
        y = y + mlp({"wi_gate": sg, "wi_up": su, "wo": so}, xl)
        return jax.lax.psum(y, "model")

    yf = compat_shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, Psp(), Psp(None, None, "model"),
                  Psp(None, None, "model"), Psp(None, "model"),
                  Psp(None, "model"), Psp(None, "model"), Psp("model")),
        out_specs=x_spec)(
        xf, params["router"], params["wi_gate"], params["wi_up"],
        params["wo"], params["shared"]["wi_gate"],
        params["shared"]["wi_up"], params["shared"]["wo"])
    return yf.reshape(shape)


def _prod_axis(mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.devices.shape[mesh.axis_names.index(a)]
    return out
