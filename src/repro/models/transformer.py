"""Decoder block assembly: per-family block specs + scanned stacks.

Layers are lax.scan'ned (params stacked on a leading 'layers' axis) with
per-layer remat, so HLO size / compile time stay O(1 layer) and live
activations stay bounded.  The zamba2 hybrid uses a two-level scan:
groups of `hybrid_attn_every` mamba layers followed by one application of
the weight-shared attention+MLP block (its KV caches are per-application).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.sharding import shard

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import mlp, mlp_spec, rms_norm, rms_norm_spec, stack_specs


# ===========================================================================
# Per-layer specs
# ===========================================================================
def block_spec(cfg: ModelConfig) -> Dict:
    if cfg.family in ("dense", "audio", "vlm"):
        spec = {
            "ln1": rms_norm_spec(cfg.d_model),
            "ln2": rms_norm_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, cfg.mlp_type),
        }
        spec["attn"] = (attn.mla_spec(cfg) if cfg.attention == "mla"
                        else attn.gqa_spec(cfg))
        return spec
    if cfg.family == "moe":
        return {
            "ln1": rms_norm_spec(cfg.d_model),
            "ln2": rms_norm_spec(cfg.d_model),
            "attn": attn.gqa_spec(cfg),
            "moe": moe_mod.moe_spec(cfg),
        }
    if cfg.family == "ssm":
        return {
            "ln1": rms_norm_spec(cfg.d_model),
            "mamba": ssm_mod.mamba2_spec(cfg),
        }
    if cfg.family == "hybrid":
        return {
            "ln1": rms_norm_spec(cfg.d_model),
            "mamba": ssm_mod.mamba2_spec(cfg),
        }
    raise ValueError(cfg.family)


def shared_block_spec(cfg: ModelConfig) -> Optional[Dict]:
    """Zamba2's weight-shared attention+MLP block (counted once)."""
    if cfg.family != "hybrid":
        return None
    return {
        "ln1": rms_norm_spec(cfg.d_model),
        "ln2": rms_norm_spec(cfg.d_model),
        "attn": attn.gqa_spec(cfg),
        "mlp": mlp_spec(cfg.d_model, cfg.d_ff, cfg.mlp_type),
    }


# ===========================================================================
# Train-time blocks
# ===========================================================================
def _attn_train(params, cfg, x):
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        a = attn.mla_train(params["attn"], cfg, h)
    else:
        a = attn.gqa_train(params["attn"], cfg, h)
    return x + shard(a, "batch", "seq", "embed")


def _ffn_train(params, cfg, x, moe_mode: str):
    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        f = moe_mod.moe_block(params["moe"], cfg, h, mode=moe_mode)
    else:
        f = mlp(params["mlp"], h)
    return x + shard(f, "batch", "seq", "embed")


def block_train(params, cfg: ModelConfig, x: jax.Array,
                moe_mode: str = "tp") -> jax.Array:
    if cfg.family in ("ssm", "hybrid"):
        h = rms_norm(params["ln1"], x, cfg.norm_eps)
        return x + ssm_mod.mamba2_train(params["mamba"], cfg, h)
    x = _attn_train(params, cfg, x)
    return _ffn_train(params, cfg, x, moe_mode)


def shared_block_train(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    x = x + attn.gqa_train(params["attn"], cfg, h)
    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    return x + mlp(params["mlp"], h)


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.nothing_saveable)


def stack_train(params, cfg: ModelConfig, x: jax.Array,
                moe_mode: str = "tp") -> jax.Array:
    """Run the full decoder stack (training)."""
    body = _maybe_remat(
        lambda p, y: block_train(p, cfg, y, moe_mode), cfg)

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        shared = params["shared"]
        sbody = _maybe_remat(
            lambda y: shared_block_train(shared, cfg, y), cfg)

        if not cfg.scan_layers:     # unrolled (cost probes)
            for i in range(cfg.n_layers):
                p_i = jax.tree_util.tree_map(lambda a: a[i],
                                             params["layers"])
                x = body(p_i, x)
                if (i + 1) % every == 0:
                    x = sbody(x)
            return x

        grouped = jax.tree_util.tree_map(
            lambda a: a[:n_groups * every].reshape(
                n_groups, every, *a.shape[1:]), params["layers"])
        tail = jax.tree_util.tree_map(
            lambda a: a[n_groups * every:], params["layers"])

        def group_step(y, gp):
            def inner(y2, p):
                return body(p, y2), None
            y, _ = jax.lax.scan(inner, y, gp)
            return sbody(y), None

        x, _ = jax.lax.scan(group_step, x, grouped)
        if rem:
            def inner(y2, p):
                return body(p, y2), None
            x, _ = jax.lax.scan(inner, x, tail)
        return x

    if cfg.scan_layers:
        def step(y, p):
            return body(p, y), None
        x, _ = jax.lax.scan(step, x, params["layers"])
        return x
    for i in range(cfg.n_layers):
        p_i = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
        x = body(p_i, x)
    return x


# ===========================================================================
# Prefill: train-path compute that also emits the decode cache
# ===========================================================================
def block_prefill(params, cfg: ModelConfig, x: jax.Array,
                  moe_mode: str = "tp") -> Tuple[jax.Array, Dict]:
    if cfg.family in ("ssm", "hybrid"):
        h = rms_norm(params["ln1"], x, cfg.norm_eps)
        y, st = ssm_mod.mamba2_train(params["mamba"], cfg, h,
                                     return_state=True)
        return x + y, st
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        a, kv = attn.mla_train(params["attn"], cfg, h, return_kv=True)
    else:
        a, kv = attn.gqa_train(params["attn"], cfg, h, return_kv=True)
    x = x + shard(a, "batch", "seq", "embed")
    return _ffn_train(params, cfg, x, moe_mode), kv


def shared_block_prefill(params, cfg: ModelConfig, x: jax.Array
                         ) -> Tuple[jax.Array, Dict]:
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    a, kv = attn.gqa_train(params["attn"], cfg, h, return_kv=True)
    x = x + a
    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    return x + mlp(params["mlp"], h), kv


def stack_prefill(params, cfg: ModelConfig, x: jax.Array,
                  moe_mode: str = "tp") -> Tuple[jax.Array, Dict]:
    """Run the stack over a whole prompt, emitting the decode cache."""
    body = _maybe_remat(
        lambda p, y: block_prefill(p, cfg, y, moe_mode), cfg)

    if not cfg.scan_layers:         # unrolled (cost probes)
        if cfg.family == "hybrid":
            every = cfg.hybrid_attn_every
            shared = params["shared"]
            mamba_cs, attn_cs = [], []
            for i in range(cfg.n_layers):
                p_i = jax.tree_util.tree_map(lambda a: a[i],
                                             params["layers"])
                x, c = body(p_i, x)
                mamba_cs.append(c)
                if (i + 1) % every == 0:
                    x, ac = shared_block_prefill(shared, cfg, x)
                    attn_cs.append(ac)
            stackc = lambda cs: jax.tree_util.tree_map(
                lambda *a: jnp.stack(a), *cs)
            return x, {"mamba": stackc(mamba_cs), "attn": stackc(attn_cs)}
        caches = []
        for i in range(cfg.n_layers):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, c = body(p_i, x)
            caches.append(c)
        return x, jax.tree_util.tree_map(lambda *a: jnp.stack(a), *caches)

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        shared = params["shared"]
        sbody = _maybe_remat(
            lambda y: shared_block_prefill(shared, cfg, y), cfg)
        grouped = jax.tree_util.tree_map(
            lambda a: a[:n_groups * every].reshape(
                n_groups, every, *a.shape[1:]), params["layers"])
        tail = jax.tree_util.tree_map(
            lambda a: a[n_groups * every:], params["layers"])

        def group_step(y, gp):
            def inner(y2, p):
                return body(p, y2)
            y, mamba_c = jax.lax.scan(inner, y, gp)
            y, attn_c = sbody(y)
            return y, (mamba_c, attn_c)

        x, (g_mamba, attn_c) = jax.lax.scan(group_step, x, grouped)
        mamba_c = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups * every, *a.shape[2:]), g_mamba)
        if rem:
            def inner(y2, p):
                return body(p, y2)
            x, t_mamba = jax.lax.scan(inner, x, tail)
            mamba_c = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], 0), mamba_c, t_mamba)
        return x, {"mamba": mamba_c, "attn": attn_c}

    def step(y, p):
        return body(p, y)

    x, cache = jax.lax.scan(step, x, params["layers"])
    return x, cache


# ===========================================================================
# Decode-time blocks
# ===========================================================================
def block_decode(params, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                 cache: Dict, moe_mode: str = "tp"
                 ) -> Tuple[jax.Array, Dict]:
    """x (B,d) one token."""
    if cfg.family in ("ssm", "hybrid"):
        h = rms_norm(params["ln1"], x, cfg.norm_eps)
        y, new_cache = ssm_mod.mamba2_decode(params["mamba"], cfg, h, cache)
        return x + y, new_cache
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    if cfg.attention == "mla":
        a, new_cache = attn.mla_decode(params["attn"], cfg, h, pos, cache)
    else:
        a, new_cache = attn.gqa_decode(params["attn"], cfg, h, pos, cache)
    x = x + a
    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        f = moe_mod.moe_block(params["moe"], cfg, h, mode=moe_mode)
    else:
        f = mlp(params["mlp"], h)
    return x + f, new_cache


def shared_block_decode(params, cfg: ModelConfig, x: jax.Array,
                        pos: jax.Array, cache: Dict
                        ) -> Tuple[jax.Array, Dict]:
    h = rms_norm(params["ln1"], x, cfg.norm_eps)
    a, new_cache = attn.gqa_decode(params["attn"], cfg, h, pos, cache)
    x = x + a
    h = rms_norm(params["ln2"], x, cfg.norm_eps)
    return x + mlp(params["mlp"], h), new_cache


def stack_decode(params, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
                 cache: Dict, moe_mode: str = "tp"
                 ) -> Tuple[jax.Array, Dict]:
    """Scanned decode over layers; caches are scan xs/ys."""
    if not cfg.scan_layers:         # unrolled (cost probes)
        take = lambda t, i: jax.tree_util.tree_map(lambda a: a[i], t)
        stackc = lambda cs: jax.tree_util.tree_map(
            lambda *a: jnp.stack(a), *cs)
        if cfg.family == "hybrid":
            every = cfg.hybrid_attn_every
            shared = params["shared"]
            mamba_cs, attn_cs = [], []
            for i in range(cfg.n_layers):
                x, c = block_decode(take(params["layers"], i), cfg, x, pos,
                                    take(cache["mamba"], i), moe_mode)
                mamba_cs.append(c)
                if (i + 1) % every == 0:
                    j = (i + 1) // every - 1
                    x, ac = shared_block_decode(shared, cfg, x, pos,
                                                take(cache["attn"], j))
                    attn_cs.append(ac)
            return x, {"mamba": stackc(mamba_cs), "attn": stackc(attn_cs)}
        caches = []
        for i in range(cfg.n_layers):
            x, c = block_decode(take(params["layers"], i), cfg, x, pos,
                                take(cache, i), moe_mode)
            caches.append(c)
        return x, stackc(caches)

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.n_layers, every)
        shared = params["shared"]
        grouped = jax.tree_util.tree_map(
            lambda a: a[:n_groups * every].reshape(
                n_groups, every, *a.shape[1:]), params["layers"])
        tail = jax.tree_util.tree_map(
            lambda a: a[n_groups * every:], params["layers"])
        g_mamba = jax.tree_util.tree_map(
            lambda a: a[:n_groups * every].reshape(
                n_groups, every, *a.shape[1:]), cache["mamba"])
        t_mamba = jax.tree_util.tree_map(
            lambda a: a[n_groups * every:], cache["mamba"])

        def group_step(y, xs):
            gp, mc, ac = xs

            def inner(y2, xs2):
                p, c = xs2
                y2, c2 = block_decode(p, cfg, y2, pos, c, moe_mode)
                return y2, c2
            y, mc2 = jax.lax.scan(inner, y, (gp, mc))
            y, ac2 = shared_block_decode(shared, cfg, y, pos, ac)
            return y, (mc2, ac2)

        x, (g_mamba2, attn2) = jax.lax.scan(
            group_step, x, (grouped, g_mamba, cache["attn"]))
        new_mamba = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups * every, *a.shape[2:]), g_mamba2)
        if rem:
            def inner(y2, xs2):
                p, c = xs2
                y2, c2 = block_decode(p, cfg, y2, pos, c, moe_mode)
                return y2, c2
            x, t2 = jax.lax.scan(inner, x, (tail, t_mamba))
            new_mamba = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], 0), new_mamba, t2)
        return x, {"mamba": new_mamba, "attn": attn2}

    def step(y, xs):
        p, c = xs
        y, c2 = block_decode(p, cfg, y, pos, c, moe_mode)
        return y, c2

    x, new_cache = jax.lax.scan(step, x, (params["layers"], cache))
    return x, new_cache
