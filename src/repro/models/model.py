"""Top-level model: specs, init, train forward (loss), decode forward.

Public API used by the runtime / launcher:

    specs   = model_specs(cfg)             # P-spec tree (shapes + axes)
    params  = init_params(key, cfg)
    loss, m = loss_fn(params, cfg, batch)
    cache   = init_cache(cfg, batch, seq)  # or cache_specs(...) for dry-run
    logits, cache = forward_decode(params, cfg, inputs, pos, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.sharding import shard

from . import layers as L
from . import transformer as T


# ===========================================================================
# Specs / init
# ===========================================================================
def model_specs(cfg: ModelConfig) -> Dict:
    d, V = cfg.d_model, cfg.padded_vocab
    specs: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        specs["embed"] = L.embedding_spec(V, d)
    else:
        # modality stub: inputs arrive as precomputed frame/patch embeddings
        specs["in_proj"] = {"kernel": L.P((d, d), ("embed", "mlp"))}
        specs["embed"] = L.embedding_spec(V, d)  # still needed for labels tie
    specs["layers"] = L.stack_specs(T.block_spec(cfg), cfg.n_layers)
    sb = T.shared_block_spec(cfg)
    if sb is not None:
        specs["shared"] = sb
    specs["ln_f"] = L.rms_norm_spec(d)
    if not cfg.tie_embeddings:
        specs["unembed"] = L.unembed_spec(d, V)
    return specs


def init_params(key: jax.Array, cfg: ModelConfig) -> Dict:
    return L.init_params(key, model_specs(cfg))


def param_axes(cfg: ModelConfig):
    return L.axes_tree(model_specs(cfg))


def abstract_params(cfg: ModelConfig):
    return L.abstract_params(model_specs(cfg))


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


# ===========================================================================
# Embedding in / logits out
# ===========================================================================
def _embed_in(params, cfg: ModelConfig, batch: Dict) -> jax.Array:
    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], batch["tokens"])
    else:
        x = jnp.einsum("...d,de->...e", batch["embeds"],
                       params["in_proj"]["kernel"])
    return x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)


def _logits_out(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, params["embed"]["table"]
                          ).astype(jnp.float32)
    return L.unembed(params["unembed"], x)


# ===========================================================================
# Training forward + loss
# ===========================================================================
def forward_train(params, cfg: ModelConfig, batch: Dict,
                  moe_mode: str = "tp") -> jax.Array:
    x = _embed_in(params, cfg, batch)
    x = shard(x, "batch", "seq", "embed")
    x = T.stack_train(params, cfg, x, moe_mode)
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    return _logits_out(params, cfg, x)


def loss_fn(params, cfg: ModelConfig, batch: Dict, moe_mode: str = "tp"
            ) -> Tuple[jax.Array, Dict]:
    logits = forward_train(params, cfg, batch, moe_mode)
    logits = shard(logits, "batch", "seq", "vocab")
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


def forward_prefill(params, cfg: ModelConfig, batch: Dict,
                    moe_mode: str = "tp") -> Tuple[jax.Array, Dict]:
    """Prefill a prompt: returns (last-position logits (B,V), decode cache).

    The cache's seq capacity equals the prompt length; serving code that
    continues decoding should allocate a longer cache and copy in (see
    runtime/serve_loop.py)."""
    x = _embed_in(params, cfg, batch)
    x = shard(x, "batch", "seq", "embed")
    x, cache = T.stack_prefill(params, cfg, x, moe_mode)
    x = L.rms_norm(params["ln_f"], x[:, -1], cfg.norm_eps)
    return _logits_out(params, cfg, x), cache


# ===========================================================================
# Decode: cache construction + one-token step
# ===========================================================================
def _cache_dt(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.cache_dtype == "bfloat16" else jnp.float32


def _gqa_cache_entry(cfg: ModelConfig, batch: int, seq: int):
    KVH, Dh, Lr = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    shape = (Lr, batch, seq, KVH, Dh)
    axes = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    cdt = _cache_dt(cfg)
    return {
        "k": (shape, axes, cdt),
        "v": (shape, axes, cdt),
    }


def cache_layout(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    """{name: (shape, logical_axes, dtype)} tree describing the cache."""
    Lr = cfg.n_layers
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        if cfg.attention == "mla":
            return {
                "c": ((Lr, batch, seq, cfg.kv_lora_rank),
                      ("layers", "batch", "kv_seq", "kv_lora"),
                      _cache_dt(cfg)),
                "kr": ((Lr, batch, seq, cfg.qk_rope_dim),
                       ("layers", "batch", "kv_seq", None), _cache_dt(cfg)),
            }
        return _gqa_cache_entry(cfg, batch, seq)
    if cfg.family == "ssm":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return {
            "conv": ((Lr, batch, cfg.ssm_conv - 1, conv_dim),
                     ("layers", "batch", None, "ssm_inner"), _cache_dt(cfg)),
            "ssm": ((Lr, batch, cfg.ssm_heads, cfg.ssm_headdim,
                     cfg.ssm_state),
                    ("layers", "batch", "ssm_heads", None, None),
                    jnp.float32),
        }
    if cfg.family == "hybrid":
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        n_apps = cfg.n_layers // cfg.hybrid_attn_every
        KVH, Dh = cfg.n_kv_heads, cfg.head_dim
        return {
            "mamba": {
                "conv": ((Lr, batch, cfg.ssm_conv - 1, conv_dim),
                         ("layers", "batch", None, "ssm_inner"),
                         _cache_dt(cfg)),
                "ssm": ((Lr, batch, cfg.ssm_heads, cfg.ssm_headdim,
                         cfg.ssm_state),
                        ("layers", "batch", "ssm_heads", None, None),
                        jnp.float32),
            },
            "attn": {
                "k": ((n_apps, batch, seq, KVH, Dh),
                      ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                      _cache_dt(cfg)),
                "v": ((n_apps, batch, seq, KVH, Dh),
                      ("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                      _cache_dt(cfg)),
            },
        }
    raise ValueError(cfg.family)


def _is_entry(x) -> bool:
    return (isinstance(x, tuple) and len(x) == 3
            and isinstance(x[0], tuple))


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    return jax.tree_util.tree_map(
        lambda e: jnp.zeros(e[0], e[2]), cache_layout(cfg, batch, seq),
        is_leaf=_is_entry)


def cache_specs(cfg: ModelConfig, batch: int, seq: int):
    """(ShapeDtypeStruct tree, logical-axes tree) for dry-run lowering."""
    layout = cache_layout(cfg, batch, seq)
    shapes = jax.tree_util.tree_map(
        lambda e: jax.ShapeDtypeStruct(e[0], e[2]), layout,
        is_leaf=_is_entry)
    axes = jax.tree_util.tree_map(lambda e: e[1], layout, is_leaf=_is_entry)
    return shapes, axes


def forward_decode(params, cfg: ModelConfig, inputs: Dict, pos: jax.Array,
                   cache: Dict, moe_mode: str = "tp"
                   ) -> Tuple[jax.Array, Dict]:
    """One decode step.  inputs: {'token': (B,)} or {'embed': (B,d)}."""
    if cfg.input_mode == "tokens":
        x = L.embed(params["embed"], inputs["token"])
    else:
        x = jnp.einsum("bd,de->be", inputs["embed"],
                       params["in_proj"]["kernel"])
    x = x.astype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32)
    x = shard(x, "batch", "embed")
    x, new_cache = T.stack_decode(params, cfg, x, pos, cache, moe_mode)
    x = L.rms_norm(params["ln_f"], x, cfg.norm_eps)
    logits = _logits_out(params, cfg, x)
    return shard(logits, "batch", "vocab"), new_cache
