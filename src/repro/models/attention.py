"""Attention: GQA (with flash-style chunked training path) and MLA.

Training uses a pure-JAX flash attention (double scan over query/kv chunks
with online softmax) so the S=4096 training shapes never materialize an SxS
score matrix.  Decode attends one new token against a KV cache; the
CAM-retrieval decode path lives in cam_attention.py.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.sharding import shard

from .layers import P, apply_rope, rms_norm, rms_norm_spec

NEG_INF = -1e30


# ===========================================================================
# Flash attention (pure JAX, chunked, online softmax)
# ===========================================================================
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024,
                    scale: Optional[float] = None) -> jax.Array:
    """q (B,S,H,Dk), k (B,S,KVH,Dk), v (B,S,KVH,Dv) -> (B,S,H,Dv).

    GQA handled by grouping: H = KVH * G.  Memory is O(q_chunk * kv_chunk)
    per step instead of O(S^2).
    """
    B, S, H, Dk = q.shape
    _, Skv, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    scale = Dk ** -0.5 if scale is None else scale
    qc = min(q_chunk, S)
    kc = min(kv_chunk, Skv)
    nq, nk = S // qc, Skv // kc
    assert S % qc == 0 and Skv % kc == 0, (S, qc, Skv, kc)

    qch = q.reshape(B, nq, qc, KVH, G, Dk).transpose(1, 0, 2, 3, 4, 5)
    kch = k.reshape(B, nk, kc, KVH, Dk).transpose(1, 0, 2, 3, 4)
    vch = v.reshape(B, nk, kc, KVH, Dv).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi):
        qi_idx, qblk = qi                       # (B, qc, KVH, G, Dk)
        q_pos = qi_idx * qc + jnp.arange(qc)

        def kv_step(carry, kj):
            m, l, acc = carry
            kj_idx, kblk, vblk = kj
            k_pos = kj_idx * kc + jnp.arange(kc)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if causal:
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, qc, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kch, vch))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)       # (B, KVH, G, qc, Dv)

    _, out = jax.lax.scan(q_step, None, (jnp.arange(nq), qch))
    # (nq, B, KVH, G, qc, Dv) -> (B, S, H, Dv)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KVH * G, Dv)
    return out


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    scale: Optional[float] = None) -> jax.Array:
    """Reference full-S^2 attention (same FLOPs as flash_attention; no
    inner scans — used by the dry-run cost probes and small tests)."""
    B, S, H, Dk = q.shape
    KVH = k.shape[2]
    G = H // KVH
    Dv = v.shape[-1]
    scale = Dk ** -0.5 if scale is None else scale
    qg = q.reshape(B, S, KVH, G, Dk)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, Dv).astype(q.dtype)


def _attention(cfg, q, k, v, scale=None):
    if cfg.attn_impl == "naive":
        return naive_attention(q, k, v, scale=scale)
    if cfg.attn_impl == "skip":
        # cost-probe surrogate for the fused Pallas kernel: the in-HLO
        # attention cost is removed and re-injected analytically from the
        # kernel's true VMEM-resident traffic (dryrun.fused_attention_cost)
        B, S, H, _ = q.shape
        return jnp.zeros((B, S, H, v.shape[-1]), q.dtype)
    if cfg.attn_impl == "flash_fullq":   # single q block (seq-sharded q)
        return flash_attention(q, k, v, scale=scale, q_chunk=q.shape[1])
    return flash_attention(q, k, v, scale=scale)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array,
                     scale: Optional[float] = None) -> jax.Array:
    """One-token decode: q (B,H,Dk), cache (B,S,KVH,D*) -> (B,H,Dv).

    ``pos`` (B,) is the index of the new token; entries > pos are masked.
    """
    B, H, Dk = q.shape
    _, S, KVH, _ = k_cache.shape
    G = H // KVH
    scale = Dk ** -0.5 if scale is None else scale
    qg = q.reshape(B, KVH, G, Dk)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S)[None, :] <= pos[:, None]          # (B, S)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, -1).astype(q.dtype)


# ===========================================================================
# GQA block
# ===========================================================================
def gqa_spec(cfg: ModelConfig) -> Dict:
    d, H, KVH, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": P((d, H, Dh), ("embed", "heads", "head_dim")),
        "wk": P((d, KVH, Dh), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, KVH, Dh), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, Dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((H, Dh), ("heads", "head_dim"), init="zeros")
        spec["bk"] = P((KVH, Dh), ("kv_heads", "head_dim"), init="zeros")
        spec["bv"] = P((KVH, Dh), ("kv_heads", "head_dim"), init="zeros")
    return spec


def gqa_qkv(params, cfg: ModelConfig, x: jax.Array):
    """x (B,S,d) -> q (B,S,H,Dh), k/v (B,S,KVH,Dh), rope applied."""
    q = jnp.einsum("bsd,dhe->bshe", x, params["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, params["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    return q, k, v


def gqa_train(params, cfg: ModelConfig, x: jax.Array,
              return_kv: bool = False):
    B, S, _ = x.shape
    q, k, v = gqa_qkv(params, cfg, x)
    pos = jnp.arange(S)[None, :]
    q = apply_rope(q, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(pos, (B, S)), cfg.rope_theta)
    # heads shard over 'model' when divisible; otherwise 'attn_seq' puts
    # the query-seq dim on 'model' (context-parallel fallback) and K/V
    # replicate across it (cheap: KV heads are small exactly when heads
    # fail to divide)
    q = shard(q, "batch", "attn_seq", "heads", "head_dim")
    k = shard(k, "batch", None, "kv_heads", "head_dim")
    v = shard(v, "batch", None, "kv_heads", "head_dim")
    out = _attention(cfg, q, k, v)
    out = shard(out, "batch", "attn_seq", "heads", "head_dim")
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    if return_kv:
        cdt = jnp.bfloat16 if cfg.cache_dtype == "bfloat16" else jnp.float32
        return y, {"k": k.astype(cdt), "v": v.astype(cdt)}
    return y


def gqa_decode(params, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    """x (B,d) one token; cache {'k': (B,S,KVH,Dh), 'v': ...}."""
    B, _ = x.shape
    q = jnp.einsum("bd,dhe->bhe", x, params["wq"])
    k = jnp.einsum("bd,dhe->bhe", x, params["wk"])
    v = jnp.einsum("bd,dhe->bhe", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
    kc = _cache_update(cache["k"], k, pos)
    vc = _cache_update(cache["v"], v, pos)
    if cfg.cam_attention:
        from .cam_attention import cam_decode
        out = cam_decode(q, kc, vc, pos, cfg)
    else:
        out = decode_attention(q, kc, vc, pos)
    y = jnp.einsum("bhe,hed->bd", out, params["wo"])
    return y, {"k": kc, "v": vc}


def _cache_update(cache: jax.Array, new: jax.Array,
                  pos: jax.Array) -> jax.Array:
    """cache (B,S,KVH,Dh), new (B,KVH,Dh), per-example position (B,).

    vmapped dynamic_update_slice: O(KVH*Dh) bytes per token (donated
    caches update in place), not O(S) like a one-hot blend."""
    def one(c, n, p):
        return jax.lax.dynamic_update_slice(
            c, n[None].astype(c.dtype), (p, 0, 0))
    return jax.vmap(one)(cache, new, pos)


# ===========================================================================
# MLA (multi-head latent attention, minicpm3 / deepseek-style)
# ===========================================================================
def mla_spec(cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        "w_dq": P((d, qr), ("embed", "q_lora")),
        "q_norm": rms_norm_spec(qr),
        "w_uq": P((qr, H, dn + dr), ("q_lora", "heads", "head_dim")),
        "w_dkv": P((d, kvr + dr), ("embed", "kv_lora")),
        "kv_norm": rms_norm_spec(kvr),
        "w_uk": P((kvr, H, dn), ("kv_lora", "heads", "head_dim")),
        "w_uv": P((kvr, H, dv), ("kv_lora", "heads", "head_dim")),
        "wo": P((H, dv, d), ("heads", "head_dim", "embed")),
    }


def _mla_q(params, cfg, x, pos):
    """x (B,S,d) -> q_nope (B,S,H,dn), q_rope (B,S,H,dr)."""
    cq = jnp.einsum("...d,dr->...r", x, params["w_dq"])
    cq = rms_norm(params["q_norm"], cq, cfg.norm_eps)
    q = jnp.einsum("...r,rhe->...he", cq, params["w_uq"])
    qn = q[..., :cfg.qk_nope_dim]
    qr = apply_rope(q[..., cfg.qk_nope_dim:], pos, cfg.rope_theta)
    return qn, qr


def _mla_kv_latent(params, cfg, x, pos):
    """x (B,S,d) -> c_kv (B,S,kvr) normalized, k_rope (B,S,dr) roped."""
    ckv = jnp.einsum("...d,dr->...r", x, params["w_dkv"])
    c, kr = ckv[..., :cfg.kv_lora_rank], ckv[..., cfg.kv_lora_rank:]
    c = rms_norm(params["kv_norm"], c, cfg.norm_eps)
    kr = apply_rope(kr[..., None, :], pos, cfg.rope_theta)[..., 0, :]
    return c, kr


def mla_train(params, cfg: ModelConfig, x: jax.Array,
              return_kv: bool = False):
    B, S, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    qn, qr = _mla_q(params, cfg, x, pos)
    c, kr = _mla_kv_latent(params, cfg, x, pos)
    # expand keys/values from the latent (training path: explicit heads)
    kn = jnp.einsum("bsr,rhe->bshe", c, params["w_uk"])
    v = jnp.einsum("bsr,rhe->bshe", c, params["w_uv"])
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :],
                              (*kn.shape[:-1], cfg.qk_rope_dim))], axis=-1)
    q = shard(q, "batch", "attn_seq", "heads", "head_dim")
    k = shard(k, "batch", None, "heads", "head_dim")
    out = _attention(cfg, q, k, v,
                     scale=(cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5)
    y = jnp.einsum("bshe,hed->bsd", out, params["wo"])
    if return_kv:
        cdt = jnp.bfloat16 if cfg.cache_dtype == "bfloat16" else jnp.float32
        return y, {"c": c.astype(cdt), "kr": kr.astype(cdt)}
    return y


def mla_decode(params, cfg: ModelConfig, x: jax.Array, pos: jax.Array,
               cache: Dict) -> Tuple[jax.Array, Dict]:
    """Absorbed-matmul MLA decode over the compressed latent cache.

    cache: {'c': (B,S,kvr), 'kr': (B,S,dr)} — this 2-tensor latent cache is
    MLA's raison d'être: (kvr+dr) per token instead of 2*H*Dh.
    """
    B, _ = x.shape
    x1 = x[:, None]                                      # (B,1,d)
    p1 = pos[:, None]
    qn, qr = _mla_q(params, cfg, x1, p1)                 # (B,1,H,*)
    cq, krq = _mla_kv_latent(params, cfg, x1, p1)        # new latent entry
    cc = _cache_update_2d(cache["c"], cq[:, 0], pos)
    krc = _cache_update_2d(cache["kr"], krq[:, 0], pos)

    # absorb W_uk into the query: q_eff (B,H,kvr)
    q_eff = jnp.einsum("bhe,rhe->bhr", qn[:, 0], params["w_uk"])
    scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
    s = (jnp.einsum("bhr,bsr->bhs", q_eff, cc,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhe,bse->bhs", qr[:, 0], krc,
                      preferred_element_type=jnp.float32)) * scale
    if cfg.cam_attention:
        from .cam_attention import cam_select_scores
        s = cam_select_scores(s, pos, cfg)
    S = cc.shape[1]
    valid = jnp.arange(S)[None, None, :] <= pos[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w.astype(cc.dtype), cc,
                     preferred_element_type=jnp.float32)   # latent context
    out = jnp.einsum("bhr,rhe->bhe", ctx.astype(x.dtype), params["w_uv"])
    y = jnp.einsum("bhe,hed->bd", out, params["wo"])
    return y, {"c": cc, "kr": krc}


def _cache_update_2d(cache: jax.Array, new: jax.Array,
                     pos: jax.Array) -> jax.Array:
    """cache (B,S,D), new (B,D) — vmapped dynamic_update_slice."""
    def one(c, n, p):
        return jax.lax.dynamic_update_slice(
            c, n[None].astype(c.dtype), (p, 0))
    return jax.vmap(one)(cache, new, pos)
