"""CAM-retrieval attention: the paper's best-match CAM search as an LM layer.

At decode time the KV cache plays the role of the CAM stored data; the query
performs a *best-match with sensing limit* search (top-k) over the keys and
attention is computed only over the retrieved entries — the direct LM
transliteration of the paper's MANN application, and what makes the
long_500k shape sub-quadratic in bytes for attention archs (DESIGN.md §3).

Non-idealities from the paper's functional simulator are available:
``cam_attn_bits`` applies MCAM linear quantization to keys and query before
the distance pass (Fig. 4's accuracy knob).  Two backends:

  * 'xla'    — shardable jnp ops (used under pjit / for the dry-run)
  * 'pallas' — the cam_topk streaming kernel (single-device TPU hot path)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.quantize import linear_quantize
from repro.launch.mesh import compat_shard_map

NEG_INF = -1e30


def _maybe_quantize(q: jax.Array, k: jax.Array, bits: int):
    """MCAM quantization of the retrieval operands (shared scale)."""
    if bits <= 0:
        return q, k
    lo = jnp.minimum(jnp.min(k), jnp.min(q))
    hi = jnp.maximum(jnp.max(k), jnp.max(q))
    qq, _, _ = linear_quantize(q.astype(jnp.float32), bits, lo, hi)
    kq, _, _ = linear_quantize(k.astype(jnp.float32), bits, lo, hi)
    return qq, kq


def cam_topk_scores(scores: jax.Array, k: int):
    """Best-match-with-SL selection: keep top-k scores, mask the rest."""
    S = scores.shape[-1]
    k = min(k, S)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx


def cam_decode_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array, pos: jax.Array,
                         cfg: ModelConfig,
                         backend: str = "xla") -> jax.Array:
    """GQA decode via CAM retrieval.

    q (B,H,Dh); k_cache/v_cache (B,S,KVH,D*); pos (B,).
    Returns (B,H,Dv).
    """
    B, H, Dk = q.shape
    _, S, KVH, Dv = v_cache.shape
    G = H // KVH
    scale = Dk ** -0.5
    topk = min(cfg.cam_topk, S)

    qq, kk = _maybe_quantize(q, k_cache, cfg.cam_attn_bits)
    qg = qq.reshape(B, KVH, G, Dk)
    kc = kk.transpose(0, 2, 1, 3)                      # (B,KVH,S,Dk)

    # CAM distance pass (dot distance == best-match over inner product)
    s = jnp.einsum("bhgd,bhsd->bhgs", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    valid = (jnp.arange(S)[None, :] <= pos[:, None])   # (B,S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)

    # winner-take-all sensing: top-k selection
    vals, idx = cam_topk_scores(s, topk)               # (B,KVH,G,k)

    # gather retrieved values only — the bytes win vs full attention
    vc = v_cache.transpose(0, 2, 1, 3)                 # (B,KVH,S,Dv)
    vg = jnp.take_along_axis(
        vc[:, :, None], idx[..., None].clip(0), axis=-2)  # (B,KVH,G,k,Dv)

    w = jax.nn.softmax(vals, axis=-1)                  # over retrieved set
    out = jnp.einsum("bhgk,bhgkd->bhgd", w.astype(vg.dtype), vg,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Dv).astype(q.dtype)


def cam_select_scores(s: jax.Array, pos: jax.Array,
                      cfg: ModelConfig) -> jax.Array:
    """MLA variant: mask all but the CAM-retrieved top-k of the latent
    scores (B,H,S) — retrieval happens in the compressed latent space."""
    S = s.shape[-1]
    topk = min(cfg.cam_topk, S)
    valid = jnp.arange(S)[None, None, :] <= pos[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    kth = jax.lax.top_k(s, topk)[0][..., -1:]
    return jnp.where(s >= kth, s, NEG_INF)


def cam_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
               pos: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Dispatch between merge strategies.

    'hierarchical' engages when a model mesh axis exists, the cache's seq
    dim shards over it (kv_heads didn't divide), and the seq length splits
    evenly; otherwise falls back to the global top-k."""
    from repro.runtime import sharding as shmod
    if cfg.cam_merge == "hierarchical":
        m = shmod.model_axis_size()
        S, KVH = k_cache.shape[1], k_cache.shape[2]
        if m > 1 and KVH % m != 0 and S % m == 0 and (S // m) >= 1:
            return cam_decode_attention_hierarchical(q, k_cache, v_cache,
                                                     pos, cfg)
    return cam_decode_attention(q, k_cache, v_cache, pos, cfg)


def cam_decode_attention_hierarchical(q: jax.Array, k_cache: jax.Array,
                                      v_cache: jax.Array, pos: jax.Array,
                                      cfg: ModelConfig) -> jax.Array:
    """CAM retrieval with the paper's partition-and-merge over a
    seq-sharded cache (Fig. 3: per-subarray best match + comparator-style
    vertical merge), as a shard_map.

    Each model shard = one vertical CAM partition holding S/m cache rows:
      1. local distance pass + local top-k (the subarray winner set);
      2. all-gather only the (m x k) winner SCORES (bytes ~ m*k*4, vs the
         full cache for the global variant) and derive the global k-th
         score (the comparator tree);
      3. each shard computes exp-weighted partial sums over its local
         winners that clear the global threshold; psum merges them.

    Exact w.r.t. the global variant (same retrieved set; ties at the k-th
    score may admit extras — precisely the paper's sensing-limit
    semantics).
    """
    from repro.runtime import sharding as shmod
    ctx = shmod._ctx.get()
    B, H, Dk = q.shape
    _, S, KVH, Dv = v_cache.shape
    G = H // KVH
    scale = Dk ** -0.5
    mesh = ctx.mesh
    m = shmod.model_axis_size()
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_ok = B % max(1, _prod(mesh, dp)) == 0
    Psp = jax.sharding.PartitionSpec
    b_spec = Psp(dp) if dp_ok else Psp()
    S_l = S // m
    topk = min(cfg.cam_topk, S_l)

    def body(qb, kb, vb, posb):
        sidx = jax.lax.axis_index("model")
        qq, kk = _maybe_quantize(qb, kb, cfg.cam_attn_bits)
        qg = qq.reshape(-1, KVH, G, Dk)
        kc = kk.transpose(0, 2, 1, 3)                  # (b,KVH,S_l,Dk)
        s = jnp.einsum("bhgd,bhsd->bhgs", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        gpos = sidx * S_l + jnp.arange(S_l)            # global positions
        valid = gpos[None, :] <= posb[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        # 1. local winner set (the subarray best-match outputs)
        vals, idx = jax.lax.top_k(s, topk)             # (b,KVH,G,k)
        # 2. comparator merge: gather only winner scores, global k-th
        allv = jax.lax.all_gather(vals, "model")       # (m,b,KVH,G,k)
        allv = jnp.moveaxis(allv, 0, -2).reshape(
            *vals.shape[:-1], m * topk)
        kth = jax.lax.top_k(allv, topk)[0][..., -1:]   # global threshold
        mx = jnp.max(allv, axis=-1, keepdims=True)
        # 3. local partial attention over winners clearing the threshold
        keep = vals >= kth
        p = jnp.where(keep, jnp.exp(vals - mx), 0.0)   # (b,KVH,G,k)
        vloc = vb.transpose(0, 2, 1, 3)                # (b,KVH,S_l,Dv)
        vg = jnp.take_along_axis(vloc[:, :, None],
                                 idx[..., None].clip(0), axis=-2)
        num = jnp.einsum("bhgk,bhgkd->bhgd", p.astype(vg.dtype), vg,
                         preferred_element_type=jnp.float32)
        den = jnp.sum(p, axis=-1, keepdims=True)
        num = jax.lax.psum(num, "model")
        den = jax.lax.psum(den, "model")
        out = num / jnp.maximum(den, 1e-30)
        return out.reshape(-1, H, Dv).astype(qb.dtype)

    return compat_shard_map(
        body, mesh=mesh,
        in_specs=(b_spec, Psp(b_spec[0] if dp_ok else None, "model"),
                  Psp(b_spec[0] if dp_ok else None, "model"), b_spec),
        out_specs=b_spec)(q, k_cache, v_cache, pos)


def _prod(mesh, axes) -> int:
    out = 1
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    for a in axes:
        out *= sizes[a]
    return out


def cam_decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                                v_cache: jax.Array, pos: jax.Array,
                                cfg: ModelConfig) -> jax.Array:
    """Kernel-backed variant: streaming cam_topk over the cache (per
    (batch, head)); single-device TPU path, validated against the xla
    backend in tests."""
    from repro.kernels import ops as kops
    B, H, Dk = q.shape
    _, S, KVH, Dv = v_cache.shape
    G = H // KVH
    scale = Dk ** -0.5
    topk = min(cfg.cam_topk, S)
    qg = q.reshape(B, KVH, G, Dk)
    kc = jnp.broadcast_to(k_cache.transpose(0, 2, 1, 3)[:, :, None],
                          (B, KVH, G, S, Dk))
    vals, idx = kops.cam_topk(
        kc.reshape(-1, S, Dk) * scale,
        qg.reshape(-1, Dk),
        k=topk, chunk=min(cfg.cam_chunk, S), distance="dot")
    vals = vals.reshape(B, KVH, G, topk)
    idx = idx.reshape(B, KVH, G, topk)
    # mask entries beyond pos (cache not yet written)
    written = idx <= pos[:, None, None, None]
    vals = jnp.where(written, vals, NEG_INF)
    vc = v_cache.transpose(0, 2, 1, 3)
    vg = jnp.take_along_axis(vc[:, :, None], idx[..., None].clip(0),
                             axis=-2)
    w = jax.nn.softmax(vals, axis=-1)
    out = jnp.einsum("bhgk,bhgkd->bhgd", w.astype(vg.dtype), vg,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Dv).astype(q.dtype)
