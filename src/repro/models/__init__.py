"""Model zoo: the 10 assigned architectures + CAM-integrated layers."""
from .model import (abstract_params, cache_specs, forward_decode,
                    forward_prefill, forward_train, init_cache, init_params,
                    loss_fn, model_specs, param_axes, param_count)

__all__ = [
    "abstract_params", "cache_specs", "forward_decode", "forward_prefill",
    "forward_train",
    "init_cache", "init_params", "loss_fn", "model_specs", "param_axes",
    "param_count",
]
