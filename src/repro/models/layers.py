"""Parameter-spec machinery + basic layers (norms, embeddings, rope).

Every parameter is declared exactly once as a ``P`` spec carrying its shape,
*logical axes* (resolved to mesh axes by runtime/sharding.py) and init
style.  ``init_params`` materializes values, ``axes_tree`` extracts the
logical-axes pytree — the two never drift apart.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Axes = Tuple[Optional[str], ...]


@dataclass(frozen=True)
class P:
    """Declarative parameter spec."""
    shape: Tuple[int, ...]
    axes: Axes
    init: str = "normal"          # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Any = jnp.bfloat16

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def tree_map_specs(f, tree):
    return jax.tree_util.tree_map(f, tree, is_leaf=is_spec)


def stack_specs(tree, n: int):
    """Prepend a scanned 'layers' axis to every spec in the tree."""
    return tree_map_specs(
        lambda p: dataclasses.replace(p, shape=(n, *p.shape),
                                      axes=("layers", *p.axes)), tree)


def init_params(key: jax.Array, specs) -> Dict:
    """Materialize a spec tree into parameter arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(k, p: P):
        if p.init == "zeros":
            return jnp.zeros(p.shape, p.dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, p.dtype)
        if p.init == "embed":
            return (0.02 * p.scale
                    * jax.random.normal(k, p.shape)).astype(p.dtype)
        if p.init == "small":
            return (1e-2 * p.scale
                    * jax.random.normal(k, p.shape)).astype(p.dtype)
        # 'normal': truncated-normal, fan-in scaled; scanned layer axis and
        # any leading 'layers' axis excluded from fan-in.
        fan_axes = [s for s, a in zip(p.shape, p.axes) if a != "layers"]
        fan_in = fan_axes[0] if len(fan_axes) >= 2 else max(1, fan_axes[0])
        std = p.scale / (fan_in ** 0.5)
        return (std * jax.random.truncated_normal(
            k, -2.0, 2.0, p.shape)).astype(p.dtype)

    return jax.tree_util.tree_unflatten(
        treedef, [one(k, p) for k, p in zip(keys, leaves)])


def axes_tree(specs):
    """Extract the logical-axes pytree (same structure as params)."""
    return tree_map_specs(lambda p: p.axes, specs)


def abstract_params(specs):
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return tree_map_specs(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), specs)


# ---------------------------------------------------------------------------
# Layers (pure functions over param dicts)
# ---------------------------------------------------------------------------
def rms_norm_spec(d: int) -> Dict:
    return {"scale": P((d,), ("embed",), init="ones", dtype=jnp.float32)}


def rms_norm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


def embedding_spec(vocab: int, d: int) -> Dict:
    return {"table": P((vocab, d), ("vocab", "embed"), init="embed")}


def embed(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def unembed_spec(d: int, vocab: int) -> Dict:
    return {"kernel": P((d, vocab), ("embed", "vocab"), init="normal")}


def unembed(params, x: jax.Array) -> jax.Array:
    # logits in f32 for a stable softmax-xent
    return jnp.einsum("...d,dv->...v", x, params["kernel"]
                      ).astype(jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, Dh) or (..., H, Dh) w/ pos (..., S) or scalar/vec."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    # broadcast over heads axis (second-to-last of x)
    angles = angles[..., None, :]                        # (..., S, 1, dh/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_spec(d: int, d_ff: int, kind: str = "swiglu") -> Dict:
    if kind == "gelu":              # 2-matrix gpt-bigcode style
        return {
            "wi": P((d, d_ff), ("embed", "mlp")),
            "wo": P((d_ff, d), ("mlp", "embed")),
        }
    return {
        "wi_gate": P((d, d_ff), ("embed", "mlp")),
        "wi_up": P((d, d_ff), ("embed", "mlp")),
        "wo": P((d_ff, d), ("mlp", "embed")),
    }


def mlp(params, x: jax.Array) -> jax.Array:
    if "wi" in params:              # gelu (2-matrix)
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["wi"]))
        return jnp.einsum("...f,fd->...d", h, params["wo"])
    g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, params["wi_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, params["wo"])
