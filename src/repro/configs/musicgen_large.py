"""musicgen-large [audio]: decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 == MHA) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf:facebook/musicgen-large]

The EnCodec modality frontend is a STUB: input_specs() provides precomputed
frame embeddings (input_mode='embeddings'); the backbone + LM head over the
2048-entry codebook vocab is what we model.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    input_mode="embeddings",
    cam_attention=True,      # CAM-retrieval attention at decode
)
