"""deepseek-moe-16b [moe]: fine-grained MoE, 2 shared + 64 routed top-6.

28L d_model=2048 16H (kv=16) per-expert d_ff=1408 vocab=102400
[arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    moe_d_ff=1408,
    n_experts=64,
    n_shared_experts=2,
    moe_top_k=6,
    vocab_size=102400,
    cam_attention=True,
    cam_router=True,         # the paper's best-match CAM search as router
)
