"""zamba2-7b [hybrid]: Mamba2 backbone + weight-shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 ssm_state=64 vocab=32000
shared attention+MLP block applied every 6 mamba layers
[arXiv:2411.15242; hf:Zyphra/Zamba2-7B; simplified weight-sharing, see
DESIGN.md]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    hybrid_attn_every=6,
    cam_attention=True,      # used by the shared attention blocks
)
