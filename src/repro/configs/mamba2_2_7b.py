"""mamba2-2.7b [ssm]: SSD (state-space duality), attention-free.

64L d_model=2560 d_inner=5120 (expand 2) headdim=64 state=128 vocab=50280
[arXiv:2405.21060; hf:state-spaces/mamba2-2.7b]

The paper's CAM technique is inapplicable to the token-mixing path (no KV
store to search — DESIGN.md §Arch-applicability); implemented without it.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    tie_embeddings=True,
    cam_attention=False,
)
