"""granite-20b [dense]: llama-arch code model, MQA.

52L d_model=6144 48H (GQA kv=1 == MQA) d_ff=24576 vocab=49152
[arXiv:2405.04324; hf:ibm-granite/granite-20b-code-base]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    mlp_type="gelu",          # gpt-bigcode arch: 2-matrix GELU MLP
    vocab_size=49152,
    cam_attention=True,
)
